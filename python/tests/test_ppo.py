"""PPO / DPO update semantics (Eq. 1–2) and optimizer math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    d_model=64, n_heads=2, n_layers=2, d_ff=128, s_max=32, prompt_max=8,
    lanes=4, ppo_batch=4, chunk_sizes=(4,), lr=1e-3, ent_coef=0.0,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def synth_batch(params, key, adv_scale=1.0):
    """A self-consistent PPO batch: old_logp really is the model's logp."""
    kt, ka = jax.random.split(key)
    b, s = CFG.ppo_batch, CFG.s_max
    tokens = jax.random.randint(kt, (b, s), 3, CFG.vocab).astype(jnp.int32)
    logp, values = M.token_logprobs(CFG, params, tokens)
    mask = jnp.broadcast_to(
        (jnp.arange(s)[None, :] >= CFG.prompt_max).astype(jnp.float32), (b, s)
    )
    rewards = jnp.zeros((b, s)).at[:, -1].set(jax.random.normal(ka, (b,)))
    adv, ret = ref.gae(rewards * adv_scale, values * mask, mask,
                       gamma=CFG.gamma, lam=CFG.lam)
    return dict(tokens=tokens, mask=mask, old_logp=logp, adv=adv, ret=ret)


def zeros_like_params():
    shapes = M.param_shapes(CFG)
    return [jnp.zeros(shapes[n]) for n in M.param_names(CFG)]


def test_ppo_loss_at_old_policy_has_zero_pg_term(params):
    """ratio == 1 everywhere => pg loss == -mean(normalized adv) and
    clip_frac == 0 (Eq. 2 degenerates at theta == theta_old)."""
    batch = synth_batch(params, jax.random.PRNGKey(1))
    _, stats = M.ppo_loss(CFG, params, batch)
    clip_frac = float(stats[5])
    approx_kl = float(stats[4])
    assert clip_frac == 0.0
    assert abs(approx_kl) < 1e-5


def test_ppo_update_runs_and_changes_params(params):
    batch = synth_batch(params, jax.random.PRNGKey(2))
    fn = M.make_ppo_update(CFG)
    flat = M.flatten_params(CFG, params)
    zeros = zeros_like_params()
    out = fn(*flat, *zeros, *zeros,
             batch["tokens"], batch["mask"], batch["old_logp"],
             batch["adv"], batch["ret"], jnp.int32(1))
    np_ = len(flat)
    new_flat = out[:np_]
    stats = out[3 * np_]
    assert stats.shape == (6,)
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(new_flat, flat)]
    assert max(diffs) > 0.0
    # Adam's first step moves every coordinate by at most ~lr
    assert max(diffs) < 10 * CFG.lr


def test_ppo_update_reduces_value_loss_over_steps(params):
    """Repeated updates on one batch must drive the value loss down."""
    batch = synth_batch(params, jax.random.PRNGKey(3))
    fn = M.make_ppo_update(CFG)
    flat = M.flatten_params(CFG, params)
    m, v = zeros_like_params(), zeros_like_params()
    v_losses = []
    for step in range(1, 9):
        out = fn(*flat, *m, *v,
                 batch["tokens"], batch["mask"], batch["old_logp"],
                 batch["adv"], batch["ret"], jnp.int32(step))
        np_ = len(flat)
        flat = list(out[:np_])
        m = list(out[np_: 2 * np_])
        v = list(out[2 * np_: 3 * np_])
        v_losses.append(float(out[3 * np_][2]))
    assert v_losses[-1] < v_losses[0]


def test_adam_math_matches_numpy():
    """_adam_update against a hand-rolled numpy Adam on random tensors."""
    rng = np.random.RandomState(0)
    p = [jnp.asarray(rng.randn(3, 4), jnp.float32)]
    g = [jnp.asarray(rng.randn(3, 4), jnp.float32)]
    m = [jnp.zeros((3, 4))]
    v = [jnp.zeros((3, 4))]
    for step in (1, 2, 3):
        newp, newm, newv = M._adam_update(CFG, p, m, v, g, jnp.int32(step))
        mn = CFG.adam_b1 * np.asarray(m[0]) + (1 - CFG.adam_b1) * np.asarray(g[0])
        vn = CFG.adam_b2 * np.asarray(v[0]) + (1 - CFG.adam_b2) * np.asarray(g[0]) ** 2
        mh = mn / (1 - CFG.adam_b1**step)
        vh = vn / (1 - CFG.adam_b2**step)
        want = np.asarray(p[0]) - CFG.lr * mh / (np.sqrt(vh) + CFG.adam_eps)
        np.testing.assert_allclose(np.asarray(newp[0]), want, rtol=1e-5, atol=1e-6)
        p, m, v = newp, newm, newv


def test_gae_against_numpy_reference():
    """A third, fully-independent numpy implementation of Eq. 1."""
    rng = np.random.RandomState(1)
    b, t, gamma, lam = 3, 17, 0.97, 0.88
    r = rng.randn(b, t).astype(np.float32)
    v = rng.randn(b, t).astype(np.float32)
    lens = rng.randint(1, t + 1, size=b)
    mask = (np.arange(t)[None] < lens[:, None]).astype(np.float32)

    adv = np.zeros((b, t), np.float32)
    for i in range(b):
        last = 0.0
        for tt in reversed(range(t)):
            nm = mask[i, tt + 1] if tt + 1 < t else 0.0
            nv = v[i, tt + 1] if tt + 1 < t else 0.0
            delta = r[i, tt] + gamma * nv * nm - v[i, tt]
            last = delta + gamma * lam * nm * last
            adv[i, tt] = last * mask[i, tt]

    got, _ = ref.gae(jnp.asarray(r), jnp.asarray(v), jnp.asarray(mask), gamma, lam)
    np.testing.assert_allclose(np.asarray(got), adv, rtol=1e-5, atol=1e-5)


def test_dpo_update_improves_preference_margin(params):
    key = jax.random.PRNGKey(5)
    b, s = CFG.ppo_batch, CFG.s_max
    kc, kr = jax.random.split(key)
    chosen = jax.random.randint(kc, (b, s), 3, CFG.vocab).astype(jnp.int32)
    rejected = jax.random.randint(kr, (b, s), 3, CFG.vocab).astype(jnp.int32)
    mask = jnp.ones((b, s), jnp.float32).at[:, 0].set(0.0)
    logp_c, _ = M.token_logprobs(CFG, params, chosen)
    logp_r, _ = M.token_logprobs(CFG, params, rejected)
    ref_c = (logp_c * mask).sum(-1)
    ref_r = (logp_r * mask).sum(-1)

    fn = M.make_dpo_update(CFG)
    flat = M.flatten_params(CFG, params)
    m, v = [jnp.zeros_like(x) for x in flat], [jnp.zeros_like(x) for x in flat]
    margins = []
    for step in range(1, 7):
        out = fn(*flat, *m, *v, chosen, rejected, mask, mask, ref_c, ref_r,
                 jnp.int32(step))
        np_ = len(flat)
        flat = list(out[:np_]); m = list(out[np_:2*np_]); v = list(out[2*np_:3*np_])
        margins.append(float(out[3 * np_][2]))
    assert margins[-1] > margins[0]  # chosen gets relatively more likely


def test_param_flatten_roundtrip(params):
    flat = M.flatten_params(CFG, params)
    again = M.unflatten_params(CFG, flat)
    assert set(again) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(again[k]))


def test_param_names_stable_and_complete():
    names = M.param_names(CFG)
    assert len(names) == len(set(names))
    assert len(names) == CFG.n_layers * 12 + 6
    shapes = M.param_shapes(CFG)
    assert set(names) == set(shapes)
