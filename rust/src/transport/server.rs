//! The `remote-stage` serve loop: host one stage replica behind a TCP
//! listener.
//!
//! One connection at a time — a remote replica has exactly one coordinator
//! (its `StagePool` slot), so concurrent connections would mean two
//! coordinators mutating one KV/seam state.  When a connection ends the
//! loop accepts the next one, so a coordinator that reconnects at spawn
//! (bounded backoff) finds the replica again.
//!
//! Request handling is strictly serial per connection (one frame in, one
//! frame out), which is all the client ever does: the *pipelining* of
//! multiple in-flight chunks happens coordinator-side in the
//! `StageWorker`'s bounded queue, exactly as for in-process replicas.
//! Handler errors go back as `ErrMsg` frames and the connection stays up —
//! they surface coordinator-side as per-request stage errors, the same
//! contract as in-process handlers.  Only transport faults (EOF, bad
//! frame, timeout) end the connection.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{crc32, read_frame, write_frame};
use super::wire::{self, kind};
use crate::coordinator::worker::{RefReq, RefResp, RewardReq, RewardResp};

/// What a serve loop hosts: one stage's request processor plus a hook for
/// the one-shot parameter distribution at handshake.
pub enum Backend {
    Reward(Box<dyn FnMut(RewardReq) -> Result<RewardResp> + Send>),
    Ref(Box<dyn FnMut(RefReq) -> Result<RefResp> + Send>),
}

impl Backend {
    pub fn stage(&self) -> &'static str {
        match self {
            Backend::Reward(_) => "reward",
            Backend::Ref(_) => "ref",
        }
    }
}

/// Callback invoked with the distributed parameter blob (`which`, raw
/// bytes).  Returning an error refuses the handshake.  The ack always
/// carries the CRC-32 of the received bytes, which the client checks
/// against its local copy — digest equality is the "identical params"
/// proof.
pub type ParamsSink<'a> = dyn FnMut(&str, &[u8]) -> Result<()> + Send + 'a;

/// Serve one established connection to completion.  Returns `Ok` on a
/// clean client disconnect (EOF before a frame), `Err` on a transport
/// fault mid-stream.
pub fn serve_conn(
    stream: &mut TcpStream,
    backend: &mut Backend,
    on_params: &mut ParamsSink,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut hello_seen = false;
    loop {
        let (k, payload) = match read_frame(stream) {
            Ok(f) => f,
            Err(e) => {
                // EOF at a frame boundary is the client closing cleanly
                let msg = format!("{e:#}");
                if msg.contains("truncated frame (header)") {
                    return Ok(());
                }
                return Err(e.context("reading request frame"));
            }
        };
        match k {
            kind::HELLO => {
                let hello = wire::decode_hello(&payload)?;
                if hello.stage != backend.stage() {
                    let msg = format!(
                        "stage mismatch: this server hosts {:?}, client wants {:?}",
                        backend.stage(),
                        hello.stage
                    );
                    write_frame(stream, kind::ERR, &wire::encode_err(&msg))?;
                    bail!("{msg}");
                }
                hello_seen = true;
                write_frame(stream, kind::HELLO_ACK, &[])?;
            }
            kind::PARAMS => {
                let p = wire::decode_params(&payload)?;
                match on_params(&p.which, &p.data) {
                    Ok(()) => write_frame(
                        stream,
                        kind::PARAMS_ACK,
                        &wire::encode_params_ack(crc32(&p.data)),
                    )?,
                    Err(e) => {
                        let msg = format!("params rejected: {e:#}");
                        write_frame(stream, kind::ERR, &wire::encode_err(&msg))?;
                        bail!("{msg}");
                    }
                }
            }
            kind::PING => {
                write_frame(stream, kind::PONG, &payload)?;
            }
            kind::REWARD_REQ => {
                if !hello_seen {
                    bail!("request before handshake");
                }
                let Backend::Reward(handler) = backend else {
                    write_frame(stream, kind::ERR, &wire::encode_err("not a reward server"))?;
                    continue;
                };
                let req = wire::decode_reward_req(&payload)?;
                match handler(req) {
                    Ok(resp) => {
                        write_frame(stream, kind::REWARD_RESP, &wire::encode_reward_resp(&resp))?
                    }
                    Err(e) => write_frame(stream, kind::ERR, &wire::encode_err(&format!("{e:#}")))?,
                }
            }
            kind::REF_REQ => {
                if !hello_seen {
                    bail!("request before handshake");
                }
                let Backend::Ref(handler) = backend else {
                    write_frame(stream, kind::ERR, &wire::encode_err("not a ref server"))?;
                    continue;
                };
                let req = wire::decode_ref_req(&payload)?;
                match handler(req) {
                    Ok(resp) => {
                        write_frame(stream, kind::REF_RESP, &wire::encode_ref_resp(&resp))?
                    }
                    Err(e) => write_frame(stream, kind::ERR, &wire::encode_err(&format!("{e:#}")))?,
                }
            }
            other => bail!("unexpected frame kind {other} from client"),
        }
    }
}

/// Blocking accept-and-serve loop for the CLI `remote-stage` mode.
/// `max_conns` bounds how many connections are served before returning
/// (`None` = forever) — tests and the loopback smoke use `Some(1)`.
pub fn serve(
    listener: &TcpListener,
    backend: &mut Backend,
    on_params: &mut ParamsSink,
    max_conns: Option<usize>,
) -> Result<()> {
    let mut served = 0usize;
    loop {
        if let Some(max) = max_conns {
            if served >= max {
                return Ok(());
            }
        }
        let (mut stream, peer) = listener.accept().context("accepting connection")?;
        log::info!("remote-stage: serving {} for {peer}", backend.stage());
        if let Err(e) = serve_conn(&mut stream, backend, on_params) {
            log::warn!("remote-stage: connection from {peer} ended: {e:#}");
        }
        served += 1;
    }
}

/// A server running on its own thread — the test/bench harness form, with
/// a kill switch for fault injection.
pub struct ServerHandle {
    pub addr: String,
    conn: Arc<Mutex<Option<TcpStream>>>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Bind an ephemeral loopback port and serve `backend` on a thread.
    /// Accepts any number of sequential connections until stopped.
    pub fn spawn(mut backend: Backend) -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding loopback")?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let conn: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
        let stop = Arc::new(AtomicBool::new(false));
        let (conn2, stop2) = (conn.clone(), stop.clone());
        let thread = std::thread::Builder::new()
            .name(format!("remote-{}", backend.stage()))
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            *conn2.lock().unwrap() = stream.try_clone().ok();
                            let _ = serve_conn(
                                &mut stream,
                                &mut backend,
                                &mut |_which, _data| Ok(()),
                            );
                            *conn2.lock().unwrap() = None;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self { addr, conn, stop, thread: Some(thread) })
    }

    /// Fault injection: forcibly shut down the live connection (the client
    /// sees a mid-stream transport fault) and stop accepting new ones —
    /// the replica is dead, permanently.
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(conn) = self.conn.lock().unwrap().as_ref() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.kill();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
