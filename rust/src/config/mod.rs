//! Run configuration: typed schema over the TOML-subset parser, with
//! defaults, presets, CLI overrides, and validation against the AOT
//! manifest (shape contracts are static — a config that disagrees with the
//! artifacts must fail fast, not at dispatch time).

pub mod parse;

use anyhow::{bail, Context, Result};

use parse::{Doc, Val};

/// Which training pipeline drives the run (§4 baselines + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Full OPPO: intra-step streaming + inter-step overcommit (Algorithm 1).
    Oppo,
    /// TRL-style sequential PPO: generate-all → score-all → train.
    Sequential,
    /// Ablation "OPPO w/o Intra": overcommit only, monolithic scoring.
    OppoNoIntra,
    /// Ablation "OPPO w/o Inter": streaming only, Δ = 0.
    OppoNoInter,
    /// Ablation "OPPO w/o ref streaming": reward streams, but reference
    /// log-probs run as the monolithic post-generation call (the arm that
    /// isolates the third pipeline stage's contribution).
    OppoNoRef,
    /// Async staleness-k baseline (Fig. 2c): scoring uses k-step-old actor outputs.
    AsyncStale,
    /// DPO generalization (§4.3): generate B+Δ, update on first B pairs.
    Dpo,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "oppo" => Mode::Oppo,
            "sequential" | "trl" => Mode::Sequential,
            "oppo-no-intra" | "no-intra" => Mode::OppoNoIntra,
            "oppo-no-inter" | "no-inter" => Mode::OppoNoInter,
            "oppo-no-ref" | "no-ref" => Mode::OppoNoRef,
            "async" | "async-stale" => Mode::AsyncStale,
            "dpo" => Mode::Dpo,
            _ => bail!(
                "unknown mode {s:?} \
                 (want oppo|sequential|oppo-no-intra|oppo-no-inter|oppo-no-ref|async|dpo)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Oppo => "oppo",
            Mode::Sequential => "sequential",
            Mode::OppoNoIntra => "oppo-no-intra",
            Mode::OppoNoInter => "oppo-no-inter",
            Mode::OppoNoRef => "oppo-no-ref",
            Mode::AsyncStale => "async-stale",
            Mode::Dpo => "dpo",
        }
    }

    /// Does this mode stream chunks to the downstream stages mid-generation?
    pub fn intra_enabled(&self) -> bool {
        matches!(self, Mode::Oppo | Mode::OppoNoInter | Mode::OppoNoRef | Mode::Dpo)
    }

    /// Does this mode overcommit Δ extra prompts and defer stragglers?
    pub fn inter_enabled(&self) -> bool {
        matches!(self, Mode::Oppo | Mode::OppoNoIntra | Mode::OppoNoRef | Mode::Dpo)
    }

    /// Does this mode feed the *reference model* from streamed chunks (vs
    /// the monolithic post-generation `ref_logprobs` call)?  `OppoNoRef` is
    /// the ablation arm that keeps reward streaming but not ref streaming.
    pub fn ref_stream_enabled(&self) -> bool {
        matches!(self, Mode::Oppo | Mode::OppoNoInter)
    }
}

/// How prompts are admitted to generation lanes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionMode {
    /// Legacy step-synchronous loop: lanes refill only at step boundaries,
    /// pulling straight from the sampler.  The default.
    Step,
    /// Rolling admission under saturated arrivals: a freed lane is refilled
    /// at the next chunk boundary, and a prompt is always available (zero
    /// queue wait).  Training parity mode — at Δ=0 it is step-for-step
    /// score-equivalent to `Step`.
    Saturated,
    /// Rolling admission under Poisson traffic at `admission_rate` prompts
    /// per chunk tick, through a bounded queue (serving simulation; the
    /// queue sheds load past `admission_queue_depth`).
    Poisson,
}

impl AdmissionMode {
    pub fn parse(s: &str) -> Result<AdmissionMode> {
        Ok(match s {
            "step" | "sync" => AdmissionMode::Step,
            "saturated" | "rolling" => AdmissionMode::Saturated,
            "poisson" | "traffic" => AdmissionMode::Poisson,
            _ => bail!("unknown admission mode {s:?} (want step|saturated|poisson)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionMode::Step => "step",
            AdmissionMode::Saturated => "saturated",
            AdmissionMode::Poisson => "poisson",
        }
    }

    /// Does this mode refill lanes mid-step (continuous batching)?
    pub fn rolling(&self) -> bool {
        !matches!(self, AdmissionMode::Step)
    }
}

/// Configuration for the real-compute training loop (runtime + coordinator).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub mode: Mode,
    /// PPO steps to run.
    pub steps: usize,
    /// PPO batch size B (must equal the manifest's `ppo_batch`).
    pub batch: usize,
    /// Initial / min / max overcommitment Δ (Alg. 1; `batch + delta_max`
    /// must not exceed the manifest's `lanes`).
    pub delta_init: usize,
    pub delta_min: usize,
    pub delta_max: usize,
    /// Reward sliding-window W for the dynamic Δ controller.
    pub window: usize,
    /// Initial streaming chunk size C (must be one of the manifest's
    /// `chunk_sizes` — executables are pre-compiled per variant).
    pub chunk_size: usize,
    /// Enable the dynamic controllers (§3.1 / §3.2).
    pub adaptive_chunk: bool,
    pub adaptive_delta: bool,
    /// Chunk controller exploration period in steps (paper: "every 50").
    pub explore_every: usize,
    /// Control-loop arm: `"heuristic"` (the paper's §3.1 chunk exploration
    /// + §3.2 Δ trend controllers) or `"learned"` (a frozen Q-policy
    /// trained in the simulator by `oppo train-controller`).  Both run
    /// behind the same `Controller` trait; this flag is the A/B switch.
    pub controller: String,
    /// Path to the frozen policy artifact for `controller = "learned"`
    /// (ignored by the heuristic arm).
    pub controller_policy: Option<String>,
    /// Per-token KL penalty coefficient β (InstructGPT-style reward shaping).
    pub kl_beta: f64,
    /// Synthetic task: "arith" | "copy" | "sort" | "mixed".
    pub task: String,
    pub seed: u64,
    /// Hard cap on generated tokens per response.
    pub max_new_tokens: usize,
    /// PPO epochs per batch.
    pub ppo_epochs: usize,
    /// Staleness k for `Mode::AsyncStale`.
    pub staleness: usize,
    /// Blend weight of the learned reward model vs the rule reward in
    /// [0, 1]; rule-only tasks (GSM8K-style) use 0.0.
    pub reward_model_weight: f64,
    /// Per-stage enable knobs: stream chunks to the reward / reference
    /// stage workers when the mode's intra overlap is on.  Disabling a
    /// stage falls back to its monolithic path (ablations, debugging).
    pub stream_reward: bool,
    pub stream_ref: bool,
    /// Bounded request-queue depth per stage worker: how many streamed
    /// chunks may be in flight before submission backpressures the actor
    /// loop (>= 1).  With replicated stages the depth applies per replica.
    pub stage_queue_depth: usize,
    /// Worker replicas behind the streamed reward / reference stages
    /// (>= 1).  Chunks are routed `lane % replicas` (sequence affinity: a
    /// lane's KV/seam state lives on one replica for the whole run), so
    /// raising these keeps streaming actor-bound once a single scorer can
    /// no longer keep pace with actor decoding.
    pub reward_replicas: usize,
    pub ref_replicas: usize,
    /// How many stage replicas live on *remote* nodes, reached over the
    /// framed-TCP transport instead of an in-process worker thread.  Must
    /// equal the number of entries in `connect_addrs`; remotes take the
    /// highest replica indices of their stage's pool.  0 = all in-process.
    pub remote_replicas: usize,
    /// `remote-stage` serve mode: address to listen on (e.g.
    /// "127.0.0.1:7701").  Ignored by the training loop itself.
    pub listen_addr: String,
    /// Comma-separated `stage@host:port` endpoints hosting remote replicas,
    /// e.g. "reward@10.0.0.2:7701,ref@10.0.0.3:7702".  Empty = no remotes.
    pub connect_addrs: String,
    /// Remote liveness probe period in milliseconds (>= 1).  A replica that
    /// misses a ping/pong round trip within the per-send deadline is
    /// retired and its lanes replayed onto a survivor.
    pub heartbeat_ms: u64,
    /// Prompt admission: `step` (legacy step-synchronous refill),
    /// `saturated` (rolling admission, prompt always available), or
    /// `poisson` (rolling admission under simulated traffic).
    pub admission_mode: AdmissionMode,
    /// Bound of the arrival queue (prompts), `poisson` mode only; arrivals
    /// past the bound are shed and counted per step.
    pub admission_queue_depth: usize,
    /// Poisson arrival rate in prompts per chunk tick (one tick = one
    /// `actor_generate_chunk` call), `poisson` mode only.
    pub admission_rate: f64,
    pub artifacts_dir: String,
    pub log_every: usize,
    /// Where to drop JSON metrics (None = don't write).
    pub out_dir: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            mode: Mode::Oppo,
            steps: 50,
            batch: 8,
            delta_init: 2,
            delta_min: 0,
            delta_max: 4,
            window: 8,
            chunk_size: 16,
            adaptive_chunk: true,
            adaptive_delta: true,
            explore_every: 20,
            controller: "heuristic".into(),
            controller_policy: None,
            kl_beta: 0.02,
            task: "arith".into(),
            seed: 0,
            max_new_tokens: 96,
            ppo_epochs: 1,
            staleness: 0,
            reward_model_weight: 0.25,
            stream_reward: true,
            stream_ref: true,
            stage_queue_depth: 2,
            reward_replicas: 1,
            ref_replicas: 1,
            remote_replicas: 0,
            listen_addr: String::new(),
            connect_addrs: String::new(),
            heartbeat_ms: 500,
            admission_mode: AdmissionMode::Step,
            admission_queue_depth: 64,
            admission_rate: 1.0,
            artifacts_dir: "artifacts".into(),
            log_every: 10,
            out_dir: None,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed document's `[run]` section (missing keys keep
    /// defaults), then validate.
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let mut cfg = TrainConfig::default();
        let empty = Default::default();
        let sec = doc.get("run").unwrap_or(&empty);
        let get = |k: &str| -> Option<&Val> { sec.get(k).or_else(|| doc.get("")?.get(k)) };

        macro_rules! set {
            ($field:ident, $conv:ident) => {
                if let Some(v) = get(stringify!($field)) {
                    cfg.$field = v.$conv().context(stringify!($field))?;
                }
            };
        }
        if let Some(v) = get("mode") {
            cfg.mode = Mode::parse(v.as_str()?)?;
        }
        set!(steps, as_usize);
        set!(batch, as_usize);
        set!(delta_init, as_usize);
        set!(delta_min, as_usize);
        set!(delta_max, as_usize);
        set!(window, as_usize);
        set!(chunk_size, as_usize);
        set!(adaptive_chunk, as_bool);
        set!(adaptive_delta, as_bool);
        set!(explore_every, as_usize);
        if let Some(v) = get("controller") {
            cfg.controller = v.as_str()?.to_string();
        }
        if let Some(v) = get("controller_policy") {
            cfg.controller_policy = Some(v.as_str()?.to_string());
        }
        set!(kl_beta, as_f64);
        set!(seed, as_u64);
        set!(max_new_tokens, as_usize);
        set!(ppo_epochs, as_usize);
        set!(staleness, as_usize);
        set!(reward_model_weight, as_f64);
        set!(stream_reward, as_bool);
        set!(stream_ref, as_bool);
        set!(stage_queue_depth, as_usize);
        set!(reward_replicas, as_usize);
        set!(ref_replicas, as_usize);
        set!(remote_replicas, as_usize);
        set!(heartbeat_ms, as_u64);
        if let Some(v) = get("listen_addr") {
            cfg.listen_addr = v.as_str()?.to_string();
        }
        if let Some(v) = get("connect_addrs") {
            cfg.connect_addrs = v.as_str()?.to_string();
        }
        if let Some(v) = get("admission_mode") {
            cfg.admission_mode = AdmissionMode::parse(v.as_str()?)?;
        }
        set!(admission_queue_depth, as_usize);
        set!(admission_rate, as_f64);
        set!(log_every, as_usize);
        if let Some(v) = get("task") {
            cfg.task = v.as_str()?.to_string();
        }
        if let Some(v) = get("artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = get("out_dir") {
            cfg.out_dir = Some(v.as_str()?.to_string());
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str, overrides: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut doc = parse::parse(&text)?;
        parse::apply_overrides(&mut doc, overrides)?;
        Self::from_doc(&doc)
    }

    pub fn from_overrides(overrides: &[String]) -> Result<Self> {
        let mut doc: Doc = Default::default();
        parse::apply_overrides(&mut doc, overrides)?;
        Self::from_doc(&doc)
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.batch == 0 {
            bail!("batch must be > 0");
        }
        if self.delta_min > self.delta_max {
            bail!("delta_min {} > delta_max {}", self.delta_min, self.delta_max);
        }
        if !(self.delta_min..=self.delta_max).contains(&self.delta_init) {
            bail!(
                "delta_init {} outside [{}, {}]",
                self.delta_init, self.delta_min, self.delta_max
            );
        }
        if self.window == 0 {
            bail!("window must be > 0");
        }
        match self.controller.as_str() {
            "heuristic" => {}
            "learned" => {
                let has_policy =
                    matches!(self.controller_policy.as_deref(), Some(p) if !p.is_empty());
                if !has_policy {
                    bail!(
                        "controller = \"learned\" needs controller_policy = \"<artifact>\" \
                         (train one with `oppo train-controller`)"
                    );
                }
            }
            c => bail!("unknown controller {c:?} (want heuristic|learned)"),
        }
        if !(0.0..=1.0).contains(&self.reward_model_weight) {
            bail!("reward_model_weight must be in [0,1]");
        }
        if self.mode == Mode::AsyncStale && self.staleness == 0 {
            bail!("async-stale mode needs staleness >= 1");
        }
        if self.stage_queue_depth == 0 {
            bail!("stage_queue_depth must be >= 1 (bounded stage queues need room)");
        }
        if self.reward_replicas == 0 || self.ref_replicas == 0 {
            bail!(
                "stage replica counts must be >= 1 (reward_replicas {}, ref_replicas {})",
                self.reward_replicas, self.ref_replicas
            );
        }
        if self.heartbeat_ms == 0 {
            bail!("heartbeat_ms must be >= 1");
        }
        // remote placement: connect_addrs is the source of truth for where
        // remote replicas live; remote_replicas is the declared head-count.
        // They must agree, and each stage's remote share must fit its pool.
        let (reward_addrs, ref_addrs) =
            crate::transport::split_connect_addrs(&self.connect_addrs)?;
        let n_remote = reward_addrs.len() + ref_addrs.len();
        if n_remote != self.remote_replicas {
            bail!(
                "connect_addrs lists {n_remote} endpoint(s) but remote_replicas = {} \
                 (they must agree)",
                self.remote_replicas
            );
        }
        if reward_addrs.len() > self.reward_replicas {
            bail!(
                "{} remote reward endpoints > reward_replicas {}",
                reward_addrs.len(), self.reward_replicas
            );
        }
        if ref_addrs.len() > self.ref_replicas {
            bail!(
                "{} remote ref endpoints > ref_replicas {}",
                ref_addrs.len(), self.ref_replicas
            );
        }
        if !reward_addrs.is_empty() && !(self.mode.intra_enabled() && self.stream_reward) {
            bail!(
                "remote reward replicas need a streaming reward stage \
                 (mode {:?} / stream_reward {})",
                self.mode.name(), self.stream_reward
            );
        }
        if !ref_addrs.is_empty() && !(self.mode.ref_stream_enabled() && self.stream_ref) {
            bail!(
                "remote ref replicas need a streaming ref stage \
                 (mode {:?} / stream_ref {})",
                self.mode.name(), self.stream_ref
            );
        }
        if self.admission_queue_depth == 0 {
            bail!("admission_queue_depth must be >= 1");
        }
        if self.admission_mode == AdmissionMode::Poisson
            && !(self.admission_rate > 0.0 && self.admission_rate.is_finite())
        {
            bail!(
                "poisson admission needs a finite admission_rate > 0 (got {})",
                self.admission_rate
            );
        }
        match self.task.as_str() {
            "arith" | "copy" | "sort" | "mixed" => {}
            t => bail!("unknown task {t:?} (want arith|copy|sort|mixed)"),
        }
        Ok(())
    }

    /// Cross-check against the AOT manifest's static shapes.
    pub fn validate_against_manifest(
        &self,
        ppo_batch: usize,
        lanes: usize,
        chunk_sizes: &[usize],
        s_max: usize,
        prompt_max: usize,
    ) -> Result<()> {
        if self.batch != ppo_batch {
            bail!("config batch {} != manifest ppo_batch {ppo_batch}", self.batch);
        }
        if self.batch + self.delta_max > lanes {
            bail!(
                "batch {} + delta_max {} exceeds manifest lanes {lanes}",
                self.batch, self.delta_max
            );
        }
        if !chunk_sizes.contains(&self.chunk_size) {
            bail!(
                "chunk_size {} has no compiled executable (manifest has {chunk_sizes:?})",
                self.chunk_size
            );
        }
        // lane % replicas routing: a replica beyond the lane count could
        // never own a lane, yet would still allocate full params + KV state
        if self.reward_replicas > lanes || self.ref_replicas > lanes {
            bail!(
                "stage replica counts exceed manifest lanes {lanes} \
                 (reward_replicas {}, ref_replicas {}): surplus replicas can never own a lane",
                self.reward_replicas, self.ref_replicas
            );
        }
        if prompt_max + self.max_new_tokens > s_max {
            bail!(
                "prompt_max {prompt_max} + max_new_tokens {} exceeds s_max {s_max}",
                self.max_new_tokens
            );
        }
        // Streamed prefill scatters a full [G, C] window at each lane's
        // cursor, so the last chunk of a maximal sequence must still fit:
        // otherwise the stage kernels would clamp the scatter against s_max
        // and overwrite earlier KV rows (or trip the runtime guard mid-step).
        let max_chunk = chunk_sizes.iter().copied().max().unwrap_or(0);
        if self.mode.intra_enabled() && prompt_max + self.max_new_tokens + max_chunk > s_max {
            bail!(
                "prompt_max {prompt_max} + max_new_tokens {} + largest chunk {max_chunk} \
                 exceeds s_max {s_max}: the final streamed chunk window would clamp",
                self.max_new_tokens
            );
        }
        // Under Poisson traffic a queue bound below B makes the partial-
        // batch path the steady state: the queue can never hold a full
        // batch's worth of waiting prompts even when arrivals allow it.
        if self.admission_mode == AdmissionMode::Poisson
            && self.admission_queue_depth < ppo_batch
        {
            bail!(
                "admission_queue_depth {} < manifest ppo_batch {ppo_batch}: \
                 a bound below B starves every batch under poisson arrivals",
                self.admission_queue_depth
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_with_overrides() {
        let mut doc = parse::parse("[run]\nmode = \"trl\"\nsteps = 7").unwrap();
        parse::apply_overrides(&mut doc, &["run.batch=8".into(), "run.seed=99".into()]).unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.mode, Mode::Sequential);
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.batch, 8);
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn rejects_bad_delta_bounds() {
        let cfg = TrainConfig { delta_init: 9, delta_max: 4, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = TrainConfig { delta_min: 5, delta_max: 4, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_unknown_mode_and_task() {
        assert!(Mode::parse("warp-speed").is_err());
        let cfg = TrainConfig { task: "cooking".into(), ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn manifest_cross_check() {
        let cfg = TrainConfig::default();
        cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).unwrap();
        assert!(cfg.validate_against_manifest(16, 12, &[8, 16, 32], 160, 24).is_err());
        assert!(cfg.validate_against_manifest(8, 10, &[8, 16, 32], 160, 24).is_err());
        assert!(cfg.validate_against_manifest(8, 12, &[64], 160, 24).is_err());
        assert!(cfg.validate_against_manifest(8, 12, &[8, 16, 32], 100, 24).is_err());
        // more replicas than lanes: surplus replicas could never own a lane
        let cfg = TrainConfig { reward_replicas: 13, ..Default::default() };
        assert!(cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).is_err());
        let cfg = TrainConfig { ref_replicas: 13, ..Default::default() };
        assert!(cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).is_err());
        let cfg = TrainConfig { reward_replicas: 12, ref_replicas: 12, ..Default::default() };
        cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).unwrap();
    }

    #[test]
    fn streamed_tail_chunk_must_fit_s_max() {
        // prompt 10 + max_new 50 = 60 <= 64, but the last streamed chunk
        // window (start 58, C=8) would clamp against s_max — reject it for
        // streaming modes, allow it for the non-streaming baseline.
        let cfg = TrainConfig { max_new_tokens: 50, chunk_size: 8, ..Default::default() };
        assert!(cfg.validate_against_manifest(8, 12, &[8], 64, 10).is_err());
        let seq = TrainConfig {
            mode: Mode::Sequential,
            max_new_tokens: 50,
            chunk_size: 8,
            ..Default::default()
        };
        seq.validate_against_manifest(8, 12, &[8], 64, 10).unwrap();
    }

    #[test]
    fn mode_capability_flags() {
        assert!(Mode::Oppo.intra_enabled() && Mode::Oppo.inter_enabled());
        assert!(!Mode::Sequential.intra_enabled() && !Mode::Sequential.inter_enabled());
        assert!(Mode::OppoNoIntra.inter_enabled() && !Mode::OppoNoIntra.intra_enabled());
        assert!(Mode::OppoNoInter.intra_enabled() && !Mode::OppoNoInter.inter_enabled());
        // the no-ref arm keeps both overlaps but not the ref stream
        assert!(Mode::OppoNoRef.intra_enabled() && Mode::OppoNoRef.inter_enabled());
        assert!(!Mode::OppoNoRef.ref_stream_enabled());
        assert!(Mode::Oppo.ref_stream_enabled() && Mode::OppoNoInter.ref_stream_enabled());
        assert!(!Mode::Sequential.ref_stream_enabled());
        assert_eq!(Mode::parse("no-ref").unwrap(), Mode::OppoNoRef);
        assert_eq!(Mode::OppoNoRef.name(), "oppo-no-ref");
    }

    #[test]
    fn stage_knobs_validate() {
        let cfg = TrainConfig { stage_queue_depth: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = TrainConfig { stream_reward: false, stream_ref: false, ..Default::default() };
        cfg.validate().unwrap();
        let cfg = TrainConfig { reward_replicas: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = TrainConfig { ref_replicas: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg =
            TrainConfig { reward_replicas: 3, ref_replicas: 2, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn replica_knobs_parse_from_doc() {
        let doc =
            parse::parse("[run]\nreward_replicas = 2\nref_replicas = 3").unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.reward_replicas, 2);
        assert_eq!(cfg.ref_replicas, 3);
    }

    #[test]
    fn remote_knobs_parse_and_validate() {
        let doc = parse::parse(
            "[run]\nremote_replicas = 2\nreward_replicas = 2\nref_replicas = 2\n\
             connect_addrs = \"reward@10.0.0.2:7701,ref@10.0.0.3:7702\"\n\
             heartbeat_ms = 250",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.remote_replicas, 2);
        assert_eq!(cfg.heartbeat_ms, 250);
        assert_eq!(cfg.connect_addrs, "reward@10.0.0.2:7701,ref@10.0.0.3:7702");

        // head-count disagreement
        let cfg = TrainConfig {
            connect_addrs: "reward@h:1".into(),
            remote_replicas: 2,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        // per-stage share exceeds the pool
        let cfg = TrainConfig {
            connect_addrs: "reward@h:1,reward@h:2".into(),
            remote_replicas: 2,
            reward_replicas: 1,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        // remote reward replicas need a streaming reward stage
        let cfg = TrainConfig {
            connect_addrs: "reward@h:1".into(),
            remote_replicas: 1,
            stream_reward: false,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        // remote ref replicas need a ref-streaming mode
        let cfg = TrainConfig {
            connect_addrs: "ref@h:1".into(),
            remote_replicas: 1,
            mode: Mode::OppoNoRef,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = TrainConfig { heartbeat_ms: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        // a well-formed remote split validates
        let cfg = TrainConfig {
            connect_addrs: "reward@h:1,ref@h:2".into(),
            remote_replicas: 2,
            reward_replicas: 2,
            ref_replicas: 2,
            ..Default::default()
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn controller_knobs_parse_and_validate() {
        let doc = parse::parse(
            "[run]\ncontroller = \"learned\"\ncontroller_policy = \"artifacts/q.json\"",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.controller, "learned");
        assert_eq!(cfg.controller_policy.as_deref(), Some("artifacts/q.json"));

        // the learned arm without an artifact path must fail fast
        let cfg = TrainConfig { controller: "learned".into(), ..Default::default() };
        assert!(cfg.validate().unwrap_err().to_string().contains("controller_policy"));
        let cfg = TrainConfig { controller: "oracle".into(), ..Default::default() };
        assert!(cfg.validate().is_err());
        // heuristic (the default) ignores controller_policy entirely
        let cfg = TrainConfig::default();
        assert_eq!(cfg.controller, "heuristic");
        cfg.validate().unwrap();
    }

    #[test]
    fn async_mode_needs_staleness() {
        let cfg = TrainConfig { mode: Mode::AsyncStale, staleness: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn admission_knobs_parse_and_validate() {
        assert_eq!(AdmissionMode::parse("rolling").unwrap(), AdmissionMode::Saturated);
        assert_eq!(AdmissionMode::parse("step").unwrap(), AdmissionMode::Step);
        assert_eq!(AdmissionMode::parse("traffic").unwrap(), AdmissionMode::Poisson);
        assert!(AdmissionMode::parse("teleport").is_err());
        assert!(!AdmissionMode::Step.rolling());
        assert!(AdmissionMode::Saturated.rolling() && AdmissionMode::Poisson.rolling());

        let doc = parse::parse(
            "[run]\nadmission_mode = \"poisson\"\nadmission_queue_depth = 32\n\
             admission_rate = 0.5",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.admission_mode, AdmissionMode::Poisson);
        assert_eq!(cfg.admission_queue_depth, 32);
        assert!((cfg.admission_rate - 0.5).abs() < 1e-12);

        let cfg = TrainConfig { admission_queue_depth: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = TrainConfig {
            admission_mode: AdmissionMode::Poisson,
            admission_rate: 0.0,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        // rate is irrelevant outside poisson mode
        let cfg = TrainConfig { admission_rate: 0.0, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn poisson_queue_depth_checked_against_manifest_batch() {
        let cfg = TrainConfig {
            admission_mode: AdmissionMode::Poisson,
            admission_queue_depth: 4,
            ..Default::default()
        };
        assert!(cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).is_err());
        let cfg = TrainConfig {
            admission_mode: AdmissionMode::Poisson,
            admission_queue_depth: 8,
            ..Default::default()
        };
        cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).unwrap();
        // step mode is indifferent to a small queue bound
        let cfg = TrainConfig { admission_queue_depth: 4, ..Default::default() };
        cfg.validate_against_manifest(8, 12, &[8, 16, 32], 160, 24).unwrap();
    }
}

#[cfg(test)]
mod config_file_tests {
    use super::*;

    fn repo_config(name: &str) -> String {
        format!("{}/configs/{name}", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn shipped_configs_all_parse_and_validate() {
        for name in [
            "oppo_default.toml",
            "trl_baseline.toml",
            "gsm8k_rule.toml",
            "async_stale.toml",
            "rolling_traffic.toml",
        ] {
            let cfg = TrainConfig::load(&repo_config(name), &[]).unwrap_or_else(|e| {
                panic!("configs/{name}: {e:#}");
            });
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn cli_overrides_beat_file_values() {
        let cfg = TrainConfig::load(
            &repo_config("oppo_default.toml"),
            &["run.steps=7".into(), "run.mode=\"no-intra\"".into()],
        )
        .unwrap();
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.mode, Mode::OppoNoIntra);
        assert_eq!(cfg.task, "mixed"); // untouched value survives
    }

    #[test]
    fn gsm8k_config_is_rule_based() {
        let cfg = TrainConfig::load(&repo_config("gsm8k_rule.toml"), &[]).unwrap();
        assert_eq!(cfg.reward_model_weight, 0.0);
        assert_eq!(cfg.task, "arith");
    }

    #[test]
    fn rolling_traffic_config_is_poisson() {
        let cfg = TrainConfig::load(&repo_config("rolling_traffic.toml"), &[]).unwrap();
        assert_eq!(cfg.admission_mode, AdmissionMode::Poisson);
        assert!(cfg.admission_mode.rolling());
        assert!(cfg.admission_rate > 0.0);
        assert!(cfg.admission_queue_depth >= cfg.batch);
        // the default run stays on the legacy step-synchronous loop
        let cfg = TrainConfig::load(&repo_config("oppo_default.toml"), &[]).unwrap();
        assert_eq!(cfg.admission_mode, AdmissionMode::Step);
    }
}
