//! Build-shim for the patched PJRT `xla` crate.
//!
//! The real runtime backend is the locally patched xla/xla_extension crate
//! (with `execute_b_untupled`) described in `rust/src/runtime/mod.rs`; it is
//! not redistributable through the offline crate set, so this shim provides
//! the exact API surface the `oppo` crate compiles against.  Every
//! constructor returns [`XlaError`] at runtime, and the engine-dependent
//! tests gate themselves on `artifacts/manifest.json` being present, so the
//! full suite builds and runs green without a PJRT backend.  To run real
//! compute, point the `xla` path dependency in `rust/Cargo.toml` at the
//! patched crate instead of this shim.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: the `xla` dependency is the build-shim \
     (point rust/Cargo.toml's `xla` path at the patched crate to execute artifacts)";

/// Error type mirroring the real crate's.
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    pub fn new(msg: impl Into<String>) -> Self {
        XlaError(msg.into())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// A PJRT client (stub: cannot be constructed).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(XlaError::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(UNAVAILABLE))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// A device-resident buffer (stub: cannot be constructed).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// A compiled executable (stub: cannot be constructed).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Untupled execution: one `Vec<PjRtBuffer>` per replica, one buffer per
    /// root-tuple element (the patched-crate extension the engine relies on).
    pub fn execute_b_untupled(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// A host-side literal (stub: cannot be constructed).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }
}
