//! Message payload codec for the stage wire — the typed layer above
//! [`frame`](super::frame).
//!
//! One frame kind per message type; payloads are hand-rolled little-endian
//! encodings (the offline crate set ships no serde).  The messages are the
//! *actual* coordinator request/response types ([`RewardReq`],
//! [`RewardResp`], [`RefReq`], [`RefResp`]) — a remote replica speaks the
//! same vocabulary as an in-process one, so [`StagePool`] routing cannot
//! tell them apart.  Control messages cover the connection lifecycle:
//!
//! * `Hello`/`HelloAck` — stage-name handshake (a reward client refusing a
//!   ref server is a config error caught at connect, not mid-step);
//! * `Params`/`ParamsAck` — one-shot parameter distribution at spawn: the
//!   coordinator ships the raw `params_<stage>.bin` bytes, the server loads
//!   them and acks with their CRC-32, and the client verifies the digest
//!   against its local copy — proof both ends score with identical weights;
//! * `Ping`/`Pong` — heartbeat (client-initiated, only on an idle
//!   connection);
//! * `ErrMsg` — a *per-request* handler error.  The connection stays up and
//!   the error propagates through the stage channel exactly like an
//!   in-process handler error; only transport faults kill the replica.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::worker::{Pick, RefReq, RefResp, RewardReq, RewardResp};

/// Frame kind bytes (`frame::write_frame`'s `kind`).
pub mod kind {
    pub const HELLO: u8 = 1;
    pub const HELLO_ACK: u8 = 2;
    pub const PARAMS: u8 = 3;
    pub const PARAMS_ACK: u8 = 4;
    pub const PING: u8 = 5;
    pub const PONG: u8 = 6;
    pub const REWARD_REQ: u8 = 7;
    pub const REWARD_RESP: u8 = 8;
    pub const REF_REQ: u8 = 9;
    pub const REF_RESP: u8 = 10;
    pub const ERR: u8 = 11;
}

// ---------------------------------------------------------------------------
// byte-level helpers
// ---------------------------------------------------------------------------

pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    pub fn i32_vec(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn f32_vec(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn usize_vec(&mut self, v: &[usize]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&(x as u32).to_le_bytes());
        }
    }
}

pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "short payload: need {n} more bytes");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Length prefix for a sequence of `elem_bytes`-wide elements, bounded
    /// by the remaining payload so a corrupt count cannot trigger a huge
    /// allocation.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.buf.len() - self.pos,
            "length prefix {n} overruns payload"
        );
        Ok(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        String::from_utf8(self.take(n)?.to_vec()).context("non-utf8 string field")
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.len_prefix(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let n = self.len_prefix(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn usize_vec(&mut self) -> Result<Vec<usize>> {
        let n = self.len_prefix(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
            .collect())
    }

    pub fn finish(self) -> Result<()> {
        ensure!(self.pos == self.buf.len(), "{} trailing payload bytes", self.buf.len() - self.pos);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// control messages
// ---------------------------------------------------------------------------

/// Connection handshake: which stage the client expects to talk to and
/// which replica slot it fills (diagnostics only).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub stage: String,
    pub replica: u32,
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(&h.stage);
    w.u32(h.replica);
    w.into_bytes()
}

pub fn decode_hello(payload: &[u8]) -> Result<Hello> {
    let mut r = Reader::new(payload);
    let h = Hello { stage: r.str()?, replica: r.u32()? };
    r.finish()?;
    Ok(h)
}

/// One-shot parameter distribution: `which` names the param set
/// (reward|ref), `data` is the raw little-endian f32 blob in manifest
/// order (the exact `params_<which>.bin` contents).
pub struct Params {
    pub which: String,
    pub data: Vec<u8>,
}

pub fn encode_params(p: &Params) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(&p.which);
    w.bytes(&p.data);
    w.into_bytes()
}

pub fn decode_params(payload: &[u8]) -> Result<Params> {
    let mut r = Reader::new(payload);
    let p = Params { which: r.str()?, data: r.bytes()? };
    r.finish()?;
    Ok(p)
}

pub fn encode_params_ack(crc: u32) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(crc);
    w.into_bytes()
}

pub fn decode_params_ack(payload: &[u8]) -> Result<u32> {
    let mut r = Reader::new(payload);
    let crc = r.u32()?;
    r.finish()?;
    Ok(crc)
}

pub fn encode_nonce(nonce: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(nonce);
    w.into_bytes()
}

pub fn decode_nonce(payload: &[u8]) -> Result<u64> {
    let mut r = Reader::new(payload);
    let n = r.u64()?;
    r.finish()?;
    Ok(n)
}

pub fn encode_err(msg: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(msg);
    w.into_bytes()
}

pub fn decode_err(payload: &[u8]) -> Result<String> {
    let mut r = Reader::new(payload);
    let m = r.str()?;
    r.finish()?;
    Ok(m)
}

// ---------------------------------------------------------------------------
// stage requests / responses
// ---------------------------------------------------------------------------

fn put_picks(w: &mut Writer, picks: &[Pick]) {
    w.u32(picks.len() as u32);
    for p in picks {
        w.u32(p.lane as u32);
        w.u32(p.idx_in_chunk as u32);
    }
}

fn get_picks(r: &mut Reader) -> Result<Vec<Pick>> {
    let n = r.len_prefix(8)?;
    let mut picks = Vec::with_capacity(n);
    for _ in 0..n {
        picks.push(Pick { lane: r.u32()? as usize, idx_in_chunk: r.u32()? as usize });
    }
    Ok(picks)
}

pub fn encode_reward_req(req: &RewardReq) -> Vec<u8> {
    let mut w = Writer::new();
    match req {
        RewardReq::Stream { entry, chunk, start, n_valid, picks, lane_map } => {
            w.u8(0);
            w.str(entry);
            w.i32_vec(chunk);
            w.i32_vec(start);
            w.i32_vec(n_valid);
            put_picks(&mut w, picks);
            w.usize_vec(lane_map);
        }
        RewardReq::StreamPaged { entry, chunk, start, n_valid, picks, lane_map, table } => {
            w.u8(1);
            w.str(entry);
            w.i32_vec(chunk);
            w.i32_vec(start);
            w.i32_vec(n_valid);
            put_picks(&mut w, picks);
            w.usize_vec(lane_map);
            w.i32_vec(table);
        }
        RewardReq::ScoreFull { tokens, last_idx } => {
            w.u8(2);
            w.i32_vec(tokens);
            w.i32_vec(last_idx);
        }
        RewardReq::Reset => w.u8(3),
    }
    w.into_bytes()
}

pub fn decode_reward_req(payload: &[u8]) -> Result<RewardReq> {
    let mut r = Reader::new(payload);
    let req = match r.u8()? {
        0 => RewardReq::Stream {
            entry: r.str()?,
            chunk: r.i32_vec()?,
            start: r.i32_vec()?,
            n_valid: r.i32_vec()?,
            picks: get_picks(&mut r)?,
            lane_map: r.usize_vec()?,
        },
        1 => RewardReq::StreamPaged {
            entry: r.str()?,
            chunk: r.i32_vec()?,
            start: r.i32_vec()?,
            n_valid: r.i32_vec()?,
            picks: get_picks(&mut r)?,
            lane_map: r.usize_vec()?,
            table: r.i32_vec()?,
        },
        2 => RewardReq::ScoreFull { tokens: r.i32_vec()?, last_idx: r.i32_vec()? },
        3 => RewardReq::Reset,
        v => bail!("unknown RewardReq variant {v}"),
    };
    r.finish()?;
    Ok(req)
}

pub fn encode_reward_resp(resp: &RewardResp) -> Vec<u8> {
    let mut w = Writer::new();
    match resp {
        RewardResp::StreamScores(scores) => {
            w.u8(0);
            w.u32(scores.len() as u32);
            for &(lane, score) in scores {
                w.u32(lane as u32);
                w.f32_vec(&[score]);
            }
        }
        RewardResp::FullScores(scores) => {
            w.u8(1);
            w.f32_vec(scores);
        }
        RewardResp::ResetDone => w.u8(2),
    }
    w.into_bytes()
}

pub fn decode_reward_resp(payload: &[u8]) -> Result<RewardResp> {
    let mut r = Reader::new(payload);
    let resp = match r.u8()? {
        0 => {
            let n = r.len_prefix(8)?;
            let mut scores = Vec::with_capacity(n);
            for _ in 0..n {
                let lane = r.u32()? as usize;
                let v = r.f32_vec()?;
                ensure!(v.len() == 1, "malformed StreamScores entry");
                scores.push((lane, v[0]));
            }
            RewardResp::StreamScores(scores)
        }
        1 => RewardResp::FullScores(r.f32_vec()?),
        2 => RewardResp::ResetDone,
        v => bail!("unknown RewardResp variant {v}"),
    };
    r.finish()?;
    Ok(resp)
}

pub fn encode_ref_req(req: &RefReq) -> Vec<u8> {
    let mut w = Writer::new();
    match req {
        RefReq::Stream { entry, chunk, start, n_valid } => {
            w.u8(0);
            w.str(entry);
            w.i32_vec(chunk);
            w.i32_vec(start);
            w.i32_vec(n_valid);
        }
        RefReq::StreamPaged { entry, chunk, start, n_valid, table } => {
            w.u8(1);
            w.str(entry);
            w.i32_vec(chunk);
            w.i32_vec(start);
            w.i32_vec(n_valid);
            w.i32_vec(table);
        }
        RefReq::Reset => w.u8(2),
    }
    w.into_bytes()
}

pub fn decode_ref_req(payload: &[u8]) -> Result<RefReq> {
    let mut r = Reader::new(payload);
    let req = match r.u8()? {
        0 => RefReq::Stream {
            entry: r.str()?,
            chunk: r.i32_vec()?,
            start: r.i32_vec()?,
            n_valid: r.i32_vec()?,
        },
        1 => RefReq::StreamPaged {
            entry: r.str()?,
            chunk: r.i32_vec()?,
            start: r.i32_vec()?,
            n_valid: r.i32_vec()?,
            table: r.i32_vec()?,
        },
        2 => RefReq::Reset,
        v => bail!("unknown RefReq variant {v}"),
    };
    r.finish()?;
    Ok(req)
}

pub fn encode_ref_resp(resp: &RefResp) -> Vec<u8> {
    let mut w = Writer::new();
    match resp {
        RefResp::StreamLogps(lp) => {
            w.u8(0);
            w.f32_vec(lp);
        }
        RefResp::ResetDone => w.u8(1),
    }
    w.into_bytes()
}

pub fn decode_ref_resp(payload: &[u8]) -> Result<RefResp> {
    let mut r = Reader::new(payload);
    let resp = match r.u8()? {
        0 => RefResp::StreamLogps(r.f32_vec()?),
        1 => RefResp::ResetDone,
        v => bail!("unknown RefResp variant {v}"),
    };
    r.finish()?;
    Ok(resp)
}
