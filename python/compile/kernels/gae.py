"""Pallas GAE kernel — Eq. (1) of the paper as a reverse scan.

Advantage estimation is a strictly sequential reverse recurrence along the
time axis, but embarrassingly parallel across the batch.  The kernel maps
one program per sequence (grid over B); the whole row (T ≤ a few hundred)
fits in VMEM, and the recurrence runs as an on-chip ``fori_loop`` — no HBM
traffic beyond one read and one write per element.  ``ref.gae`` is the
oracle; the AOT pipeline exports this kernel as the ``gae`` executable used
by the Rust coordinator after composing the per-token reward vector
(score-at-end + per-token KL penalty).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gae_kernel(r_ref, v_ref, m_ref, adv_ref, ret_ref, *, gamma: float, lam: float):
    t = r_ref.shape[1]
    # whole-block reads + squeeze: int ref indices fail interpret-mode
    # discharge on this jax version.
    r = r_ref[...][0]
    v = v_ref[...][0]
    m = m_ref[...][0]

    def body(i, carry):
        # walk t-1 .. 0; carry = A_{t+1}
        idx = t - 1 - i
        nm = jnp.where(idx + 1 < t, m[jnp.minimum(idx + 1, t - 1)], 0.0)
        nv = jnp.where(idx + 1 < t, v[jnp.minimum(idx + 1, t - 1)], 0.0)
        delta = r[idx] + gamma * nv * nm - v[idx]
        adv = delta + gamma * lam * nm * carry
        pl.store(adv_ref, (slice(None), pl.dslice(idx, 1)), (adv * m[idx]).reshape(1, 1))
        pl.store(ret_ref, (slice(None), pl.dslice(idx, 1)), ((adv + v[idx]) * m[idx]).reshape(1, 1))
        return adv

    jax.lax.fori_loop(0, t, body, jnp.float32(0.0))


@functools.partial(jax.jit, static_argnames=("gamma", "lam"))
def gae(
    rewards: jax.Array,  # [B, T] f32
    values: jax.Array,  # [B, T] f32
    mask: jax.Array,  # [B, T] f32 (0/1)
    gamma: float = 1.0,
    lam: float = 0.95,
) -> tuple[jax.Array, jax.Array]:
    """Pallas GAE; semantics match ``ref.gae``."""
    b, t = rewards.shape
    out_shape = (
        jax.ShapeDtypeStruct((b, t), jnp.float32),
        jax.ShapeDtypeStruct((b, t), jnp.float32),
    )
    spec = pl.BlockSpec((1, t), lambda i: (i, 0))
    adv, ret = pl.pallas_call(
        functools.partial(_gae_kernel, gamma=gamma, lam=lam),
        grid=(b,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=True,
    )(rewards.astype(jnp.float32), values.astype(jnp.float32), mask.astype(jnp.float32))
    return adv, ret
