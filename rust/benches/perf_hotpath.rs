//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf records the
//! before/after of the optimization pass).
//!
//! L3 coordinator structures (buffer, controllers, GAE, simulator) and, when
//! artifacts are present, the PJRT dispatch path (per-chunk decode latency,
//! per-token cost, dispatch overhead vs execute time).
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use oppo::coordinator::buffer::SeqBuffer;
use oppo::coordinator::engine_ops::{Ops, RewardOps};
use oppo::coordinator::stage::{StageHandler, StagePool, StageWorker};
use oppo::coordinator::worker::{RefReq, RefWorker, StreamChunk};
use oppo::data::tasks::{Prompt, TaskKind};
use oppo::eval::{print_table, save_rows, Row};
use oppo::ppo::gae::gae;
use oppo::runtime::Engine;
use oppo::coordinator::BlockPool;
use oppo::sim::pipeline::{kv_lane_bounds, simulate, Pipeline, SimConfig};
use oppo::sim::presets;

fn time_it(mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() {
    let mut rows = Vec::new();

    // L3: buffer churn (admit + finish + take) — must be negligible
    let n = 200_000;
    let secs = time_it(|| {
        let mut buf = SeqBuffer::new(12, 12);
        for i in 0..n {
            let p = Prompt {
                kind: TaskKind::Arith, text: "1+1=".into(),
                tokens: vec![1, 5, 40, 5, 44], answer: "2".into(), id: i,
            };
            let lane = buf.add(p, i).unwrap();
            {
                let s = buf.by_lane_mut(lane).unwrap();
                s.phase = oppo::model::sequence::SeqPhase::Generating;
                s.push_token(2, 0.0, 0.0, 2, 8, 100);
            }
            buf.mark_finished(lane);
            let taken = buf.take_finished(1, i);
            assert_eq!(taken.len(), 1);
        }
    });
    rows.push(Row::new("buffer admit+take").cell("ops_per_sec", n as f64 / secs));

    // L3: Rust GAE mirror over a [8, 160] batch
    let (b, s) = (8, 160);
    let r = vec![0.1f32; b * s];
    let v = vec![0.05f32; b * s];
    let m = vec![1.0f32; b * s];
    let iters = 20_000;
    let secs = time_it(|| {
        for _ in 0..iters {
            let _ = gae(&r, &v, &m, b, s, 1.0, 0.95);
        }
    });
    rows.push(Row::new("rust gae [8x160]").cell("ops_per_sec", iters as f64 / secs));

    // simulator throughput: steps/sec of the heaviest pipeline
    let steps = 400;
    let secs = time_it(|| {
        let cfg = SimConfig::new(presets::stackex_7b_h200(), steps, 3);
        let _ = simulate(Pipeline::oppo(), &cfg);
    });
    rows.push(Row::new("sim oppo steps").cell("ops_per_sec", steps as f64 / secs));

    // L3: paged-KV allocator churn — one lane's whole life (reserve the
    // full budget, map every block, free it all) per op; must stay
    // negligible next to a PJRT chunk dispatch
    {
        let (lanes, bs, bpl) = (12usize, 16usize, 10usize);
        let n = 100_000u64;
        let secs = time_it(|| {
            let mut pool = BlockPool::new(lanes, bs, bpl, lanes * bpl + 1);
            for i in 0..n {
                let lane = (i as usize) % lanes;
                pool.admit(lane, 8, bs * bpl).unwrap();
                pool.grow_to(lane, bs * bpl);
                pool.release(lane);
            }
        });
        rows.push(
            Row::new("block pool admit+grow+release").cell("ops_per_sec", n as f64 / secs),
        );
    }

    // Paged vs dense KV on the traffic sim: same seed, same rolling
    // schedule — the paged arm's peak commitment and the lane bound the
    // freed memory buys are the whole point of the block allocator
    {
        let su = presets::traffic_7b_h200();
        let rate = su.arrival_rate;
        let block_tokens = 64.0;
        let dense_cfg = SimConfig::new(su, 40, 9).rolling_poisson(rate);
        let paged_cfg = dense_cfg.clone().paged(block_tokens);
        let peak = |cfg: &SimConfig| {
            simulate(Pipeline::oppo(), cfg)
                .records
                .iter()
                .map(|r| r.peak_kv_bytes)
                .max()
                .unwrap_or(0) as f64
        };
        let (d, p) = (peak(&dense_cfg), peak(&paged_cfg));
        let (dense_lanes, paged_lanes) = kv_lane_bounds(&dense_cfg, block_tokens);
        rows.push(
            Row::new("paged kv (traffic sim)")
                .cell("dense_peak_gb", d / 1e9)
                .cell("paged_peak_gb", p / 1e9)
                .cell("reduction", 1.0 - p / d.max(1.0))
                .cell("lane_bound_x", paged_lanes / dense_lanes.max(1.0)),
        );
    }

    // StageWorker dispatch overhead: submit/recv round trips with a no-op
    // handler — the per-chunk tax of the stage runtime itself
    {
        struct Nop;
        impl StageHandler for Nop {
            type Req = u64;
            type Resp = u64;
            fn handle(&mut self, x: u64) -> Result<u64> {
                Ok(x)
            }
        }
        let mut w = StageWorker::spawn("nop", 2, || Ok(Nop)).expect("spawn");
        let n = 20_000u64;
        let secs = time_it(|| {
            for i in 0..n {
                w.submit(i).expect("submit");
                w.recv().expect("recv");
            }
        });
        rows.push(Row::new("stage dispatch (1-deep)").cell("ops_per_sec", n as f64 / secs));
    }

    // Stage-overlap microbench: synchronous downstream scoring vs streamed
    // prefill through two StageWorkers overlapping a simulated actor decode
    // (the §3.1 shape with sleep-based costs: decode 3ms/chunk, each of the
    // two downstream stages 2ms/chunk)
    {
        struct SleepStage(Duration);
        impl StageHandler for SleepStage {
            type Req = ();
            type Resp = ();
            fn handle(&mut self, _: ()) -> Result<()> {
                std::thread::sleep(self.0);
                Ok(())
            }
        }
        let n_chunks = 25;
        let decode = Duration::from_millis(3);
        let stage = Duration::from_millis(2);

        let sync_secs = time_it(|| {
            for _ in 0..n_chunks {
                std::thread::sleep(decode); // actor chunk
                std::thread::sleep(stage); // reward prefill, synchronous
                std::thread::sleep(stage); // ref prefill, synchronous
            }
        });

        let mut reward = StageWorker::spawn("bench-reward", 2, move || Ok(SleepStage(stage)))
            .expect("spawn");
        let mut refm = StageWorker::spawn("bench-ref", 2, move || Ok(SleepStage(stage)))
            .expect("spawn");
        let overlap_secs = time_it(|| {
            for _ in 0..n_chunks {
                reward.submit(()).expect("submit");
                refm.submit(()).expect("submit");
                std::thread::sleep(decode); // actor decodes while stages prefill
                while reward.try_recv().expect("recv").is_some() {}
                while refm.try_recv().expect("recv").is_some() {}
            }
            while reward.in_flight() > 0 {
                reward.recv().expect("recv");
            }
            while refm.in_flight() > 0 {
                refm.recv().expect("recv");
            }
        });
        rows.push(
            Row::new("stage overlap (2 stages)")
                .cell("sync_ms", 1e3 * sync_secs)
                .cell("overlap_ms", 1e3 * overlap_secs)
                .cell("speedup", sync_secs / overlap_secs),
        );
    }

    // Replica-pool scaling: streamed-chunk throughput through 1 vs 2 reward
    // replicas, with per-chunk stage cost proportional to the lanes a
    // replica owns (the lane % replicas split).  This models replicas on
    // independent execution resources — separate devices/streams, or the
    // lane-sliced [G/N, C] entries — where splitting a stage slower than
    // the actor across 2 replicas roughly halves the per-replica prefill
    // and pulls the pipeline back toward actor-bound.
    {
        struct LaneCost {
            per_lane: Duration,
        }
        impl StageHandler for LaneCost {
            type Req = usize; // lanes this replica owns in the sub-chunk
            type Resp = ();
            fn handle(&mut self, lanes: usize) -> Result<()> {
                std::thread::sleep(self.per_lane * lanes as u32);
                Ok(())
            }
        }
        let lanes = 8usize;
        let per_lane = Duration::from_micros(400); // full chunk: 3.2 ms of scoring
        let decode = Duration::from_millis(1); // actor: 1 ms per chunk
        let n_chunks = 30;
        let mut row = Row::new("stage pool replicas (8 lanes)");
        let mut thru = Vec::new();
        for replicas in [1usize, 2] {
            let mut pool: StagePool<usize, ()> =
                StagePool::spawn("bench-pool", replicas, 2, |_r| {
                    move || Ok(LaneCost { per_lane })
                })
                .expect("spawn");
            let secs = time_it(|| {
                for _ in 0..n_chunks {
                    for r in 0..replicas {
                        // lane % replicas ownership => lanes split evenly
                        let owned = lanes / replicas + usize::from(r < lanes % replicas);
                        pool.submit_to(r, owned).expect("submit");
                    }
                    std::thread::sleep(decode); // actor decodes while the pool prefills
                    while pool.try_recv_any().expect("recv").is_some() {}
                }
                for r in 0..replicas {
                    while pool.in_flight_on(r) > 0 {
                        pool.recv_from(r).expect("recv");
                    }
                }
            });
            thru.push(n_chunks as f64 / secs);
            row = row.cell(
                if replicas == 1 { "chunks_per_sec_x1" } else { "chunks_per_sec_x2" },
                n_chunks as f64 / secs,
            );
        }
        rows.push(row.cell("speedup_x2", thru[1] / thru[0]));
    }

    // Sliced vs masked replica pools on ONE shared device.  A device mutex
    // serializes every grid: masked replicas each execute the full [G, C]
    // grid (pool compute multiplies by N), sliced replicas execute the
    // compacted [G/N, C] grids that the real `StreamChunk::for_replica`
    // produces (pool compute stays at G rows whatever N is).  The
    // crossover this demonstrates: on a single device, masked pools lose
    // throughput linearly with N while sliced pools hold it — per-replica
    // grid rows (reported below) scale as G/N.
    {
        struct GridCost {
            device: Arc<Mutex<()>>,
            per_row: Duration,
        }
        impl StageHandler for GridCost {
            type Req = usize; // grid rows this replica's entry executes
            type Resp = ();
            fn handle(&mut self, rows: usize) -> Result<()> {
                let _dev = self.device.lock().unwrap(); // one shared device
                std::thread::sleep(self.per_row * rows as u32);
                Ok(())
            }
        }
        let lanes = 8usize;
        let c = 16usize;
        let per_row = Duration::from_micros(400); // full [8, C] grid: 3.2 ms
        let decode = Duration::from_millis(1); // actor: 1 ms per chunk
        let n_chunks = 24;
        let ck = StreamChunk {
            c,
            tokens: vec![0i32; lanes * c],
            start: vec![0; lanes],
            n_valid: vec![c as i32; lanes],
            picks: vec![],
        };
        for &sliced in &[false, true] {
            let mode = if sliced { "sliced" } else { "masked" };
            let mut row = Row::new(format!("{mode} grids (8 lanes, 1 device)"));
            for replicas in [1usize, 2, 4] {
                let device = Arc::new(Mutex::new(()));
                let mut pool: StagePool<usize, ()> =
                    StagePool::spawn("bench-slice", replicas, 2, |_r| {
                        let device = device.clone();
                        move || Ok(GridCost { device, per_row })
                    })
                    .expect("spawn");
                let mut grid_rows = 0usize;
                let secs = time_it(|| {
                    for _ in 0..n_chunks {
                        for r in 0..replicas {
                            if let Some(part) = ck.for_replica(r, replicas, sliced) {
                                grid_rows = part.chunk.lanes();
                                pool.submit_to(r, grid_rows).expect("submit");
                            }
                        }
                        std::thread::sleep(decode); // actor decodes meanwhile
                        while pool.try_recv_any().expect("recv").is_some() {}
                    }
                    for r in 0..replicas {
                        while pool.in_flight_on(r) > 0 {
                            pool.recv_from(r).expect("recv");
                        }
                    }
                });
                row = row
                    .cell(&format!("chunks_per_sec_x{replicas}"), n_chunks as f64 / secs)
                    .cell(&format!("grid_rows_x{replicas}"), grid_rows as f64);
            }
            rows.push(row);
        }
    }

    // PJRT dispatch path (needs artifacts)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Arc::new(Engine::load("artifacts").unwrap());
        let shape = engine.manifest().shape.clone();
        let (g, smax) = (shape.lanes, shape.s_max);
        let mut ops = Ops::new(engine.clone(), 0).unwrap();
        let tokens = {
            let mut t = vec![0i32; g * smax];
            for lane in 0..g {
                t[lane * smax] = 1;
                t[lane * smax + 1] = 5;
            }
            t
        };
        let mut state = ops.fresh_actor_state(&tokens).unwrap();
        ops.actor_prefill(&mut state, &tokens, &vec![2; g], &vec![1; g]).unwrap();
        for &c in &shape.chunk_sizes {
            // warm up compile
            let pos = vec![2i32; g];
            let live = vec![1i32; g];
            let _ = ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
            let reps = 8;
            let secs = time_it(|| {
                for _ in 0..reps {
                    let _ = ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
                }
            });
            let per_call = secs / reps as f64;
            rows.push(
                Row::new(format!("generate_chunk c={c}"))
                    .cell("ms_per_call", 1e3 * per_call)
                    .cell("us_per_token", 1e6 * per_call / (c * g) as f64),
            );
        }

        // paged decode vs dense on real compute: same chunk grid, KV
        // gathered/scattered through the block table instead of per-lane
        // rows — the per-call tax paying for the pooled memory
        if engine.manifest().paged_supported() {
            let bpl = shape.paged_blocks_per_lane();
            let mut pool = BlockPool::new(g, shape.kv_block_size, bpl, g * bpl + 1);
            for lane in 0..g {
                pool.admit(lane, 2, smax).unwrap();
                pool.grow_to(lane, smax); // map every block: worst-case table
            }
            let table = pool.flat_table(g);
            let mut pstate = ops.fresh_actor_state_paged(&tokens).unwrap();
            ops.actor_prefill_paged(&mut pstate, &tokens, &vec![2; g], &vec![1; g], &table)
                .unwrap();
            let c = shape.chunk_sizes[0];
            let pos = vec![2i32; g];
            let live = vec![1i32; g];
            let _ = ops.generate_chunk_paged(&mut pstate, c, &pos, &live, &table).unwrap();
            let _ = ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
            let reps = 8;
            let dense_secs = time_it(|| {
                for _ in 0..reps {
                    let _ = ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
                }
            }) / reps as f64;
            let paged_secs = time_it(|| {
                for _ in 0..reps {
                    let _ = ops.generate_chunk_paged(&mut pstate, c, &pos, &live, &table).unwrap();
                }
            }) / reps as f64;
            rows.push(
                Row::new(format!("paged generate_chunk c={c}"))
                    .cell("dense_ms", 1e3 * dense_secs)
                    .cell("paged_ms", 1e3 * paged_secs)
                    .cell("overhead_x", paged_secs / dense_secs.max(1e-12)),
            );
        } else {
            println!("(artifacts lack paged entries — paged decode bench skipped)");
        }

        // dispatch overhead: the gae entry is tiny, so its latency ≈ overhead
        let grid = vec![0.0; shape.ppo_batch * smax];
        let ones = vec![1.0; shape.ppo_batch * smax];
        let rb = engine.upload_f32(&grid, &[shape.ppo_batch, smax]).unwrap();
        let vb = engine.upload_f32(&grid, &[shape.ppo_batch, smax]).unwrap();
        let mb = engine.upload_f32(&ones, &[shape.ppo_batch, smax]).unwrap();
        let _ = engine.execute("gae", &[&rb, &vb, &mb]).unwrap();
        let reps = 100;
        let secs = time_it(|| {
            for _ in 0..reps {
                let _ = engine.execute("gae", &[&rb, &vb, &mb]).unwrap();
            }
        });
        rows.push(Row::new("pjrt dispatch (gae)").cell("ms_per_call", 1e3 * secs / reps as f64));

        // sliced entry latency on real compute: a [G/N, C] grid should
        // cost ~G/N of the full [G, C] call — the FLOP division that lets
        // replica pools pay off on one shared device
        {
            let rops = RewardOps::new(engine.clone()).unwrap();
            let c = shape.chunk_sizes[0];
            let bench = |entry: String, grid_rows: usize| -> f64 {
                let chunk = vec![1i32; grid_rows * c];
                let starts = vec![0i32; grid_rows];
                let nv = vec![c as i32; grid_rows];
                let mut state = rops.fresh_state_rows(grid_rows).unwrap();
                rops.prefill_chunk(&mut state, &entry, &chunk, &starts, &nv).unwrap();
                let reps = 8;
                let secs = time_it(|| {
                    for _ in 0..reps {
                        rops.prefill_chunk(&mut state, &entry, &chunk, &starts, &nv).unwrap();
                    }
                });
                secs / reps as f64
            };
            let full_ms = 1e3 * bench(format!("reward_prefill_chunk_c{c}"), g);
            let mut row =
                Row::new(format!("reward prefill sliced c={c}")).cell("full_ms", full_ms);
            let mut any = false;
            for n in [2usize, 4] {
                if g % n != 0 || !engine.manifest().sliced_prefill_supported("reward", g / n) {
                    continue;
                }
                let r = g / n;
                let ms = 1e3 * bench(format!("reward_prefill_chunk_g{r}_c{c}"), r);
                row = row
                    .cell(&format!("g{r}_ms"), ms)
                    .cell(&format!("g{r}_frac_of_full"), ms / full_ms.max(1e-9));
                any = true;
            }
            if any {
                rows.push(row);
            } else {
                println!("(artifacts lack sliced reward entries — sliced bench skipped)");
            }
        }

        // streamed vs synchronous reference scoring — the third-stage
        // overlap win, measured over real compute.  Dense `ref_logprobs`
        // blocks after generation; streamed `ref_prefill_chunk` hides
        // behind actor decode chunks, so only the non-overlapped remainder
        // (`exposed`) lands on the step's critical path.
        if engine.manifest().ref_prefill_supported() {
            let c = shape.chunk_sizes[shape.chunk_sizes.len() / 2];
            let dense_tokens = vec![1i32; shape.ppo_batch * smax];
            let _ = ops.ref_logprobs(&dense_tokens).unwrap(); // warm compile
            let reps = 5;
            let dense_secs = time_it(|| {
                for _ in 0..reps {
                    ops.ref_logprobs(&dense_tokens).unwrap();
                }
            }) / reps as f64;

            let mut refw = RefWorker::spawn(engine.clone(), 2).unwrap();
            let entry = format!("ref_prefill_chunk_c{c}");
            let mk_req = |start: usize| RefReq::Stream {
                entry: entry.clone(),
                chunk: vec![1i32; g * c],
                start: vec![start as i32; g],
                n_valid: vec![c as i32; g],
            };
            refw.submit(mk_req(0)).unwrap(); // warm compile (worker thread)
            refw.recv().unwrap();

            let n_chunks = (64.min(smax - c)) / c;
            let pos = vec![2i32; g];
            let live = vec![1i32; g];
            let actor_secs = time_it(|| {
                for _ in 0..n_chunks {
                    ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
                }
            });
            let overlap_secs = time_it(|| {
                for k in 0..n_chunks {
                    refw.submit(mk_req(k * c)).unwrap();
                    ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
                    refw.recv().unwrap();
                }
            });
            let exposed = (overlap_secs - actor_secs).max(0.0);
            rows.push(
                Row::new(format!("ref prefill c={c}"))
                    .cell("sync_dense_ms", 1e3 * dense_secs)
                    .cell("streamed_exposed_ms", 1e3 * exposed)
                    .cell("hidden_frac", (1.0 - exposed / dense_secs.max(1e-9)).max(0.0)),
            );
        } else {
            println!("(artifacts lack ref_prefill_chunk entries — ref overlap bench skipped)");
        }
    } else {
        println!("(artifacts missing — PJRT microbenches skipped)");
    }

    print_table("§Perf — hot-path microbenchmarks", &rows);
    save_rows("perf_hotpath", &rows).expect("save");
}
