//! Table 4 — per-step latency under identical settings: VeRL DP > DP+SP >
//! AReaL > OPPO (paper: 125.4 / 120.5 / 109.9 / 99.8 s).
use oppo::eval::{print_table, save_rows, tables};

fn main() {
    let rows = tables::table4();
    print_table("Table 4 — framework comparison (mean step latency)", &rows);
    save_rows("table4", &rows).expect("save");
    let get = |name: &str| rows.iter().find(|r| r.label == name).unwrap().cells[0].1;
    assert!(get("VeRL w/ DP") > get("VeRL w/ DP+SP"));
    assert!(get("VeRL w/ DP+SP") > get("AReaL"));
    assert!(get("AReaL") > get("OPPO"));
    println!("shape check passed: OPPO achieves the lowest per-step latency");
}
