//! Integration: the training scheduler completes steps in every mode over
//! real compute, with controllers live and metrics recorded.
use std::sync::Arc;

use once_cell::sync::Lazy;
use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::OppoScheduler;
use oppo::runtime::Engine;

static ENGINE: Lazy<Option<Arc<Engine>>> = Lazy::new(|| {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
});

fn cfg(mode: Mode) -> TrainConfig {
    TrainConfig {
        mode,
        steps: 3,
        task: "mixed".into(),
        seed: 5,
        log_every: 0,
        max_new_tokens: 48,
        staleness: if mode == Mode::AsyncStale { 2 } else { 0 },
        ..Default::default()
    }
}

fn run_mode(mode: Mode) -> oppo::metrics::RunLog {
    let engine = ENGINE.clone().expect("artifacts");
    let sched = OppoScheduler::with_engine(cfg(mode), engine).expect("scheduler");
    sched.run().expect("run")
}

#[test]
fn oppo_mode_runs_and_records() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    let mut sched = OppoScheduler::with_engine(cfg(Mode::Oppo), engine).unwrap();
    let mut logs = Vec::new();
    for s in 0..3 {
        let rec = sched.run_step(s).unwrap();
        assert_eq!(rec.finished, engine_batch());
        assert!(rec.mean_score.is_finite());
        assert!(rec.gen_tokens > 0);
        assert!(rec.train_stats.iter().all(|x| x.is_finite()));
        logs.push(rec);
    }
    // inter-step overlap engaged: capacity B+Δ with Δ >= delta_min
    assert!(sched.delta() <= 4);
}

fn engine_batch() -> usize {
    ENGINE.clone().unwrap().manifest().shape.ppo_batch
}

#[test]
fn sequential_and_ablations_run() {
    if ENGINE.is_none() { return }
    for mode in [Mode::Sequential, Mode::OppoNoIntra, Mode::OppoNoInter, Mode::OppoNoRef] {
        let log = run_mode(mode);
        assert_eq!(log.records.len(), 3, "{mode:?}");
        assert!(log.records.iter().all(|r| r.finished == engine_batch()));
    }
}

#[test]
fn oppo_reports_per_stage_timings() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    let mut sched = OppoScheduler::with_engine(cfg(Mode::Oppo), engine.clone()).unwrap();
    // reward always streams in Oppo mode; ref streams when artifacts ship
    // the chunked ref entries
    assert!(sched.stage_names().contains(&"reward"));
    if engine.manifest().ref_prefill_supported() {
        assert!(sched.ref_streamed(), "ref stage should stream with capable artifacts");
        assert!(sched.stage_names().contains(&"ref"));
    }
    let rec = sched.run_step(0).unwrap();
    assert!(!rec.stages.is_empty(), "Oppo steps must attribute stage time");
    for st in &rec.stages {
        assert!(st.items > 0, "stage {} processed no requests", st.name);
        assert!(st.busy_s > 0.0, "stage {} recorded no busy time", st.name);
        assert!(st.busy_s <= rec.wall_s * 2.0, "stage {} busy time implausible", st.name);
    }
}

#[test]
fn sequential_mode_has_no_streaming_stages() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    let sched = OppoScheduler::with_engine(cfg(Mode::Sequential), engine).unwrap();
    assert!(sched.stage_names().is_empty());
    assert!(!sched.ref_streamed());
}

#[test]
fn sequential_has_no_deferrals_oppo_may() {
    if ENGINE.is_none() { return }
    let seq = run_mode(Mode::Sequential);
    let (rows, mean) = seq.deferral_distribution();
    assert!(rows.len() == 1 && rows[0].0 == 0, "sequential deferred: {rows:?}");
    assert_eq!(mean, 0.0);
}

#[test]
fn async_stale_defers_updates() {
    if ENGINE.is_none() { return }
    let log = run_mode(Mode::AsyncStale);
    // first `staleness` steps have no applied update (zero stats)
    assert!(log.records[0].train_stats.iter().all(|&x| x == 0.0));
    assert!(log.records[1].train_stats.iter().all(|&x| x == 0.0));
    assert!(log.records[2].train_stats[0] != 0.0);
}

#[test]
fn replicated_stage_pools_match_single_worker_run() {
    if ENGINE.is_none() { return }
    // acceptance: a pool with replicas = 1 is the old single-worker path,
    // and replicated pools (lane % replicas routing) must stream the same
    // per-sequence reward/ref data — generation is untouched, scores agree
    // to float re-association tolerance.
    let run = |reward_replicas: usize, ref_replicas: usize| {
        let mut c = cfg(Mode::Oppo);
        c.reward_replicas = reward_replicas;
        c.ref_replicas = ref_replicas;
        let sched = OppoScheduler::with_engine(c, ENGINE.clone().unwrap()).unwrap();
        sched.run().unwrap()
    };
    let single = run(1, 1);
    let pooled = run(2, 2);
    assert_eq!(single.records.len(), pooled.records.len());
    for (a, b) in single.records.iter().zip(&pooled.records) {
        assert_eq!(a.gen_tokens, b.gen_tokens, "generation must not depend on replicas");
        assert!(
            (a.mean_score - b.mean_score).abs() < 2e-3,
            "step {}: single {} vs pooled {}",
            a.step, a.mean_score, b.mean_score
        );
        for (x, y) in a.train_stats.iter().zip(&b.train_stats) {
            assert!((x - y).abs() < 2e-2, "train stats diverged: {x} vs {y}");
        }
    }
    // the pooled run reports its pool sizes in the stage rows
    let rec = pooled.records.last().unwrap();
    let reward_row = rec.stages.iter().find(|s| s.name == "reward").unwrap();
    assert_eq!(reward_row.replicas, 2);
}

#[test]
fn streamed_steps_report_nonzero_bounded_utilization() {
    if ENGINE.is_none() { return }
    let log = run_mode(Mode::Oppo);
    for r in &log.records {
        assert!(
            r.util > 0.0 && r.util <= 1.0,
            "step {}: streamed-mode util {} outside (0, 1]",
            r.step, r.util
        );
    }
}

#[test]
fn async_stale_drains_queued_updates_at_end_of_run() {
    if ENGINE.is_none() { return }
    let log = run_mode(Mode::AsyncStale);
    // 3 steps at staleness 2: the run ends with 2 assembled batches still
    // queued; the drain applies them and records one step row each
    assert_eq!(log.records.len(), 3 + 2, "drain must append the queued updates");
    for rec in &log.records[3..] {
        assert_eq!(rec.finished, 0, "drained rows generate nothing");
        assert_eq!(rec.gen_tokens, 0);
        assert!(rec.train_stats[0] != 0.0, "drained update must actually apply");
    }
}

#[test]
fn same_seed_same_mode_is_deterministic() {
    if ENGINE.is_none() { return }
    let a = run_mode(Mode::Oppo);
    let b = run_mode(Mode::Oppo);
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.mean_score, y.mean_score);
        assert_eq!(x.gen_tokens, y.gen_tokens);
    }
}
