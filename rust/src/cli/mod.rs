//! Command-line interface (hand-rolled; clap is not in the offline crate
//! set).  Subcommands:
//!
//! ```text
//! oppo train    [--config FILE] [--set k=v ...]    real-compute RLHF run
//! oppo dpo      [--config FILE] [--set k=v ...]    DPO generalization run
//! oppo simulate [--pipeline P] [--setup S] [--steps N] [--seed K]
//! oppo train-controller [--episodes N] [--seed K] [--out FILE]
//! oppo figures  [--only NAME]                      regenerate paper artifacts
//! oppo info     [--artifacts DIR]                  inspect the AOT manifest
//! ```

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::dpo::DpoTrainer;
use crate::coordinator::OppoScheduler;
use crate::eval::{figures, print_table, save_rows, tables};
use crate::sim::pipeline::{simulate, steady_state_latency, Pipeline, SimConfig};
use crate::sim::presets;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: Vec<(String, String)>,
    pub sets: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: a subcommand followed by `--flag value` pairs;
    /// `--set k=v` may repeat.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(flag) = it.next() {
            let name = flag
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {flag:?}"))?;
            let value = it
                .next()
                .with_context(|| format!("--{name} needs a value"))?
                .clone();
            if name == "set" {
                args.sets.push(value);
            } else {
                args.flags.push((name.to_string(), value));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?} is not an integer")),
            None => Ok(default),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?} is not an integer")),
            None => Ok(default),
        }
    }
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    crate::util::logging::init();
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "dpo" => cmd_dpo(&args),
        "simulate" => cmd_simulate(&args),
        "train-controller" => cmd_train_controller(&args),
        "figures" => cmd_figures(&args),
        "info" => cmd_info(&args),
        "remote-stage" => cmd_remote_stage(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
OPPO: Accelerating PPO-based RLHF via Pipeline Overlap (reproduction)

USAGE:
  oppo train    [--config FILE] [--set section.key=value ...]
  oppo dpo      [--config FILE] [--set section.key=value ...]
  oppo simulate [--pipeline trl|oppo|oppo-no-intra|oppo-no-inter|areal|verl-dp|verl-dp-sp]
                [--setup stackex-7b|stackex-3b|gsm8k-7b|opencoder-3b|multinode|table4]
                [--steps N] [--seed K] [--controller heuristic|learned] [--policy FILE]
  oppo train-controller [--episodes N] [--seed K] [--out FILE]
  oppo figures  [--only fig2a|fig2b|fig2c|fig3|fig4|fig5|fig6|fig7a|fig7b|table1|table2|table3|table4]
  oppo info     [--artifacts DIR]
  oppo remote-stage --stage reward|ref --listen HOST:PORT
                [--backend engine|toy] [--artifacts DIR] [--max-conns N]

train-controller runs pinned-seed Q-learning inside the simulator (episodes
alternate the stackex-7b and traffic presets), freezes the policy to a
versioned artifact, and prices the learned arm against the heuristic
controllers on both presets.  Deploy it with `controller = \"learned\"` +
`controller_policy = FILE` in the run config, or
`oppo simulate --controller learned --policy FILE`.

remote-stage hosts one stage replica behind a framed-TCP listener; point a
training run at it via run.connect_addrs = \"reward@HOST:PORT,...\" (with
run.remote_replicas matching the endpoint count).  --backend toy serves the
deterministic engine-free scorer used by transport tests and the CI
loopback smoke; --max-conns 0 serves forever.
";

fn load_cfg(args: &Args) -> Result<TrainConfig> {
    match args.flag("config") {
        Some(path) => TrainConfig::load(path, &args.sets),
        None => TrainConfig::from_overrides(&args.sets),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    log::info!("training: mode={} task={} steps={}", cfg.mode.name(), cfg.task, cfg.steps);
    let log = OppoScheduler::new(cfg)?.run()?;
    println!(
        "done: {} steps, final score {:.3}, wall {:.1}s",
        log.records.len(),
        log.records.last().map(|r| r.mean_score).unwrap_or(0.0),
        log.total_wall_s()
    );
    Ok(())
}

fn cmd_dpo(args: &Args) -> Result<()> {
    let mut cfg = load_cfg(args)?;
    cfg.mode = crate::config::Mode::Dpo;
    let log = DpoTrainer::new(cfg)?.run()?;
    println!(
        "done: {} DPO steps, final margin {:.3}",
        log.records.len(),
        log.records.last().map(|r| r.mean_score).unwrap_or(0.0)
    );
    Ok(())
}

fn pipeline_by_name(name: &str) -> Result<Pipeline> {
    Ok(match name {
        "trl" | "sequential" => Pipeline::TrlSequential,
        "oppo" => Pipeline::oppo(),
        "oppo-no-intra" => Pipeline::Oppo { intra: false, inter: true, fixed_delta: None },
        "oppo-no-inter" => Pipeline::Oppo { intra: true, inter: false, fixed_delta: None },
        "areal" => Pipeline::AReal,
        "verl-dp" => Pipeline::VerlDp,
        "verl-dp-sp" => Pipeline::VerlDpSp,
        "verl-async-sp" => Pipeline::VerlAsyncSp,
        other => bail!("unknown pipeline {other:?}"),
    })
}

fn setup_by_name(name: &str) -> Result<presets::Setup> {
    Ok(match name {
        "stackex-7b" => presets::stackex_7b_h200(),
        "stackex-3b" => presets::stackex_3b_a100(),
        "gsm8k-7b" => presets::gsm8k_7b_gh200(),
        "opencoder-3b" => presets::opencoder_3b_a100(),
        "multinode" => presets::multinode_7b_a100_40(),
        "table4" => presets::table4_setup(),
        other => bail!("unknown setup {other:?}"),
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let pipeline = pipeline_by_name(args.flag("pipeline").unwrap_or("oppo"))?;
    let setup = setup_by_name(args.flag("setup").unwrap_or("stackex-7b"))?;
    let steps = args.flag_usize("steps", 120)?;
    let seed = args.flag_u64("seed", 11)?;
    let mut cfg = SimConfig::new(setup.clone(), steps, seed);
    match args.flag("controller").unwrap_or("heuristic") {
        "heuristic" => {}
        "learned" => {
            let path = args.flag("policy").context(
                "--controller learned needs --policy FILE (train one with \
                 `oppo train-controller`)",
            )?;
            cfg = cfg.learned(crate::ctl::QPolicy::load(path)?);
        }
        other => bail!("unknown controller {other:?} (want heuristic|learned)"),
    }
    let log = simulate(pipeline, &cfg);
    println!(
        "{} on {}: {} steps, steady-state latency {:.2}s, final reward {:.3}, \
         time-to-{:.2} {}",
        pipeline.name(),
        setup.name,
        steps,
        steady_state_latency(&log),
        log.records.last().map(|r| r.mean_score).unwrap_or(0.0),
        setup.target_reward,
        log.time_to_reward(setup.target_reward, 8)
            .map(crate::util::fmt_secs)
            .unwrap_or_else(|| "not reached".into()),
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let only = args.flag("only");
    let run = |name: &str| only.is_none() || only == Some(name);
    let mut emit = |name: &str, title: &str, rows: Vec<crate::eval::Row>| -> Result<()> {
        print_table(title, &rows);
        save_rows(name, &rows)
    };
    if run("fig2a") {
        emit("fig2a", "Fig 2a — GPU utilization per stage", figures::fig2a())?;
    }
    if run("fig2b") {
        emit("fig2b", "Fig 2b — rollout length distribution", figures::fig2b())?;
    }
    if run("fig2c") {
        emit("fig2c", "Fig 2c — staleness hurts convergence", figures::fig2c())?;
    }
    if run("fig3") {
        emit("fig3", "Fig 3 — time-to-reward speedup", figures::fig3())?;
    }
    if run("fig4") {
        emit("fig4", "Fig 4 — step-to-reward parity", figures::fig4())?;
    }
    if run("fig5") {
        emit("fig5", "Fig 5 — GPU utilization improvement", figures::fig5())?;
    }
    if run("fig6") {
        emit("fig6", "Fig 6 — ablation breakdown", figures::fig6())?;
    }
    if run("fig7a") {
        emit("fig7a", "Fig 7a — fixed vs dynamic Δ", figures::fig7a())?;
    }
    if run("fig7b") {
        emit("fig7b", "Fig 7b — chunk size vs step speed", figures::fig7b())?;
    }
    if run("table1") {
        emit("table1", "Table 1 — multi-node step latency", tables::table1())?;
    }
    if run("table2") {
        emit("table2", "Table 2 — deferral distribution", tables::table2())?;
    }
    if run("table3") {
        emit("table3", "Table 3 (sim) — final reward parity", tables::table3_sim())?;
    }
    if run("table4") {
        emit("table4", "Table 4 — framework comparison", tables::table4())?;
    }
    Ok(())
}

/// `train-controller`: pinned-seed Q-learning in the simulator, frozen to
/// a versioned artifact, plus a heuristic-vs-learned pricing pass on both
/// benchmark presets.  The `arm` lines are stable and machine-parseable —
/// the CI train-smoke greps them to assert the learned arm's step
/// throughput is no worse than the heuristics'.
fn cmd_train_controller(args: &Args) -> Result<()> {
    let episodes = args.flag_u64("episodes", 50)?;
    let seed = args.flag_u64("seed", 0)?;
    let out = args.flag("out").unwrap_or("artifacts/controller_q.json");
    anyhow::ensure!(episodes > 0, "--episodes must be positive");

    let (policy, report) = crate::sim::train_qpolicy(episodes, seed);
    println!(
        "trained controller: episodes={} seed={} visited_cells={}",
        report.episodes, report.seed, report.visited_cells
    );
    for arm in &report.arms {
        println!(
            "arm {}: heuristic_steps_per_s={:.6} learned_steps_per_s={:.6} speedup={:.4}",
            arm.preset, arm.heuristic_steps_per_s, arm.learned_steps_per_s, arm.speedup
        );
    }
    policy.save(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `remote-stage`: host one stage replica behind a TCP listener.  Prints
/// `listening on ADDR` (flushed) once bound, so a parent process — the CI
/// loopback smoke — can wait for readiness and recover the ephemeral port.
fn cmd_remote_stage(args: &Args) -> Result<()> {
    use crate::transport::{serve, Backend};

    let stage = args.flag("stage").context("--stage reward|ref is required")?.to_string();
    anyhow::ensure!(stage == "reward" || stage == "ref", "--stage must be reward or ref");
    let listen = args.flag("listen").context("--listen HOST:PORT is required")?;
    let backend_kind = args.flag("backend").unwrap_or("engine");
    let max_conns = match args.flag_usize("max-conns", 1)? {
        0 => None,
        n => Some(n),
    };

    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;
    println!("listening on {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush().ok();

    // params sink default: remotes without an engine have nothing to load
    // weights into — accept and drop the blob (the ack CRC still proves
    // what arrived), which is exactly right for the toy backend
    let mut drop_params = |_which: &str, _data: &[u8]| Ok(());
    match backend_kind {
        "toy" => {
            let mut backend = if stage == "reward" {
                let mut b = crate::transport::ToyRewardBackend::new();
                Backend::Reward(Box::new(move |req| b.handle(req)))
            } else {
                let mut b = crate::transport::ToyRefBackend::new();
                Backend::Ref(Box::new(move |req| b.handle(req)))
            };
            serve(&listener, &mut backend, &mut drop_params, max_conns)
        }
        "engine" => {
            let dir = args.flag("artifacts").unwrap_or("artifacts");
            let engine = std::sync::Arc::new(crate::runtime::Engine::load(dir)?);
            let (mut backend, mut on_params) =
                crate::coordinator::worker::engine_serve_backend(engine, &stage)?;
            serve(&listener, &mut backend, &mut *on_params, max_conns)
        }
        other => bail!("unknown backend {other:?} (want engine|toy)"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    let manifest = crate::runtime::Manifest::load(dir)?;
    let m = &manifest.shape;
    println!("artifacts: {}", manifest.dir.display());
    println!(
        "model: d={} layers={} heads={} vocab={} s_max={} lanes={} ppo_batch={} (~{} params)",
        m.d_model, m.n_layers, m.n_heads, m.vocab, m.s_max, m.lanes, m.ppo_batch,
        m.approx_params()
    );
    println!("chunk variants: {:?}", m.chunk_sizes);
    println!("entries ({}):", manifest.entries.len());
    for (name, e) in &manifest.entries {
        println!("  {name:40} {} in / {} out", e.inputs.len(), e.outputs.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_basic() {
        let a = Args::parse(&sv(&["simulate", "--steps", "50", "--seed", "3"])).unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.flag_usize("steps", 0).unwrap(), 50);
        assert_eq!(a.flag_u64("seed", 0).unwrap(), 3);
        assert_eq!(a.flag_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn parse_sets_accumulate() {
        let a = Args::parse(&sv(&["train", "--set", "run.steps=5", "--set", "run.seed=2"]))
            .unwrap();
        assert_eq!(a.sets.len(), 2);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Args::parse(&sv(&["train", "steps"])).is_err());
        assert!(Args::parse(&sv(&["train", "--steps"])).is_err());
    }

    #[test]
    fn name_lookups() {
        assert!(pipeline_by_name("oppo").is_ok());
        assert!(pipeline_by_name("warp").is_err());
        assert!(setup_by_name("gsm8k-7b").is_ok());
        assert!(setup_by_name("bogus").is_err());
    }
}
