#!/usr/bin/env python3
"""Cross-PR benchmark trajectory over the committed BENCH_*.json snapshots.

Each PR commits one pinned-seed snapshot (BENCH_6.json, BENCH_7.json, ...);
this script lines them up and renders ASCII trajectories of the headline
metrics per scenario, so a perf regression shows up as a kink in the chart
rather than a number buried in a JSON diff.  Tolerant of missing scenarios
and keys — older snapshots predate newer metrics (e.g. lane_idle_frac_mean
and the SLO block only exist from BENCH_7 on).

Usage:
  python3 scripts/plot_bench.py              # chart everything found
  python3 scripts/plot_bench.py --check      # exit non-zero on structural
                                             # problems in the newest snapshot
  python3 scripts/plot_bench.py --dir /path  # snapshots live elsewhere

Stdlib only (no matplotlib in CI).
"""

import argparse
import glob
import json
import os
import re
import sys

# (scenario-level key, display label, lower-is-better)
METRICS = [
    ("step_wall_s_mean", "step wall (s)", True),
    ("util_mean", "utilization", False),
    ("gen_tokens_per_s", "gen tok/s", False),
    ("lane_idle_frac_mean", "lane idle frac", True),
]
SLO_KEYS = ["queue_wait_p50", "queue_wait_p99", "e2e_p50", "e2e_p99"]
BAR_WIDTH = 40


def load_snapshots(root):
    """[(pr_number, path, doc)] sorted by PR number."""
    out = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(path))
        if not m:
            continue
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {path}: {e}", file=sys.stderr)
            continue
        out.append((int(m.group(1)), path, doc))
    return sorted(out)


def series(snaps, scenario, key):
    """[(pr, value)] for one scenario-level metric, skipping absences."""
    pts = []
    for pr, _path, doc in snaps:
        v = doc.get("scenarios", {}).get(scenario, {}).get(key)
        if isinstance(v, (int, float)):
            pts.append((pr, float(v)))
    return pts


def bar_chart(title, pts, lower_better):
    if not pts:
        return
    print(f"  {title}")
    hi = max(v for _, v in pts)
    for pr, v in pts:
        w = 0 if hi <= 0 else int(round(BAR_WIDTH * v / hi))
        mark = ""
        best = min(pts, key=lambda p: p[1]) if lower_better else max(pts, key=lambda p: p[1])
        if (pr, v) == best and len(pts) > 1:
            mark = "  <- best"
        print(f"    PR{pr:>3} | {'#' * w:<{BAR_WIDTH}} {v:.4g}{mark}")


def chart_all(snaps):
    scenarios = []
    for _pr, _path, doc in snaps:
        for name in doc.get("scenarios", {}):
            if name not in scenarios:
                scenarios.append(name)
    for sc in scenarios:
        printed = False
        for key, label, lower in METRICS:
            pts = series(snaps, sc, key)
            if not pts:
                continue
            if not printed:
                print(f"\n== scenario: {sc} ==")
                printed = True
            bar_chart(label, pts, lower)
        # SLO percentiles (flattened from the nested block)
        for k in SLO_KEYS:
            pts = []
            for pr, _path, doc in snaps:
                slo = doc.get("scenarios", {}).get(sc, {}).get("slo")
                if isinstance(slo, dict) and isinstance(slo.get(k), (int, float)):
                    pts.append((pr, float(slo[k])))
            if pts:
                if not printed:
                    print(f"\n== scenario: {sc} ==")
                    printed = True
                bar_chart(f"slo {k} (ticks)", pts, True)
    # repo-level trajectory
    pts = [
        (pr, float(doc["sliced_knee_reward_replicas"]))
        for pr, _path, doc in snaps
        if isinstance(doc.get("sliced_knee_reward_replicas"), (int, float))
    ]
    if pts:
        print("\n== repo-level ==")
        bar_chart("sliced knee (reward replicas)", pts, True)


def check_latest(snaps):
    """Structural sanity of the newest snapshot; returns error strings."""
    errors = []
    pr, path, doc = snaps[-1]
    scen = doc.get("scenarios")
    if not isinstance(scen, dict) or not scen:
        return [f"{path}: no scenarios block"]
    for name, sc in scen.items():
        for key in ("step_wall_s_mean", "util_mean", "gen_tokens_per_s"):
            if not isinstance(sc.get(key), (int, float)):
                errors.append(f"{path}: scenarios.{name}.{key} missing/non-numeric")
    if pr >= 7:
        # rolling-admission era: the continuous-batching arms must report
        # lane idle, the Poisson arm must report SLO percentiles, and
        # rolling must beat its step-synchronous baseline on lane idle
        pairs = [
            ("oppo_x1", "oppo_rolling_saturated"),
            ("traffic_stepsync", "traffic_rolling_poisson"),
        ]
        for base_name, roll_name in pairs:
            base, roll = scen.get(base_name), scen.get(roll_name)
            if base is None or roll is None:
                errors.append(f"{path}: missing scenario pair {base_name}/{roll_name}")
                continue
            bi, ri = base.get("lane_idle_frac_mean"), roll.get("lane_idle_frac_mean")
            if not isinstance(bi, (int, float)) or not isinstance(ri, (int, float)):
                errors.append(
                    f"{path}: lane_idle_frac_mean missing on {base_name}/{roll_name}"
                )
            elif not ri < bi:
                errors.append(
                    f"{path}: rolling lane idle {ri:.4g} not below "
                    f"step-sync baseline {bi:.4g} ({roll_name} vs {base_name})"
                )
        poisson = scen.get("traffic_rolling_poisson", {})
        slo = poisson.get("slo")
        if not isinstance(slo, dict):
            errors.append(f"{path}: traffic_rolling_poisson.slo missing")
        else:
            for k in ("queue_wait_p50", "queue_wait_p99", "e2e_p50", "e2e_p99"):
                if not isinstance(slo.get(k), (int, float)):
                    errors.append(f"{path}: traffic_rolling_poisson.slo.{k} missing")
    return errors


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None, help="directory holding BENCH_*.json")
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the newest snapshot's structure; non-zero exit on problems",
    )
    args = ap.parse_args()
    root = args.dir or os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    snaps = load_snapshots(root)
    if not snaps:
        print(f"no BENCH_*.json snapshots under {root}", file=sys.stderr)
        return 1
    print(f"found {len(snaps)} snapshot(s): " + ", ".join(p for _, p, _ in [(n, os.path.basename(p), d) for n, p, d in snaps]))
    chart_all(snaps)
    if args.check:
        errors = check_latest(snaps)
        if errors:
            print("\ncheck FAILED:", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        print("\ncheck OK: newest snapshot is structurally sound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
