"""L2 — the JAX model: transformer actor (policy + value head), reward model,
reference model, and the PPO/DPO training math (Eqs. 1–2 of the paper).

Everything here is *build-time only*.  ``aot.py`` lowers the entry points
defined at the bottom of this file to HLO text; the Rust coordinator
executes them through PJRT and Python never appears on the training path.

Model: a GPT-style causal LM over a small byte-ish vocabulary with learned
positional embeddings and a scalar head.  The actor uses the scalar head as
the PPO value function (TRL-style "model with value head"); the reward model
is an independently-initialized copy whose scalar head emits the score.  The
reference model is a frozen copy of the initial actor.

Parameters travel as a flat, deterministically-ordered list of arrays (see
``param_names``) so the Rust side can treat them as an opaque ``Vec<Buffer>``
and thread them through ``ppo_update`` without understanding the pytree.

All attention goes through ``kernels.select(impl)`` so the Pallas kernels
(L1) lower into the same HLO as the surrounding model.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import kernels


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model/shape configuration baked into the AOT artifacts."""

    vocab: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    s_max: int = 160  # maximum total sequence length (prompt + response)
    prompt_max: int = 24  # maximum prompt length
    lanes: int = 12  # generation lanes G = B + delta_max
    ppo_batch: int = 8  # PPO update batch B
    chunk_sizes: tuple[int, ...] = (8, 16, 32)  # streaming chunk variants
    # PPO hyper-parameters (baked at lowering; step index stays dynamic).
    gamma: float = 1.0
    lam: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    temperature: float = 1.0
    dpo_beta: float = 0.1
    kernel_impl: str = "jnp"  # "jnp" (fused oracle) or "pallas" (L1 kernels)
    # Paged KV: block granularity (tokens; must divide s_max) and physical
    # pool size for the paged entry family.  0 pool blocks = auto-size to
    # full capacity (lanes * blocks_per_lane + the reserved scratch block),
    # which keeps the paged entries numerically interchangeable with the
    # dense ones while the host allocator decides how much is actually used.
    kv_block_size: int = 16
    kv_pool_blocks: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def kv_blocks_per_lane(self) -> int:
        assert self.s_max % self.kv_block_size == 0, (self.s_max, self.kv_block_size)
        return self.s_max // self.kv_block_size

    @property
    def kv_pool_size(self) -> int:
        """Physical blocks in the pool, scratch block 0 included."""
        return self.kv_pool_blocks or self.lanes * self.kv_blocks_per_lane + 1

    def kernels(self):
        return kernels.select(self.kernel_impl)


# Special token ids — mirrored in rust/src/data/tokenizer.rs via the manifest.
PAD, BOS, EOS = 0, 1, 2


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> list[str]:
    """The canonical flat parameter ordering (manifest + Rust rely on it)."""
    names = ["embed", "pos_embed"]
    for i in range(cfg.n_layers):
        p = f"l{i:02d}_"
        names += [
            p + "ln1_s", p + "ln1_b",
            p + "wq", p + "wk", p + "wv", p + "wo",
            p + "ln2_s", p + "ln2_b",
            p + "w1", p + "b1", p + "w2", p + "b2",
        ]
    names += ["lnf_s", "lnf_b", "head_w", "head_b"]
    return names


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (cfg.vocab, d),
        "pos_embed": (cfg.s_max, d),
        "lnf_s": (d,),
        "lnf_b": (d,),
        "head_w": (d,),  # scalar head: value (actor) / score (reward model)
        "head_b": (),
    }
    for i in range(cfg.n_layers):
        p = f"l{i:02d}_"
        shapes.update({
            p + "ln1_s": (d,), p + "ln1_b": (d,),
            p + "wq": (d, d), p + "wk": (d, d), p + "wv": (d, d), p + "wo": (d, d),
            p + "ln2_s": (d,), p + "ln2_b": (d,),
            p + "w1": (d, f), p + "b1": (f,), p + "w2": (f, d), p + "b2": (d,),
        })
    return shapes


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Small-scale GPT init: scaled-normal matrices, unit LN scales."""
    shapes = param_shapes(cfg)
    params: dict[str, jax.Array] = {}
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("_s",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "b1", "b2")) or name == "head_b":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        elif name == "pos_embed":
            params[name] = 0.01 * jax.random.normal(sub, shape, jnp.float32)
        elif name == "head_w":
            params[name] = 0.01 * jax.random.normal(sub, shape, jnp.float32)
        else:  # weight matrices
            fan_in = shape[0]
            std = (2.0 / (fan_in + shape[-1])) ** 0.5
            # residual-branch scaling keeps deep-net activations tame
            if name.endswith(("wo", "w2")):
                std /= (2.0 * cfg.n_layers) ** 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: dict[str, jax.Array]) -> list[jax.Array]:
    return [params[n] for n in param_names(cfg)]


def unflatten_params(cfg: ModelConfig, flat: list[jax.Array]) -> dict[str, jax.Array]:
    names = param_names(cfg)
    assert len(flat) == len(names), (len(flat), len(names))
    return dict(zip(names, flat))


# --------------------------------------------------------------------------
# Transformer building blocks
# --------------------------------------------------------------------------


def _ln(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * s + b


def _mlp(p, prefix, x):
    h = jax.nn.gelu(x @ p[prefix + "w1"] + p[prefix + "b1"])
    return h @ p[prefix + "w2"] + p[prefix + "b2"]


def _split_heads(cfg: ModelConfig, x):  # [..., T, D] -> [..., H, T, hd]
    *lead, t, _ = x.shape
    return x.reshape(*lead, t, cfg.n_heads, cfg.head_dim).swapaxes(-2, -3)


def _merge_heads(cfg: ModelConfig, x):  # [..., H, T, hd] -> [..., T, D]
    *lead, _, t, _ = x.shape
    return x.swapaxes(-2, -3).reshape(*lead, t, cfg.d_model)


def forward_full(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """Teacher-forced forward over the whole buffer.

    Returns ``(logits [B,S,V], scalar [B,S])`` where ``scalar`` is the value
    estimate (actor) or reward score (reward model) at every position.
    Dense causal attention — used by training/scoring entry points where all
    positions are needed anyway, so chunked streaming does not apply.
    """
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg.n_layers):
        p = f"l{i:02d}_"
        h = _ln(x, params[p + "ln1_s"], params[p + "ln1_b"])
        q = _split_heads(cfg, h @ params[p + "wq"])
        k = _split_heads(cfg, h @ params[p + "wk"])
        v = _split_heads(cfg, h @ params[p + "wv"])
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (cfg.head_dim**0.5)
        scores = jnp.where(causal[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        x = x + _merge_heads(cfg, jnp.einsum("bhqk,bhkd->bhqd", att, v)) @ params[p + "wo"]
        h2 = _ln(x, params[p + "ln2_s"], params[p + "ln2_b"])
        x = x + _mlp(params, p, h2)
    x = _ln(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["embed"].T  # tied LM head
    scalar = x @ params["head_w"] + params["head_b"]
    return logits, scalar


def token_logprobs(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """``logp[b, t] = log P(tokens[t] | tokens[:t])`` with ``logp[:,0] = 0``."""
    logits, scalar = forward_full(cfg, params, tokens)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    b, s = tokens.shape
    shifted = jnp.take_along_axis(logp_all[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
    logp = jnp.concatenate([jnp.zeros((b, 1), jnp.float32), shifted], axis=1)
    return logp, scalar


# ---- KV-cache incremental paths (generation / streamed scoring) ----------


def _scatter_rows(cache: jax.Array, rows: jax.Array, start: jax.Array):
    """Write ``rows [B,H,C,hd]`` into ``cache [B,H,S,hd]`` at per-batch ``start``."""

    def one(c, r, s):
        return jax.lax.dynamic_update_slice(c, r, (0, s, 0))

    return jax.vmap(one)(cache, rows, start)


def decode_step(cfg: ModelConfig, params: dict, tok: jax.Array, pos: jax.Array, kv: list):
    """One autoregressive step: feed token at ``pos``, predict ``pos+1``.

    ``kv`` is a flat list ``[k0, v0, k1, v1, ...]`` of ``[B,H,S,hd]`` caches.
    Writes the step's K/V at row ``pos`` and attends ``j <= pos``.
    Returns ``(logits [B,V], scalar [B], new_kv)``.
    """
    kn = cfg.kernels()
    b = tok.shape[0]
    x = params["embed"][tok] + params["pos_embed"][pos]  # [B, D]
    new_kv = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}_"
        h = _ln(x, params[p + "ln1_s"], params[p + "ln1_b"])
        q = (h @ params[p + "wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(b, cfg.n_heads, 1, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(b, cfg.n_heads, 1, cfg.head_dim)
        k_cache = _scatter_rows(kv[2 * i], k, pos)
        v_cache = _scatter_rows(kv[2 * i + 1], v, pos)
        att = kn.decode_attention(q, k_cache, v_cache, pos)  # [B,H,hd]
        x = x + att.reshape(b, cfg.d_model) @ params[p + "wo"]
        h2 = _ln(x, params[p + "ln2_s"], params[p + "ln2_b"])
        x = x + _mlp(params, p, h2)
        new_kv += [k_cache, v_cache]
    x = _ln(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["embed"].T
    scalar = x @ params["head_w"] + params["head_b"]
    return logits, scalar, new_kv


def prefill_chunk(cfg: ModelConfig, params: dict, chunk: jax.Array, start: jax.Array, kv: list):
    """Incremental prefill of ``C`` tokens starting at per-batch ``start``.

    This is the intra-step-overlap workhorse (§3.1): the reward worker calls
    it once per streamed chunk while the actor is still decoding the next
    chunk.  Scatters the chunk's K/V into the cache, then runs the L1
    chunked-prefill attention kernel against the full history.
    Returns ``(scalar [B,C], logits [B,C,V], new_kv)``.
    """
    kn = cfg.kernels()
    b, c = chunk.shape
    pos_idx = start[:, None] + jnp.arange(c)[None, :]  # [B, C]
    pos_idx = jnp.minimum(pos_idx, cfg.s_max - 1)
    x = params["embed"][chunk] + params["pos_embed"][pos_idx]
    new_kv = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}_"
        h = _ln(x, params[p + "ln1_s"], params[p + "ln1_b"])
        q = _split_heads(cfg, h @ params[p + "wq"])  # [B,H,C,hd]
        k = _split_heads(cfg, h @ params[p + "wk"])
        v = _split_heads(cfg, h @ params[p + "wv"])
        k_cache = _scatter_rows(kv[2 * i], k, start)
        v_cache = _scatter_rows(kv[2 * i + 1], v, start)
        att = kn.chunked_prefill_attention(q, k_cache, v_cache, start)
        x = x + _merge_heads(cfg, att) @ params[p + "wo"]
        h2 = _ln(x, params[p + "ln2_s"], params[p + "ln2_b"])
        x = x + _mlp(params, p, h2)
        new_kv += [k_cache, v_cache]
    x = _ln(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["embed"].T
    scalar = x @ params["head_w"] + params["head_b"]
    return scalar, logits, new_kv


# ---- Paged KV (block-table-indexed pool) ----------------------------------
#
# vLLM-style paged layout: each layer's K (or V) cache is one pooled buffer
# ``[P, H, bs, hd]`` of P physical blocks shared by all lanes, and the host
# allocator hands every call an i32 block table ``[rows, s_max/bs]`` mapping
# lane-local block j of row r to a physical block.  The dense position
# ``t`` of row ``r`` lives at ``pool[table[r, t//bs], :, t % bs, :]``.
#
# Physical block 0 is reserved as the *scratch sink*: table slots the host
# has not allocated yet point at it.  Writes to it collide across lanes and
# reads from it return garbage — both harmless, because the attention masks
# (``start``/``pos``) never let a valid query attend a position beyond its
# allocated prefix, the same garbage-in-garbage-out contract the dense
# caches already rely on past ``n_valid``.
#
# The reference implementation is gather → dense compute → scatter: exact
# semantics (paged == dense wherever the table covers the written rows), so
# every dense attention kernel — jnp oracle or Pallas — runs unchanged on
# the gathered view.


def paged_gather(cfg: ModelConfig, pool: jax.Array, table: jax.Array) -> jax.Array:
    """``pool [P,H,bs,hd]`` + ``table [B, s_max/bs]`` → dense ``[B,H,s_max,hd]``."""
    d = pool[table]  # [B, nblk, H, bs, hd]
    b, nblk, h, bs, hd = d.shape
    return d.transpose(0, 2, 1, 3, 4).reshape(b, h, nblk * bs, hd)


def paged_scatter(cfg: ModelConfig, pool: jax.Array, table: jax.Array,
                  dense: jax.Array) -> jax.Array:
    """Write a dense ``[B,H,S,hd]`` view back into the pool through the table."""
    b, h, s, hd = dense.shape
    bs = cfg.kv_block_size
    blocks = dense.reshape(b, h, s // bs, bs, hd).transpose(0, 2, 1, 3, 4)
    return pool.at[table].set(blocks)


def decode_step_paged(cfg: ModelConfig, params: dict, tok: jax.Array,
                      pos: jax.Array, pool_kv: list, table: jax.Array):
    """``decode_step`` against pooled caches: gather → step → scatter."""
    dense_kv = [paged_gather(cfg, p, table) for p in pool_kv]
    logits, scalar, new_kv = decode_step(cfg, params, tok, pos, dense_kv)
    new_pool = [paged_scatter(cfg, p, table, nk) for p, nk in zip(pool_kv, new_kv)]
    return logits, scalar, new_pool


def prefill_chunk_paged(cfg: ModelConfig, params: dict, chunk: jax.Array,
                        start: jax.Array, pool_kv: list, table: jax.Array):
    """``prefill_chunk`` against pooled caches: gather → prefill → scatter."""
    dense_kv = [paged_gather(cfg, p, table) for p in pool_kv]
    scalar, logits, new_kv = prefill_chunk(cfg, params, chunk, start, dense_kv)
    new_pool = [paged_scatter(cfg, p, table, nk) for p, nk in zip(pool_kv, new_kv)]
    return scalar, logits, new_pool


# --------------------------------------------------------------------------
# Entry points (lowered to HLO by aot.py)
# --------------------------------------------------------------------------
#
# Shape legend: G = cfg.lanes (generation side), B = cfg.ppo_batch (training
# side), S = cfg.s_max, C = chunk size, L = cfg.n_layers, P = len(params).
# KV caches are always the flat list [k0, v0, ..., k_{L-1}, v_{L-1}].


def make_actor_prefill(cfg: ModelConfig) -> Callable:
    """(params, tokens [G,S], prompt_len [G], reset [G], kv) -> kv'.

    Recomputes prompt prefill for all lanes over positions [0, prompt_max)
    and swaps the result into the cache only where ``reset != 0``.  Lanes
    keep their KV rows otherwise — deferred sequences' partial work is
    preserved verbatim (§3.2's "partial work is preserved").
    """

    def fn(*args):
        flat, rest = args[: len(param_names(cfg))], args[len(param_names(cfg)) :]
        params = unflatten_params(cfg, list(flat))
        tokens, prompt_len, reset = rest[0], rest[1], rest[2]
        kv = list(rest[3:])
        del prompt_len  # garbage rows beyond the prompt are overwritten by decode
        g = tokens.shape[0]
        chunk = tokens[:, : cfg.prompt_max]
        start = jnp.zeros((g,), jnp.int32)
        _, _, new_kv = prefill_chunk(cfg, params, chunk, start, kv)
        sel = (reset != 0)[:, None, None, None]
        out_kv = [jnp.where(sel, nk, ok) for nk, ok in zip(new_kv, kv)]
        return tuple(out_kv)

    return fn


def make_actor_generate_chunk(cfg: ModelConfig, c: int) -> Callable:
    """(params, tokens [G,S], pos [G], live [G], kv, key [2]u32)
    -> (tokens', pos', kv', out_tok [G,C], logp [G,C], value [G,C]).

    Runs ``C`` decode+sample steps.  Dead lanes (live == 0) are fully
    frozen: their KV rows, token buffer, and position are bit-identical
    afterwards, which the equivalence tests rely on.
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens, pos, live = args[np_], args[np_ + 1], args[np_ + 2]
        kv = list(args[np_ + 3 : np_ + 3 + 2 * cfg.n_layers])
        key = args[np_ + 3 + 2 * cfg.n_layers]
        g = tokens.shape[0]
        lanes = jnp.arange(g)

        def step(carry, i):
            tokens, pos, kv, key = carry
            alive = live != 0
            qpos = jnp.maximum(pos - 1, 0)
            last_tok = tokens[lanes, qpos]
            logits, value, new_kv = decode_step(cfg, params, last_tok, qpos, kv)
            # freeze dead lanes' caches
            kv = [jnp.where(alive[:, None, None, None], nk, ok) for nk, ok in zip(new_kv, kv)]
            key, sub = jax.random.split(key)
            next_tok = jax.random.categorical(sub, logits / cfg.temperature, axis=-1)
            next_tok = next_tok.astype(jnp.int32)
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            logp = logp_all[lanes, next_tok]
            write_pos = jnp.minimum(pos, cfg.s_max - 1)
            old_at_pos = tokens[lanes, write_pos]
            tok_write = jnp.where(alive, next_tok, old_at_pos)
            tokens = tokens.at[lanes, write_pos].set(tok_write)
            pos = pos + alive.astype(jnp.int32)
            out = (
                jnp.where(alive, next_tok, PAD),
                jnp.where(alive, logp, 0.0),
                jnp.where(alive, value, 0.0),
            )
            return (tokens, pos, kv, key), out

        (tokens, pos, kv, _), (toks, logps, values) = jax.lax.scan(
            step, (tokens, pos, kv, key), jnp.arange(c)
        )
        # scan stacks along axis 0 -> [C, G]; transpose to [G, C]
        return (tokens, pos, *kv, toks.T, logps.T, values.T)

    return fn


def make_reward_prefill_chunk(cfg: ModelConfig, c: int) -> Callable:
    """(rparams, chunk [G,C], start [G], n_valid [G], kv) -> (kv', score [G,C]).

    Incremental scoring prefill: one streamed chunk of actor output enters
    the reward model's KV cache; per-position scores come back so the
    coordinator can pick the score at each sequence's final token without a
    second pass.  Positions ``i >= n_valid`` are garbage-in-garbage-out by
    construction (the next chunk overwrites those cache rows; see module
    docs in kernels/ref.py).
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        chunk, start, n_valid = args[np_], args[np_ + 1], args[np_ + 2]
        kv = list(args[np_ + 3 :])
        del n_valid
        score, _, new_kv = prefill_chunk(cfg, params, chunk, start, kv)
        return (*new_kv, score)

    return fn


def make_reward_score_full(cfg: ModelConfig) -> Callable:
    """(rparams, tokens [G,S], last_idx [G]) -> score [G].

    Monolithic scoring — the baseline path (no streaming) and the oracle the
    equivalence tests compare streamed scores against.
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens, last_idx = args[np_], args[np_ + 1]
        _, scalar = forward_full(cfg, params, tokens)
        g = tokens.shape[0]
        return (scalar[jnp.arange(g), last_idx],)

    return fn


def make_ref_logprobs(cfg: ModelConfig) -> Callable:
    """(refparams, tokens [B,S]) -> logp [B,S]  (KL term inputs, §2.1)."""

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens = args[np_]
        logp, _ = token_logprobs(cfg, params, tokens)
        return (logp,)

    return fn


def make_ref_prefill_chunk(cfg: ModelConfig, c: int) -> Callable:
    """(refparams, chunk [G,C], start [G], n_valid [G], boundary [G,V], kv)
    -> (kv', boundary' [G,V], logp [G,C]).

    Incremental reference log-probs: the same streamed ``[G, C]`` chunks the
    reward worker consumes also feed the reference model, so the KL-term
    inputs are prefileld *during* actor decoding instead of in one dense
    post-generation pass (the third pipeline stage of the intra-step
    overlap).  ``logp[g, j] = log P(chunk[g, j] | prefix)``, matching
    ``token_logprobs`` exactly when chunks are streamed contiguously.

    The cross-chunk seam: token ``j = 0`` of a chunk is predicted by the
    logits *after* the previous chunk's last valid token.  Those logits
    travel as the device-resident ``boundary [G, V]`` log-softmax, updated
    each call at ``n_valid - 1`` (lanes with ``n_valid == 0`` keep their
    boundary).  At ``start == 0`` there is no prefix and ``logp[:, 0] = 0``,
    the same convention as ``token_logprobs``.  Positions ``j >= n_valid``
    are garbage-in-garbage-out exactly like the reward flavour.
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        chunk, start, n_valid, boundary = args[np_], args[np_ + 1], args[np_ + 2], args[np_ + 3]
        kv = list(args[np_ + 4 :])
        _, logits, new_kv = prefill_chunk(cfg, params, chunk, start, kv)
        logp_all = jax.nn.log_softmax(logits, axis=-1)  # [G, C, V]
        g = chunk.shape[0]
        lanes = jnp.arange(g)
        # within-chunk: token j is predicted by this chunk's logits at j-1
        intra = jnp.take_along_axis(logp_all[:, :-1], chunk[:, 1:, None], axis=-1)[..., 0]
        first = jnp.where(start > 0, boundary[lanes, chunk[:, 0]], 0.0)
        logp = jnp.concatenate([first[:, None], intra], axis=1)  # [G, C]
        last_idx = jnp.maximum(n_valid - 1, 0)
        new_boundary = jnp.where(
            (n_valid > 0)[:, None], logp_all[lanes, last_idx], boundary
        )
        return (*new_kv, new_boundary, logp)

    return fn


# ---- Paged entry family ---------------------------------------------------
#
# Same contracts as the dense flavours above, with the per-state dense
# ``[rows, H, S, hd]`` caches replaced by the shared ``[P, H, bs, hd]`` pool
# + per-call ``[rows, S/bs]`` block table.  The table rides as the LAST
# input (after the RNG key where one exists) so the pool buffers occupy the
# same argument positions the dense caches did.


def make_actor_prefill_paged(cfg: ModelConfig) -> Callable:
    """(params, tokens [G,S], prompt_len [G], reset [G], pool, table [G,S/bs])
    -> pool'.  Selective-reset semantics identical to ``actor_prefill``:
    lanes with ``reset == 0`` round-trip their pooled blocks bit-identically.
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens, prompt_len, reset = args[np_], args[np_ + 1], args[np_ + 2]
        pool = list(args[np_ + 3 : np_ + 3 + 2 * cfg.n_layers])
        table = args[np_ + 3 + 2 * cfg.n_layers]
        del prompt_len
        g = tokens.shape[0]
        chunk = tokens[:, : cfg.prompt_max]
        start = jnp.zeros((g,), jnp.int32)
        dense_kv = [paged_gather(cfg, p, table) for p in pool]
        _, _, new_kv = prefill_chunk(cfg, params, chunk, start, dense_kv)
        sel = (reset != 0)[:, None, None, None]
        out_kv = [jnp.where(sel, nk, ok) for nk, ok in zip(new_kv, dense_kv)]
        out_pool = [paged_scatter(cfg, p, table, ok) for p, ok in zip(pool, out_kv)]
        return tuple(out_pool)

    return fn


def make_actor_generate_chunk_paged(cfg: ModelConfig, c: int) -> Callable:
    """(params, tokens [G,S], pos [G], live [G], pool, key [2]u32, table)
    -> (tokens', pos', pool', out_tok [G,C], logp [G,C], value [G,C]).

    ``C`` decode+sample steps through ``decode_step_paged``.  The host must
    have grown every live lane's table to cover ``pos + C`` before the call;
    dead lanes' pooled blocks round-trip bit-identically (same freeze
    contract as the dense flavour).
    """

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens, pos, live = args[np_], args[np_ + 1], args[np_ + 2]
        pool = list(args[np_ + 3 : np_ + 3 + 2 * cfg.n_layers])
        key = args[np_ + 3 + 2 * cfg.n_layers]
        table = args[np_ + 4 + 2 * cfg.n_layers]
        g = tokens.shape[0]
        lanes = jnp.arange(g)

        def step(carry, i):
            tokens, pos, pool, key = carry
            alive = live != 0
            qpos = jnp.maximum(pos - 1, 0)
            last_tok = tokens[lanes, qpos]
            dense_kv = [paged_gather(cfg, p, table) for p in pool]
            logits, value, new_kv = decode_step(cfg, params, last_tok, qpos, dense_kv)
            # freeze dead lanes' caches (scatter then writes the old rows back)
            new_kv = [
                jnp.where(alive[:, None, None, None], nk, ok)
                for nk, ok in zip(new_kv, dense_kv)
            ]
            pool = [paged_scatter(cfg, p, table, nk) for p, nk in zip(pool, new_kv)]
            key, sub = jax.random.split(key)
            next_tok = jax.random.categorical(sub, logits / cfg.temperature, axis=-1)
            next_tok = next_tok.astype(jnp.int32)
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            logp = logp_all[lanes, next_tok]
            write_pos = jnp.minimum(pos, cfg.s_max - 1)
            old_at_pos = tokens[lanes, write_pos]
            tok_write = jnp.where(alive, next_tok, old_at_pos)
            tokens = tokens.at[lanes, write_pos].set(tok_write)
            pos = pos + alive.astype(jnp.int32)
            out = (
                jnp.where(alive, next_tok, PAD),
                jnp.where(alive, logp, 0.0),
                jnp.where(alive, value, 0.0),
            )
            return (tokens, pos, pool, key), out

        (tokens, pos, pool, _), (toks, logps, values) = jax.lax.scan(
            step, (tokens, pos, pool, key), jnp.arange(c)
        )
        return (tokens, pos, *pool, toks.T, logps.T, values.T)

    return fn


def make_reward_prefill_chunk_paged(cfg: ModelConfig, c: int) -> Callable:
    """(rparams, chunk [G,C], start [G], n_valid [G], pool, table)
    -> (pool', score [G,C]) — the paged ``reward_prefill_chunk``."""

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        chunk, start, n_valid = args[np_], args[np_ + 1], args[np_ + 2]
        pool = list(args[np_ + 3 : np_ + 3 + 2 * cfg.n_layers])
        table = args[np_ + 3 + 2 * cfg.n_layers]
        del n_valid
        score, _, new_pool = prefill_chunk_paged(cfg, params, chunk, start, pool, table)
        return (*new_pool, score)

    return fn


def make_ref_prefill_chunk_paged(cfg: ModelConfig, c: int) -> Callable:
    """(refparams, chunk [G,C], start [G], n_valid [G], boundary [G,V], pool,
    table) -> (pool', boundary' [G,V], logp [G,C]) — paged ref prefill with
    the same cross-chunk boundary-carry seam as the dense flavour."""

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        chunk, start, n_valid, boundary = (
            args[np_], args[np_ + 1], args[np_ + 2], args[np_ + 3]
        )
        pool = list(args[np_ + 4 : np_ + 4 + 2 * cfg.n_layers])
        table = args[np_ + 4 + 2 * cfg.n_layers]
        _, logits, new_pool = prefill_chunk_paged(cfg, params, chunk, start, pool, table)
        logp_all = jax.nn.log_softmax(logits, axis=-1)
        g = chunk.shape[0]
        lanes = jnp.arange(g)
        intra = jnp.take_along_axis(logp_all[:, :-1], chunk[:, 1:, None], axis=-1)[..., 0]
        first = jnp.where(start > 0, boundary[lanes, chunk[:, 0]], 0.0)
        logp = jnp.concatenate([first[:, None], intra], axis=1)
        last_idx = jnp.maximum(n_valid - 1, 0)
        new_boundary = jnp.where(
            (n_valid > 0)[:, None], logp_all[lanes, last_idx], boundary
        )
        return (*new_pool, new_boundary, logp)

    return fn


def make_actor_forward_full(cfg: ModelConfig) -> Callable:
    """(params, tokens [B,S]) -> (logp [B,S], values [B,S]) — test/debug aid."""

    def fn(*args):
        np_ = len(param_names(cfg))
        params = unflatten_params(cfg, list(args[:np_]))
        tokens = args[np_]
        logp, scalar = token_logprobs(cfg, params, tokens)
        return (logp, scalar)

    return fn


def make_gae(cfg: ModelConfig) -> Callable:
    """(rewards [B,S], values [B,S], mask [B,S]) -> (adv, ret) via the L1 kernel."""

    kn = cfg.kernels()

    def fn(rewards, values, mask):
        adv, ret = kn.gae(rewards, values, mask, gamma=cfg.gamma, lam=cfg.lam)
        return (adv, ret)

    return fn


# ---- PPO / DPO updates ----------------------------------------------------


def _adam_update(cfg: ModelConfig, params, m, v, grads, step):
    """Adam with bias correction; ``step`` is the 1-based update index."""
    t = step.astype(jnp.float32)
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        new_p.append(p - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def ppo_loss(cfg: ModelConfig, params: dict, batch: dict):
    """Clipped-surrogate PPO objective (Eq. 2) + value loss + entropy bonus.

    ``batch`` holds ``tokens [B,S]``, ``mask [B,S]`` (1 on response tokens),
    ``old_logp``, ``adv``, ``ret`` — all aligned so index ``t`` refers to the
    token at position ``t`` predicted from its prefix.
    Returns ``(loss, stats[6])`` with stats =
    (loss, pg_loss, v_loss, entropy, approx_kl, clip_frac).
    """
    tokens, mask = batch["tokens"], batch["mask"]
    old_logp, adv, ret = batch["old_logp"], batch["adv"], batch["ret"]
    logits, values = forward_full(cfg, params, tokens)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    b, s = tokens.shape
    shifted = jnp.take_along_axis(logp_all[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
    logp = jnp.concatenate([jnp.zeros((b, 1), jnp.float32), shifted], axis=1)

    n = jnp.maximum(mask.sum(), 1.0)
    # advantage normalization over the masked set (standard PPO practice)
    adv_mean = (adv * mask).sum() / n
    adv_var = (((adv - adv_mean) * mask) ** 2).sum() / n
    adv_n = (adv - adv_mean) * jax.lax.rsqrt(adv_var + 1e-8)

    ratio = jnp.exp(logp - old_logp)
    unclipped = ratio * adv_n
    clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv_n
    pg = -(jnp.minimum(unclipped, clipped) * mask).sum() / n

    # value loss against the GAE returns; values at position t-1 predict the
    # return of the state from which token t was sampled — we keep the
    # simpler aligned form used by TRL (value at t vs return at t).
    v_loss = (((values - ret) ** 2) * mask).sum() / n

    probs = jnp.exp(logp_all)
    ent_all = -(probs * logp_all).sum(-1)  # [B,S] entropy of next-token dist
    entropy = (ent_all * mask).sum() / n

    approx_kl = ((old_logp - logp) * mask).sum() / n
    clip_frac = ((jnp.abs(ratio - 1.0) > cfg.clip_eps) * mask).sum() / n

    loss = pg + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    stats = jnp.stack([loss, pg, v_loss, entropy, approx_kl, clip_frac])
    return loss, stats


def make_ppo_update(cfg: ModelConfig) -> Callable:
    """(params, m, v, tokens, mask, old_logp, adv, ret, step)
    -> (params', m', v', stats [6])."""

    np_ = len(param_names(cfg))

    def fn(*args):
        flat = list(args[:np_])
        m = list(args[np_ : 2 * np_])
        v = list(args[2 * np_ : 3 * np_])
        tokens, mask, old_logp, adv, ret, step = args[3 * np_ :]
        batch = {
            "tokens": tokens, "mask": mask,
            "old_logp": old_logp, "adv": adv, "ret": ret,
        }

        def loss_fn(flat_params):
            return ppo_loss(cfg, unflatten_params(cfg, flat_params), batch)

        grads, stats = jax.grad(loss_fn, has_aux=True)(flat)
        new_p, new_m, new_v = _adam_update(cfg, flat, m, v, grads, step)
        return (*new_p, *new_m, *new_v, stats)

    return fn


def dpo_loss(cfg: ModelConfig, params: dict, batch: dict):
    """Direct Preference Optimization loss (§4.3 generalization)."""
    logp_c, _ = token_logprobs(cfg, params, batch["chosen"])
    logp_r, _ = token_logprobs(cfg, params, batch["rejected"])
    sum_c = (logp_c * batch["mask_c"]).sum(-1)
    sum_r = (logp_r * batch["mask_r"]).sum(-1)
    logits = cfg.dpo_beta * ((sum_c - batch["ref_c"]) - (sum_r - batch["ref_r"]))
    loss = -jax.nn.log_sigmoid(logits).mean()
    acc = (logits > 0).mean()
    margin = logits.mean()
    stats = jnp.stack([loss, acc, margin, jnp.float32(0.0)])
    return loss, stats


def make_dpo_update(cfg: ModelConfig) -> Callable:
    """(params, m, v, chosen, rejected, mask_c, mask_r, ref_c, ref_r, step)
    -> (params', m', v', stats [4])."""

    np_ = len(param_names(cfg))

    def fn(*args):
        flat = list(args[:np_])
        m = list(args[np_ : 2 * np_])
        v = list(args[2 * np_ : 3 * np_])
        chosen, rejected, mask_c, mask_r, ref_c, ref_r, step = args[3 * np_ :]
        batch = {
            "chosen": chosen, "rejected": rejected,
            "mask_c": mask_c, "mask_r": mask_r,
            "ref_c": ref_c, "ref_r": ref_r,
        }

        def loss_fn(flat_params):
            return dpo_loss(cfg, unflatten_params(cfg, flat_params), batch)

        grads, stats = jax.grad(loss_fn, has_aux=True)(flat)
        new_p, new_m, new_v = _adam_update(cfg, flat, m, v, grads, step)
        return (*new_p, *new_m, *new_v, stats)

    return fn
