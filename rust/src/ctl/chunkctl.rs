//! Dynamic chunk-size controller — intra-step streaming adaptation (§3.1).
//!
//! The paper's observation: the chunk-size ↔ overlap-efficiency tradeoff is
//! monotone and predictable (Fig. 7b is U-shaped in step time), and PPO runs
//! for many steps, so cheap online exploration suffices.  "OPPO periodically
//! (e.g., every 50 training steps) applies a few candidate chunk sizes
//! across different steps and selects the best-performing configuration for
//! subsequent windows."
//!
//! Implementation: a two-phase state machine.
//!
//! * **Exploit(c)** for `period` steps;
//! * **Explore**: run `probes_per_candidate` steps at each candidate (they
//!   must be sizes with pre-compiled executables), record mean step
//!   latency, then exploit the argmin.
//!
//! Candidates are probed in order; measurement updates arrive via
//! `observe_step(step_secs)` after every PPO step.

/// Dynamic chunk-size controller.
#[derive(Clone, Debug)]
pub struct ChunkController {
    candidates: Vec<usize>,
    period: usize,
    probes_per_candidate: usize,
    adaptive: bool,
    current: usize,
    state: State,
    /// adaptation log: (step, chosen_chunk) after each exploration round
    pub history: Vec<(u64, usize)>,
    steps_seen: u64,
}

#[derive(Clone, Debug)]
enum State {
    Exploit { steps_left: usize },
    Explore { candidate_idx: usize, probe: usize, sums: Vec<f64> },
}

impl ChunkController {
    /// Validated construction: every candidate must have a compiled
    /// `c{C}` entry in `compiled` (the manifest's chunk-size set).  The
    /// unchecked [`ChunkController::new`] used to let a bad candidate
    /// list through, and the first exploration round would then probe an
    /// entry that does not exist and fail mid-run — reject it here, at
    /// build time, with the compiled sizes spelled out.
    pub fn try_new(
        candidates: Vec<usize>,
        initial: usize,
        period: usize,
        probes_per_candidate: usize,
        adaptive: bool,
        compiled: &[usize],
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!candidates.is_empty(), "chunk controller needs at least one candidate");
        for &c in &candidates {
            anyhow::ensure!(
                compiled.contains(&c),
                "chunk candidate {c} has no compiled c{c} entry; compiled sizes: {compiled:?}"
            );
        }
        anyhow::ensure!(
            candidates.contains(&initial),
            "initial chunk {initial} is not a candidate (candidates: {candidates:?})"
        );
        anyhow::ensure!(
            period >= candidates.len() * probes_per_candidate || !adaptive,
            "exploration period {period} cannot cover {} candidates × {} probes",
            candidates.len(),
            probes_per_candidate
        );
        Ok(Self::new(candidates, initial, period, probes_per_candidate, adaptive))
    }

    pub fn new(
        candidates: Vec<usize>,
        initial: usize,
        period: usize,
        probes_per_candidate: usize,
        adaptive: bool,
    ) -> Self {
        assert!(!candidates.is_empty());
        assert!(candidates.contains(&initial), "initial chunk must be a candidate");
        assert!(period >= candidates.len() * probes_per_candidate || !adaptive);
        Self {
            candidates,
            period,
            probes_per_candidate,
            adaptive,
            current: initial,
            state: State::Exploit { steps_left: period },
            history: Vec::new(),
            steps_seen: 0,
        }
    }

    /// The chunk size the *next* step should use.
    pub fn chunk(&self) -> usize {
        match &self.state {
            State::Exploit { .. } => self.current,
            State::Explore { candidate_idx, .. } => self.candidates[*candidate_idx],
        }
    }

    /// Is the controller currently probing (step timings are measurements)?
    pub fn exploring(&self) -> bool {
        matches!(self.state, State::Explore { .. })
    }

    /// Report the wall-clock seconds of the step that just ran with
    /// [`Self::chunk`]'s size.
    pub fn observe_step(&mut self, step_secs: f64) {
        self.steps_seen += 1;
        if !self.adaptive {
            return;
        }
        match &mut self.state {
            State::Exploit { steps_left } => {
                *steps_left -= 1;
                if *steps_left == 0 {
                    self.state = State::Explore {
                        candidate_idx: 0,
                        probe: 0,
                        sums: vec![0.0; self.candidates.len()],
                    };
                }
            }
            State::Explore { candidate_idx, probe, sums } => {
                sums[*candidate_idx] += step_secs;
                *probe += 1;
                if *probe >= self.probes_per_candidate {
                    *probe = 0;
                    *candidate_idx += 1;
                    if *candidate_idx >= self.candidates.len() {
                        // pick argmin mean latency
                        let best = sums
                            .iter()
                            .enumerate()
                            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap();
                        self.current = self.candidates[best];
                        self.history.push((self.steps_seen, self.current));
                        self.state = State::Exploit { steps_left: self.period };
                    }
                }
            }
        }
    }

    pub fn candidates(&self) -> &[usize] {
        &self.candidates
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic latency model: U-shaped in chunk size with optimum at 16
    /// (small chunks pay dispatch overhead, big chunks lose overlap —
    /// Fig. 7b's shape).
    fn latency(chunk: usize) -> f64 {
        let c = chunk as f64;
        1.0 + 8.0 / c + c / 24.0
    }

    #[test]
    fn converges_to_best_candidate() {
        let mut ctl = ChunkController::new(vec![4, 16, 64], 64, 6, 2, true);
        for _ in 0..200 {
            let c = ctl.chunk();
            ctl.observe_step(latency(c));
        }
        assert_eq!(ctl.chunk(), 16);
        assert!(!ctl.history.is_empty());
        assert!(ctl.history.iter().rev().take(3).all(|&(_, c)| c == 16));
    }

    #[test]
    fn explores_every_period() {
        let mut ctl = ChunkController::new(vec![8, 16], 8, 4, 1, true);
        let mut explored_steps = 0;
        for _ in 0..40 {
            if ctl.exploring() {
                explored_steps += 1;
            }
            let c = ctl.chunk();
            ctl.observe_step(latency(c));
        }
        // 2 candidates × 1 probe per round; rounds every 4 exploit steps
        assert!(explored_steps >= 8, "explored {explored_steps}");
    }

    #[test]
    fn non_adaptive_never_changes() {
        let mut ctl = ChunkController::new(vec![8, 16], 16, 4, 1, false);
        for _ in 0..50 {
            let c = ctl.chunk();
            assert_eq!(c, 16);
            ctl.observe_step(latency(c));
        }
        assert!(ctl.history.is_empty());
    }

    #[test]
    fn probes_each_candidate_equally() {
        let mut ctl = ChunkController::new(vec![4, 8, 16], 4, 6, 2, true);
        let mut probes = std::collections::HashMap::new();
        for _ in 0..(6 + 3 * 2) {
            if ctl.exploring() {
                *probes.entry(ctl.chunk()).or_insert(0) += 1;
            }
            let c = ctl.chunk();
            ctl.observe_step(latency(c));
        }
        assert_eq!(probes.len(), 3);
        assert!(probes.values().all(|&n| n == 2), "{probes:?}");
    }

    #[test]
    #[should_panic]
    fn initial_must_be_candidate() {
        ChunkController::new(vec![8, 16], 32, 10, 1, true);
    }

    #[test]
    fn try_new_rejects_uncompiled_candidates() {
        let err = ChunkController::try_new(vec![8, 24], 8, 10, 1, true, &[8, 16, 32])
            .unwrap_err()
            .to_string();
        assert!(err.contains("candidate 24"), "{err}");
        assert!(err.contains("[8, 16, 32]"), "{err}");
    }

    #[test]
    fn try_new_accepts_compiled_subset_and_rejects_bad_period() {
        let ctl = ChunkController::try_new(vec![8, 32], 32, 10, 2, true, &[8, 16, 32]).unwrap();
        assert_eq!(ctl.chunk(), 32);
        assert!(ChunkController::try_new(vec![8, 32], 8, 3, 2, true, &[8, 16, 32]).is_err());
        assert!(ChunkController::try_new(vec![8, 32], 16, 10, 1, true, &[8, 16, 32]).is_err());
    }
}
