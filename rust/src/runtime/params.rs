//! Parameter sets: raw `params_*.bin` → device-resident buffer lists.
//!
//! A [`ParamSet`] is the opaque `Vec<PjRtBuffer>` threaded through the AOT
//! entry points.  `ppo_update` returns fresh param/optimizer buffers; the
//! trainer swaps them in without any host copy (the weights live on device
//! for the entire run).

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use super::engine::Engine;

/// One model's parameters (or one Adam moment set) on device, in the
/// canonical manifest order.
pub struct ParamSet {
    bufs: Vec<PjRtBuffer>,
}

impl ParamSet {
    /// Load `params_<which>.bin` (which ∈ actor|reward|ref) onto the device.
    pub fn load(engine: &Engine, which: &str) -> Result<Self> {
        Self::from_bytes(engine, &Self::raw_bytes(engine, which)?)
    }

    /// The raw on-disk blob for one model's parameters — the unit the
    /// transport layer distributes to remote replicas (digest-verified, so
    /// every node provably loads identical weights).
    pub fn raw_bytes(engine: &Engine, which: &str) -> Result<Vec<u8>> {
        let m = engine.manifest();
        let file = m
            .params_files
            .get(which)
            .with_context(|| format!("no params file for {which:?} in manifest"))?;
        let path = m.dir.join(file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != m.params_bytes() {
            bail!(
                "{}: {} bytes on disk, manifest says {}",
                path.display(), bytes.len(), m.params_bytes()
            );
        }
        Ok(bytes)
    }

    /// Upload a raw parameter blob (disk layout) onto the device — the
    /// receive half of remote param distribution.
    pub fn from_bytes(engine: &Engine, bytes: &[u8]) -> Result<Self> {
        let m = engine.manifest();
        if bytes.len() != m.params_bytes() {
            bail!("param blob is {} bytes, manifest says {}", bytes.len(), m.params_bytes());
        }
        let mut bufs = Vec::with_capacity(m.param_table.len());
        for spec in &m.param_table {
            let raw = &bytes[spec.offset..spec.offset + spec.bytes];
            // params are little-endian f32 (native on all supported targets)
            let floats: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            bufs.push(engine.upload_f32(&floats, &spec.shape)?);
        }
        Ok(Self { bufs })
    }

    /// Zero-initialized set with the same shapes (Adam m/v).
    pub fn zeros_like(engine: &Engine) -> Result<Self> {
        let m = engine.manifest();
        let mut bufs = Vec::with_capacity(m.param_table.len());
        for spec in &m.param_table {
            bufs.push(engine.zeros_f32(&spec.shape)?);
        }
        Ok(Self { bufs })
    }

    /// Wrap buffers returned by an update entry (must match the table arity).
    pub fn from_bufs(engine: &Engine, bufs: Vec<PjRtBuffer>) -> Result<Self> {
        if bufs.len() != engine.manifest().param_table.len() {
            bail!(
                "param set arity {} != manifest {}",
                bufs.len(), engine.manifest().param_table.len()
            );
        }
        Ok(Self { bufs })
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    pub fn bufs(&self) -> &[PjRtBuffer] {
        &self.bufs
    }

    /// Download one named parameter (tests / debugging).
    pub fn download(&self, engine: &Engine, name: &str) -> Result<Vec<f32>> {
        let idx = engine
            .manifest()
            .param_table
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("no param named {name:?}"))?;
        engine.download_f32(&self.bufs[idx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("manifest.json")
            .exists()
            .then(|| Engine::load(dir).unwrap())
    }

    #[test]
    fn actor_and_ref_params_are_identical() {
        let Some(e) = engine() else { return };
        let actor = ParamSet::load(&e, "actor").unwrap();
        let refm = ParamSet::load(&e, "ref").unwrap();
        let a = actor.download(&e, "embed").unwrap();
        let r = refm.download(&e, "embed").unwrap();
        assert_eq!(a, r);
        let reward = ParamSet::load(&e, "reward").unwrap();
        let w = reward.download(&e, "embed").unwrap();
        assert_ne!(a, w);
    }

    #[test]
    fn zeros_like_is_zero() {
        let Some(e) = engine() else { return };
        let z = ParamSet::zeros_like(&e).unwrap();
        assert_eq!(z.len(), e.manifest().param_table.len());
        let x = z.download(&e, "embed").unwrap();
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ln_scales_initialized_to_one() {
        let Some(e) = engine() else { return };
        let actor = ParamSet::load(&e, "actor").unwrap();
        let s = actor.download(&e, "l00_ln1_s").unwrap();
        assert!(s.iter().all(|&v| v == 1.0));
    }
}
