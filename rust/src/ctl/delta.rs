//! Dynamic Δ controller — inter-step overcommitment adaptation (§3.2).
//!
//! The paper specifies the controller twice, with *opposite signs*:
//!
//! * **Eq. (4)** (+ surrounding prose): reward slope `s_t > 0` ⇒ *increase*
//!   Δ (training is healthy, buy throughput); `s_t <= 0` ⇒ *decrease*
//!   toward `Δ_min` ("as training starts to converge … Δ naturally decays
//!   toward Δ_min, preventing overcommitment to ensure convergence").
//! * **Algorithm 1, lines 21-27**: `Δ ← clip(Δ − sign(d)·Δ_change, …)` —
//!   literally the opposite direction.
//!
//! The prose argument and the ablation (Fig. 7a: dynamic Δ decays as rollout
//! lengths stabilize) are only consistent with the Eq. (4) reading, so that
//! is the default here; `Policy::Alg1Literal` implements the pseudocode
//! verbatim for comparison (the discrepancy is called out in DESIGN.md and
//! exercised by `benches/fig7_adaptation`).
//!
//! Step size follows Alg. 1's adaptive magnitude `max(1, Δ/4)`, and the
//! window bookkeeping is Alg. 1's: act only when `2W` rewards accumulated,
//! then keep the last `W`.

use crate::util::stats;

/// Direction convention for the Δ update (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Eq. (4): improving reward ⇒ grow Δ; flat/declining ⇒ shrink.
    Eq4,
    /// Algorithm 1 line 24, taken literally (opposite sign).
    Alg1Literal,
    /// Fixed Δ (the paper's fixed-Δ ablation arms, Fig. 7a).
    Fixed,
}

/// Windowed-trend Δ controller.
#[derive(Clone, Debug)]
pub struct DeltaController {
    delta: usize,
    delta_min: usize,
    delta_max: usize,
    window: usize,
    policy: Policy,
    rewards: Vec<f64>,
    /// adaptation log: (step_index, new_delta) for tests / benches
    pub history: Vec<(u64, usize)>,
}

impl DeltaController {
    pub fn new(
        delta_init: usize,
        delta_min: usize,
        delta_max: usize,
        window: usize,
        policy: Policy,
    ) -> Self {
        assert!(delta_min <= delta_init && delta_init <= delta_max);
        assert!(window >= 1);
        Self {
            delta: delta_init,
            delta_min,
            delta_max,
            window,
            policy,
            rewards: Vec::new(),
            history: Vec::new(),
        }
    }

    pub fn delta(&self) -> usize {
        self.delta
    }

    pub fn bounds(&self) -> (usize, usize) {
        (self.delta_min, self.delta_max)
    }

    /// Feed one step's mean reward (Alg. 1 line 18); maybe adapt Δ
    /// (lines 21-27).  Returns the (possibly unchanged) Δ.
    pub fn observe(&mut self, step: u64, mean_reward: f64) -> usize {
        self.rewards.push(mean_reward);
        if self.policy == Policy::Fixed {
            return self.delta;
        }
        let w = self.window;
        if self.rewards.len() >= 2 * w {
            let n = self.rewards.len();
            let recent = stats::mean(&self.rewards[n - w..]);
            let previous = stats::mean(&self.rewards[n - 2 * w..n - w]);
            let d = recent - previous;
            let change = (self.delta / 4).max(1);
            let signed: isize = match (self.policy, d > 0.0) {
                (Policy::Eq4, true) => change as isize, // improving → grow
                (Policy::Eq4, false) => -(change as isize),
                (Policy::Alg1Literal, true) => -(change as isize),
                (Policy::Alg1Literal, false) => change as isize,
                (Policy::Fixed, _) => 0,
            };
            let new = (self.delta as isize + signed)
                .clamp(self.delta_min as isize, self.delta_max as isize) as usize;
            if new != self.delta {
                self.history.push((step, new));
            }
            self.delta = new;
            // Alg. 1 line 26: keep only the trailing window
            self.rewards.drain(..n - w);
        }
        self.delta
    }

    /// Number of rewards currently buffered (test hook).
    pub fn window_fill(&self) -> usize {
        self.rewards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_while_improving_eq4() {
        let mut c = DeltaController::new(2, 0, 8, 3, Policy::Eq4);
        for step in 0..30 {
            c.observe(step, step as f64 * 0.1); // strictly improving
        }
        assert!(c.delta() > 2, "delta {}", c.delta());
        assert!(c.delta() <= 8);
    }

    #[test]
    fn decays_to_min_at_convergence_eq4() {
        let mut c = DeltaController::new(6, 1, 8, 3, Policy::Eq4);
        for step in 0..40 {
            c.observe(step, 4.0); // flat — converged
        }
        assert_eq!(c.delta(), 1, "Δ must decay to Δ_min at convergence");
    }

    #[test]
    fn alg1_literal_is_opposite() {
        let mut up = DeltaController::new(4, 0, 8, 3, Policy::Eq4);
        let mut dn = DeltaController::new(4, 0, 8, 3, Policy::Alg1Literal);
        for step in 0..18 {
            up.observe(step, step as f64);
            dn.observe(step, step as f64);
        }
        assert!(up.delta() > 4);
        assert!(dn.delta() < 4);
    }

    #[test]
    fn fixed_policy_never_moves() {
        let mut c = DeltaController::new(4, 0, 8, 2, Policy::Fixed);
        for step in 0..50 {
            c.observe(step, (step as f64).sin());
        }
        assert_eq!(c.delta(), 4);
        assert!(c.history.is_empty());
    }

    #[test]
    fn bounds_are_respected() {
        let mut c = DeltaController::new(8, 0, 8, 2, Policy::Eq4);
        for step in 0..40 {
            c.observe(step, step as f64); // improving forever
        }
        assert_eq!(c.delta(), 8);
        let mut c = DeltaController::new(0, 0, 8, 2, Policy::Eq4);
        for step in 0..40 {
            c.observe(step, -(step as f64));
        }
        assert_eq!(c.delta(), 0);
    }

    #[test]
    fn step_magnitude_is_adaptive() {
        // Δ = 8 → change = max(1, 2) = 2 per adaptation
        let mut c = DeltaController::new(8, 0, 16, 2, Policy::Eq4);
        for step in 0..4 {
            c.observe(step, -(step as f64));
        }
        assert_eq!(c.delta(), 6);
    }

    #[test]
    fn window_bookkeeping_matches_alg1() {
        let mut c = DeltaController::new(2, 0, 8, 4, Policy::Eq4);
        for step in 0..7 {
            c.observe(step, 0.0);
        }
        assert_eq!(c.window_fill(), 7); // not yet 2W
        c.observe(7, 0.0); // hits 2W = 8 → adapt + truncate to W
        assert_eq!(c.window_fill(), 4);
    }
}
