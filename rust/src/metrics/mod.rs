//! Run metrics: per-step records, deferral accounting (Table 2), and JSON
//! export for the bench harness / examples.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};
use crate::util::stats;

/// One pipeline stage's share of a step (reward / ref / future stages).
/// `busy_s` is time inside the stage's compute, `idle_s` time the stage
/// worker spent waiting for work — the per-stage attribution behind the
/// Fig. 5-style utilization analysis.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageTiming {
    pub name: String,
    /// worker replicas behind this stage (pool size; 1 for a single
    /// worker).  `busy_s`/`idle_s`/`items` are summed across replicas, so
    /// `busy_s` may legitimately exceed the step's wall time when > 1.
    pub replicas: usize,
    pub busy_s: f64,
    pub idle_s: f64,
    /// requests (streamed chunks / scoring calls) the stage processed
    pub items: u64,
}

/// One PPO step's telemetry.
#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: u64,
    /// wall-clock duration of the step (seconds)
    pub wall_s: f64,
    /// cumulative wall-clock since run start (seconds)
    pub elapsed_s: f64,
    /// mean sequence score of the PPO batch (Alg. 1's reward signal)
    pub mean_score: f64,
    /// current overcommitment Δ
    pub delta: usize,
    /// current streaming chunk size C
    pub chunk: usize,
    /// sequences finished this step / left unfinished (deferred)
    pub finished: usize,
    pub deferred: usize,
    /// generated tokens this step (throughput accounting)
    pub gen_tokens: usize,
    /// ppo_update stats: [loss, pg, v_loss, entropy, approx_kl, clip_frac]
    pub train_stats: [f32; 6],
    /// utilization for the step, in (0, 1] when stages ran.  Real runs
    /// report stage-worker utilization — busy/(busy+idle) aggregated over
    /// `stages`; simulator runs report the cluster-level activity model.
    /// 0 = no stage workers (e.g. DPO).
    pub util: f64,
    /// per-stage busy/idle attribution for the step: one row per streaming
    /// sink, plus the monolithic reward scorer when that path is active
    /// (so even the sequential baseline reports a "reward" row); empty when
    /// no stage workers exist (e.g. DPO)
    pub stages: Vec<StageTiming>,
}

/// Whole-run log for one pipeline mode.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub mode: String,
    pub task: String,
    pub seed: u64,
    pub records: Vec<StepRecord>,
    /// deferral histogram: steps-deferred -> request count (Table 2)
    pub deferral_hist: BTreeMap<u64, u64>,
}

impl RunLog {
    pub fn new(mode: &str, task: &str, seed: u64) -> Self {
        Self { mode: mode.into(), task: task.into(), seed, ..Default::default() }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    pub fn record_deferral(&mut self, steps: u64) {
        *self.deferral_hist.entry(steps).or_insert(0) += 1;
    }

    pub fn total_wall_s(&self) -> f64 {
        self.records.last().map(|r| r.elapsed_s).unwrap_or(0.0)
    }

    pub fn scores(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.mean_score).collect()
    }

    /// First elapsed time at which the trailing-`w` mean score reaches
    /// `target` (the paper's *time-to-reward*); None if never.
    pub fn time_to_reward(&self, target: f64, w: usize) -> Option<f64> {
        let scores = self.scores();
        for i in 0..scores.len() {
            let lo = (i + 1).saturating_sub(w);
            if stats::mean(&scores[lo..=i]) >= target {
                return Some(self.records[i].elapsed_s);
            }
        }
        None
    }

    /// First step index at which the trailing-`w` mean score reaches
    /// `target` (the paper's *step-to-reward*).
    pub fn step_to_reward(&self, target: f64, w: usize) -> Option<u64> {
        let scores = self.scores();
        for i in 0..scores.len() {
            let lo = (i + 1).saturating_sub(w);
            if stats::mean(&scores[lo..=i]) >= target {
                return Some(self.records[i].step);
            }
        }
        None
    }

    /// Deferral distribution as (steps, share) rows plus the mean —
    /// Table 2's exact format.
    pub fn deferral_distribution(&self) -> (Vec<(u64, f64)>, f64) {
        let total: u64 = self.deferral_hist.values().sum();
        if total == 0 {
            return (vec![], 0.0);
        }
        let rows = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| (k, v as f64 / total as f64))
            .collect();
        let mean = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| k as f64 * v as f64)
            .sum::<f64>()
            / total as f64;
        (rows, mean)
    }

    pub fn to_json(&self) -> Value {
        let records: Vec<Value> = self
            .records
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("step", json::num(r.step as f64)),
                    ("wall_s", json::num(r.wall_s)),
                    ("elapsed_s", json::num(r.elapsed_s)),
                    ("mean_score", json::num(r.mean_score)),
                    ("delta", json::num(r.delta as f64)),
                    ("chunk", json::num(r.chunk as f64)),
                    ("finished", json::num(r.finished as f64)),
                    ("deferred", json::num(r.deferred as f64)),
                    ("gen_tokens", json::num(r.gen_tokens as f64)),
                    ("util", json::num(r.util)),
                    (
                        "train_stats",
                        json::arr_f64(&r.train_stats.map(|x| x as f64)),
                    ),
                    (
                        "stages",
                        Value::Arr(
                            r.stages
                                .iter()
                                .map(|st| {
                                    json::obj(vec![
                                        ("name", json::s(&st.name)),
                                        ("replicas", json::num(st.replicas as f64)),
                                        ("busy_s", json::num(st.busy_s)),
                                        ("idle_s", json::num(st.idle_s)),
                                        ("items", json::num(st.items as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let hist: Vec<Value> = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| json::arr_f64(&[k as f64, v as f64]))
            .collect();
        json::obj(vec![
            ("mode", json::s(&self.mode)),
            ("task", json::s(&self.task)),
            ("seed", json::num(self.seed as f64)),
            ("records", Value::Arr(records)),
            ("deferral_hist", Value::Arr(hist)),
        ])
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with_scores(scores: &[f64]) -> RunLog {
        let mut log = RunLog::new("oppo", "arith", 0);
        for (i, &sc) in scores.iter().enumerate() {
            log.push(StepRecord {
                step: i as u64,
                wall_s: 1.0,
                elapsed_s: (i + 1) as f64,
                mean_score: sc,
                ..Default::default()
            });
        }
        log
    }

    #[test]
    fn time_and_step_to_reward() {
        let log = log_with_scores(&[0.0, 0.2, 0.5, 0.9, 0.95]);
        assert_eq!(log.time_to_reward(0.85, 1), Some(4.0));
        assert_eq!(log.step_to_reward(0.85, 1), Some(3));
        assert_eq!(log.time_to_reward(2.0, 1), None);
        // windowed: mean of last 2 must reach target
        assert_eq!(log.step_to_reward(0.7, 2), Some(3));
    }

    #[test]
    fn deferral_distribution_matches_counts() {
        let mut log = RunLog::new("oppo", "arith", 0);
        for _ in 0..78 {
            log.record_deferral(0);
        }
        for _ in 0..20 {
            log.record_deferral(1);
        }
        for _ in 0..2 {
            log.record_deferral(3);
        }
        let (rows, mean) = log.deferral_distribution();
        assert_eq!(rows[0].0, 0);
        assert!((rows[0].1 - 0.78).abs() < 1e-9);
        assert!((mean - (20.0 + 6.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let mut log = log_with_scores(&[0.1, 0.4]);
        log.record_deferral(0);
        log.record_deferral(1);
        let v = log.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("mode").unwrap().as_str().unwrap(), "oppo");
        assert_eq!(back.get("records").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn write_json_creates_dirs() {
        let dir = std::env::temp_dir().join("oppo_test_metrics");
        let _ = std::fs::remove_dir_all(&dir);
        let log = log_with_scores(&[0.5]);
        let path = dir.join("nested/run.json");
        log.write_json(&path).unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
