//! Generic pipeline-stage worker — the runtime every downstream model
//! (reward, reference, and future critic / sharded-replica stages) plugs
//! into.
//!
//! The paper frames intra-step overlap (§3.1) as model-agnostic: *any*
//! downstream consumer of actor output can prefill incrementally while the
//! actor keeps decoding.  [`StageWorker`] is that contract as code: one OS
//! thread per stage, a **bounded** request queue (submitting past
//! `queue_depth` in-flight requests applies backpressure to the producer
//! instead of buffering unboundedly), **tagged** requests so multiple
//! chunks can be in flight concurrently and responses remain attributable,
//! and per-stage busy/idle counters so step records can show where wall
//! time went (the Fig. 5 utilization attribution).
//!
//! The handler is constructed *on the worker thread* via the `init`
//! closure — device state (parameter buffers, KV caches) therefore never
//! crosses threads, only plain `Send` request/response values do.  Dropping
//! a [`StageWorker`] sends a shutdown, disconnects the queue, and joins the
//! thread, so a scheduler dropped mid-test (e.g. on an error path) never
//! leaks the worker or deadlocks on channel teardown.
//!
//! [`StagePool`] replicates a stage: N workers behind one facade, with
//! **sequence-affinity routing** (`lane % replicas`) so every chunk of one
//! sequence lands on the replica that holds its KV/seam state.  Once a
//! single reward or ref worker can no longer keep pace with the actor's
//! streamed chunks, replicas are the scaling lever that keeps §3.1's
//! overlap actor-bound instead of downstream-bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::metrics::StageTiming;

/// A stage's request processor, constructed and driven on the worker thread.
pub trait StageHandler {
    type Req: Send + 'static;
    type Resp: Send + 'static;

    /// Process one request.  Errors are reported back to the submitter of
    /// that request; the worker keeps serving subsequent requests.
    fn handle(&mut self, req: Self::Req) -> Result<Self::Resp>;
}

/// Cumulative counters for one stage (lock-free; shared with the worker).
#[derive(Default)]
pub struct StageStats {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// nanoseconds spent inside `handle`
    pub busy_nanos: AtomicU64,
    /// nanoseconds spent waiting for the next request
    pub idle_nanos: AtomicU64,
}

enum Msg<Req> {
    Job(u64, Req),
    Shutdown,
}

/// Handle to one pipeline-stage worker thread.
pub struct StageWorker<Req, Resp> {
    name: &'static str,
    tx: Option<SyncSender<Msg<Req>>>,
    rx: Receiver<(u64, std::result::Result<Resp, String>)>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<StageStats>,
    next_tag: u64,
    in_flight: usize,
    // counters at the last `timing_delta` call (per-step reporting)
    last_busy: u64,
    last_idle: u64,
    last_items: u64,
}

impl<Req: Send + 'static, Resp: Send + 'static> StageWorker<Req, Resp> {
    /// Spawn a stage worker.  `init` runs on the new thread and builds the
    /// handler (loading params, allocating device state); its failure is
    /// reported through the first `recv` rather than panicking the thread.
    pub fn spawn<H, F>(name: &'static str, queue_depth: usize, init: F) -> Result<Self>
    where
        H: StageHandler<Req = Req, Resp = Resp> + 'static,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        let (tx, req_rx) = sync_channel::<Msg<Req>>(queue_depth.max(1));
        let (resp_tx, rx) = channel();
        let stats = Arc::new(StageStats::default());
        let thread_stats = stats.clone();
        let handle = std::thread::Builder::new()
            .name(format!("stage-{name}"))
            .spawn(move || worker_main(init, req_rx, resp_tx, thread_stats))
            .with_context(|| format!("spawning stage worker {name:?}"))?;
        Ok(Self {
            name,
            tx: Some(tx),
            rx,
            handle: Some(handle),
            stats,
            next_tag: 0,
            in_flight: 0,
            last_busy: 0,
            last_idle: 0,
            last_items: 0,
        })
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Requests submitted but not yet received.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Enqueue a request; blocks only when `queue_depth` requests are
    /// already waiting (bounded-queue backpressure).  Returns the tag that
    /// will come back with the response.
    pub fn submit(&mut self, req: Req) -> Result<u64> {
        let tag = self.next_tag;
        let tx = self.tx.as_ref().context("stage worker already shut down")?;
        if tx.send(Msg::Job(tag, req)).is_err() {
            bail!("stage {} worker hung up", self.name);
        }
        self.next_tag += 1;
        self.in_flight += 1;
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(tag)
    }

    /// Non-blocking submit: `Ok(Ok(tag))` when enqueued, `Ok(Err(req))` —
    /// handing the request back — when the queue is full.  Lets a producer
    /// feed other workers before blocking on a busy one.
    pub fn try_submit(&mut self, req: Req) -> Result<std::result::Result<u64, Req>> {
        let tag = self.next_tag;
        let tx = self.tx.as_ref().context("stage worker already shut down")?;
        match tx.try_send(Msg::Job(tag, req)) {
            Ok(()) => {
                self.next_tag += 1;
                self.in_flight += 1;
                self.stats.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(Ok(tag))
            }
            Err(std::sync::mpsc::TrySendError::Full(Msg::Job(_, req))) => Ok(Err(req)),
            Err(std::sync::mpsc::TrySendError::Full(Msg::Shutdown)) => {
                unreachable!("try_submit only sends jobs")
            }
            Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
                bail!("stage {} worker hung up", self.name)
            }
        }
    }

    /// Block for the next response (submission order).
    pub fn recv(&mut self) -> Result<(u64, Resp)> {
        ensure!(self.in_flight > 0, "stage {}: recv with nothing in flight", self.name);
        let (tag, resp) = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("stage {} worker hung up", self.name))?;
        self.in_flight -= 1;
        match resp {
            Ok(r) => Ok((tag, r)),
            Err(e) => bail!("stage {} error: {e}", self.name),
        }
    }

    /// Non-blocking receive; `Ok(None)` when no response is ready.
    pub fn try_recv(&mut self) -> Result<Option<(u64, Resp)>> {
        if self.in_flight == 0 {
            return Ok(None);
        }
        match self.rx.try_recv() {
            Ok((tag, resp)) => {
                self.in_flight -= 1;
                match resp {
                    Ok(r) => Ok(Some((tag, r))),
                    Err(e) => bail!("stage {} error: {e}", self.name),
                }
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("stage {} worker hung up", self.name),
        }
    }

    /// Non-blocking receive that hands back per-request handler errors as
    /// values instead of bailing — the failover path needs to know *which*
    /// request failed without tearing down the whole receive loop.
    pub fn try_recv_result(
        &mut self,
    ) -> Result<Option<(u64, std::result::Result<Resp, String>)>> {
        if self.in_flight == 0 {
            return Ok(None);
        }
        match self.rx.try_recv() {
            Ok((tag, resp)) => {
                self.in_flight -= 1;
                Ok(Some((tag, resp)))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("stage {} worker hung up", self.name),
        }
    }

    /// Blocking flavour of [`try_recv_result`](Self::try_recv_result).
    pub fn recv_result(&mut self) -> Result<(u64, std::result::Result<Resp, String>)> {
        ensure!(self.in_flight > 0, "stage {}: recv with nothing in flight", self.name);
        let (tag, resp) = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("stage {} worker hung up", self.name))?;
        self.in_flight -= 1;
        Ok((tag, resp))
    }

    /// Abandon every in-flight request: drain whatever responses already
    /// arrived (discarding them) and zero the in-flight count.  Used when a
    /// replica is retired — its queued work is lost by definition and will
    /// be replayed elsewhere; the worker thread itself keeps answering (and
    /// being ignored) until dropped.
    pub fn abandon_in_flight(&mut self) -> usize {
        let abandoned = self.in_flight;
        while self.rx.try_recv().is_ok() {}
        self.in_flight = 0;
        abandoned
    }

    /// Cumulative stats handle.
    pub fn stats(&self) -> &Arc<StageStats> {
        &self.stats
    }

    /// Busy/idle/items accumulated since the previous call — one PPO step's
    /// worth when called once per step.
    pub fn timing_delta(&mut self) -> StageTiming {
        let busy = self.stats.busy_nanos.load(Ordering::Relaxed);
        let idle = self.stats.idle_nanos.load(Ordering::Relaxed);
        let items = self.stats.completed.load(Ordering::Relaxed);
        let out = StageTiming {
            name: self.name.to_string(),
            replicas: 1,
            busy_s: (busy - self.last_busy) as f64 * 1e-9,
            idle_s: (idle - self.last_idle) as f64 * 1e-9,
            items: items - self.last_items,
        };
        self.last_busy = busy;
        self.last_idle = idle;
        self.last_items = items;
        out
    }

    /// Graceful shutdown (also performed by `Drop`).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }
}

// unbounded impl: `Drop` has no `Send`/`'static` bounds, so the shared
// teardown lives where both it and `shutdown` can call it
impl<Req, Resp> StageWorker<Req, Resp> {
    fn shutdown_impl(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.try_send(Msg::Shutdown);
            // dropping the sender disconnects the queue, so the worker exits
            // even if the shutdown message found the queue full
        }
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl<Req, Resp> Drop for StageWorker<Req, Resp> {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// replicated stage pool
// ---------------------------------------------------------------------------

/// N [`StageWorker`] replicas behind one submit/recv facade.
///
/// * **Sequence-affinity routing** — [`replica_for_lane`](Self::replica_for_lane)
///   is `lane % replicas` and static for the whole run, so every chunk of a
///   sequence reaches the replica that holds its KV/seam state; no two
///   chunks of one sequence can ever land on different replicas.
/// * **Per-replica bounded queues** — each replica keeps its own
///   `queue_depth`-bounded request queue; [`submit_to`](Self::submit_to)
///   blocks when that replica's queue is full.  A fan-out producer should
///   use [`try_submit_to`](Self::try_submit_to) first so a busy replica
///   delays only its own feeding, then block on the stragglers — the
///   producer still cannot outrun the slowest replica by more than its
///   queue depth (that *is* the backpressure), but fast replicas receive
///   their work before the producer parks.
/// * **Per-replica stats** — every replica keeps its own [`StageStats`];
///   [`timing_delta`](Self::timing_delta) sums them into one pool-level
///   [`StageTiming`] row (`replicas` records the pool size).
/// * **Failover routing** — [`route`](Self::retire) starts as the identity
///   (slot *s* → replica *s*) and is rewritten when a replica is retired:
///   its slots re-home onto a survivor, which then receives those lanes'
///   replayed chunks and all their future traffic.  Rerouting only works on
///   the masked full-shape path (a compacted `[G/N, C]` grid has a fixed
///   row ↔ lane binding baked into its KV state), which callers enforce.
pub struct StagePool<Req, Resp> {
    workers: Vec<StageWorker<Req, Resp>>,
    /// slot → replica.  A lane's slot is `lane % route.len()`; the routing
    /// rule is `route[lane % slots]`.  Identity until a retire.
    route: Vec<usize>,
    /// replicas permanently removed from service (transport death)
    dead: Vec<bool>,
}

impl<Req: Send + 'static, Resp: Send + 'static> StagePool<Req, Resp> {
    /// Spawn `replicas` workers.  `factory(r)` builds replica `r`'s init
    /// closure; each init runs on its own worker thread and constructs an
    /// **independent** handler (own parameters, own device state) — replicas
    /// share nothing except whatever handle the factory clones into them.
    pub fn spawn<H, F, M>(
        name: &'static str,
        replicas: usize,
        queue_depth: usize,
        mut factory: M,
    ) -> Result<Self>
    where
        H: StageHandler<Req = Req, Resp = Resp> + 'static,
        F: FnOnce() -> Result<H> + Send + 'static,
        M: FnMut(usize) -> F,
    {
        ensure!(replicas >= 1, "stage {name}: a pool needs at least one replica");
        let workers = (0..replicas)
            .map(|r| StageWorker::spawn(name, queue_depth, factory(r)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { workers, route: (0..replicas).collect(), dead: vec![false; replicas] })
    }

    pub fn name(&self) -> &'static str {
        self.workers[0].name()
    }

    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// The routing rule: which replica owns `lane`'s KV/seam state.
    /// `lane % slots` picks the slot, the route table picks the replica —
    /// identical to plain `lane % replicas` until a retire rewrites it.
    pub fn replica_for_lane(&self, lane: usize) -> usize {
        self.route[lane % self.route.len()]
    }

    /// The slots currently routed to `replica` (empty once retired).
    pub fn slots_of(&self, replica: usize) -> Vec<usize> {
        (0..self.route.len()).filter(|&s| self.route[s] == replica).collect()
    }

    /// Is this replica still in service?
    pub fn is_alive(&self, replica: usize) -> bool {
        !self.dead[replica]
    }

    /// Replicas still in service.
    pub fn alive_count(&self) -> usize {
        self.dead.iter().filter(|d| !**d).count()
    }

    /// Has any retire rewritten the identity routing?
    pub fn rerouted(&self) -> bool {
        self.route.iter().enumerate().any(|(s, &r)| s != r)
    }

    /// Permanently remove `replica` from service: its slots re-home onto
    /// the first surviving replica, its in-flight requests are abandoned
    /// (the caller replays the lost lane data), and it will never be
    /// submitted to again.  Returns `(survivor, rerouted_slots)` — the
    /// slots whose lanes the caller must now replay onto the survivor.
    pub fn retire(&mut self, replica: usize) -> Result<(usize, Vec<usize>)> {
        ensure!(replica < self.workers.len(), "retire: replica {replica} out of range");
        ensure!(!self.dead[replica], "retire: replica {replica} already retired");
        let survivor = (0..self.workers.len())
            .find(|&r| r != replica && !self.dead[r])
            .with_context(|| {
                format!("stage {}: replica {replica} died with no survivor", self.name())
            })?;
        self.dead[replica] = true;
        let mut rerouted = Vec::new();
        for (slot, r) in self.route.iter_mut().enumerate() {
            if *r == replica {
                *r = survivor;
                rerouted.push(slot);
            }
        }
        let abandoned = self.workers[replica].abandon_in_flight();
        log::warn!(
            "stage {}: retired replica {replica} -> survivor {survivor} \
             ({} slots rerouted, {abandoned} in-flight requests abandoned)",
            self.name(),
            rerouted.len()
        );
        Ok((survivor, rerouted))
    }

    /// Enqueue on one replica; blocks only when that replica's bounded
    /// queue is full (per-replica backpressure).
    pub fn submit_to(&mut self, replica: usize, req: Req) -> Result<u64> {
        ensure!(
            replica < self.workers.len(),
            "replica {replica} out of range (pool has {})",
            self.workers.len()
        );
        ensure!(!self.dead[replica], "stage {}: submit to retired replica {replica}", self.name());
        self.workers[replica].submit(req)
    }

    /// Non-blocking enqueue: `Ok(Err(req))` hands the request back when the
    /// replica's queue is full, so the caller can feed the other replicas
    /// first and come back to block on this one.
    pub fn try_submit_to(
        &mut self,
        replica: usize,
        req: Req,
    ) -> Result<std::result::Result<u64, Req>> {
        ensure!(
            replica < self.workers.len(),
            "replica {replica} out of range (pool has {})",
            self.workers.len()
        );
        ensure!(!self.dead[replica], "stage {}: submit to retired replica {replica}", self.name());
        self.workers[replica].try_submit(req)
    }

    /// Two-phase fan-out of `(replica, request)` parts: try-submit each,
    /// then block on the ones whose bounded queue was full.  A busy replica
    /// delays only its own feeding; the caller still parks until every part
    /// is enqueued — that is the pool's backpressure onto the producer.
    /// Per-replica submission order always matches `parts` order: once a
    /// replica has a blocked part, its later parts queue behind it even if
    /// space frees up mid-loop (order-matched bookkeeping like the ref
    /// sink's meta FIFO depends on this).
    pub fn fan_out(&mut self, parts: Vec<(usize, Req)>) -> Result<()> {
        let mut blocked: Vec<(usize, Req)> = Vec::new();
        for (r, req) in parts {
            if blocked.iter().any(|(br, _)| *br == r) {
                blocked.push((r, req));
                continue;
            }
            if let Err(req) = self.try_submit_to(r, req)? {
                blocked.push((r, req));
            }
        }
        for (r, req) in blocked {
            self.submit_to(r, req)?;
        }
        Ok(())
    }

    /// Requests in flight across all replicas.
    pub fn in_flight(&self) -> usize {
        self.workers.iter().map(|w| w.in_flight()).sum()
    }

    pub fn in_flight_on(&self, replica: usize) -> usize {
        self.workers[replica].in_flight()
    }

    /// Non-blocking: the first ready response from any replica, tagged with
    /// the replica index.  Responses stay in submission order *per replica*.
    pub fn try_recv_any(&mut self) -> Result<Option<(usize, u64, Resp)>> {
        for (r, w) in self.workers.iter_mut().enumerate() {
            if self.dead[r] {
                continue;
            }
            if let Some((tag, resp)) = w.try_recv()? {
                return Ok(Some((r, tag, resp)));
            }
        }
        Ok(None)
    }

    /// Like [`try_recv_any`](Self::try_recv_any) but per-request handler
    /// errors come back as values tagged with their replica — the failover
    /// path's detection point.
    pub fn try_recv_any_result(
        &mut self,
    ) -> Result<Option<(usize, u64, std::result::Result<Resp, String>)>> {
        for (r, w) in self.workers.iter_mut().enumerate() {
            if self.dead[r] {
                continue;
            }
            if let Some((tag, resp)) = w.try_recv_result()? {
                return Ok(Some((r, tag, resp)));
            }
        }
        Ok(None)
    }

    /// Blocking receive from one replica with the per-request error as a
    /// value (see [`try_recv_any_result`](Self::try_recv_any_result)).
    pub fn recv_from_result(
        &mut self,
        replica: usize,
    ) -> Result<(u64, std::result::Result<Resp, String>)> {
        ensure!(
            replica < self.workers.len(),
            "replica {replica} out of range (pool has {})",
            self.workers.len()
        );
        self.workers[replica].recv_result()
    }

    /// Blocking receive from one replica (the flush join drains each
    /// replica in turn).
    pub fn recv_from(&mut self, replica: usize) -> Result<(u64, Resp)> {
        ensure!(
            replica < self.workers.len(),
            "replica {replica} out of range (pool has {})",
            self.workers.len()
        );
        self.workers[replica].recv()
    }

    /// One replica's cumulative stats handle.
    pub fn replica_stats(&self, replica: usize) -> &Arc<StageStats> {
        self.workers[replica].stats()
    }

    /// Pool-level timing since the previous call: per-replica busy/idle/item
    /// deltas summed into a single row.
    pub fn timing_delta(&mut self) -> StageTiming {
        let mut out = StageTiming {
            name: self.name().to_string(),
            replicas: self.workers.len(),
            ..Default::default()
        };
        for w in &mut self.workers {
            let t = w.timing_delta();
            out.busy_s += t.busy_s;
            out.idle_s += t.idle_s;
            out.items += t.items;
        }
        out
    }
}

fn worker_main<H, F>(
    init: F,
    rx: Receiver<Msg<H::Req>>,
    tx: Sender<(u64, std::result::Result<H::Resp, String>)>,
    stats: Arc<StageStats>,
) where
    H: StageHandler,
    F: FnOnce() -> Result<H>,
{
    let mut handler = match init() {
        Ok(h) => h,
        Err(e) => {
            // answer every request with the init failure, then exit
            let msg = format!("stage init: {e:#}");
            while let Ok(m) = rx.recv() {
                match m {
                    Msg::Job(tag, _) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        if tx.send((tag, Err(msg.clone()))).is_err() {
                            return;
                        }
                    }
                    Msg::Shutdown => return,
                }
            }
            return;
        }
    };
    loop {
        let wait = Instant::now();
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // producer dropped
        };
        stats.idle_nanos.fetch_add(wait.elapsed().as_nanos() as u64, Ordering::Relaxed);
        match msg {
            Msg::Shutdown => return,
            Msg::Job(tag, req) => {
                let t0 = Instant::now();
                let resp = handler.handle(req).map_err(|e| format!("{e:#}"));
                stats.busy_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.completed.fetch_add(1, Ordering::Relaxed);
                if resp.is_err() {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                }
                if tx.send((tag, resp)).is_err() {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    struct Echo {
        fail_on: Option<i32>,
        dropped: Option<Arc<AtomicBool>>,
    }

    impl StageHandler for Echo {
        type Req = i32;
        type Resp = i32;

        fn handle(&mut self, req: i32) -> Result<i32> {
            if self.fail_on == Some(req) {
                bail!("poisoned input {req}");
            }
            Ok(req * 2)
        }
    }

    impl Drop for Echo {
        fn drop(&mut self) {
            if let Some(flag) = &self.dropped {
                flag.store(true, Ordering::SeqCst);
            }
        }
    }

    fn echo(queue: usize) -> StageWorker<i32, i32> {
        StageWorker::spawn("echo", queue, || Ok(Echo { fail_on: None, dropped: None })).unwrap()
    }

    #[test]
    fn responses_are_tagged_and_in_order() {
        let mut w = echo(4);
        let tags: Vec<u64> = (0..5).map(|i| w.submit(i).unwrap()).collect();
        assert_eq!(tags, vec![0, 1, 2, 3, 4]);
        assert_eq!(w.in_flight(), 5);
        for i in 0..5 {
            let (tag, resp) = w.recv().unwrap();
            assert_eq!(tag, i as u64);
            assert_eq!(resp, i * 2);
        }
        assert_eq!(w.in_flight(), 0);
    }

    #[test]
    fn error_propagates_and_worker_survives() {
        let mut w = StageWorker::spawn("half-evil", 2, || {
            Ok(Echo { fail_on: Some(13), dropped: None })
        })
        .unwrap();
        w.submit(13).unwrap();
        let err = w.recv().unwrap_err();
        assert!(format!("{err:#}").contains("poisoned input 13"), "{err:#}");
        // the stage keeps serving after a per-request failure
        w.submit(4).unwrap();
        assert_eq!(w.recv().unwrap().1, 8);
        let stats = w.stats();
        assert_eq!(stats.errors.load(Ordering::Relaxed), 1);
        assert_eq!(stats.completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn drop_joins_thread_and_drops_handler() {
        let flag = Arc::new(AtomicBool::new(false));
        let thread_flag = flag.clone();
        let mut w: StageWorker<i32, i32> = StageWorker::spawn("dropper", 1, move || {
            Ok(Echo { fail_on: None, dropped: Some(thread_flag) })
        })
        .unwrap();
        w.submit(1).unwrap();
        assert_eq!(w.recv().unwrap().1, 2);
        drop(w); // must join: the handler is dropped on the worker thread
        assert!(flag.load(Ordering::SeqCst), "worker thread leaked past drop");
    }

    #[test]
    fn drop_with_requests_still_in_flight_does_not_deadlock() {
        let mut w = echo(1);
        for i in 0..3 {
            w.submit(i).unwrap(); // bounded queue: may block until consumed
        }
        drop(w); // responses never received — must still join cleanly
    }

    #[test]
    fn init_failure_is_reported_on_recv() {
        let mut w: StageWorker<i32, i32> =
            StageWorker::spawn("stillborn", 2, || -> Result<Echo> {
                bail!("no params on disk")
            })
            .unwrap();
        w.submit(7).unwrap();
        let err = w.recv().unwrap_err();
        assert!(format!("{err:#}").contains("stage init"), "{err:#}");
        assert!(format!("{err:#}").contains("no params on disk"), "{err:#}");
    }

    #[test]
    fn try_recv_is_nonblocking_and_drainable() {
        let mut w = echo(4);
        assert!(w.try_recv().unwrap().is_none()); // nothing in flight
        for i in 0..3 {
            w.submit(i).unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 3 {
            match w.try_recv().unwrap() {
                Some((_, r)) => got.push(r),
                None => std::thread::yield_now(),
            }
        }
        assert_eq!(got, vec![0, 2, 4]);
    }

    #[test]
    fn timing_delta_is_per_interval() {
        let mut w = echo(2);
        for i in 0..4 {
            w.submit(i).unwrap();
        }
        for _ in 0..4 {
            w.recv().unwrap();
        }
        let t1 = w.timing_delta();
        assert_eq!(t1.name, "echo");
        assert_eq!(t1.items, 4);
        assert!(t1.busy_s >= 0.0 && t1.idle_s >= 0.0);
        w.submit(9).unwrap();
        w.recv().unwrap();
        let t2 = w.timing_delta();
        assert_eq!(t2.items, 1, "delta must cover only the new interval");
    }

    #[test]
    fn try_submit_hands_back_the_request_when_the_queue_is_full() {
        // handler blocks on a gate, so the bounded queue fills deterministically
        struct Gated(std::sync::mpsc::Receiver<()>);
        impl StageHandler for Gated {
            type Req = i32;
            type Resp = i32;
            fn handle(&mut self, x: i32) -> Result<i32> {
                let _ = self.0.recv();
                Ok(x)
            }
        }
        let (gate_tx, gate_rx) = channel();
        let mut w: StageWorker<i32, i32> =
            StageWorker::spawn("gated", 1, move || Ok(Gated(gate_rx))).unwrap();
        let mut accepted: i32 = 0;
        loop {
            match w.try_submit(accepted).unwrap() {
                Ok(_) => accepted += 1,
                Err(req) => {
                    assert_eq!(req, accepted, "the rejected request comes back intact");
                    break;
                }
            }
            assert!(accepted <= 3, "depth-1 queue must report Full quickly");
        }
        assert!(accepted >= 1, "an empty queue must accept");
        assert_eq!(w.in_flight(), accepted as usize);
        for _ in 0..accepted {
            gate_tx.send(()).unwrap();
        }
        for i in 0..accepted {
            assert_eq!(w.recv().unwrap().1, i);
        }
        assert_eq!(w.in_flight(), 0);
    }

    #[test]
    fn pool_requires_at_least_one_replica() {
        let r: Result<StagePool<i32, i32>> =
            StagePool::spawn("empty", 0, 2, |_| || Ok(Echo { fail_on: None, dropped: None }));
        assert!(r.is_err());
    }

    #[test]
    fn pool_routes_lanes_stably_and_aggregates_timing() {
        let mut pool: StagePool<i32, i32> =
            StagePool::spawn("pool", 3, 2, |_| || Ok(Echo { fail_on: None, dropped: None }))
                .unwrap();
        assert_eq!(pool.replicas(), 3);
        // affinity: the mapping is a pure function of the lane
        for lane in 0..24 {
            assert_eq!(pool.replica_for_lane(lane), lane % 3);
            assert_eq!(pool.replica_for_lane(lane), pool.replica_for_lane(lane));
        }
        // fan a batch out by lane and drain each replica in turn
        for lane in 0..9i32 {
            let r = pool.replica_for_lane(lane as usize);
            pool.submit_to(r, lane).unwrap();
        }
        assert_eq!(pool.in_flight(), 9);
        let mut got = Vec::new();
        for r in 0..pool.replicas() {
            assert_eq!(pool.in_flight_on(r), 3);
            while pool.in_flight_on(r) > 0 {
                let (_, resp) = pool.recv_from(r).unwrap();
                got.push(resp);
            }
        }
        got.sort();
        assert_eq!(got, (0..9).map(|x| x * 2).collect::<Vec<_>>());
        // per-replica stats roll up into one pool-level row
        let t = pool.timing_delta();
        assert_eq!(t.name, "pool");
        assert_eq!(t.replicas, 3);
        assert_eq!(t.items, 9);
        for r in 0..pool.replicas() {
            assert_eq!(pool.replica_stats(r).completed.load(Ordering::Relaxed), 3);
        }
    }

    #[test]
    fn pool_try_recv_any_tags_the_replica() {
        let mut pool: StagePool<i32, i32> =
            StagePool::spawn("tagged", 2, 2, |_| || Ok(Echo { fail_on: None, dropped: None }))
                .unwrap();
        pool.submit_to(0, 10).unwrap();
        pool.submit_to(1, 20).unwrap();
        let mut seen = Vec::new();
        while seen.len() < 2 {
            match pool.try_recv_any().unwrap() {
                Some((r, _, resp)) => seen.push((r, resp)),
                None => std::thread::yield_now(),
            }
        }
        seen.sort();
        assert_eq!(seen, vec![(0, 20), (1, 40)]);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn pool_replicas_own_independent_handlers() {
        // each factory call builds a distinct handler: poison replica 0 only
        let mut pool: StagePool<i32, i32> = StagePool::spawn("mixed", 2, 2, |r| {
            move || Ok(Echo { fail_on: (r == 0).then_some(7), dropped: None })
        })
        .unwrap();
        pool.submit_to(0, 7).unwrap();
        assert!(pool.recv_from(0).is_err(), "replica 0 is poisoned on 7");
        pool.submit_to(1, 7).unwrap();
        assert_eq!(pool.recv_from(1).unwrap().1, 14, "replica 1 must not share state");
    }

    #[test]
    fn backpressure_bounded_queue_completes() {
        struct Slow;
        impl StageHandler for Slow {
            type Req = u32;
            type Resp = u32;
            fn handle(&mut self, req: u32) -> Result<u32> {
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(req + 1)
            }
        }
        let mut w = StageWorker::spawn("slow", 1, || Ok(Slow)).unwrap();
        for i in 0..6 {
            w.submit(i).unwrap(); // queue depth 1: submits beyond it block briefly
        }
        for i in 0..6 {
            assert_eq!(w.recv().unwrap().1, i + 1);
        }
    }
}
