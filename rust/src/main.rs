//! `oppo` — leader entrypoint for the OPPO reproduction.
//! See `oppo help` (or `rust/src/cli/mod.rs`) for the subcommands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = oppo::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
