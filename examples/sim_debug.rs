use oppo::sim::*;
use oppo::sim::pipeline::{simulate, steady_state_latency, Pipeline, SimConfig};
fn main() {
    let su = presets::stackex_7b_h200();
    // manual stage probe
    let cm = costmodel::CostModel { model: su.model, gpu: su.cluster.gpu, tp: 1.0,
        software_efficiency: su.gen_eff, iter_overhead_s: su.iter_overhead_s };
    let score_cm = costmodel::CostModel { model: su.model, gpu: su.cluster.gpu, tp: 1.0,
        software_efficiency: su.score_eff, iter_overhead_s: 0.0 };
    let train_cm = costmodel::CostModel { model: su.model, gpu: su.cluster.gpu, tp: 1.0,
        software_efficiency: su.train_eff, iter_overhead_s: 0.0 };
    let mut rng = oppo::util::rng::Rng::new(1);
    let lens = su.lengths.sample_batch(&mut rng, 0.3, su.batch);
    let maxlen = lens.iter().cloned().fold(0.0, f64::max);
    let meanlen: f64 = lens.iter().sum::<f64>() / lens.len() as f64;
    let t_iter = cm.decode_iter(su.batch as f64 / 7.0, 220.0 + meanlen);
    let total_tokens: f64 = lens.iter().map(|l| l + 220.0).sum();
    println!("median len {:.0} mean {meanlen:.0} max {maxlen:.0}", oppo::util::stats::percentile(&lens, 50.0));
    println!("t_iter {:.4}s  gen_to_mean {:.1}s gen_to_max {:.1}s", t_iter, meanlen*t_iter, maxlen*t_iter);
    println!("reward prefill {:.1}s ref+value {:.1}s train {:.1}s const {:.1}s",
        score_cm.prefill(total_tokens, meanlen),
        2.0*train_cm.prefill(total_tokens, meanlen)/7.0,
        train_cm.train_step(total_tokens, 7.0, 0.0), su.step_const_s);
    for (name, p) in [("trl", Pipeline::TrlSequential), ("oppo", Pipeline::oppo()),
                      ("no-intra", Pipeline::Oppo{intra:false,inter:true,fixed_delta:None}),
                      ("no-inter", Pipeline::Oppo{intra:true,inter:false,fixed_delta:None}),
                      ("verl-dp", Pipeline::VerlDp), ("verl-dp-sp", Pipeline::VerlDpSp),
                      ("verl-async-sp", Pipeline::VerlAsyncSp), ("areal", Pipeline::AReal)] {
        let cfg = SimConfig::new(su.clone(), 60, 1);
        let log = simulate(p, &cfg);
        println!("{name:14} steady latency {:.1}s util {:.2}", steady_state_latency(&log),
                 pipeline::steady_state_util(&log));
    }
}
