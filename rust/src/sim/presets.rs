//! The paper's experimental setups (§4.1), calibrated so the TRL baseline's
//! stage composition matches the behaviour the paper reports (scoring ≈
//! 15-25% of a step, heavy generation tails, framework overhead) — see
//! DESIGN.md §1 on why shape, not absolute seconds, is the reproduction
//! target.

use super::cluster::ClusterSetup;
use super::costmodel::ModelSpec;
use super::gpu::GpuSpec;
use super::lengths::{LengthModel, Phase};
use super::rewardmodel::RewardCurve;

/// One experiment's full parameterization.
#[derive(Clone, Debug)]
pub struct Setup {
    pub name: &'static str,
    pub model: ModelSpec,
    pub cluster: ClusterSetup,
    /// PPO batch size B (paper default 112)
    pub batch: usize,
    /// mean prompt length in tokens
    pub prompt_len: f64,
    pub lengths: LengthModel,
    pub reward: RewardCurve,
    /// time-to-reward measurement target (paper's reported reward)
    pub target_reward: f64,
    /// nominal total training steps (drives length-phase progress)
    pub total_steps: usize,
    /// software efficiencies (fraction of roofline) per stage
    pub gen_eff: f64,
    pub score_eff: f64,
    pub train_eff: f64,
    /// fixed per-step overhead (weight sync, dataloader, logging)
    pub step_const_s: f64,
    /// per-decode-iteration dispatch overhead
    pub iter_overhead_s: f64,
    /// per-streamed-chunk dispatch/context-switch cost (Fig. 7b left side)
    pub chunk_overhead_s: f64,
    /// generation slowdown when scoring shares the GPUs
    pub colocation_contention: f64,
    /// AReaL interruption/sync overhead
    pub areal_sync_overhead: f64,
    /// learned reward model (false ⇒ rule-based, GSM8K style)
    pub use_reward_model: bool,
    /// sequence-parallel tail speedup for the VeRL +SP arms
    pub sp_gain: f64,
    /// Δ_max for the dynamic controller (scales with tail heaviness: at
    /// B=112, a heavy-tailed task needs a deeper overcommit pool to skip
    /// all concurrent stragglers)
    pub delta_max: usize,
    /// calibrated Poisson arrival rate (prompts/second) for rolling-
    /// admission traffic simulation — set somewhat above the setup's
    /// steady-state service rate so the lanes stay loaded and queueing
    /// delay is visible (a serving-style workload, not training parity)
    pub arrival_rate: f64,
}

/// Stack-Exchange-Paired + Qwen2.5-7B-Instruct on 8×H200 (7 gen + 1 score).
pub fn stackex_7b_h200() -> Setup {
    Setup {
        name: "stackex-7b-h200",
        model: ModelSpec::QWEN25_7B,
        cluster: ClusterSetup::single_node(GpuSpec::H200, 7, 1),
        batch: 112,
        prompt_len: 220.0,
        lengths: LengthModel {
            warmup: Phase { mu: 6.05, sigma: 1.05 },
            converged: Phase { mu: 5.75, sigma: 0.85 },
            max_len: 4096.0,
        },
        reward: RewardCurve {
            r0: 0.2,
            plateau: 4.17,
            tau: 170.0,
            dip_depth: 0.0,
            dip_center: 0.0,
            dip_width: 1.0,
            noise: 0.04,
        },
        target_reward: 4.0,
        total_steps: 650,
        gen_eff: 0.30,
        score_eff: 0.07,
        train_eff: 0.35,
        step_const_s: 12.0,
        iter_overhead_s: 6e-3,
        chunk_overhead_s: 0.010,
        colocation_contention: 0.12,
        areal_sync_overhead: 0.12,
        use_reward_model: true,
        sp_gain: 1.6,
        delta_max: 12,
        arrival_rate: 1.5,
    }
}

/// Stack-Exchange-Paired + Qwen2.5-3B-Instruct on 8×A100-80GB.
pub fn stackex_3b_a100() -> Setup {
    Setup {
        name: "stackex-3b-a100",
        model: ModelSpec::QWEN25_3B,
        cluster: ClusterSetup::single_node(GpuSpec::A100_80, 7, 1),
        batch: 112,
        prompt_len: 220.0,
        lengths: LengthModel {
            // the 3B model rambles: heavier tails → bigger inter gains
            // (paper: 2.5× e2e, 2.06× inter-only)
            warmup: Phase { mu: 6.2, sigma: 1.25 },
            converged: Phase { mu: 5.9, sigma: 1.0 },
            max_len: 4096.0,
        },
        reward: RewardCurve {
            r0: 0.3,
            plateau: 5.12,
            tau: 260.0,
            dip_depth: 0.0,
            dip_center: 0.0,
            dip_width: 1.0,
            noise: 0.05,
        },
        target_reward: 5.0,
        total_steps: 1000,
        gen_eff: 0.30,
        score_eff: 0.07,
        train_eff: 0.35,
        step_const_s: 12.0,
        iter_overhead_s: 6e-3,
        chunk_overhead_s: 0.010,
        colocation_contention: 0.12,
        areal_sync_overhead: 0.12,
        use_reward_model: true,
        sp_gain: 1.6,
        delta_max: 16,
        arrival_rate: 2.0,
    }
}

/// GSM8K + Qwen2.5-7B (rule-based reward) on 4×GH200-96GB.
pub fn gsm8k_7b_gh200() -> Setup {
    Setup {
        name: "gsm8k-7b-gh200",
        model: ModelSpec::QWEN25_7B,
        // rule-based scoring: no dedicated reward GPU (colocated/none)
        cluster: ClusterSetup::single_node(GpuSpec::GH200_96, 4, 0),
        batch: 112,
        prompt_len: 180.0,
        lengths: LengthModel {
            // chain-of-thought math: the heaviest tail of the four tasks
            // (paper: 2.8×, the largest speedup)
            warmup: Phase { mu: 6.1, sigma: 1.45 },
            converged: Phase { mu: 5.9, sigma: 1.15 },
            max_len: 8192.0,
        },
        reward: RewardCurve {
            r0: 0.70,
            plateau: 0.82,
            tau: 70.0,
            dip_depth: 0.07,
            dip_center: 35.0,
            dip_width: 14.0,
            noise: 0.008,
        },
        target_reward: 0.80,
        total_steps: 200,
        gen_eff: 0.30,
        score_eff: 0.07,
        train_eff: 0.35,
        step_const_s: 10.0,
        iter_overhead_s: 6e-3,
        chunk_overhead_s: 0.010,
        colocation_contention: 0.12,
        areal_sync_overhead: 0.12,
        use_reward_model: false,
        sp_gain: 1.6,
        delta_max: 24,
        arrival_rate: 1.0,
    }
}

/// OpenCoder-SFT (Stage 2) + Qwen2.5-3B-Instruct on 8×A100-80GB.
pub fn opencoder_3b_a100() -> Setup {
    Setup {
        name: "opencoder-3b-a100",
        model: ModelSpec::QWEN25_3B,
        cluster: ClusterSetup::single_node(GpuSpec::A100_80, 7, 1),
        batch: 112,
        prompt_len: 300.0,
        lengths: LengthModel {
            warmup: Phase { mu: 6.3, sigma: 1.3 },
            converged: Phase { mu: 6.0, sigma: 1.05 },
            max_len: 6144.0,
        },
        reward: RewardCurve {
            r0: 0.5,
            plateau: 2.4,
            tau: 25.0,
            dip_depth: 0.0,
            dip_center: 0.0,
            dip_width: 1.0,
            noise: 0.03,
        },
        target_reward: 2.3,
        total_steps: 80,
        gen_eff: 0.30,
        score_eff: 0.07,
        train_eff: 0.35,
        step_const_s: 12.0,
        iter_overhead_s: 6e-3,
        chunk_overhead_s: 0.010,
        colocation_contention: 0.12,
        areal_sync_overhead: 0.12,
        use_reward_model: true,
        sp_gain: 1.6,
        delta_max: 16,
        arrival_rate: 2.0,
    }
}

/// Table 1's multi-node setting: StackEx-7B over 2 × 4×A100-40GB.
pub fn multinode_7b_a100_40() -> Setup {
    let mut s = stackex_7b_h200();
    s.name = "stackex-7b-2node-a100-40";
    s.cluster = ClusterSetup::two_node_a100_40();
    // cross-node NCCL + weight broadcast make the fixed overhead heavier,
    // and the straggler barrier now spans nodes
    s.step_const_s = 40.0;
    s.gen_eff = 0.22;
    s.lengths.warmup.sigma = 1.35;
    s.lengths.converged.sigma = 1.1;
    s.lengths.max_len = 8192.0;
    s.delta_max = 16;
    s
}

/// Table 4's controlled comparison: identical hardware and rollout settings
/// for all frameworks (milder tail than the e2e runs — the paper's Table 4
/// spread is ~1.26×, far narrower than the e2e speedups).
pub fn table4_setup() -> Setup {
    let mut s = stackex_7b_h200();
    s.name = "table4-7b-h200";
    s.lengths.warmup.sigma = 0.9;
    s.lengths.converged.sigma = 0.8;
    s.areal_sync_overhead = 0.18;
    s
}

/// Traffic-simulation variant of the StackEx-7B setup: rolling admission
/// under Poisson arrivals at `arrival_rate` — the serving-style workload
/// the continuous-batching runtime is benchmarked on (pair with
/// `SimConfig::rolling_poisson(setup.arrival_rate)`).
pub fn traffic_7b_h200() -> Setup {
    let mut s = stackex_7b_h200();
    s.name = "stackex-7b-h200-traffic";
    s.arrival_rate = 1.5;
    s
}

/// The Figure 3/4/5 sweep: all four single-node setups.
pub fn all_main_setups() -> Vec<Setup> {
    vec![stackex_7b_h200(), stackex_3b_a100(), gsm8k_7b_gh200(), opencoder_3b_a100()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setups_have_paper_targets() {
        let all = all_main_setups();
        assert_eq!(all.len(), 4);
        assert!((all[0].reward.plateau - 4.17).abs() < 1e-9);
        assert!((all[1].reward.plateau - 5.12).abs() < 1e-9);
        assert!((all[2].reward.plateau - 0.82).abs() < 1e-9);
        assert!((all[3].reward.plateau - 2.4).abs() < 1e-9);
        for s in &all {
            assert_eq!(s.batch, 112);
            assert!(s.gen_eff > 0.0 && s.gen_eff <= 1.0);
        }
    }

    #[test]
    fn gsm8k_is_rule_based_and_colocated() {
        let s = gsm8k_7b_gh200();
        assert!(!s.use_reward_model);
        assert_eq!(s.cluster.n_score, 0);
        assert!(s.cluster.colocated_scoring);
    }

    #[test]
    fn traffic_preset_has_a_positive_rate() {
        let s = traffic_7b_h200();
        assert!(s.arrival_rate > 0.0);
        for s in all_main_setups() {
            assert!(s.arrival_rate > 0.0, "{} needs a calibrated arrival rate", s.name);
        }
    }

    #[test]
    fn multinode_crosses_nodes() {
        let s = multinode_7b_a100_40();
        assert_eq!(s.cluster.nodes, 2);
        assert!(s.cluster.train_network_gbps() > 0.0);
    }
}
