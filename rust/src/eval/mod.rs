//! Evaluation harness: the code that regenerates every table and figure of
//! the paper (DESIGN.md §4's experiment index).  The `rust/benches/*`
//! binaries and `examples/paper_figures.rs` are thin wrappers over
//! [`figures`] / [`tables`]; results also land as JSON under
//! `target/paper/`.

pub mod figures;
pub mod report;
pub mod tables;

pub use report::{print_table, save_rows, Row};
