//! PPO math mirrored in Rust.
//!
//! The authoritative implementations live in L2 (`python/compile/model.py`)
//! and run as AOT executables; these mirrors exist to (a) cross-check the
//! artifacts numerically in integration tests, and (b) compose the
//! per-token reward vector (score + KL penalty) on the host, which is
//! cheap elementwise work not worth a device dispatch.

pub mod gae;
pub mod reward;

pub use reward::compose_rewards;
