//! Pinned-seed performance snapshot → `BENCH_10.json`.
//!
//! Runs the deterministic simulator on the paper's main preset at a fixed
//! seed and emits a machine-readable snapshot of the metrics this repo's
//! perf work is judged by: per-stage busy/idle attribution, steady-state
//! step wall time, streamed-chunk throughput, the lane-slicing knee
//! (`min_replicas_actor_bound`), lane idle fractions and per-prompt
//! latency percentiles for the continuous-batching arms, the `paged_kv`
//! section comparing peak KV commitment and the max-concurrent-lanes
//! bound between the dense (one worst-case row per lane) and
//! block-granular arms at *identical* decode schedules — and, new with
//! multi-node transport, a `transport` section pricing the remote-replica
//! arm against its local sliced twin from the cost model's closed-form
//! link terms (per-chunk wire cost, masked-grid penalty, chunk-replay
//! failover overhead) alongside host-measured frame codec throughput —
//! and, new with learned controllers, a `learned_controller` section
//! pricing the frozen Q-policy (trained at the CI-pinned
//! `--episodes 50 --seed 0` setting) against the heuristic controllers on
//! both benchmark presets, step throughput head to head.
//! The sim and cost-model sections are bit-reproducible on any machine —
//! same seed, same numbers — so the committed snapshot diffs cleanly
//! against a re-run; the `host` section (peak RSS, hot-path timings,
//! frame MB/s, runner wall time) is machine-dependent and refreshed by
//! each local run (committed as null when the runner lacks a toolchain).
//! `scripts/plot_bench.py` charts the committed `BENCH_*.json` sequence
//! across PRs.
//!
//! Usage:
//!   cargo bench --bench bench_snapshot              # writes ../BENCH_10.json
//!   cargo bench --bench bench_snapshot -- --out /tmp/snap.json

use std::time::Instant;

use oppo::eval::{print_table, Row};
use oppo::metrics::RunLog;
use oppo::ppo::gae::gae;
use oppo::sim::pipeline::{kv_lane_bounds, min_replicas_actor_bound, simulate, Pipeline, SimConfig};
use oppo::sim::presets;
use oppo::util::json::{self, Value};

const SEED: u64 = 600;
const STEPS: usize = 60;
const KNEE_MAX: usize = 8;
const KNEE_TOL: f64 = 0.02;
/// Paged-KV block size for the paged arms (tokens per physical block).
const KV_BLOCK_TOKENS: f64 = 64.0;
/// Link the remote transport arm is priced at (the `SimConfig` defaults:
/// 100 Gb/s fabric, 50 µs one-way framed-message latency).
const LINK_GBPS: f64 = 100.0;
const LINK_LATENCY_S: f64 = 5e-5;
/// Remote reward pool size for the transport comparison.
const REMOTE_POOL: f64 = 2.0;
/// Controller training budget — the same pinned setting the CI train-smoke
/// runs (`oppo train-controller --episodes 50 --seed 0`), so the committed
/// block and the CI assertion price the identical frozen policy.
const TRAIN_EPISODES: u64 = 50;
const TRAIN_SEED: u64 = 0;

fn cfg(reward_replicas: usize, ref_replicas: usize) -> SimConfig {
    let mut c = SimConfig::new(presets::stackex_7b_h200(), STEPS, SEED);
    c.reward_replicas = reward_replicas;
    c.ref_replicas = ref_replicas;
    c
}

/// Steady-state (last-half) aggregates for one run, as a JSON scenario
/// block plus a human table row.
fn scenario(name: &str, log: &RunLog) -> (Value, Row) {
    let tail = &log.records[log.records.len() / 2..];
    let n = tail.len() as f64;
    let (mut wall, mut util, mut chunks, mut gen_tokens) = (0.0, 0.0, 0.0, 0.0);
    let (mut lane_idle, mut mid_step, mut dropped) = (0.0, 0u64, 0u64);
    for r in tail {
        wall += r.wall_s;
        util += r.util;
        chunks += r.gen_tokens as f64 / r.chunk.max(1) as f64;
        gen_tokens += r.gen_tokens as f64;
        lane_idle += r.lane_idle_frac;
        mid_step += r.admitted_mid_step as u64;
        dropped += r.queue_dropped as u64;
    }
    // peak over the whole run — KV pressure spikes early while lanes warm
    // up, so a tail-only max would understate the dense arm's commitment
    let peak_kv = log.records.iter().map(|r| r.peak_kv_bytes).max().unwrap_or(0);
    let mut stages = Vec::new();
    for (i, st0) in tail[0].stages.iter().enumerate() {
        let (mut busy, mut idle) = (0.0, 0.0);
        let mut items = 0u64;
        for r in tail {
            busy += r.stages[i].busy_s;
            idle += r.stages[i].idle_s;
            items += r.stages[i].items;
        }
        stages.push(json::obj(vec![
            ("name", json::s(&st0.name)),
            ("replicas", json::num(st0.replicas as f64)),
            ("busy_s_mean", json::num(busy / n)),
            ("idle_s_mean", json::num(idle / n)),
            ("util", json::num(busy / (busy + idle).max(1e-12))),
            ("items", json::num(items as f64)),
        ]));
    }
    // per-prompt SLO percentiles over the *whole* run (latency samples are
    // too sparse per step to cut at the tail boundary)
    let slo = match log.slo_summary() {
        Some(s) => json::obj(vec![
            ("prompts", json::num(s.prompts as f64)),
            ("queue_wait_p50", json::num(s.queue_wait_p50)),
            ("queue_wait_p95", json::num(s.queue_wait_p95)),
            ("queue_wait_p99", json::num(s.queue_wait_p99)),
            ("e2e_p50", json::num(s.e2e_p50)),
            ("e2e_p95", json::num(s.e2e_p95)),
            ("e2e_p99", json::num(s.e2e_p99)),
        ]),
        None => Value::Null,
    };
    let v = json::obj(vec![
        ("mode", json::s(&log.mode)),
        ("step_wall_s_mean", json::num(wall / n)),
        ("util_mean", json::num(util / n)),
        ("streamed_chunks_per_s", json::num(chunks / wall)),
        ("gen_tokens_per_s", json::num(gen_tokens / wall)),
        ("lane_idle_frac_mean", json::num(lane_idle / n)),
        ("admitted_mid_step", json::num(mid_step as f64)),
        ("queue_dropped", json::num(dropped as f64)),
        ("peak_kv_bytes", json::num(peak_kv as f64)),
        ("slo", slo),
        ("stages", Value::Arr(stages)),
    ]);
    let row = Row::new(name)
        .cell("step_s", wall / n)
        .cell("util", util / n)
        .cell("lane_idle", lane_idle / n)
        .cell("tok_ps", gen_tokens / wall);
    (v, row)
}

fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn time_it(mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Host hot-path timings (machine-dependent, folded from the perf_hotpath
/// microbenches so the snapshot captures the coordinator-side cost
/// trajectory alongside the sim's modelled one).
fn host_timings() -> Value {
    use oppo::coordinator::buffer::SeqBuffer;
    use oppo::data::tasks::{Prompt, TaskKind};

    // buffer churn: admit + finish + take, per op
    let n = 50_000u64;
    let buf_secs = time_it(|| {
        let mut buf = SeqBuffer::new(12, 12);
        for i in 0..n {
            let p = Prompt {
                kind: TaskKind::Arith,
                text: "1+1=".into(),
                tokens: vec![1, 5, 40, 5, 44],
                answer: "2".into(),
                id: i,
            };
            let lane = buf.add(p, i).unwrap();
            {
                let s = buf.by_lane_mut(lane).unwrap();
                s.phase = oppo::model::sequence::SeqPhase::Generating;
                s.push_token(2, 0.0, 0.0, 2, 8, 100);
            }
            buf.mark_finished(lane);
            assert_eq!(buf.take_finished(1, i).len(), 1);
        }
    });

    // Rust GAE mirror over a [8, 160] batch
    let (b, s) = (8usize, 160usize);
    let r = vec![0.1f32; b * s];
    let v = vec![0.05f32; b * s];
    let m = vec![1.0f32; b * s];
    let iters = 5_000u64;
    let gae_secs = time_it(|| {
        for _ in 0..iters {
            let _ = gae(&r, &v, &m, b, s, 1.0, 0.95);
        }
    });

    // simulator throughput on the heaviest arm
    let sim_steps = 200usize;
    let sim_secs = time_it(|| {
        let c = SimConfig::new(presets::stackex_7b_h200(), sim_steps, 3);
        let _ = simulate(Pipeline::oppo(), &c);
    });

    json::obj(vec![
        ("buffer_ops_per_s", json::num(n as f64 / buf_secs.max(1e-12))),
        ("gae_8x160_per_s", json::num(iters as f64 / gae_secs.max(1e-12))),
        ("sim_oppo_steps_per_s", json::num(sim_steps as f64 / sim_secs.max(1e-12))),
    ])
}

/// The `transport` section: the remote-replica arm priced against its
/// local sliced twin at the preset's steady shapes, straight from the
/// cost model's closed-form link terms (pure f64 arithmetic, so the
/// modelled fields are bit-reproducible anywhere) — plus frame codec
/// throughput measured on this runner over an in-memory pipe
/// (machine-dependent, refreshed by each local run).
fn transport_block() -> Value {
    use oppo::sim::costmodel::CostModel;
    use oppo::transport::frame::{read_frame, write_frame};
    use oppo::transport::wire::kind;

    let su = presets::stackex_7b_h200();
    // the same score-stage cost model `simulate` builds, on the default link
    let cm = CostModel {
        model: su.model,
        gpu: su.cluster.gpu,
        tp: su.cluster.n_score.max(1) as f64,
        software_efficiency: su.score_eff,
        iter_overhead_s: 0.0,
        link_gbps: LINK_GBPS,
        link_latency_s: LINK_LATENCY_S,
    };
    // steady shapes: every lane near the converged median response
    // (~314 tokens) plus the 220-token prompt, full batch
    let mean_seq = 534.0;
    let total_tokens = su.batch as f64 * mean_seq;
    let chunk = cfg(1, 1).chunk_tokens;
    let local = cm.sliced_prefill(total_tokens, mean_seq, REMOTE_POOL);
    let remote = cm.remote_masked_prefill(total_tokens, mean_seq, chunk);
    // failover replay: one pool member dies half-streamed and the survivor
    // re-executes its retained share through the same remote path
    let replay_tokens = total_tokens / REMOTE_POOL / 2.0;
    let replay = cm.replay_overhead(replay_tokens, mean_seq, chunk);

    // frame codec throughput: one chunk-sized payload (i32 tokens for a
    // full [G, C] grid) per frame, encoded to / decoded from memory
    let payload = vec![0x5Au8; su.batch * chunk as usize * 4];
    let iters = 200usize;
    let mut buf: Vec<u8> = Vec::with_capacity((payload.len() + 64) * iters);
    let enc_secs = time_it(|| {
        buf.clear();
        for _ in 0..iters {
            write_frame(&mut buf, kind::REWARD_REQ, &payload).expect("encode");
        }
    });
    let mut r = &buf[..];
    let dec_secs = time_it(|| {
        for _ in 0..iters {
            let (_, p) = read_frame(&mut r).expect("decode");
            assert_eq!(p.len(), payload.len());
        }
    });
    let mb = (payload.len() * iters) as f64 / 1e6;

    json::obj(vec![
        ("link_gbps", json::num(LINK_GBPS)),
        ("link_latency_s", json::num(LINK_LATENCY_S)),
        ("remote_replicas", json::num(REMOTE_POOL)),
        ("mean_seq_tokens", json::num(mean_seq)),
        ("step_score_tokens", json::num(total_tokens)),
        ("chunk_transfer_s", json::num(cm.chunk_transfer(chunk))),
        ("local_sliced_prefill_s", json::num(local)),
        ("remote_masked_prefill_s", json::num(remote)),
        ("remote_over_local", json::num(remote / local)),
        ("replay_tokens", json::num(replay_tokens)),
        ("replay_overhead_s", json::num(replay)),
        ("replay_overhead_frac", json::num(replay / remote)),
        ("frame_encode_mb_s", json::num(mb / enc_secs.max(1e-12))),
        ("frame_decode_mb_s", json::num(mb / dec_secs.max(1e-12))),
    ])
}

/// The `learned_controller` section: train the Q-policy at the CI-pinned
/// setting and price the frozen artifact against the heuristic controllers
/// on both benchmark presets.  Pure sim — bit-reproducible anywhere.
fn learned_controller_block() -> Value {
    let (policy, report) = oppo::sim::train_qpolicy(TRAIN_EPISODES, TRAIN_SEED);
    let mut doc = match report.to_json() {
        Value::Obj(m) => m,
        _ => unreachable!("TrainReport::to_json returns an object"),
    };
    doc.insert("artifact".into(), oppo::ctl::qpolicy::artifact_meta(&policy));
    Value::Obj(doc)
}

fn main() {
    let mut out_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            out_path = args.next();
        }
        // anything else (--bench, harness flags) is cargo's — ignore
    }
    let out_path = out_path
        .unwrap_or_else(|| format!("{}/../BENCH_10.json", env!("CARGO_MANIFEST_DIR")));

    let t0 = Instant::now();
    let mut rows = Vec::new();
    let mut svals = Vec::new();
    let mut run = |name: &'static str, p: Pipeline, c: SimConfig| {
        let log = simulate(p, &c);
        let (v, row) = scenario(name, &log);
        svals.push((name, v));
        rows.push(row);
    };
    // the PR-6 baselines, unchanged for cross-PR comparability
    run("trl", Pipeline::TrlSequential, cfg(1, 1));
    run("oppo_x1", Pipeline::oppo(), cfg(1, 1));
    run("oppo_reward4_ref2", Pipeline::oppo(), cfg(4, 2));
    // rolling admission: saturated (training parity) against the oppo_x1
    // step-synchronous baseline above — lane idle must drop
    run("oppo_rolling_saturated", Pipeline::oppo(), cfg(1, 1).rolling_saturated());
    // Poisson traffic on the calibrated serving preset, step-sync vs
    // rolling — the rolling arm reports queue-wait/e2e SLO percentiles and
    // strictly lower lane idle
    let traffic = presets::traffic_7b_h200();
    let rate = traffic.arrival_rate;
    run(
        "traffic_stepsync",
        Pipeline::oppo(),
        SimConfig::new(traffic.clone(), STEPS, SEED),
    );
    // paged KV vs dense at the SAME schedule: the rolling-Poisson arm runs
    // twice, dense and block-granular.  Throughput columns must match
    // exactly (paging is memory accounting only); peak KV must not.
    let dense_cfg = SimConfig::new(traffic, STEPS, SEED).rolling_poisson(rate);
    let paged_cfg = dense_cfg.clone().paged(KV_BLOCK_TOKENS);
    let dense_log = simulate(Pipeline::oppo(), &dense_cfg);
    let paged_log = simulate(Pipeline::oppo(), &paged_cfg);
    let peak_of = |l: &RunLog| l.records.iter().map(|r| r.peak_kv_bytes).max().unwrap_or(0);
    let (dense_peak, paged_peak) = (peak_of(&dense_log), peak_of(&paged_log));
    run("traffic_rolling_poisson", Pipeline::oppo(), dense_cfg.clone());
    run("traffic_rolling_paged", Pipeline::oppo(), paged_cfg);
    let (dense_lanes, paged_lanes) = kv_lane_bounds(&dense_cfg, KV_BLOCK_TOKENS);
    let paged_kv = json::obj(vec![
        ("kv_block_tokens", json::num(KV_BLOCK_TOKENS)),
        ("dense_peak_kv_bytes", json::num(dense_peak as f64)),
        ("paged_peak_kv_bytes", json::num(paged_peak as f64)),
        (
            "peak_kv_reduction",
            json::num(1.0 - paged_peak as f64 / (dense_peak as f64).max(1.0)),
        ),
        ("dense_max_lanes", json::num(dense_lanes)),
        ("paged_max_lanes", json::num(paged_lanes)),
        (
            "equal_throughput",
            Value::Bool(
                dense_log
                    .records
                    .iter()
                    .zip(&paged_log.records)
                    .all(|(d, p)| d.wall_s == p.wall_s && d.gen_tokens == p.gen_tokens),
            ),
        ),
    ]);
    let knee = min_replicas_actor_bound(&cfg(1, 1), KNEE_MAX, KNEE_TOL);
    let transport = transport_block();
    let learned = learned_controller_block();

    let host = json::obj(vec![
        ("note", json::s("machine-dependent; refreshed by each local run")),
        (
            "peak_rss_kb",
            peak_rss_kb().map(|k| json::num(k as f64)).unwrap_or(Value::Null),
        ),
        ("timings", host_timings()),
        ("snapshot_wall_ms", json::num(t0.elapsed().as_secs_f64() * 1e3)),
    ]);
    let doc = json::obj(vec![
        ("bench", json::s("bench_snapshot")),
        ("preset", json::s("stackex-7b-h200")),
        ("seed", json::num(SEED as f64)),
        ("steps", json::num(STEPS as f64)),
        ("tail_steps", json::num((STEPS - STEPS / 2) as f64)),
        ("chunk_tokens", json::num(cfg(1, 1).chunk_tokens)),
        ("scenarios", json::obj(svals)),
        ("sliced_knee_reward_replicas", json::num(knee as f64)),
        ("paged_kv", paged_kv),
        ("transport", transport.clone()),
        ("learned_controller", learned.clone()),
        ("host", host),
    ]);
    let text = json::to_string(&doc) + "\n";
    std::fs::write(&out_path, &text).expect("write snapshot");

    print_table("BENCH_10 snapshot (stackex-7b-h200, seed 600, last-half means)", &rows);
    println!("sliced knee: {knee} reward replicas (tol {KNEE_TOL})");
    println!(
        "paged kv: peak {paged_peak} vs dense {dense_peak} ({:.0}% reduction), \
         lane bound {paged_lanes:.0} vs {dense_lanes:.0}",
        100.0 * (1.0 - paged_peak as f64 / (dense_peak as f64).max(1.0))
    );
    if let Value::Obj(m) = &transport {
        let get = |k: &str| match m.get(k) {
            Some(Value::Num(x)) => *x,
            _ => f64::NAN,
        };
        println!(
            "transport: remote/local {:.3}, replay frac {:.3}, frame enc {:.0} MB/s",
            get("remote_over_local"),
            get("replay_overhead_frac"),
            get("frame_encode_mb_s"),
        );
    }
    if let Ok(arms) = learned.get("arms").and_then(|a| a.as_arr()) {
        for arm in arms {
            let name = arm.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let speedup = arm.get("speedup").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            println!("learned controller vs heuristic on {name}: {speedup:.4}x");
        }
    }
    println!("wrote {out_path}");
}
