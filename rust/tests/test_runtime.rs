//! Integration: every AOT artifact loads, compiles, and executes through
//! PJRT with manifest-consistent arity; training entries mutate parameters.
use std::sync::Arc;

use once_cell::sync::Lazy;
use oppo::runtime::{Engine, ParamSet};

static ENGINE: Lazy<Option<Arc<Engine>>> = Lazy::new(|| {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
});

fn engine() -> Option<Arc<Engine>> {
    ENGINE.clone()
}

#[test]
fn all_entries_compile() {
    let Some(e) = engine() else { return };
    let names: Vec<String> = e.manifest().entries.keys().cloned().collect();
    for name in names {
        e.executable(&name).unwrap_or_else(|err| panic!("{name}: {err:#}"));
    }
}

#[test]
fn ppo_update_executes_and_moves_params() {
    let Some(e) = engine() else { return };
    let m = e.manifest().shape.clone();
    let (b, s) = (m.ppo_batch, m.s_max);
    let actor = ParamSet::load(&e, "actor").unwrap();
    let zm = ParamSet::zeros_like(&e).unwrap();
    let zv = ParamSet::zeros_like(&e).unwrap();

    let mut tokens = vec![0i32; b * s];
    let mut mask = vec![0f32; b * s];
    let mut adv = vec![0f32; b * s];
    for i in 0..b {
        for t in 0..12 {
            tokens[i * s + t] = 3 + ((i + t) % 30) as i32;
            if t >= 4 {
                mask[i * s + t] = 1.0;
                adv[i * s + t] = if i % 2 == 0 { 0.5 } else { -0.5 };
            }
        }
    }
    let old_logp = vec![-2.0f32; b * s];
    let ret = vec![0.1f32; b * s];

    let up = |x: &[f32], dims: &[usize]| e.upload_f32(x, dims).unwrap();
    let toks_b = e.upload_i32(&tokens, &[b, s]).unwrap();
    let step_b = e.scalar_i32(1).unwrap();
    let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
    args.extend(actor.bufs());
    args.extend(zm.bufs());
    args.extend(zv.bufs());
    let mask_b = up(&mask, &[b, s]);
    let old_b = up(&old_logp, &[b, s]);
    let adv_b = up(&adv, &[b, s]);
    let ret_b = up(&ret, &[b, s]);
    args.push(&toks_b);
    args.push(&mask_b);
    args.push(&old_b);
    args.push(&adv_b);
    args.push(&ret_b);
    args.push(&step_b);
    let outs = e.execute("ppo_update", &args).unwrap();
    assert_eq!(outs.len(), 3 * actor.len() + 1);

    // first output = new embed; must differ from the input embed
    let new_embed = e.download_f32(&outs[0]).unwrap();
    let old_embed = actor.download(&e, "embed").unwrap();
    assert_ne!(new_embed, old_embed);
    // stats are finite
    let stats = e.download_f32(outs.last().unwrap()).unwrap();
    assert_eq!(stats.len(), 6);
    assert!(stats.iter().all(|x| x.is_finite()), "{stats:?}");
}

#[test]
fn gae_pallas_artifact_matches_jnp_artifact() {
    let Some(e) = engine() else { return };
    let m = e.manifest().shape.clone();
    let (b, s) = (m.ppo_batch, m.s_max);
    let mut rewards = vec![0f32; b * s];
    let mut values = vec![0f32; b * s];
    let mut mask = vec![0f32; b * s];
    for i in 0..b {
        for t in 0..(20 + i * 3) {
            rewards[i * s + t] = ((i + t) as f32 * 0.7).sin();
            values[i * s + t] = ((i * t) as f32 * 0.3).cos() * 0.5;
            mask[i * s + t] = 1.0;
        }
    }
    let args = [
        e.upload_f32(&rewards, &[b, s]).unwrap(),
        e.upload_f32(&values, &[b, s]).unwrap(),
        e.upload_f32(&mask, &[b, s]).unwrap(),
    ];
    let refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
    let jnp = e.execute("gae", &refs).unwrap();
    let pal = e.execute("gae_pallas", &refs).unwrap();
    for (a, b_) in jnp.iter().zip(&pal) {
        let x = e.download_f32(a).unwrap();
        let y = e.download_f32(b_).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            assert!((xi - yi).abs() < 1e-4, "{xi} vs {yi}");
        }
    }
}

#[test]
fn execute_rejects_wrong_arity() {
    let Some(e) = engine() else { return };
    let buf = e.upload_f32(&[1.0], &[1]).unwrap();
    assert!(e.execute("ppo_update", &[&buf]).is_err());
    assert!(e.execute("no_such_entry", &[&buf]).is_err());
}
