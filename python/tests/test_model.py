"""L2 semantics: generation, incremental prefill, streaming equivalence.

These tests pin down the invariants the paper's §3.1 correctness argument
(Eq. 3) relies on and that the Rust coordinator assumes:

* chunked KV-cache decoding reproduces teacher-forced log-probs exactly;
* streamed (chunked) reward prefill produces the same final score as
  monolithic scoring — the "intra-step overlap does not change the PPO
  update" invariant;
* dead lanes are bit-frozen across generate calls (inter-step deferral
  preserves partial work).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    d_model=64, n_heads=2, n_layers=2, d_ff=128, s_max=64, prompt_max=8,
    lanes=4, ppo_batch=4, chunk_sizes=(4, 8), temperature=1.0,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(42))


def fresh_kv(batch):
    shape = (batch, CFG.n_heads, CFG.s_max, CFG.head_dim)
    return [jnp.zeros(shape, jnp.float32) for _ in range(2 * CFG.n_layers)]


def make_prompts(key, g=None):
    g = g or CFG.lanes
    toks = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    toks = toks.at[:, 0].set(M.BOS)
    prompt_len = jnp.full((g,), CFG.prompt_max, jnp.int32)
    return toks, prompt_len


def run_generate(params, tokens, pos, live, kv, key, c, n_chunks):
    """Drive make_actor_generate_chunk the way the Rust coordinator does."""
    fn = M.make_actor_generate_chunk(CFG, c)
    flat = M.flatten_params(CFG, params)
    outs = []
    for i in range(n_chunks):
        key, sub = jax.random.split(key)
        raw = jax.random.key_data(sub).astype(jnp.uint32)
        res = fn(*flat, tokens, pos, live, *kv, raw)
        tokens, pos = res[0], res[1]
        kv = list(res[2 : 2 + 2 * CFG.n_layers])
        outs.append(res[2 + 2 * CFG.n_layers :])  # (out_tok, logp, value)
    return tokens, pos, kv, outs


def test_generate_chunk_is_deterministic(params):
    key = jax.random.PRNGKey(0)
    tokens, prompt_len = make_prompts(key)
    reset = jnp.ones((CFG.lanes,), jnp.int32)
    kv = fresh_kv(CFG.lanes)
    pre = M.make_actor_prefill(CFG)
    flat = M.flatten_params(CFG, params)
    kv = list(pre(*flat, tokens, prompt_len, reset, *kv))
    pos = prompt_len
    live = jnp.ones((CFG.lanes,), jnp.int32)

    t1, p1, _, o1 = run_generate(params, tokens, pos, live, kv, jax.random.PRNGKey(9), 4, 3)
    t2, p2, _, o2 = run_generate(params, tokens, pos, live, kv, jax.random.PRNGKey(9), 4, 3)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    for a, b in zip(o1, o2):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_generated_logp_matches_teacher_forced_recompute(params):
    """The logp recorded during KV-cache generation must equal the dense
    teacher-forced recompute — this is what makes old_logp valid in Eq. 2."""
    key = jax.random.PRNGKey(1)
    tokens, prompt_len = make_prompts(key)
    reset = jnp.ones((CFG.lanes,), jnp.int32)
    kv = fresh_kv(CFG.lanes)
    flat = M.flatten_params(CFG, params)
    kv = list(M.make_actor_prefill(CFG)(*flat, tokens, prompt_len, reset, *kv))
    live = jnp.ones((CFG.lanes,), jnp.int32)
    n_chunks, c = 4, 4
    t_out, pos, _, outs = run_generate(
        params, tokens, prompt_len, live, kv, jax.random.PRNGKey(5), c, n_chunks
    )
    gen_logp = jnp.concatenate([o[1] for o in outs], axis=1)  # [G, n*c]

    dense_logp, _ = M.token_logprobs(CFG, params, t_out)
    p0 = int(CFG.prompt_max)
    want = dense_logp[:, p0 : p0 + n_chunks * c]
    np.testing.assert_allclose(np.asarray(gen_logp), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_generated_value_matches_dense_scalar(params):
    key = jax.random.PRNGKey(2)
    tokens, prompt_len = make_prompts(key)
    reset = jnp.ones((CFG.lanes,), jnp.int32)
    kv = fresh_kv(CFG.lanes)
    flat = M.flatten_params(CFG, params)
    kv = list(M.make_actor_prefill(CFG)(*flat, tokens, prompt_len, reset, *kv))
    live = jnp.ones((CFG.lanes,), jnp.int32)
    t_out, _, _, outs = run_generate(
        params, tokens, prompt_len, live, kv, jax.random.PRNGKey(6), 8, 2
    )
    gen_vals = jnp.concatenate([o[2] for o in outs], axis=1)  # [G, 16]
    _, dense_scalar = M.forward_full(CFG, params, t_out)
    p0 = int(CFG.prompt_max)
    # value emitted when sampling token at position p comes from hidden at p-1
    want = dense_scalar[:, p0 - 1 : p0 - 1 + 16]
    np.testing.assert_allclose(np.asarray(gen_vals), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_streamed_reward_prefill_equals_full_score(params):
    """Eq. 3's system-level counterpart: chunk-streamed scoring == monolithic."""
    key = jax.random.PRNGKey(3)
    g = CFG.lanes
    lens = jnp.array([13, 24, 32, 9], jnp.int32)  # ragged sequence lengths
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    flat = M.flatten_params(CFG, params)

    # monolithic
    full = M.make_reward_score_full(CFG)(*flat, tokens, lens - 1)[0]

    # streamed: chunks of c, per-lane contiguous schedule like the coordinator's
    c = 4
    fn = M.make_reward_prefill_chunk(CFG, c)
    kv = fresh_kv(g)
    score_at_last = jnp.zeros((g,), jnp.float32)
    max_len = int(lens.max())
    for start in range(0, max_len, c):
        chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
        starts = jnp.full((g,), start, jnp.int32)
        n_valid = jnp.clip(lens - start, 0, c)
        res = fn(*flat, chunk, starts, n_valid, *kv)
        kv = list(res[: 2 * CFG.n_layers])
        scores = res[2 * CFG.n_layers]  # [G, C]
        # pick the score at each lane's final token if it lies in this chunk
        idx_in_chunk = lens - 1 - start
        in_chunk = (idx_in_chunk >= 0) & (idx_in_chunk < c)
        picked = scores[jnp.arange(g), jnp.clip(idx_in_chunk, 0, c - 1)]
        score_at_last = jnp.where(in_chunk, picked, score_at_last)

    np.testing.assert_allclose(np.asarray(score_at_last), np.asarray(full), rtol=5e-4, atol=5e-4)


def test_streamed_reward_chunk_size_invariance(params):
    """Different chunk sizes must give identical final scores (§3.1)."""
    key = jax.random.PRNGKey(4)
    g = CFG.lanes
    lens = jnp.array([16, 8, 24, 12], jnp.int32)
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    flat = M.flatten_params(CFG, params)

    def stream(c):
        fn = M.make_reward_prefill_chunk(CFG, c)
        kv = fresh_kv(g)
        out = jnp.zeros((g,), jnp.float32)
        for start in range(0, int(lens.max()), c):
            chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
            starts = jnp.full((g,), start, jnp.int32)
            n_valid = jnp.clip(lens - start, 0, c)
            res = fn(*flat, chunk, starts, n_valid, *kv)
            kv = list(res[: 2 * CFG.n_layers])
            scores = res[2 * CFG.n_layers]
            idx = lens - 1 - start
            hit = (idx >= 0) & (idx < c)
            out = jnp.where(hit, scores[jnp.arange(g), jnp.clip(idx, 0, c - 1)], out)
        return out

    s4, s8 = stream(4), stream(8)
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s8), rtol=5e-4, atol=5e-4)


def test_streamed_ref_prefill_equals_dense_logprobs(params):
    """The third pipeline stage's invariant: chunk-streamed reference
    log-probs must reproduce the dense ``token_logprobs`` at every valid
    position, across the cross-chunk seam (the boundary carry)."""
    key = jax.random.PRNGKey(21)
    g = CFG.lanes
    lens = jnp.array([14, 23, 32, 7], jnp.int32)  # ragged, not chunk-aligned
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    tokens = tokens.at[:, 0].set(M.BOS)
    flat = M.flatten_params(CFG, params)

    dense, _ = M.token_logprobs(CFG, params, tokens)

    c = 4
    fn = M.make_ref_prefill_chunk(CFG, c)
    kv = fresh_kv(g)
    boundary = jnp.zeros((g, CFG.vocab), jnp.float32)
    got = np.full((g, CFG.s_max), np.nan, np.float32)
    for start in range(0, int(lens.max()), c):
        chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
        starts = jnp.full((g,), start, jnp.int32)
        n_valid = jnp.clip(lens - start, 0, c)
        res = fn(*flat, chunk, starts, n_valid, boundary, *kv)
        kv = list(res[: 2 * CFG.n_layers])
        boundary = res[2 * CFG.n_layers]
        logp = np.asarray(res[2 * CFG.n_layers + 1])  # [G, C]
        for lane in range(g):
            nv = int(n_valid[lane])
            got[lane, start : start + nv] = logp[lane, :nv]

    for lane in range(g):
        n = int(lens[lane])
        np.testing.assert_allclose(
            got[lane, :n], np.asarray(dense)[lane, :n], rtol=5e-4, atol=5e-4,
            err_msg=f"lane {lane}",
        )
    # position 0 convention matches token_logprobs (no prefix -> 0)
    assert np.all(got[:, 0] == 0.0)


def test_streamed_ref_chunk_size_invariance(params):
    """Different chunk sizes must give identical streamed ref log-probs."""
    key = jax.random.PRNGKey(22)
    g = CFG.lanes
    lens = jnp.array([16, 9, 26, 12], jnp.int32)
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    flat = M.flatten_params(CFG, params)

    def stream(c):
        fn = M.make_ref_prefill_chunk(CFG, c)
        kv = fresh_kv(g)
        boundary = jnp.zeros((g, CFG.vocab), jnp.float32)
        out = np.zeros((g, CFG.s_max), np.float32)
        for start in range(0, int(lens.max()), c):
            chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
            starts = jnp.full((g,), start, jnp.int32)
            n_valid = jnp.clip(lens - start, 0, c)
            res = fn(*flat, chunk, starts, n_valid, boundary, *kv)
            kv = list(res[: 2 * CFG.n_layers])
            boundary = res[2 * CFG.n_layers]
            logp = np.asarray(res[2 * CFG.n_layers + 1])
            for lane in range(g):
                nv = int(n_valid[lane])
                out[lane, start : start + nv] = logp[lane, :nv]
        return out

    np.testing.assert_allclose(stream(4), stream(8), rtol=5e-4, atol=5e-4)


def test_dead_lanes_are_frozen(params):
    """live=0 lanes must keep tokens, pos, and KV bit-identical (§3.2)."""
    key = jax.random.PRNGKey(8)
    tokens, prompt_len = make_prompts(key)
    reset = jnp.ones((CFG.lanes,), jnp.int32)
    kv = fresh_kv(CFG.lanes)
    flat = M.flatten_params(CFG, params)
    kv = list(M.make_actor_prefill(CFG)(*flat, tokens, prompt_len, reset, *kv))
    live = jnp.array([1, 0, 1, 0], jnp.int32)
    fn = M.make_actor_generate_chunk(CFG, 4)
    raw = jax.random.key_data(jax.random.PRNGKey(123)).astype(jnp.uint32)
    res = fn(*flat, tokens, prompt_len, live, *kv, raw)
    t2, p2 = res[0], res[1]
    kv2 = res[2 : 2 + 2 * CFG.n_layers]
    out_tok = res[2 + 2 * CFG.n_layers]
    for lane in (1, 3):
        np.testing.assert_array_equal(np.asarray(t2[lane]), np.asarray(tokens[lane]))
        assert int(p2[lane]) == int(prompt_len[lane])
        for a, b in zip(kv2, kv):
            np.testing.assert_array_equal(np.asarray(a[lane]), np.asarray(b[lane]))
        assert np.all(np.asarray(out_tok[lane]) == M.PAD)
    for lane in (0, 2):
        assert int(p2[lane]) == int(prompt_len[lane]) + 4


def test_actor_prefill_reset_selectivity(params):
    """reset=0 lanes keep their old KV exactly; reset=1 lanes get fresh prefill."""
    key = jax.random.PRNGKey(10)
    tokens, prompt_len = make_prompts(key)
    flat = M.flatten_params(CFG, params)
    old_kv = [jnp.full((CFG.lanes, CFG.n_heads, CFG.s_max, CFG.head_dim), 7.0)
              for _ in range(2 * CFG.n_layers)]
    reset = jnp.array([1, 0, 1, 0], jnp.int32)
    new_kv = M.make_actor_prefill(CFG)(*flat, tokens, prompt_len, reset, *old_kv)
    for a in new_kv:
        assert np.all(np.asarray(a[1]) == 7.0)
        assert np.all(np.asarray(a[3]) == 7.0)
        assert not np.all(np.asarray(a[0]) == 7.0)


def test_token_logprobs_are_normalized(params):
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, CFG.s_max), 3, CFG.vocab)
    logits, _ = M.forward_full(CFG, params, toks.astype(jnp.int32))
    probs = jax.nn.softmax(logits, -1).sum(-1)
    np.testing.assert_allclose(np.asarray(probs), 1.0, rtol=1e-5)


def test_kernel_impl_flavours_agree(params):
    """pallas vs jnp lowering of the same model function must agree numerically."""
    pcfg = dataclasses.replace(CFG, kernel_impl="pallas")
    key = jax.random.PRNGKey(14)
    g = CFG.lanes
    tokens = jax.random.randint(key, (g, 8), 3, CFG.vocab).astype(jnp.int32)
    start = jnp.zeros((g,), jnp.int32)
    nv = jnp.full((g,), 8, jnp.int32)
    flat = M.flatten_params(CFG, params)
    kv = fresh_kv(g)
    r_jnp = M.make_reward_prefill_chunk(CFG, 8)(*flat, tokens, start, nv, *kv)
    r_pal = M.make_reward_prefill_chunk(pcfg, 8)(*flat, tokens, start, nv, *kv)
    for a, b in zip(r_jnp, r_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
