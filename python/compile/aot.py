"""AOT compiler: lower every L2 entry point to HLO *text* + dump params.

Run once at build time (``make artifacts``); the Rust coordinator then loads
``artifacts/*.hlo.txt`` through PJRT and Python never runs again.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``--out-dir``, default ``../artifacts``):

* ``<entry>.hlo.txt``          — one per entry point × static-shape variant
* ``params_actor.bin``         — initial actor params (raw little-endian f32)
* ``params_reward.bin``        — independently-initialized reward model
* ``params_ref.bin``           — frozen copy of the initial actor (reference)
* ``manifest.json``            — model config, param table (name/shape/offset),
                                 entry-point I/O signatures, tokenizer
* ``aot_fingerprint.txt``      — hash of the compile inputs (Make no-op check)

Chunk-size variants: HLO shapes are static, so OPPO's dynamic chunk-size
controller (§3.1) selects among pre-compiled executables
``actor_generate_chunk_c{C}`` / ``reward_prefill_chunk_c{C}`` /
``ref_prefill_chunk_c{C}``, C ∈ ``cfg.chunk_sizes`` — "one compiled
executable per model variant".

Lane-sliced variants: an N-replica stage pool compacts its owned lanes into
a dense ``[G/N, C]`` grid (host-side, see rust worker.rs) and runs
``reward_prefill_chunk_g{G/N}_c{C}`` / ``ref_prefill_chunk_g{G/N}_c{C}``
so each replica pays only its share of the chunk FLOPs instead of a masked
full-shape kernel.  The builders are lane-polymorphic, so the sliced
flavours differ from the full-shape ones only in their input specs.
Emitted for every replica count N > 1 that divides G.

Paged variants: the ``*_paged`` entry family replaces each state's dense
``[rows, H, s_max, hd]`` caches with one shared ``[P, H, bs, hd]`` block
pool per layer-k/v plus a per-call ``[rows, s_max/bs]`` i32 block table
(vLLM-style; block 0 is the reserved scratch sink for unallocated slots).
The host-side ``BlockPool`` allocator (rust coordinator) decides which
physical blocks each lane owns; admission gates on free blocks instead of
free lanes.  Emitted full-G only — paged and lane-sliced are mutually
exclusive, and the Rust workers pick paged > sliced > masked at spawn.

Kernel flavours: the default artifact set lowers with ``kernel_impl="jnp"``
(XLA-fused oracles — the throughput flavour; see EXPERIMENTS.md §Perf).  The
Pallas L1 kernels additionally ship as ``*_pallas`` artifacts for the middle
chunk size + ``gae_pallas``; Rust integration tests execute both flavours
and assert they agree, so the TPU-schedule kernels are genuinely on the
load-and-execute path.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# --------------------------------------------------------------------------
# Tokenizer (mirrored by rust/src/data/tokenizer.rs through the manifest)
# --------------------------------------------------------------------------

SPECIALS = ["<pad>", "<bos>", "<eos>"]
CHARS = " 0123456789abcdefghijklmnopqrstuvwxyz+-*/=?.,:;#|()[]<>"


def tokenizer_table(vocab: int) -> list[str]:
    table = SPECIALS + list(CHARS)
    assert len(table) <= vocab, f"vocab {vocab} too small for {len(table)} tokens"
    table += [f"<unused{i}>" for i in range(vocab - len(table))]
    return table


# --------------------------------------------------------------------------
# Lowering helpers
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg: M.ModelConfig) -> list[jax.ShapeDtypeStruct]:
    shapes = M.param_shapes(cfg)
    return [_sds(shapes[n]) for n in M.param_names(cfg)]


def kv_specs(cfg: M.ModelConfig, batch: int) -> list[jax.ShapeDtypeStruct]:
    kv_shape = (batch, cfg.n_heads, cfg.s_max, cfg.head_dim)
    return [_sds(kv_shape) for _ in range(2 * cfg.n_layers)]


def paged_kv_specs(cfg: M.ModelConfig) -> list[jax.ShapeDtypeStruct]:
    """The pooled block caches shared by all lanes: [P, H, bs, hd] × 2L."""
    shape = (cfg.kv_pool_size, cfg.n_heads, cfg.kv_block_size, cfg.head_dim)
    return [_sds(shape) for _ in range(2 * cfg.n_layers)]


def block_table_spec(cfg: M.ModelConfig, rows: int) -> jax.ShapeDtypeStruct:
    """Per-call i32 block table [rows, s_max / kv_block_size]."""
    return _sds((rows, cfg.kv_blocks_per_lane), jnp.int32)


def sliced_row_counts(cfg: M.ModelConfig) -> list[int]:
    """Compacted row counts G/N for every replica count N > 1 dividing G.

    Non-divisor replica counts have no sliced entry; the Rust pool falls
    back to the masked full-shape path for those.
    """
    g = cfg.lanes
    return sorted({g // n for n in range(2, g + 1) if g % n == 0}, reverse=True)


def entry_signatures(cfg: M.ModelConfig) -> dict[str, tuple]:
    """name -> (builder fn, [input ShapeDtypeStructs])."""
    g, b, s = cfg.lanes, cfg.ppo_batch, cfg.s_max
    p = param_specs(cfg)
    i32, f32, u32 = jnp.int32, jnp.float32, jnp.uint32
    sigs: dict[str, tuple] = {}

    sigs["actor_prefill"] = (
        M.make_actor_prefill(cfg),
        [*p, _sds((g, s), i32), _sds((g,), i32), _sds((g,), i32), *kv_specs(cfg, g)],
    )
    for c in cfg.chunk_sizes:
        sigs[f"actor_generate_chunk_c{c}"] = (
            M.make_actor_generate_chunk(cfg, c),
            [*p, _sds((g, s), i32), _sds((g,), i32), _sds((g,), i32),
             *kv_specs(cfg, g), _sds((2,), u32)],
        )
        sigs[f"reward_prefill_chunk_c{c}"] = (
            M.make_reward_prefill_chunk(cfg, c),
            [*p, _sds((g, c), i32), _sds((g,), i32), _sds((g,), i32), *kv_specs(cfg, g)],
        )
        sigs[f"ref_prefill_chunk_c{c}"] = (
            M.make_ref_prefill_chunk(cfg, c),
            [*p, _sds((g, c), i32), _sds((g,), i32), _sds((g,), i32),
             _sds((g, cfg.vocab), f32), *kv_specs(cfg, g)],
        )
    # lane-sliced replica variants: same builders, [G/N]-row input specs
    for rows in sliced_row_counts(cfg):
        for c in cfg.chunk_sizes:
            sigs[f"reward_prefill_chunk_g{rows}_c{c}"] = (
                M.make_reward_prefill_chunk(cfg, c),
                [*p, _sds((rows, c), i32), _sds((rows,), i32), _sds((rows,), i32),
                 *kv_specs(cfg, rows)],
            )
            sigs[f"ref_prefill_chunk_g{rows}_c{c}"] = (
                M.make_ref_prefill_chunk(cfg, c),
                [*p, _sds((rows, c), i32), _sds((rows,), i32), _sds((rows,), i32),
                 _sds((rows, cfg.vocab), f32), *kv_specs(cfg, rows)],
            )
    # paged flavours: pooled [P, H, bs, hd] caches + a trailing block table.
    # Emitted full-G only (paged and lane-sliced are mutually exclusive —
    # a paged pool is already shared state, so replicas fall back to the
    # masked full-shape split).
    pool = paged_kv_specs(cfg)
    table_g = block_table_spec(cfg, g)
    sigs["actor_prefill_paged"] = (
        M.make_actor_prefill_paged(cfg),
        [*p, _sds((g, s), i32), _sds((g,), i32), _sds((g,), i32), *pool, table_g],
    )
    for c in cfg.chunk_sizes:
        sigs[f"actor_generate_chunk_paged_c{c}"] = (
            M.make_actor_generate_chunk_paged(cfg, c),
            [*p, _sds((g, s), i32), _sds((g,), i32), _sds((g,), i32),
             *pool, _sds((2,), u32), table_g],
        )
        sigs[f"reward_prefill_chunk_paged_c{c}"] = (
            M.make_reward_prefill_chunk_paged(cfg, c),
            [*p, _sds((g, c), i32), _sds((g,), i32), _sds((g,), i32), *pool, table_g],
        )
        sigs[f"ref_prefill_chunk_paged_c{c}"] = (
            M.make_ref_prefill_chunk_paged(cfg, c),
            [*p, _sds((g, c), i32), _sds((g,), i32), _sds((g,), i32),
             _sds((g, cfg.vocab), f32), *pool, table_g],
        )
    sigs["reward_score_full"] = (
        M.make_reward_score_full(cfg),
        [*p, _sds((g, s), i32), _sds((g,), i32)],
    )
    sigs["ref_logprobs"] = (
        M.make_ref_logprobs(cfg),
        [*p, _sds((b, s), i32)],
    )
    sigs["actor_forward_full"] = (
        M.make_actor_forward_full(cfg),
        [*p, _sds((b, s), i32)],
    )
    sigs["gae"] = (
        M.make_gae(cfg),
        [_sds((b, s), f32), _sds((b, s), f32), _sds((b, s), f32)],
    )
    sigs["ppo_update"] = (
        M.make_ppo_update(cfg),
        [*p, *p, *p, _sds((b, s), i32), _sds((b, s), f32), _sds((b, s), f32),
         _sds((b, s), f32), _sds((b, s), f32), _sds((), i32)],
    )
    sigs["dpo_update"] = (
        M.make_dpo_update(cfg),
        [*p, *p, *p, _sds((b, s), i32), _sds((b, s), i32), _sds((b, s), f32),
         _sds((b, s), f32), _sds((b,), f32), _sds((b,), f32), _sds((), i32)],
    )
    return sigs


def pallas_entry_signatures(cfg: M.ModelConfig) -> dict[str, tuple]:
    """The Pallas-flavoured subset shipped alongside the default artifacts."""
    pcfg = dataclasses.replace(cfg, kernel_impl="pallas")
    mid_c = pcfg.chunk_sizes[len(pcfg.chunk_sizes) // 2]
    g, b, s = pcfg.lanes, pcfg.ppo_batch, pcfg.s_max
    p = param_specs(pcfg)
    i32, f32 = jnp.int32, jnp.float32
    sigs = {
        f"reward_prefill_chunk_pallas_c{mid_c}": (
            M.make_reward_prefill_chunk(pcfg, mid_c),
            [*p, _sds((g, mid_c), i32), _sds((g,), i32), _sds((g,), i32),
             *kv_specs(pcfg, g)],
        ),
        "gae_pallas": (
            M.make_gae(pcfg),
            [_sds((b, s), f32), _sds((b, s), f32), _sds((b, s), f32)],
        ),
    }
    # sliced pallas flavour: the attention kernel grids over b*h at runtime
    # shape, so the same builder lowers at any compacted row count
    for rows in sliced_row_counts(pcfg):
        sigs[f"reward_prefill_chunk_pallas_g{rows}_c{mid_c}"] = (
            M.make_reward_prefill_chunk(pcfg, mid_c),
            [*p, _sds((rows, mid_c), i32), _sds((rows,), i32), _sds((rows,), i32),
             *kv_specs(pcfg, rows)],
        )
    # paged pallas flavour: the Pallas chunked-prefill kernel runs unchanged
    # on the gathered dense view, so the paged builder lowers directly
    sigs[f"reward_prefill_chunk_paged_pallas_c{mid_c}"] = (
        M.make_reward_prefill_chunk_paged(pcfg, mid_c),
        [*p, _sds((g, mid_c), i32), _sds((g,), i32), _sds((g,), i32),
         *paged_kv_specs(pcfg), block_table_spec(pcfg, g)],
    )
    return sigs


# --------------------------------------------------------------------------
# Param serialization
# --------------------------------------------------------------------------


def dump_params(cfg: M.ModelConfig, params: dict, path: str) -> list[dict]:
    """Write raw little-endian f32 in canonical order; return the param table."""
    table, offset = [], 0
    with open(path, "wb") as f:
        for name in M.param_names(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            table.append({
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "bytes": arr.nbytes,
            })
            offset += arr.nbytes
    return table


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------

PRESETS = {
    # default: the config used by examples/tests — small enough for CPU PJRT,
    # large enough to have real stage structure (4 layers, 160-token window).
    "default": M.ModelConfig(),
    # smoke: minimal shapes for fast CI-style checks of the full AOT path.
    "smoke": M.ModelConfig(
        d_model=64, n_heads=2, n_layers=2, d_ff=128, s_max=64, prompt_max=16,
        lanes=6, ppo_batch=4, chunk_sizes=(4, 8),
    ),
}


def fingerprint(paths: list[str]) -> str:
    h = hashlib.sha256()
    for p in sorted(paths):
        with open(p, "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS))
    ap.add_argument("--kernels", default="jnp", choices=["jnp", "pallas"],
                    help="kernel flavour for the default artifact set")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-pallas-extras", action="store_true",
                    help="skip the *_pallas validation artifacts")
    args = ap.parse_args()

    cfg = dataclasses.replace(PRESETS[args.preset], kernel_impl=args.kernels)
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    # ---- params ----
    key = jax.random.PRNGKey(args.seed)
    k_actor, k_reward = jax.random.split(key)
    actor = M.init_params(cfg, k_actor)
    reward = M.init_params(cfg, k_reward)
    actor_table = dump_params(cfg, actor, os.path.join(out, "params_actor.bin"))
    reward_table = dump_params(cfg, reward, os.path.join(out, "params_reward.bin"))
    ref_table = dump_params(cfg, actor, os.path.join(out, "params_ref.bin"))
    assert actor_table == ref_table

    # ---- entry points ----
    sigs = entry_signatures(cfg)
    if not args.skip_pallas_extras:
        sigs.update(pallas_entry_signatures(cfg))

    entries = {}
    for name, (fn, in_specs) in sigs.items():
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *in_specs)
        entries[name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
            ],
            "outputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in jax.tree_util.tree_leaves(out_specs)
            ],
        }
        print(f"lowered {name}: {len(text)} chars, "
              f"{len(in_specs)} inputs, {len(entries[name]['outputs'])} outputs")

    # ---- manifest ----
    manifest = {
        "format_version": 1,
        "paper": "OPPO: Accelerating PPO-based RLHF via Pipeline Overlap",
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in dataclasses.asdict(cfg).items()},
        "n_params": len(M.param_names(cfg)),
        "param_table": actor_table,
        "params_files": {
            "actor": "params_actor.bin",
            "reward": "params_reward.bin",
            "ref": "params_ref.bin",
        },
        "entries": entries,
        "tokenizer": {
            "table": tokenizer_table(cfg.vocab),
            "pad": M.PAD, "bos": M.BOS, "eos": M.EOS,
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    here = os.path.dirname(os.path.abspath(__file__))
    srcs = [os.path.join(here, f) for f in
            ["aot.py", "model.py", "kernels/__init__.py", "kernels/ref.py",
             "kernels/attention.py", "kernels/decode.py", "kernels/gae.py"]]
    with open(os.path.join(out, "aot_fingerprint.txt"), "w") as f:
        f.write(fingerprint(srcs) + f"\npreset={args.preset} kernels={args.kernels}\n")

    print(f"wrote {len(entries)} HLO modules + manifest to {out}/")


if __name__ == "__main__":
    main()
