//! Generalized advantage estimation (Eq. 1) — Rust mirror of
//! `python/compile/kernels/ref.py::gae`, bit-compatible in f32.

/// GAE over row-major `[b, s]` slices.  `mask[t] = 1.0` marks valid
/// transitions; the bootstrap value beyond the episode is zero.
/// Returns `(advantages, returns)` with `returns = adv + values`, both
/// zeroed outside the mask.
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    mask: &[f32],
    b: usize,
    s: usize,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(rewards.len(), b * s);
    assert_eq!(values.len(), b * s);
    assert_eq!(mask.len(), b * s);
    let mut adv = vec![0f32; b * s];
    let mut ret = vec![0f32; b * s];
    for i in 0..b {
        let row = i * s;
        let mut carry = 0f32;
        for t in (0..s).rev() {
            let nm = if t + 1 < s { mask[row + t + 1] } else { 0.0 };
            let nv = if t + 1 < s { values[row + t + 1] } else { 0.0 };
            let delta = rewards[row + t] + gamma * nv * nm - values[row + t];
            carry = delta + gamma * lam * nm * carry;
            adv[row + t] = carry * mask[row + t];
            ret[row + t] = (carry + values[row + t]) * mask[row + t];
        }
    }
    (adv, ret)
}

/// Mean of the masked entries (step-level reward metric for Alg. 1's
/// `reward_scores` window).
pub fn masked_mean(xs: &[f32], mask: &[f32]) -> f32 {
    let mut num = 0f32;
    let mut den = 0f32;
    for (x, m) in xs.iter().zip(mask) {
        num += x * m;
        den += m;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_hand_computed_example() {
        // Same fixture as python/tests/test_kernel.py::test_gae_manual_tiny
        let (gamma, lam) = (0.5, 0.5);
        let r = [1.0, 2.0, 3.0];
        let v = [0.5, 1.0, 1.5];
        let m = [1.0, 1.0, 1.0];
        let (adv, ret) = gae(&r, &v, &m, 1, 3, gamma, lam);
        let want = [1.53125, 2.125, 1.5];
        for (a, w) in adv.iter().zip(&want) {
            assert!((a - w).abs() < 1e-6, "{a} vs {w}");
        }
        for t in 0..3 {
            assert!((ret[t] - (want[t] + v[t])).abs() < 1e-6);
        }
    }

    #[test]
    fn masked_tail_is_zero_and_independent() {
        let s = 6;
        let r1 = [1.0, -0.5, 2.0, 99.0, -99.0, 7.0];
        let r2 = [1.0, -0.5, 2.0, 0.0, 0.0, 0.0];
        let v = [0.1; 6];
        let m = [1.0, 1.0, 1.0, 0.0, 0.0, 0.0];
        let (a1, _) = gae(&r1, &v, &m, 1, s, 0.99, 0.95);
        let (a2, _) = gae(&r2, &v, &m, 1, s, 0.99, 0.95);
        assert_eq!(&a1[..3], &a2[..3]);
        assert!(a1[3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gamma_zero_reduces_to_td_residual() {
        let r = [1.0, 2.0, 3.0];
        let v = [0.5, 0.25, 0.125];
        let m = [1.0; 3];
        let (adv, _) = gae(&r, &v, &m, 1, 3, 0.0, 0.95);
        for t in 0..3 {
            assert!((adv[t] - (r[t] - v[t])).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_row_independence() {
        let r = [1.0, 2.0, /* row 2 */ 5.0, -1.0];
        let v = [0.0, 0.0, 0.0, 0.0];
        let m = [1.0, 1.0, 1.0, 1.0];
        let (adv, _) = gae(&r, &v, &m, 2, 2, 1.0, 1.0);
        // row 0: A1 = 2, A0 = 1 + 2 = 3 ; row 1: A1 = -1, A0 = 5 - 1 = 4
        assert_eq!(adv, vec![3.0, 2.0, 4.0, -1.0]);
    }

    #[test]
    fn masked_mean_basics() {
        assert_eq!(masked_mean(&[1.0, 5.0, 100.0], &[1.0, 1.0, 0.0]), 3.0);
        assert_eq!(masked_mean(&[1.0], &[0.0]), 0.0);
    }
}
