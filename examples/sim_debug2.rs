use oppo::sim::pipeline::{simulate, Pipeline, SimConfig};
use oppo::sim::presets;
fn main() {
    let cfg = SimConfig::new(presets::gsm8k_7b_gh200(), 80, 11);
    for p in [Pipeline::TrlSequential, Pipeline::oppo()] {
        let log = simulate(p, &cfg);
        let tail = &log.records[40..];
        let u: f64 = tail.iter().map(|r| r.util).sum::<f64>() / tail.len() as f64;
        let w: f64 = tail.iter().map(|r| r.wall_s).sum::<f64>() / tail.len() as f64;
        let d: f64 = tail.iter().map(|r| r.delta as f64).sum::<f64>() / tail.len() as f64;
        let g: f64 = tail.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / tail.len() as f64;
        println!("{:8} util {u:.3} wall {w:.1} delta {d:.1} gen_tokens {g:.0}", p.name());
    }
}
