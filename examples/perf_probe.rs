//! §Perf probe: quantifies the device-resident hot path (EXPERIMENTS.md).
//! "Before" = what each chunk would cost if params + KV round-tripped
//! through the host (the unpatched literal-based execute path);
//! "after" = the actual buffer-resident dispatch.
use std::sync::Arc;
use std::time::Instant;
use oppo::coordinator::engine_ops::Ops;
use oppo::runtime::{Engine, ParamSet};

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);
    let m = engine.manifest().shape.clone();
    let (g, s) = (m.lanes, m.s_max);

    // BEFORE-proxy: re-uploading params + KV each chunk call
    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        let _p = ParamSet::load(&engine, "actor")?; // params from host
        for _ in 0..8 {
            let _kv = engine.zeros_f32(&m.kv_shape(g))?; // KV from host
        }
    }
    let upload_cost = t0.elapsed().as_secs_f64() / reps as f64;

    // AFTER: actual chunk dispatch with everything device-resident
    let mut ops = Ops::new(engine.clone(), 0)?;
    let mut tokens = vec![0i32; g * s];
    for lane in 0..g { tokens[lane*s] = 1; tokens[lane*s+1] = 5; }
    let mut state = ops.fresh_actor_state(&tokens)?;
    ops.actor_prefill(&mut state, &tokens, &vec![2; g], &vec![1; g])?;
    let pos = vec![2i32; g];
    let live = vec![1i32; g];
    let c = m.chunk_sizes[1];
    let _ = ops.generate_chunk(&mut state, c, &pos, &live)?; // warm
    let t0 = Instant::now();
    let reps = 10;
    for _ in 0..reps { let _ = ops.generate_chunk(&mut state, c, &pos, &live)?; }
    let chunk_cost = t0.elapsed().as_secs_f64() / reps as f64;

    // L1 flavour comparison: gae (fused jnp) vs gae_pallas (interpret kernel)
    let b = m.ppo_batch;
    let rb = engine.upload_f32(&vec![0.1; b*s], &[b, s])?;
    let vb = engine.upload_f32(&vec![0.0; b*s], &[b, s])?;
    let mb = engine.upload_f32(&vec![1.0; b*s], &[b, s])?;
    for entry in ["gae", "gae_pallas"] {
        let _ = engine.execute(entry, &[&rb, &vb, &mb])?;
        let t0 = Instant::now();
        let reps = 30;
        for _ in 0..reps { let _ = engine.execute(entry, &[&rb, &vb, &mb])?; }
        println!("{entry}: {:.3} ms/call", 1e3 * t0.elapsed().as_secs_f64() / reps as f64);
    }
    println!("host-roundtrip params+KV per chunk (before-proxy): {:.1} ms", 1e3*upload_cost);
    println!("device-resident generate_chunk c={c} (after): {:.1} ms", 1e3*chunk_cost);
    Ok(())
}
