//! Synthetic RLHF tasks — the dataset substitutes (DESIGN.md §1).
//!
//! Each task produces prompts whose correct answers are *rule-checkable*, so
//! the policy has a real learnable reward signal (the GSM8K-with-rule-reward
//! setting of the paper's §4), and whose answer lengths reproduce the
//! properties OPPO exploits:
//!
//! * `Arith`  — "12+34=" → "46".  Short, near-uniform lengths; stands in for
//!   GSM8K (math with rule-based evaluator).
//! * `Copy`   — "rep 7|abc=" → "abcabc…".  The repeat count is heavy-tailed,
//!   so response lengths are long-tailed *by construction*: the straggler
//!   workload of Figure 2b that inter-step overlap targets.
//! * `Sort`   — "srt|dbca=" → "abcd".  Structured output; stands in for the
//!   code-generation workload (OpenCoder).
//! * `Mixed`  — a weighted blend, standing in for free-form Stack-Exchange
//!   (diverse prompt families and length profiles).

use crate::data::tokenizer::{Tokenizer, BOS};
#[cfg(test)]
use crate::data::tokenizer::EOS;
use crate::util::rng::Rng;

/// Which synthetic task family a prompt belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Arith,
    Copy,
    Sort,
}

/// A sampled prompt: token ids (BOS-prefixed), its text, and the reference
/// answer used by the rule reward.
#[derive(Clone, Debug)]
pub struct Prompt {
    pub kind: TaskKind,
    pub text: String,
    pub tokens: Vec<i32>,
    pub answer: String,
    /// monotonically increasing sample id (deferral tracking / Table 2)
    pub id: u64,
}

/// A task family: sampling + rule reward.
#[derive(Clone, Debug)]
pub enum Task {
    Arith {
        /// max operand digits (1..=3 keeps answers in-alphabet)
        max_digits: u32,
    },
    Copy {
        /// lognormal parameters for the repeat count (heavy tail)
        mu: f64,
        sigma: f64,
        max_reps: usize,
    },
    Sort {
        min_len: usize,
        max_len: usize,
    },
    Mixed(Vec<(f64, Task)>),
}

impl Task {
    /// Task by config name (see `TrainConfig::task`).
    pub fn by_name(name: &str) -> Option<Task> {
        match name {
            "arith" => Some(Task::Arith { max_digits: 2 }),
            "copy" => Some(Task::Copy { mu: 1.1, sigma: 0.8, max_reps: 14 }),
            "sort" => Some(Task::Sort { min_len: 3, max_len: 8 }),
            "mixed" => Some(Task::Mixed(vec![
                (0.4, Task::Arith { max_digits: 2 }),
                (0.35, Task::Copy { mu: 1.0, sigma: 0.8, max_reps: 12 }),
                (0.25, Task::Sort { min_len: 3, max_len: 8 }),
            ])),
            _ => None,
        }
    }

    /// Sample one prompt.  `prompt_max` bounds the encoded prompt length
    /// (BOS included); the sampler retries internally if a draw exceeds it.
    pub fn sample(&self, rng: &mut Rng, tok: &Tokenizer, prompt_max: usize, id: u64) -> Prompt {
        for _ in 0..64 {
            let (kind, text, answer) = self.draw(rng);
            if let Ok(body) = tok.encode(&text) {
                if body.len() + 1 <= prompt_max {
                    let mut tokens = Vec::with_capacity(body.len() + 1);
                    tokens.push(BOS);
                    tokens.extend(body);
                    return Prompt { kind, text, tokens, answer, id };
                }
            }
        }
        // fall back to the smallest possible arith prompt
        let text = "1+1=".to_string();
        let mut tokens = vec![BOS];
        tokens.extend(tok.encode(&text).unwrap());
        Prompt { kind: TaskKind::Arith, text, tokens, answer: "2".into(), id }
    }

    fn draw(&self, rng: &mut Rng) -> (TaskKind, String, String) {
        match self {
            Task::Arith { max_digits } => {
                let digits = rng.range(1, *max_digits as u64 + 1) as u32;
                let hi = 10u64.pow(digits);
                let a = rng.range(0, hi);
                let b = rng.range(0, hi);
                // mix + and - (clamped at 0 so answers stay unsigned)
                if rng.bool(0.7) {
                    (TaskKind::Arith, format!("{a}+{b}="), format!("{}", a + b))
                } else {
                    let (a, b) = if a >= b { (a, b) } else { (b, a) };
                    (TaskKind::Arith, format!("{a}-{b}="), format!("{}", a - b))
                }
            }
            Task::Copy { mu, sigma, max_reps } => {
                let reps = (rng.lognormal(*mu, *sigma).round() as usize).clamp(1, *max_reps);
                let len = rng.range_usize(1, 4);
                let pat: String =
                    (0..len).map(|_| (b'a' + rng.range(0, 26) as u8) as char).collect();
                (TaskKind::Copy, format!("rep {reps}|{pat}="), pat.repeat(reps))
            }
            Task::Sort { min_len, max_len } => {
                let len = rng.range_usize(*min_len, *max_len + 1);
                let mut chars: Vec<char> =
                    (0..len).map(|_| (b'a' + rng.range(0, 26) as u8) as char).collect();
                let text: String = chars.iter().collect();
                chars.sort();
                let sorted: String = chars.into_iter().collect();
                (TaskKind::Sort, format!("srt|{text}="), sorted)
            }
            Task::Mixed(parts) => {
                let weights: Vec<f64> = parts.iter().map(|(w, _)| *w).collect();
                let idx = rng.weighted(&weights);
                parts[idx].1.draw(rng)
            }
        }
    }
}

/// Rule-based reward for a decoded response against the reference answer.
///
/// Shaped like the paper's rule evaluators: exact match earns the full
/// reward, near misses earn per-character partial credit, and rambling past
/// the answer is penalized — which is what teaches the policy to emit EOS
/// (and, over training, shortens responses: the evolving length
/// distribution of Figure 2b).
pub fn rule_reward(answer: &str, response: &str) -> f64 {
    if answer.is_empty() {
        return 0.0;
    }
    if response == answer {
        return 1.0;
    }
    let a: Vec<char> = answer.chars().collect();
    let r: Vec<char> = response.chars().collect();
    let matching = a.iter().zip(&r).filter(|(x, y)| x == y).count();
    let partial = matching as f64 / a.len() as f64;
    let overshoot = r.len().saturating_sub(a.len()) as f64;
    (0.8 * partial - 0.02 * overshoot - 0.1).clamp(-0.5, 0.8)
}

/// Held-out accuracy metric (Table 3 substitute): exact-match over a fixed
/// eval set.
pub fn exact_match(answer: &str, response: &str) -> bool {
    answer == response
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::builtin(64)
    }

    #[test]
    fn arith_answers_are_correct() {
        let task = Task::Arith { max_digits: 2 };
        let mut rng = Rng::new(1);
        for id in 0..200 {
            let p = task.sample(&mut rng, &tok(), 24, id);
            let body = &p.text[..p.text.len() - 1]; // strip '='
            let (a, b, add) = if let Some((x, y)) = body.split_once('+') {
                (x, y, true)
            } else {
                let (x, y) = body.split_once('-').unwrap();
                (x, y, false)
            };
            let (a, b): (i64, i64) = (a.parse().unwrap(), b.parse().unwrap());
            let want = if add { a + b } else { a - b };
            assert_eq!(p.answer, want.to_string(), "{}", p.text);
        }
    }

    #[test]
    fn copy_lengths_are_heavy_tailed() {
        let task = Task::by_name("copy").unwrap();
        let mut rng = Rng::new(2);
        let lens: Vec<f64> =
            (0..3000).map(|i| task.sample(&mut rng, &tok(), 24, i).answer.len() as f64).collect();
        let med = crate::util::stats::percentile(&lens, 50.0);
        let p99 = crate::util::stats::percentile(&lens, 99.0);
        assert!(p99 / med >= 3.0, "median {med}, p99 {p99}");
    }

    #[test]
    fn sort_answers_are_sorted_permutations() {
        let task = Task::by_name("sort").unwrap();
        let mut rng = Rng::new(3);
        for id in 0..100 {
            let p = task.sample(&mut rng, &tok(), 24, id);
            let mut input: Vec<char> =
                p.text.trim_start_matches("srt|").trim_end_matches('=').chars().collect();
            input.sort();
            assert_eq!(p.answer, input.into_iter().collect::<String>());
        }
    }

    #[test]
    fn prompts_fit_and_start_with_bos() {
        for name in ["arith", "copy", "sort", "mixed"] {
            let task = Task::by_name(name).unwrap();
            let mut rng = Rng::new(4);
            for id in 0..200 {
                let p = task.sample(&mut rng, &tok(), 24, id);
                assert!(p.tokens.len() <= 24, "{name}: {}", p.text);
                assert_eq!(p.tokens[0], BOS);
                assert!(!p.tokens.contains(&EOS));
            }
        }
    }

    #[test]
    fn rule_reward_ordering() {
        // exact > partial > wrong; overshoot is penalized
        let exact = rule_reward("46", "46");
        let partial = rule_reward("46", "44");
        let wrong = rule_reward("46", "99");
        let ramble = rule_reward("46", "46zzzzzzzz");
        assert_eq!(exact, 1.0);
        assert!(partial > wrong, "{partial} vs {wrong}");
        assert!(ramble < exact);
        assert!(rule_reward("46", "") <= 0.0);
    }

    #[test]
    fn mixed_uses_all_families() {
        let task = Task::by_name("mixed").unwrap();
        let mut rng = Rng::new(5);
        let mut seen = std::collections::HashSet::new();
        for id in 0..300 {
            seen.insert(task.sample(&mut rng, &tok(), 24, id).kind);
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let task = Task::by_name("mixed").unwrap();
        let a: Vec<String> = {
            let mut rng = Rng::new(9);
            (0..20).map(|i| task.sample(&mut rng, &tok(), 24, i).text).collect()
        };
        let b: Vec<String> = {
            let mut rng = Rng::new(9);
            (0..20).map(|i| task.sample(&mut rng, &tok(), 24, i).text).collect()
        };
        assert_eq!(a, b);
    }
}
