//! §4.3 generalization: OPPO's inter-step scheduling applied to DPO —
//! generate B+Δ completions pairwise, update on the first B ranked pairs,
//! carry the overflow.  Demonstrates the scheduler is not PPO-specific.
//!
//! Usage: dpo_overlap [steps]   (default 12)
use oppo::config::TrainConfig;
use oppo::coordinator::dpo::DpoTrainer;

fn main() -> anyhow::Result<()> {
    oppo::util::logging::init();
    let steps: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(12);
    let cfg = TrainConfig {
        mode: oppo::config::Mode::Dpo,
        steps,
        task: "arith".into(),
        log_every: 1,
        ..Default::default()
    };
    let log = DpoTrainer::new(cfg)?.run()?;
    let first = log.records.first().unwrap();
    let last = log.records.last().unwrap();
    println!(
        "DPO: {} steps; margin {:.3} -> {:.3}; loss {:.4} -> {:.4}; carried pool {} pairs",
        log.records.len(), first.mean_score, last.mean_score,
        first.train_stats[0], last.train_stats[0], last.deferred,
    );
    Ok(())
}
