//! Algorithm 1's sequence buffer: a FIFO holding up to `B + Δ` in-flight
//! sequences, each owning one generation lane for its whole life.
//!
//! Invariants (enforced here, property-tested in `tests/test_props.rs`):
//!
//! * `len() <= capacity()` at all times; capacity is `B + Δ` and tracks Δ
//!   as the controller moves it (shrinking capacity never evicts — it only
//!   stops refills, exactly like `Buffer.set_capacity` in Alg. 1).
//! * every buffered sequence owns a distinct lane `< lanes`;
//! * PPO batches take the **first B finished** sequences in completion
//!   order (completion order, not enqueue order — that is the whole point
//!   of inter-step overlap: fast completions are not blocked on stragglers);
//! * unfinished sequences keep their lane and state across steps
//!   ("partial work is preserved", §3.2).

use anyhow::{bail, Result};

use crate::data::tasks::Prompt;
use crate::model::sequence::{SeqPhase, Sequence};

/// The `B + Δ` sequence buffer.
pub struct SeqBuffer {
    seqs: Vec<Sequence>,
    capacity: usize,
    lanes: usize,
    lane_free: Vec<bool>,
    /// monotonically increasing completion stamp
    next_completion: u64,
    /// completion stamp per buffered sequence (u64::MAX = unfinished)
    completed_at: Vec<u64>,
}

impl SeqBuffer {
    pub fn new(capacity: usize, lanes: usize) -> Self {
        assert!(capacity <= lanes, "capacity {capacity} > lanes {lanes}");
        Self {
            seqs: Vec::new(),
            capacity,
            lanes,
            lane_free: vec![true; lanes],
            next_completion: 0,
            completed_at: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Alg. 1 line 25: `Buffer.set_capacity(B + Δ)`.  Never evicts.
    pub fn set_capacity(&mut self, capacity: usize) {
        assert!(capacity <= self.lanes);
        self.capacity = capacity;
    }

    /// Has room for another sequence right now?
    pub fn has_room(&self) -> bool {
        self.seqs.len() < self.capacity
    }

    /// Alg. 1 lines 3-5: admit a prompt, assigning it a free lane.
    /// Returns the lane index.
    pub fn add(&mut self, prompt: Prompt, step: u64) -> Result<usize> {
        if !self.has_room() {
            bail!("buffer full ({}/{})", self.seqs.len(), self.capacity);
        }
        let lane = self
            .lane_free
            .iter()
            .position(|&f| f)
            .ok_or_else(|| anyhow::anyhow!("no free lane (capacity bug)"))?;
        self.lane_free[lane] = false;
        self.seqs.push(Sequence::new(prompt, lane, step));
        self.completed_at.push(u64::MAX);
        Ok(lane)
    }

    /// All sequences still generating (Alg. 1's `get_unfinished`).
    pub fn unfinished(&self) -> impl Iterator<Item = &Sequence> {
        self.seqs.iter().filter(|s| !s.is_finished())
    }

    pub fn unfinished_count(&self) -> usize {
        self.seqs.iter().filter(|s| !s.is_finished()).count()
    }

    pub fn finished_count(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_finished()).count()
    }

    /// Newly queued sequences that still need prompt prefill.
    pub fn queued_lanes(&self) -> Vec<usize> {
        self.seqs
            .iter()
            .filter(|s| s.phase == SeqPhase::Queued)
            .map(|s| s.lane)
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Sequence> {
        self.seqs.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Sequence> {
        self.seqs.iter_mut()
    }

    pub fn by_lane_mut(&mut self, lane: usize) -> Option<&mut Sequence> {
        self.seqs.iter_mut().find(|s| s.lane == lane)
    }

    pub fn by_lane(&self, lane: usize) -> Option<&Sequence> {
        self.seqs.iter().find(|s| s.lane == lane)
    }

    /// Mark a sequence finished (stamps completion order).
    pub fn mark_finished(&mut self, lane: usize) {
        let stamp = self.next_completion;
        if let Some(idx) = self.seqs.iter().position(|s| s.lane == lane) {
            debug_assert!(self.seqs[idx].is_finished());
            if self.completed_at[idx] == u64::MAX {
                self.completed_at[idx] = stamp;
                self.next_completion += 1;
            }
        }
    }

    /// Alg. 1 line 17: `ppo_batch ← finished[:B]` — take (remove) the first
    /// `b` finished sequences in completion order, freeing their lanes.
    /// `current_step` stamps each sequence's deferral (Table 2).
    /// Returns fewer than `b` only if fewer are finished.
    pub fn take_finished(&mut self, b: usize, current_step: u64) -> Vec<Sequence> {
        let mut finished: Vec<(u64, usize)> = self
            .seqs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_finished())
            .map(|(i, s)| {
                debug_assert_ne!(self.completed_at[i], u64::MAX, "finished w/o stamp: lane {}", s.lane);
                (self.completed_at[i], i)
            })
            .collect();
        finished.sort();
        let mut selected: Vec<(u64, usize)> = finished.into_iter().take(b).collect();
        // remove highest indices first (swap_remove-safe), then restore
        // completion-stamp order
        selected.sort_unstable_by(|a, b| b.1.cmp(&a.1));
        let mut out: Vec<(u64, Sequence)> = Vec::with_capacity(selected.len());
        for (stamp, idx) in selected {
            let mut seq = self.seqs.swap_remove(idx);
            self.completed_at.swap_remove(idx);
            self.lane_free[seq.lane] = true;
            seq.deferred_steps = current_step.saturating_sub(seq.enqueued_step);
            out.push((stamp, seq));
        }
        out.sort_by_key(|(stamp, _)| *stamp);
        out.into_iter().map(|(_, s)| s).collect()
    }

    /// Consistency check used by the property tests.  Note: `len` may
    /// transiently exceed `capacity` right after the Δ controller shrinks it
    /// (Alg. 1 never evicts); the capacity bound is an *admission* invariant,
    /// checked in `add`.
    pub fn check_invariants(&self) -> Result<()> {
        if self.completed_at.len() != self.seqs.len() {
            bail!(
                "completion stamps out of sync: {} stamps vs {} sequences",
                self.completed_at.len(),
                self.seqs.len()
            );
        }
        let mut seen = vec![false; self.lanes];
        for (i, s) in self.seqs.iter().enumerate() {
            if s.lane >= self.lanes {
                bail!("lane {} out of range", s.lane);
            }
            if seen[s.lane] {
                bail!("duplicate lane {}", s.lane);
            }
            seen[s.lane] = true;
            if self.lane_free[s.lane] {
                bail!("occupied lane {} marked free", s.lane);
            }
            // finished ⇔ stamped: a stamp implies the sequence really
            // finished, and every finished sequence carries its completion
            // stamp (mark_finished ran) — the ordering take_finished sorts
            // by is meaningless if either direction breaks
            let stamped = self.completed_at[i] != u64::MAX;
            if stamped && !s.is_finished() {
                bail!("lane {}: stamped complete but sequence unfinished", s.lane);
            }
            if s.is_finished() && !stamped {
                bail!("lane {}: finished but never stamped (mark_finished missed)", s.lane);
            }
            if stamped && self.completed_at[i] >= self.next_completion {
                bail!(
                    "lane {}: stamp {} not older than next stamp {}",
                    s.lane, self.completed_at[i], self.next_completion
                );
            }
        }
        let occupied = seen.iter().filter(|&&x| x).count();
        let not_free = self.lane_free.iter().filter(|&&f| !f).count();
        if occupied != not_free {
            bail!("lane accounting mismatch: {occupied} occupied vs {not_free} not-free");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;

    fn prompt(id: u64) -> Prompt {
        Prompt {
            kind: TaskKind::Arith,
            text: "1+1=".into(),
            tokens: vec![1, 5, 40, 5, 44],
            answer: "2".into(),
            id,
        }
    }

    fn finish(buf: &mut SeqBuffer, lane: usize) {
        let s = buf.by_lane_mut(lane).unwrap();
        s.phase = SeqPhase::Generating;
        s.push_token(2, 0.0, 0.0, 2, 8, 100);
        buf.mark_finished(lane);
    }

    #[test]
    fn fill_to_capacity_then_reject() {
        let mut buf = SeqBuffer::new(3, 4);
        for i in 0..3 {
            buf.add(prompt(i), 0).unwrap();
        }
        assert!(!buf.has_room());
        assert!(buf.add(prompt(9), 0).is_err());
        buf.check_invariants().unwrap();
    }

    #[test]
    fn take_finished_respects_completion_order_not_enqueue_order() {
        let mut buf = SeqBuffer::new(4, 4);
        for i in 0..4 {
            buf.add(prompt(i), 0).unwrap();
        }
        // finish in order 2, 0, 3 (lane == enqueue index here)
        finish(&mut buf, 2);
        finish(&mut buf, 0);
        finish(&mut buf, 3);
        let batch = buf.take_finished(2, 1);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].prompt.id, 2); // completed first
        assert_eq!(batch[1].prompt.id, 0);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.finished_count(), 1); // id 3 still buffered
        buf.check_invariants().unwrap();
    }

    #[test]
    fn lanes_are_recycled() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.add(prompt(1), 0).unwrap();
        finish(&mut buf, 0);
        let taken = buf.take_finished(1, 0);
        assert_eq!(taken.len(), 1);
        let lane = buf.add(prompt(2), 1).unwrap();
        assert_eq!(lane, 0); // freed lane reused
        buf.check_invariants().unwrap();
    }

    #[test]
    fn deferral_stamping() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 5).unwrap();
        finish(&mut buf, 0);
        let batch = buf.take_finished(1, 7);
        assert_eq!(batch[0].deferred_steps, 2);
    }

    #[test]
    fn shrinking_capacity_does_not_evict() {
        let mut buf = SeqBuffer::new(4, 4);
        for i in 0..4 {
            buf.add(prompt(i), 0).unwrap();
        }
        buf.set_capacity(2);
        assert_eq!(buf.len(), 4); // over capacity is allowed transiently
        assert!(!buf.has_room());
        // invariant check tolerates the transient only via take; here we
        // simply verify nothing was dropped and no new adds are admitted
        assert!(buf.add(prompt(9), 0).is_err());
    }

    #[test]
    fn invariants_catch_finished_without_stamp() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        let s = buf.by_lane_mut(0).unwrap();
        s.phase = SeqPhase::Generating;
        s.push_token(2, 0.0, 0.0, 2, 8, 100); // EOS => finished
        assert!(buf.check_invariants().is_err(), "finished but unstamped must be caught");
        buf.mark_finished(0);
        buf.check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_stamp_desync() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.check_invariants().unwrap();
        let stamp = buf.completed_at.pop().unwrap();
        assert!(buf.check_invariants().is_err(), "stamp/seq length mismatch must be caught");
        buf.completed_at.push(stamp);
        // a stamp on an unfinished sequence is equally inconsistent
        buf.completed_at[0] = 0;
        buf.next_completion = 1;
        assert!(buf.check_invariants().is_err(), "stamped-but-unfinished must be caught");
    }

    #[test]
    fn take_more_than_finished_returns_what_exists() {
        let mut buf = SeqBuffer::new(3, 3);
        for i in 0..3 {
            buf.add(prompt(i), 0).unwrap();
        }
        finish(&mut buf, 1);
        let batch = buf.take_finished(3, 0);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].prompt.id, 1);
    }
}
