//! Baseline drivers: DPO generalization runs end to end; the async
//! staleness baseline queues and applies updates off-policy.
use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::dpo::DpoTrainer;

#[test]
fn dpo_trainer_runs_and_improves_margin_signal() {
    if !std::path::Path::new("artifacts/manifest.json").exists() { return }
    let cfg = TrainConfig {
        mode: Mode::Dpo,
        steps: 2,
        task: "arith".into(),
        seed: 1,
        log_every: 0,
        max_new_tokens: 24,
        ..Default::default()
    };
    let log = DpoTrainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 2);
    for r in &log.records {
        assert!(r.mean_score > 0.0, "chosen-vs-rejected margin must be positive");
        assert!(r.train_stats[0].is_finite());
        assert_eq!(r.finished, 8);
    }
}
