//! PPO batch assembly: B finished sequences → the dense `[B, S]` host
//! arrays the `gae` / `ppo_update` entry points consume.
//!
//! Alignment contract (shared with `python/compile/model.py::ppo_loss`):
//! a response token generated at absolute position `p = prompt_len + j`
//! occupies index `p` in every array — its log-prob is
//! `log π(tok_p | tok_{<p})`, its value estimate was taken from the hidden
//! state that produced it, and `mask[p] = 1` marks it as trained.

use anyhow::{bail, Result};

use crate::model::sequence::Sequence;
use crate::ppo::reward::{compose_rewards, RewardInputs};

/// Dense PPO inputs for one update step.
#[derive(Clone, Debug)]
pub struct PpoBatch {
    pub b: usize,
    pub s: usize,
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub old_logp: Vec<f32>,
    pub rewards: Vec<f32>,
    pub values: Vec<f32>,
    /// mean sequence-level score of the batch (Alg. 1's `reward_scores`)
    pub mean_score: f32,
    /// per-sequence deferral (steps) for Table 2
    pub deferrals: Vec<u64>,
}

/// Builds [`PpoBatch`]es with fixed `[B, S]` shapes.
pub struct RolloutAssembler {
    s_max: usize,
    kl_beta: f32,
}

impl RolloutAssembler {
    pub fn new(s_max: usize, kl_beta: f32) -> Self {
        Self { s_max, kl_beta }
    }

    /// Assemble a batch.  `scores[i]` is sequence i's blended scalar score;
    /// `ref_logp[i]` holds the reference model's per-token log-probs laid
    /// out `[S]`-dense for sequence i (as returned by `ref_logprobs`).
    pub fn assemble(
        &self,
        seqs: &[&Sequence],
        scores: &[f32],
        ref_logp_dense: &[f32],
    ) -> Result<PpoBatch> {
        let b = seqs.len();
        let s = self.s_max;
        if scores.len() != b || ref_logp_dense.len() != b * s {
            bail!(
                "arity mismatch: {b} seqs, {} scores, {} ref logps",
                scores.len(),
                ref_logp_dense.len()
            );
        }
        let mut tokens = vec![0i32; b * s]; // PAD = 0
        let mut mask = vec![0f32; b * s];
        let mut old_logp = vec![0f32; b * s];
        let mut rewards = vec![0f32; b * s];
        let mut values = vec![0f32; b * s];
        let mut deferrals = Vec::with_capacity(b);
        let mut score_sum = 0f32;

        for (i, seq) in seqs.iter().enumerate() {
            if !seq.is_finished() {
                bail!("sequence {} not finished", i);
            }
            let row = i * s;
            let p0 = seq.prompt_len;
            let n = seq.response.len();
            if p0 + n > s {
                bail!("sequence {} overflows s_max: {} + {n} > {s}", i, p0);
            }
            tokens[row..row + p0].copy_from_slice(&seq.prompt.tokens);
            tokens[row + p0..row + p0 + n].copy_from_slice(&seq.response);

            // reference log-probs for the response span, dense layout [S]
            let ref_row = &ref_logp_dense[i * s..(i + 1) * s];
            let per_tok = compose_rewards(&RewardInputs {
                score: scores[i],
                actor_logp: &seq.logps,
                ref_logp: &ref_row[p0..p0 + n],
                kl_beta: self.kl_beta,
            });
            for j in 0..n {
                let p = row + p0 + j;
                mask[p] = 1.0;
                old_logp[p] = seq.logps[j];
                values[p] = seq.values[j];
                rewards[p] = per_tok[j];
            }
            score_sum += scores[i];
            deferrals.push(seq.deferred_steps);
        }

        Ok(PpoBatch {
            b,
            s,
            tokens,
            mask,
            old_logp,
            rewards,
            values,
            mean_score: if b > 0 { score_sum / b as f32 } else { 0.0 },
            deferrals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{Prompt, TaskKind};
    use crate::model::sequence::SeqPhase;

    fn seq(prompt_len: usize, resp: &[i32], lane: usize) -> Sequence {
        let mut s = Sequence::new(
            Prompt {
                kind: TaskKind::Arith,
                text: "x".into(),
                tokens: (0..prompt_len as i32).map(|i| i + 3).collect(),
                answer: "y".into(),
                id: lane as u64,
            },
            lane,
            0,
        );
        s.phase = SeqPhase::Generating;
        for (j, &t) in resp.iter().enumerate() {
            s.logps.push(-0.1 * (j + 1) as f32);
            s.values.push(0.2 * j as f32);
            s.response.push(t);
        }
        s.phase = SeqPhase::Finished;
        s
    }

    #[test]
    fn layout_and_masking() {
        let s_max = 16;
        let asm = RolloutAssembler::new(s_max, 0.0);
        let a = seq(3, &[10, 11, 2], 0);
        let b = seq(5, &[20, 2], 1);
        let scores = [1.0, -0.5];
        let ref_lp = vec![0f32; 2 * s_max];
        let batch = asm.assemble(&[&a, &b], &scores, &ref_lp).unwrap();

        // row 0: tokens 3,4,5 then 10,11,2
        assert_eq!(&batch.tokens[0..6], &[3, 4, 5, 10, 11, 2]);
        assert_eq!(&batch.mask[0..8], &[0., 0., 0., 1., 1., 1., 0., 0.]);
        // score lands on the last response token (index 5), KL beta = 0
        assert_eq!(batch.rewards[5], 1.0);
        assert_eq!(batch.rewards[4], 0.0);
        // row 1
        let r1 = s_max;
        assert_eq!(&batch.tokens[r1..r1 + 7], &[3, 4, 5, 6, 7, 20, 2]);
        assert_eq!(batch.rewards[r1 + 6], -0.5);
        assert!((batch.mean_score - 0.25).abs() < 1e-6);
    }

    #[test]
    fn kl_penalty_applied_per_token() {
        let s_max = 8;
        let asm = RolloutAssembler::new(s_max, 0.5);
        let a = seq(2, &[10, 2], 0);
        // ref logp dense: response occupies positions 2..4
        let mut ref_lp = vec![0f32; s_max];
        ref_lp[2] = -0.5; // actor logp[0] = -0.1 => KL term = -0.5*(-0.1+0.5) = -0.2
        ref_lp[3] = -0.2;
        let batch = asm.assemble(&[&a], &[2.0], &ref_lp).unwrap();
        assert!((batch.rewards[2] - (-0.5 * (-0.1 + 0.5))).abs() < 1e-6);
        assert!((batch.rewards[3] - (2.0 + -0.5 * (-0.2 + 0.2))).abs() < 1e-6);
    }

    #[test]
    fn rejects_unfinished_or_mismatched() {
        let s_max = 8;
        let asm = RolloutAssembler::new(s_max, 0.0);
        let mut a = seq(2, &[10], 0);
        a.phase = SeqPhase::Generating;
        assert!(asm.assemble(&[&a], &[0.0], &vec![0.0; s_max]).is_err());
        let b = seq(2, &[10, 2], 0);
        assert!(asm.assemble(&[&b], &[0.0, 1.0], &vec![0.0; s_max]).is_err());
        assert!(asm.assemble(&[&b], &[0.0], &vec![0.0; 4]).is_err());
    }

    #[test]
    fn overflow_is_rejected() {
        let asm = RolloutAssembler::new(4, 0.0);
        let a = seq(3, &[10, 11, 2], 0);
        assert!(asm.assemble(&[&a], &[0.0], &vec![0.0; 4]).is_err());
    }
}
