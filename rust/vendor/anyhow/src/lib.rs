//! Vendored mini-`anyhow` for the offline build (no crates.io access).
//!
//! Implements exactly the surface this repository uses: [`Error`] with a
//! context chain, [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Alternate formatting (`{:#}`) renders the whole context chain joined by
//! `": "`, matching upstream anyhow closely enough for the error-message
//! assertions in the test suite.

use std::fmt;

/// An error with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }
}

/// Iterator over an error's context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next.take()?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut rest = self.source.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // flatten the std error's own source chain into ours
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Box<Error>> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Box::new(Error { msg, source: err }));
        }
        *err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("Condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn context_chain_renders_alternate() {
        let err = fails().context("outer").unwrap_err();
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:#}"), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let x: Option<i32> = None;
        let err = x.context("missing thing").unwrap_err();
        assert_eq!(format!("{err}"), "missing thing");
        let y: Option<i32> = Some(3);
        assert_eq!(y.with_context(|| "nope").unwrap(), 3);
    }

    #[test]
    fn std_error_converts() {
        let io = std::fs::read("/definitely/not/a/path").unwrap_err();
        let err: Error = io.into();
        assert!(!format!("{err}").is_empty());
    }

    #[test]
    fn ensure_forms() {
        fn check(x: usize) -> Result<()> {
            ensure!(x < 10);
            ensure!(x != 3, "three is right out (got {x})");
            Ok(())
        }
        assert!(check(2).is_ok());
        assert!(format!("{:#}", check(12).unwrap_err()).contains("Condition failed"));
        assert!(format!("{:#}", check(3).unwrap_err()).contains("three"));
    }
}
