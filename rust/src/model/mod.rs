//! Host-side model state: per-lane sequence lifecycle and PPO batch
//! assembly.  The heavy tensors (params, KV caches, token buffers) stay
//! device-resident in the runtime; this module tracks the small per-sequence
//! bookkeeping the coordinator schedules with.

pub mod rollout;
pub mod sequence;

pub use rollout::{PpoBatch, RolloutAssembler};
pub use sequence::{SeqPhase, Sequence};
