//! Vendored mini-`log` facade for the offline build (no crates.io access).
//!
//! Provides the macros (`error!` .. `trace!`), the [`Log`] trait,
//! [`Level`] / [`LevelFilter`] with the cross-type ordering the real crate
//! has, and the global logger installation functions used by
//! `oppo::util::logging`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // honor width/alignment specifiers like "{:5}"
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed globally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (what `Log::enabled` filters on).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, passed to `Log::log`.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if let Some(logger) = LOGGER.get() {
        logger.log(&Record { metadata: Metadata { level, target }, args });
    }
}

#[doc(hidden)]
#[macro_export]
macro_rules! __log_impl {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log_impl!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log_impl!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log_impl!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log_impl!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log_impl!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Trace);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn macros_respect_max_level() {
        // no logger installed: must be a no-op rather than a panic
        set_max_level(LevelFilter::Trace);
        info!("smoke {}", 1);
        debug!("smoke {}", 2);
    }
}
