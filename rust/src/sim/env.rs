//! The simulator wrapped as a gym-style control environment, plus the
//! Q-policy training loop behind `oppo train-controller`.
//!
//! [`PipelineEnv`] exposes the discrete-event simulator one PPO step at a
//! time: the observation is the binned [`StepTelemetry`] state (the same
//! encoding [`crate::ctl::LearnedController`] replays at deploy time), the
//! action is a [`QAction`] — a discrete nudge to the chunk-size index, the
//! overcommit Δ level, or the reward-replica count — and the reward is
//! step throughput penalized by convergence-proxy regression.
//! [`train_qpolicy`] runs pinned-seed ε-greedy tabular Q-learning with
//! Dyna-Q planning across the two benchmark presets (`stackex_7b_h200`,
//! `traffic_7b_h200`), freezes the policy, and prices the learned arm
//! against the heuristic controllers on both — the trained artifact is
//! only worth shipping if it wins where the heuristics already play.
//!
//! Two training tricks carry the sample budget (the CI smoke trains only
//! 50 episodes): **Dyna-Q planning** replays [`N_PLAN`] model-simulated
//! backups per real step, so each environment transition is squeezed for
//! [`N_PLAN`]+1 value updates instead of one; **mixed starts** alternate
//! deploy-state episodes (the knobs the frozen policy will actually start
//! from) with exploring starts at random knob corners, so the table sees
//! both the deployment trajectory and the wider knob space.

use crate::ctl::qpolicy::{
    encode_state, level_of, KnobBounds, KnobState, QAction, QPolicy, DELTA_LEVELS, N_ACTIONS,
};
use crate::sim::pipeline::{
    chunk_candidates, learned_bounds, simulate, steady_state_latency, Pipeline, SimConfig,
    SimCore, SimKnobs, DEFAULT_CHUNK_IDX,
};
use crate::sim::presets;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Control steps per training episode (after the warm-up step).
pub const EPISODE_STEPS: u64 = 400;
/// Sim steps per pricing run (heuristic vs learned arms).
pub const EVAL_STEPS: usize = 120;
/// Weight of the convergence-proxy regression penalty in the env reward:
/// a declining mean batch reward subtracts `λ · |trend|` from the step
/// throughput, so the policy cannot buy speed with reward collapse.
pub const REGRESSION_PENALTY: f64 = 10.0;
/// Dyna-Q planning updates replayed from the learned model per real step.
pub const N_PLAN: usize = 8;

const ALPHA: f64 = 0.2;
const GAMMA: f64 = 0.9;
const EPS_START: f64 = 0.5;
const EPS_END: f64 = 0.05;

/// Gym-style wrapper over [`SimCore`]: `reset` rebuilds the simulator at a
/// pinned seed, `step` applies one discrete knob adjustment and advances
/// one PPO step.  States, actions, and knob clamping are shared with the
/// deploy-time [`crate::ctl::LearnedController`], so the policy trains on
/// exactly the dynamics it will replay.
pub struct PipelineEnv {
    pipeline: Pipeline,
    cfg: SimConfig,
    core: SimCore,
    bounds: KnobBounds,
    candidates: Vec<usize>,
    knobs: KnobState,
    episode_len: u64,
}

impl PipelineEnv {
    pub fn new(pipeline: Pipeline, cfg: &SimConfig, episode_len: u64) -> Self {
        let candidates = chunk_candidates(cfg);
        let bounds = learned_bounds(cfg, candidates.len());
        let mut env = Self {
            pipeline,
            cfg: cfg.clone(),
            core: SimCore::new(pipeline, cfg),
            bounds,
            candidates,
            knobs: KnobState::default(),
            episode_len,
        };
        env.reset(cfg.seed);
        env
    }

    /// Start a fresh episode at `seed` from the deploy-time initial knobs;
    /// returns the initial state id.
    pub fn reset(&mut self, seed: u64) -> usize {
        self.reset_from(seed, None)
    }

    /// Start a fresh episode at `seed`, optionally from an explicit knob
    /// state (exploring starts).  `None` uses the same initial knobs
    /// [`crate::sim::pipeline::build_controller`] hands the deployed
    /// learned arm.  One warm-up sim step runs under the starting knobs so
    /// the first observation is real telemetry — the same alignment the
    /// deploy loop has (act only after observing a completed step).
    pub fn reset_from(&mut self, seed: u64, start: Option<KnobState>) -> usize {
        let mut cfg = self.cfg.clone();
        cfg.seed = seed;
        self.core = SimCore::new(self.pipeline, &cfg);
        self.knobs = start.unwrap_or(KnobState {
            chunk_idx: DEFAULT_CHUNK_IDX,
            delta_level: level_of((cfg.delta_max / 2).max(1), &self.bounds),
            replicas: cfg.reward_replicas.max(1),
        });
        self.knobs.clamp(&self.bounds);
        let knobs = self.sim_knobs();
        self.core.step(&knobs);
        self.state()
    }

    /// Binned state id of the latest telemetry under the current knobs.
    pub fn state(&self) -> usize {
        encode_state(self.core.telemetry(), &self.knobs, &self.bounds)
    }

    /// The chunk-size grid the env's chunk index walks.
    pub fn candidates(&self) -> &[usize] {
        &self.candidates
    }

    /// Knob bounds the env clamps every action into.
    pub fn bounds(&self) -> &KnobBounds {
        &self.bounds
    }

    fn sim_knobs(&self) -> SimKnobs {
        let idx = self.knobs.chunk_idx.min(self.candidates.len() - 1);
        SimKnobs {
            chunk_tokens: self.candidates[idx] as f64,
            delta: self.knobs.delta(&self.bounds),
            reward_replicas: self.knobs.replicas,
        }
    }

    /// Apply one discrete adjustment and advance one PPO step.  Returns
    /// `(next_state, reward, done)`; `done` flips after `episode_len`
    /// control steps (the warm-up step does not count).
    pub fn step(&mut self, a: QAction) -> (usize, f64, bool) {
        self.knobs.apply(a, &self.bounds);
        let knobs = self.sim_knobs();
        self.core.step(&knobs);
        let t = self.core.telemetry();
        let throughput = t.finished as f64 / t.wall_s.max(1e-9);
        let regression = (-t.reward_trend).max(0.0);
        let reward = throughput - REGRESSION_PENALTY * regression;
        let done = self.core.steps_run() > self.episode_len;
        (self.state(), reward, done)
    }
}

/// One preset's heuristic-vs-learned pricing.
#[derive(Clone, Debug)]
pub struct ArmEval {
    pub preset: String,
    pub heuristic_steps_per_s: f64,
    pub learned_steps_per_s: f64,
    /// learned / heuristic step throughput (≥ 1.0 means the policy wins).
    pub speedup: f64,
}

/// What a training run produced, for the CLI and the bench snapshot.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub episodes: u64,
    pub seed: u64,
    pub visited_cells: usize,
    pub arms: Vec<ArmEval>,
}

impl TrainReport {
    pub fn to_json(&self) -> Value {
        let arms = self
            .arms
            .iter()
            .map(|a| {
                json::obj(vec![
                    ("preset", json::s(&a.preset)),
                    ("heuristic_steps_per_s", json::num(a.heuristic_steps_per_s)),
                    ("learned_steps_per_s", json::num(a.learned_steps_per_s)),
                    ("speedup", json::num(a.speedup)),
                ])
            })
            .collect();
        json::obj(vec![
            ("episodes", json::num(self.episodes as f64)),
            ("seed", json::num(self.seed as f64)),
            ("visited_cells", json::num(self.visited_cells as f64)),
            ("arms", Value::Arr(arms)),
        ])
    }
}

/// The two presets the controller trains on and is priced against:
/// step-boundary StackEx-7B and its rolling-Poisson traffic variant.
pub fn training_configs(seed: u64) -> Vec<(String, SimConfig)> {
    let stackex = SimConfig::new(presets::stackex_7b_h200(), EVAL_STEPS, seed);
    let tsu = presets::traffic_7b_h200();
    let rate = tsu.arrival_rate;
    let traffic = SimConfig::new(tsu, EVAL_STEPS, seed).rolling_poisson(rate);
    vec![("stackex_7b_h200".to_string(), stackex), ("traffic_7b_h200".to_string(), traffic)]
}

/// Pinned-seed tabular Dyna-Q over [`PipelineEnv`], alternating the two
/// presets episode by episode and the start distribution every other
/// episode pair, then a frozen-policy pricing pass.  Fully deterministic:
/// the same `(episodes, seed)` produce a byte-identical policy artifact.
pub fn train_qpolicy(episodes: u64, seed: u64) -> (QPolicy, TrainReport) {
    let cfgs = training_configs(seed);
    let n_chunks = chunk_candidates(&cfgs[0].1).len();
    let mut policy = QPolicy::new(seed, n_chunks);
    let mut rng = Rng::new(seed ^ 0x9C11);
    let mut envs: Vec<PipelineEnv> = cfgs
        .iter()
        .map(|(_, c)| PipelineEnv::new(Pipeline::oppo(), c, EPISODE_STEPS))
        .collect();

    // Dyna-Q world model: per (state, action) a visit count, the running
    // mean reward, and the last observed next state.
    let mut model: Vec<Option<(u64, f64, usize)>> =
        vec![None; crate::ctl::qpolicy::N_STATES * N_ACTIONS];
    let mut seen: Vec<usize> = Vec::new();

    for ep in 0..episodes {
        let env = &mut envs[(ep % 2) as usize];
        let ep_seed = seed ^ (0x51D2 + ep).wrapping_mul(0x9E3779B97F4A7C15);
        // alternate deploy-state starts with exploring starts so the table
        // covers both the deployment trajectory and random knob corners
        let start = if (ep / 2) % 2 == 1 {
            Some(KnobState {
                chunk_idx: rng.range_usize(0, env.candidates().len()),
                delta_level: rng.range_usize(0, DELTA_LEVELS),
                replicas: rng
                    .range_usize(env.bounds().min_replicas, env.bounds().max_replicas + 1),
            })
        } else {
            None
        };
        let mut s = env.reset_from(ep_seed, start);
        let eps =
            EPS_START + (EPS_END - EPS_START) * (ep as f64 / (episodes.max(2) - 1) as f64);
        for _ in 0..EPISODE_STEPS {
            let a = policy.epsilon_greedy(s, eps, &mut rng);
            let (s2, reward, _) = env.step(a);
            policy.update(s, a, reward, s2, ALPHA, GAMMA);
            let key = s * N_ACTIONS + a.index();
            match &mut model[key] {
                Some((n, ravg, next)) => {
                    *n += 1;
                    *ravg += (reward - *ravg) / *n as f64;
                    *next = s2;
                }
                None => {
                    model[key] = Some((1, reward, s2));
                    seen.push(key);
                }
            }
            for _ in 0..N_PLAN {
                let planned = seen[rng.range_usize(0, seen.len())];
                let (_, ravg, next) = model[planned].expect("seen keys are modeled");
                policy.update(
                    planned / N_ACTIONS,
                    QAction::from_index(planned % N_ACTIONS),
                    ravg,
                    next,
                    ALPHA,
                    GAMMA,
                );
            }
            s = s2;
        }
    }
    policy.episodes = episodes;

    let arms = cfgs
        .iter()
        .map(|(name, cfg)| {
            let heuristic = steady_state_latency(&simulate(Pipeline::oppo(), cfg));
            let learned = steady_state_latency(&simulate(
                Pipeline::oppo(),
                &cfg.clone().learned(policy.clone()),
            ));
            ArmEval {
                preset: name.clone(),
                heuristic_steps_per_s: 1.0 / heuristic.max(1e-12),
                learned_steps_per_s: 1.0 / learned.max(1e-12),
                speedup: heuristic / learned.max(1e-12),
            }
        })
        .collect();

    let report = TrainReport {
        episodes,
        seed,
        visited_cells: policy.visited_cells(),
        arms,
    };
    (policy, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_reset_is_deterministic() {
        let cfg = SimConfig::new(presets::stackex_7b_h200(), 20, 7);
        let mut env = PipelineEnv::new(Pipeline::oppo(), &cfg, 10);
        let s0 = env.reset(42);
        let mut trace = Vec::new();
        for i in 0..10 {
            let (s2, r, _) = env.step(QAction::from_index(i % N_ACTIONS));
            trace.push((s2, r));
        }
        let s0b = env.reset(42);
        assert_eq!(s0, s0b, "same seed must reproduce the initial state");
        for (i, &(s2, r)) in trace.iter().enumerate() {
            let (t2, q, _) = env.step(QAction::from_index(i % N_ACTIONS));
            assert_eq!(s2, t2);
            assert!((r - q).abs() < 1e-12);
        }
    }

    #[test]
    fn env_episode_terminates() {
        let cfg = SimConfig::new(presets::stackex_7b_h200(), 20, 7);
        let mut env = PipelineEnv::new(Pipeline::oppo(), &cfg, 5);
        env.reset(1);
        let mut done = false;
        for _ in 0..5 {
            done = env.step(QAction::NOOP).2;
        }
        assert!(done, "episode must finish after episode_len control steps");
    }

    #[test]
    fn exploring_start_respects_bounds() {
        let cfg = SimConfig::new(presets::stackex_7b_h200(), 20, 7);
        let mut env = PipelineEnv::new(Pipeline::oppo(), &cfg, 5);
        let wild = KnobState { chunk_idx: 99, delta_level: 99, replicas: 99 };
        env.reset_from(3, Some(wild));
        let s = env.state();
        assert!(s < crate::ctl::qpolicy::N_STATES);
    }

    #[test]
    fn tiny_training_run_is_deterministic_and_prices_both_presets() {
        let (p1, r1) = train_qpolicy(4, 0);
        let (p2, _) = train_qpolicy(4, 0);
        assert_eq!(p1.to_artifact_string(), p2.to_artifact_string());
        assert_eq!(r1.arms.len(), 2);
        for arm in &r1.arms {
            assert!(arm.heuristic_steps_per_s > 0.0);
            assert!(arm.learned_steps_per_s > 0.0);
        }
    }
}
