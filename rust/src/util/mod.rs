//! Small self-contained utilities (the offline crate set has no serde /
//! rand / proptest, so these are hand-rolled — see DESIGN.md §2).

pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Format a duration in seconds with adaptive units (for bench tables).
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.1}h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1}min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

/// Left-pad / truncate a cell for fixed-width bench tables.
pub fn cell(s: &str, w: usize) -> String {
    if s.len() >= w {
        s[..w].to_string()
    } else {
        format!("{}{}", " ".repeat(w - s.len()), s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(7200.0), "2.0h");
        assert_eq!(fmt_secs(90.0), "1.5min");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(0.0015), "1.50ms");
        assert_eq!(fmt_secs(2e-5), "20.00us");
    }

    #[test]
    fn cell_pads_and_truncates() {
        assert_eq!(cell("ab", 4), "  ab");
        assert_eq!(cell("abcdef", 4), "abcd");
    }
}
