//! The OPPO training scheduler — Algorithm 1, plus every baseline the
//! paper compares against, driven over real AOT-compiled compute.
//!
//! One [`OppoScheduler`] owns: the `B + Δ` sequence buffer, the actor-side
//! device state, a set of downstream **stage sinks** fed by streamed chunks
//! (intra-step overlap — reward prefill *and* reference-logprob prefill run
//! concurrently with actor decoding), the dynamic Δ and chunk-size
//! controllers, and the PPO update path (`gae → ppo_update`).
//! [`config::Mode`] selects between full OPPO, the ablation arms
//! (including `oppo-no-ref`, which streams reward but scores the reference
//! model monolithically), the TRL-style sequential baseline, and the async
//! staleness-k baseline.
//!
//! Step anatomy (mode = `Oppo`):
//!
//! ```text
//! fill buffer to B+Δ ──► prefill new lanes                 (Alg.1 l.3-5)
//! while |finished| < B:                                    (Alg.1 l.7)
//!     fan chunk k-1 out to every stage    ┐ parallel       (Alg.1 l.12-15)
//!     {reward, ref} prefill chunk k-1     │
//!     actor decodes chunk k               ┘
//!     fold sampled tokens into sequences; mark EOS
//! flush: join all stage streams
//! ppo_batch = first B finished; Δ’s unfinished stay        (Alg.1 l.17-20)
//! rewards (+KL from streamed ref logps) → GAE → ppo_update
//! Δ controller observes the reward window                  (Alg.1 l.21-27)
//! chunk controller observes the step latency               (§3.1)
//! ```
//!
//! Adding a stage (critic, a remote-node consumer) means adding a
//! [`StreamSink`] variant; this loop is stage-count agnostic.  Scaling a
//! stage means raising its replica count (`reward_replicas` /
//! `ref_replicas`): each sink is a [`StagePool`] that splits chunks
//! lane-wise with sequence-affinity routing, invisible to this loop.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{Mode, TrainConfig};
use crate::coordinator::block_pool::BlockPool;
use crate::coordinator::buffer::SeqBuffer;
use crate::coordinator::engine_ops::{ActorState, ChunkOut, Ops};
use crate::ctl::{
    ChunkController, Controller, DeltaController, HeuristicController, KnobBounds, KnobState,
    LearnedController, Policy, QPolicy, StepTelemetry,
};
use crate::coordinator::worker::{
    RefSink, RefWorker, RewardReq, RewardResp, RewardWorker, StreamChunk, StreamSink,
};
use crate::data::queue::{Arrivals, PromptQueue, QueuedPrompt};
use crate::data::tasks::{rule_reward, Task};
use crate::data::tokenizer::{Tokenizer, EOS};
use crate::data::PromptSampler;
use crate::metrics::{PromptLatency, RunLog, StageTiming, StepRecord};
use crate::model::rollout::{PpoBatch, RolloutAssembler};
use crate::model::sequence::{SeqPhase, Sequence};
use crate::ppo::gae::masked_mean;
use crate::runtime::{Engine, ParamSet};

/// A fully-scored rollout waiting for its (possibly delayed) update —
/// used by the async staleness-k baseline.
struct PendingUpdate {
    batch: PpoBatch,
    /// mean sequence score at assembly time, recorded when the deferred
    /// update finally applies (end-of-run drain included)
    mean_score: f64,
}

/// The OPPO coordinator over real compute.
pub struct OppoScheduler {
    cfg: TrainConfig,
    engine: Arc<Engine>,
    ops: Ops,
    /// active streaming stages, fed every chunk during generation
    sinks: Vec<StreamSink>,
    /// monolithic reward scorer for the non-streamed modes
    mono_reward: Option<RewardWorker>,
    /// bounded prompt queue in front of the buffer (rolling admission);
    /// under `AdmissionMode::Step` it degenerates to a pass-through over
    /// the sampler, so the legacy fill loop is unchanged
    queue: PromptQueue,
    tokenizer: Tokenizer,
    buffer: SeqBuffer,
    /// the control loop (heuristic or learned, per `cfg.controller`): one
    /// [`StepTelemetry`] in per step, one [`crate::ctl::ControlActions`] out
    ctl: Box<dyn Controller + Send>,
    /// chunk size the next step runs with, cached from `ctl.actions()`
    cur_chunk: usize,
    /// overcommit Δ the next step runs with, cached from `ctl.actions()`
    cur_delta: usize,
    /// previous step's mean batch score (telemetry `reward_trend` input)
    last_mean_score: f64,
    assembler: RolloutAssembler,
    actor_state: ActorState,
    /// paged-KV allocator (`Some` iff the artifacts ship the paged entry
    /// family): admission gates on its *free blocks*, not just free lanes,
    /// and every device call routes KV through the per-lane block tables.
    /// `None` selects the dense per-lane KV path, bit-identical to before.
    block_pool: Option<BlockPool>,
    /// persistent host-authoritative `[G, S]` token mirror.  `actor_prefill`
    /// replaces the device token buffer wholesale from this slice, so every
    /// lane's row is kept current *incrementally*: admission rewrites the
    /// admitted lane's row, `process_chunk` appends each accepted token.
    /// Nothing ever rebuilds it from scratch.
    host_mirror: Vec<i32>,
    /// monotonic chunk-tick clock: one tick per `generate_chunk` call (plus
    /// idle ticks while waiting for traffic), never reset across steps.
    /// All per-prompt latency accounting is in these units.
    tick: u64,
    log: RunLog,
    /// Adam step counter (1-based across the whole run)
    update_count: i32,
    /// staleness queue for `Mode::AsyncStale`
    stale_queue: VecDeque<PendingUpdate>,
    /// clone of the most recent step's selected PPO batch (test hook: lets
    /// engine-gated tests recompute streamed scores densely)
    last_selected: Vec<Sequence>,
    started: Instant,
}

/// Per-step generation counters (rolling admission telemetry).
#[derive(Default)]
struct GenStats {
    /// tokens accepted into sequences
    gen_tokens: usize,
    /// prompts admitted into lanes mid-step
    admitted_mid_step: usize,
    /// lane-ticks available (every tick contributes `lanes`)
    lane_slots: usize,
    /// lane-ticks with no live sequence decoding
    idle_lane_slots: usize,
    /// peak KV commitment over the step's chunk boundaries, in tokens:
    /// block-rounded allocated tokens on the paged path, resident lanes ×
    /// `s_max` on the dense path (a dense lane pins a full row for life)
    peak_kv_tokens: usize,
}

impl OppoScheduler {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let engine = Arc::new(Engine::load(&cfg.artifacts_dir)?);
        Self::with_engine(cfg, engine)
    }

    /// Share one engine across schedulers (mode-comparison tests/benches
    /// avoid recompiling the artifacts per run).
    pub fn with_engine(cfg: TrainConfig, engine: Arc<Engine>) -> Result<Self> {
        cfg.validate()?;
        let m = engine.manifest().shape.clone();
        cfg.validate_against_manifest(
            m.ppo_batch, m.lanes, &m.chunk_sizes, m.s_max, m.prompt_max,
        )?;
        let tokenizer = Tokenizer::from_manifest(&engine.manifest().tokenizer)?;
        let task = Task::by_name(&cfg.task).context("unknown task")?;
        let sampler = PromptSampler::new(task, tokenizer.clone(), m.prompt_max, cfg.seed);
        // Step mode never queues (it pulls a prompt whenever a lane frees at
        // the step boundary), so it shares the saturated arrival process —
        // identical prompt stream to the legacy direct-sampler fill loop
        let arrivals = match cfg.admission_mode {
            crate::config::AdmissionMode::Poisson => {
                Arrivals::Poisson { rate: cfg.admission_rate }
            }
            _ => Arrivals::Saturated,
        };
        let mut queue = PromptQueue::new(sampler, arrivals, cfg.admission_queue_depth, cfg.seed);
        // admission-time length guard: a prompt that cannot finish within
        // the lane budget is shed at enqueue (distinct drop reason) rather
        // than admitted and caught by the mid-chunk clamp check
        queue.set_length_guard(m.s_max.saturating_sub(cfg.max_new_tokens).max(1));

        let (delta_init, delta_min, delta_max) = if cfg.mode.inter_enabled() {
            (cfg.delta_init, cfg.delta_min, cfg.delta_max)
        } else {
            (0, 0, 0) // sequential / no-inter: no overcommitment
        };
        let delta_policy = if cfg.adaptive_delta && cfg.mode.inter_enabled() {
            Policy::Eq4
        } else {
            Policy::Fixed
        };
        let probes = 1;
        let adaptive_chunk = cfg.adaptive_chunk
            && cfg.mode.intra_enabled()
            && cfg.explore_every >= m.chunk_sizes.len() * probes;

        // both arms answer through the same `Controller` trait; which one
        // is behind the box is decided once, here, by `cfg.controller`
        let ctl: Box<dyn Controller + Send> = match cfg.controller.as_str() {
            "learned" => {
                let path = cfg.controller_policy.as_deref().context(
                    "controller = \"learned\" needs controller_policy \
                     (train one with `oppo train-controller`)",
                )?;
                let policy = QPolicy::load(path)?;
                // start from the configured chunk size's slot in the
                // compiled candidate set — the policy walks indices from
                // there, exactly like the training environment did
                let chunk_idx = m
                    .chunk_sizes
                    .iter()
                    .position(|&c| c == cfg.chunk_size)
                    .unwrap_or(m.chunk_sizes.len() / 2);
                let bounds = KnobBounds {
                    n_chunks: m.chunk_sizes.len(),
                    delta_min,
                    delta_max,
                    // the runtime spawns its replica pools once at startup,
                    // so the replica knob is pinned to the configured count
                    min_replicas: cfg.reward_replicas,
                    max_replicas: cfg.reward_replicas,
                };
                let initial = KnobState {
                    chunk_idx,
                    delta_level: crate::ctl::level_of(delta_init, &bounds),
                    replicas: cfg.reward_replicas,
                };
                Box::new(LearnedController::new(
                    policy,
                    m.chunk_sizes.clone(),
                    bounds,
                    initial,
                )?)
            }
            _ => {
                let delta_ctl = DeltaController::new(
                    delta_init, delta_min, delta_max, cfg.window, delta_policy,
                );
                // construction-time manifest check: every candidate the
                // controller may pick must have a compiled `c{C}` entry
                let chunk_ctl = ChunkController::try_new(
                    m.chunk_sizes.clone(),
                    cfg.chunk_size,
                    cfg.explore_every.max(m.chunk_sizes.len() * probes),
                    probes,
                    adaptive_chunk,
                    &m.chunk_sizes,
                )?;
                Box::new(HeuristicController::full(chunk_ctl, delta_ctl))
            }
        };
        let a0 = ctl.actions();
        let cur_chunk = a0.chunk.unwrap_or(cfg.chunk_size);
        let cur_delta = a0.delta.unwrap_or(delta_init);

        let ops = Ops::new(engine.clone(), cfg.seed)?;

        // ---- downstream stage set (the N-stage fan-out targets) ----
        // each streaming stage is a replica pool: chunks split lane-wise
        // (`lane % replicas`) so a slow scorer stops being the streaming
        // bottleneck without breaking per-sequence KV affinity
        let mut sinks: Vec<StreamSink> = Vec::new();
        let mut mono_reward = None;
        // paged KV is selected at spawn, exactly like the sliced/masked
        // split: artifacts without the paged entry family run the dense
        // per-lane path bit-identically to before
        let paged = engine.manifest().paged_supported();
        // remote replica placement: `connect_addrs` splits into per-stage
        // address lists; remotes take the *highest* replica indices of each
        // pool, and the coordinator ships the stage's params over the wire
        // at spawn (digest-verified one-shot distribution)
        let (reward_addrs, ref_addrs) =
            crate::transport::split_connect_addrs(&cfg.connect_addrs)?;
        if !reward_addrs.is_empty() || !ref_addrs.is_empty() {
            ensure!(
                !paged,
                "remote replicas are not supported with paged artifacts (the \
                 block table is host-local); regenerate dense artifacts or \
                 drop connect_addrs"
            );
        }
        let opts = crate::transport::ConnectOpts {
            heartbeat_ms: cfg.heartbeat_ms.max(1),
            ..Default::default()
        };
        if cfg.mode.intra_enabled() && cfg.stream_reward {
            let pool = if !reward_addrs.is_empty() {
                ensure!(
                    reward_addrs.len() <= cfg.reward_replicas,
                    "{} remote reward addrs but only {} reward replicas",
                    reward_addrs.len(),
                    cfg.reward_replicas
                );
                let blob = Arc::new(ParamSet::raw_bytes(&engine, "reward")?);
                RewardWorker::spawn_replicated_remote(
                    engine.clone(),
                    cfg.reward_replicas - reward_addrs.len(),
                    &reward_addrs,
                    cfg.stage_queue_depth,
                    &opts,
                    Some(blob),
                )?
            } else if paged {
                RewardWorker::spawn_replicated_paged(
                    engine.clone(),
                    cfg.reward_replicas,
                    cfg.stage_queue_depth,
                )?
            } else {
                RewardWorker::spawn_replicated(
                    engine.clone(),
                    cfg.reward_replicas,
                    cfg.stage_queue_depth,
                )?
            };
            sinks.push(StreamSink::Reward(pool));
        } else {
            mono_reward = Some(RewardWorker::spawn(engine.clone(), cfg.stage_queue_depth)?);
        }
        if cfg.mode.ref_stream_enabled() && cfg.stream_ref {
            if engine.manifest().ref_prefill_supported() {
                let pool = if !ref_addrs.is_empty() {
                    ensure!(
                        ref_addrs.len() <= cfg.ref_replicas,
                        "{} remote ref addrs but only {} ref replicas",
                        ref_addrs.len(),
                        cfg.ref_replicas
                    );
                    let blob = Arc::new(ParamSet::raw_bytes(&engine, "ref")?);
                    RefSink::from_worker(RefWorker::spawn_replicated_remote(
                        engine.clone(),
                        cfg.ref_replicas - ref_addrs.len(),
                        &ref_addrs,
                        cfg.stage_queue_depth,
                        &opts,
                        Some(blob),
                    )?)
                } else if paged {
                    RefSink::spawn_replicated_paged(
                        engine.clone(),
                        cfg.ref_replicas,
                        cfg.stage_queue_depth,
                    )?
                } else {
                    RefSink::spawn_replicated(
                        engine.clone(),
                        cfg.ref_replicas,
                        cfg.stage_queue_depth,
                    )?
                };
                sinks.push(StreamSink::Ref(pool));
            } else {
                log::warn!(
                    "artifacts lack ref_prefill_chunk_c* entries; falling back to \
                     monolithic ref logprobs (regenerate artifacts to stream the ref stage)"
                );
            }
        }

        let host_mirror = vec![0i32; m.lanes * m.s_max];
        let actor_state = if paged {
            ops.fresh_actor_state_paged(&host_mirror)?
        } else {
            ops.fresh_actor_state(&host_mirror)?
        };
        let block_pool = paged.then(|| {
            BlockPool::new(
                m.lanes,
                m.kv_block_size,
                m.paged_blocks_per_lane(),
                m.paged_pool_blocks(),
            )
        });
        let assembler = RolloutAssembler::new(m.s_max, cfg.kl_beta as f32);
        let buffer = SeqBuffer::new(m.ppo_batch + cur_delta, m.lanes);
        let log = RunLog::new(cfg.mode.name(), &cfg.task, cfg.seed);

        Ok(Self {
            cfg,
            engine,
            ops,
            sinks,
            mono_reward,
            queue,
            tokenizer,
            buffer,
            ctl,
            cur_chunk,
            cur_delta,
            last_mean_score: 0.0,
            assembler,
            actor_state,
            block_pool,
            host_mirror,
            tick: 0,
            log,
            update_count: 0,
            stale_queue: VecDeque::new(),
            last_selected: Vec::new(),
            started: Instant::now(),
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn log(&self) -> &RunLog {
        &self.log
    }

    pub fn delta(&self) -> usize {
        self.cur_delta
    }

    pub fn chunk(&self) -> usize {
        self.cur_chunk
    }

    /// The active control loop (test / introspection hook).
    pub fn controller(&self) -> &dyn Controller {
        self.ctl.as_ref()
    }

    /// Names of the active streaming stages (test / introspection hook).
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.sinks.iter().map(|s| s.name()).collect()
    }

    /// The admission queue (test / introspection hook).
    pub fn queue(&self) -> &PromptQueue {
        &self.queue
    }

    /// The paged-KV allocator, when the paged path is active
    /// (test / introspection hook).
    pub fn block_pool(&self) -> Option<&BlockPool> {
        self.block_pool.as_ref()
    }

    /// Is the actor (and every streaming stage) running on pooled paged KV?
    pub fn paged(&self) -> bool {
        self.block_pool.is_some()
    }

    /// Clones of the sequences selected by the most recent `run_step` —
    /// lets engine-gated tests recompute streamed reward/ref scores with
    /// the dense monolithic entry points and compare.
    pub fn last_selected(&self) -> &[Sequence] {
        &self.last_selected
    }

    /// Is the reference model fed by streamed chunks (vs the monolithic
    /// post-generation `ref_logprobs` call)?
    pub fn ref_streamed(&self) -> bool {
        self.sinks.iter().any(|s| matches!(s, StreamSink::Ref(_)))
    }

    fn reward_streamed(&self) -> bool {
        self.sinks.iter().any(|s| matches!(s, StreamSink::Reward(_)))
    }

    /// Run the configured number of PPO steps; returns the run log.
    pub fn run(mut self) -> Result<RunLog> {
        self.started = Instant::now();
        for step in 0..self.cfg.steps as u64 {
            let rec = self.run_step(step)?;
            if self.cfg.log_every > 0 && step % self.cfg.log_every as u64 == 0 {
                log::info!(
                    "step {step}: score={:.3} Δ={} C={} wall={:.2}s finished={} deferred={}",
                    rec.mean_score, rec.delta, rec.chunk, rec.wall_s, rec.finished, rec.deferred
                );
            }
        }
        self.drain_stale_queue()?;
        if let Some(dir) = &self.cfg.out_dir {
            let path = format!("{dir}/{}_{}.json", self.cfg.mode.name(), self.cfg.seed);
            self.log.write_json(&path)?;
        }
        Ok(self.log)
    }

    /// End of run: the staleness-k baseline's loop leaves up to k assembled
    /// batches queued; silently dropping them would under-train short runs
    /// vs the paper's staleness-k baseline.  Apply each and record it in
    /// the log as a generation-free step.  Note the tradeoff this makes
    /// explicit: an AsyncStale log carries up to k more records than
    /// `cfg.steps`, and each drained row re-reports its batch's
    /// assembly-time mean score (the update really applied; the score is
    /// the best available label for it).
    fn drain_stale_queue(&mut self) -> Result<()> {
        let mut step = self.cfg.steps as u64;
        while let Some(pending) = self.stale_queue.pop_front() {
            let t0 = Instant::now();
            let train_stats = self.apply_update(&pending.batch)?;
            log::info!(
                "end-of-run drain: applied queued stale update as step {step} \
                 ({} still queued)",
                self.stale_queue.len()
            );
            self.log.push(StepRecord {
                step,
                wall_s: t0.elapsed().as_secs_f64(),
                elapsed_s: self.started.elapsed().as_secs_f64(),
                mean_score: pending.mean_score,
                delta: self.cur_delta,
                chunk: self.cur_chunk,
                finished: 0,
                deferred: self.buffer.len(),
                gen_tokens: 0,
                train_stats,
                util: 0.0,
                stages: Vec::new(),
                ..Default::default()
            });
            step += 1;
        }
        Ok(())
    }

    /// One PPO step (Alg. 1's loop body) in the configured mode.
    pub fn run_step(&mut self, step: u64) -> Result<StepRecord> {
        let t0 = Instant::now();
        let b = self.engine.manifest().shape.ppo_batch;
        let chunk = self.cur_chunk;
        let dropped_before = self.queue.dropped();

        // ---- Stage 1: fill the buffer to B + Δ (Alg. 1 l.3-5) ----
        // step boundary: last step's mid-step admits become batch-eligible
        self.buffer.promote_admitted();
        self.buffer.set_capacity(b + self.cur_delta);
        self.queue.advance_to(self.tick);
        while self.buffer.has_room() && self.pool_can_admit() && self.queue.has_prompt() {
            let Some(qp) = self.queue.pop(self.tick) else { break };
            self.admit_prompt(qp, step, false)?;
        }
        self.prefill_queued()?;

        // ---- Stage 2: generation (+ intra-step streaming to N stages,
        //      rolling admission into lanes that free up mid-step) ----
        let gen = self.generation_loop(chunk, b, step)?;
        let gen_tokens = gen.gen_tokens;

        // ---- Stage 3: PPO update with inter-step overlap (l.17-20) ----
        self.flush_streams(chunk)?; // no-op when no sinks are active
        let selected = self.buffer.take_finished(b, step);
        // batch selection vacated the selected resident lanes; their KV
        // blocks go back to the pool before the next step's fill
        self.release_vacant_lanes();
        if selected.len() < b {
            // graceful degradation: all lanes dead (or traffic starved the
            // queue) before B sequences finished — train on what we have
            // rather than aborting the run
            log::warn!(
                "step {step}: only {} of {b} sequences finished; {}",
                selected.len(),
                if selected.is_empty() {
                    "skipping the update"
                } else {
                    "training on the partial batch"
                }
            );
        }
        let deferred_left = self.buffer.len();
        for seq in &selected {
            self.log.record_deferral(seq.deferred_steps);
        }
        let prompt_latencies: Vec<PromptLatency> = selected
            .iter()
            .map(|s| PromptLatency {
                prompt_id: s.prompt.id,
                queue_wait: s.admitted_tick.saturating_sub(s.enqueued_tick) as f64,
                e2e: s.finished_tick.saturating_sub(s.enqueued_tick) as f64,
                mid_step: s.admitted_mid_step,
            })
            .collect();

        let (mean_score, train_stats) = if selected.is_empty() {
            // nothing finished: a generation-free step (all-zero batch has
            // an empty mask, which would poison the masked PPO statistics)
            (0.0f32, [0f32; 6])
        } else {
            let scores = self.score_batch(&selected)?;
            let mean = scores.iter().sum::<f32>() / scores.len() as f32;
            let stats = match self.cfg.mode {
                Mode::AsyncStale => self.async_update(&selected, &scores)?,
                _ => self.ppo_step(&selected, &scores)?,
            };
            (mean, stats)
        };
        self.last_selected = selected.clone();

        let wall = t0.elapsed().as_secs_f64();

        // per-stage busy/idle attribution for this step (pool rows sum
        // their replicas' counters)
        let mut stages: Vec<StageTiming> = Vec::with_capacity(self.sinks.len() + 1);
        for sink in &mut self.sinks {
            stages.push(sink.timing_delta());
        }
        if let Some(w) = &mut self.mono_reward {
            stages.push(w.timing_delta());
        }
        // stage-worker utilization: share of worker wall time spent inside
        // stage compute, aggregated across stages — busy/(busy+idle) is in
        // (0, 1] whenever any stage did work this step
        let (busy, idle) =
            stages.iter().fold((0.0, 0.0), |(b, i), st| (b + st.busy_s, i + st.idle_s));
        let util = if busy > 0.0 { (busy / (busy + idle)).min(1.0) } else { 0.0 };
        let lane_idle_frac = if gen.lane_slots > 0 {
            gen.idle_lane_slots as f64 / gen.lane_slots as f64
        } else {
            0.0
        };
        let queue_dropped = (self.queue.dropped() - dropped_before) as usize;
        let mut seq_lens: Vec<f64> =
            selected.iter().map(|s| (s.prompt_len + s.response.len()) as f64).collect();
        let mut queue_waits: Vec<f64> = prompt_latencies.iter().map(|l| l.queue_wait).collect();
        let mut e2es: Vec<f64> = prompt_latencies.iter().map(|l| l.e2e).collect();

        // ---- dynamic control (Alg. 1 l.21-27 + §3.1): one telemetry ----
        // snapshot through the unified Controller API, whichever arm is live
        let telemetry = StepTelemetry {
            step,
            wall_s: wall,
            mean_reward: mean_score as f64,
            reward_trend: if step == 0 {
                0.0
            } else {
                mean_score as f64 - self.last_mean_score
            },
            util,
            lane_idle_frac,
            queue_depth: self.queue.len(),
            queue_dropped,
            finished: selected.len(),
            gen_tokens,
            chunk,
            delta: self.cur_delta,
            mean_seq_len: mean_or_zero(&seq_lens),
            p95_seq_len: pct_sorted(&mut seq_lens, 95),
            queue_wait_p99: pct_sorted(&mut queue_waits, 99),
            e2e_p99: pct_sorted(&mut e2es, 99),
        };
        self.ctl.observe(&telemetry);
        self.last_mean_score = mean_score as f64;
        let actions = self.ctl.actions();
        if let Some(c) = actions.chunk {
            self.cur_chunk = c;
        }
        if let Some(d) = actions.delta {
            self.cur_delta = d;
        }
        // (a reward_replicas opinion is ignored here by design: the runtime
        // spawns its replica pools once at startup)
        self.buffer.set_capacity(b + self.cur_delta);

        let rec = StepRecord {
            step,
            wall_s: wall,
            elapsed_s: self.started.elapsed().as_secs_f64(),
            mean_score: mean_score as f64,
            delta: self.cur_delta,
            chunk,
            finished: selected.len(),
            deferred: deferred_left,
            gen_tokens,
            train_stats,
            util,
            stages,
            prompt_latencies,
            lane_idle_frac,
            admitted_mid_step: gen.admitted_mid_step,
            queue_dropped,
            peak_kv_bytes: (gen.peak_kv_tokens
                * self.engine.manifest().shape.kv_bytes_per_token()) as u64,
        };
        self.log.push(rec.clone());
        Ok(rec)
    }

    // ------------------------------------------------------------------
    // generation machinery
    // ------------------------------------------------------------------

    /// Admit one queued prompt into a free lane and stamp its tick clock.
    /// On the paged path the lane's whole-sequence block budget is reserved
    /// here, so generation can never run out of KV mid-sequence.
    fn admit_prompt(&mut self, qp: QueuedPrompt, step: u64, mid_step: bool) -> Result<usize> {
        let prompt_len = qp.prompt.tokens.len();
        let lane = self.buffer.admit(qp.prompt, step, qp.enqueued_tick, self.tick, mid_step)?;
        if let Some(pool) = &mut self.block_pool {
            let s_max = self.engine.manifest().shape.s_max;
            let max_total = (prompt_len + self.cfg.max_new_tokens).min(s_max);
            pool.admit(lane, prompt_len, max_total)?;
        }
        Ok(lane)
    }

    /// The admission gate beyond "a lane is free": on the paged path the
    /// pool must also hold a worst-case whole-sequence reservation, so a
    /// near-empty pool *defers* admits to a later chunk boundary instead of
    /// overcommitting KV.  Dense KV always has room by construction (one
    /// full-length row per lane).
    fn pool_can_admit(&self) -> bool {
        match &self.block_pool {
            Some(pool) => {
                let m = &self.engine.manifest().shape;
                pool.can_admit((m.prompt_max + self.cfg.max_new_tokens).min(m.s_max))
            }
            None => true,
        }
    }

    /// Paged KV, at a chunk boundary: map reserved blocks so every live
    /// lane's table covers the positions the coming chunk can write, capped
    /// at the sequence's own end-to-end budget (tokens past it are junk the
    /// device scatters into the scratch block).  Growth always succeeds —
    /// admission reserved the whole budget.  Returns the flattened
    /// `[lanes, s_max/block]` table for upload; `None` on the dense path.
    fn grow_for_chunk(&mut self, chunk: usize) -> Option<Vec<i32>> {
        let pool = self.block_pool.as_mut()?;
        let m = &self.engine.manifest().shape;
        for seq in self.buffer.iter() {
            if seq.phase != SeqPhase::Generating {
                continue;
            }
            let cap = (seq.prompt_len + self.cfg.max_new_tokens).min(m.s_max);
            pool.grow_to(seq.lane, (seq.total_len() + chunk).min(cap));
        }
        Some(pool.flat_table(m.lanes))
    }

    /// KV tokens currently committed on the device: block-rounded pool
    /// allocation (paged) or one full `s_max` row per resident lane (dense).
    fn committed_kv_tokens(&self) -> usize {
        match &self.block_pool {
            Some(pool) => pool.allocated_tokens(),
            None => self.buffer.iter().count() * self.engine.manifest().shape.s_max,
        }
    }

    /// Fan one streamed chunk out to every sink, through the block tables
    /// when the stages run pooled KV.
    fn fan_out(&mut self, ck: &StreamChunk, table: Option<&[i32]>) -> Result<()> {
        for sink in &mut self.sinks {
            match table {
                Some(t) => sink.submit_chunk_paged(ck, t)?,
                None => sink.submit_chunk(ck)?,
            }
        }
        Ok(())
    }

    /// Return pool blocks held by lanes that no longer have a resident
    /// sequence (batch selection just freed them; parked sequences returned
    /// theirs at release time).  Idempotent — releasing a vacant lane that
    /// holds nothing is a no-op — and must never touch an occupied lane.
    fn release_vacant_lanes(&mut self) {
        let Some(pool) = &mut self.block_pool else { return };
        let lanes = self.buffer.lanes();
        let mut resident = vec![false; lanes];
        for seq in self.buffer.iter() {
            resident[seq.lane] = true;
        }
        for (lane, occupied) in resident.iter().enumerate() {
            if !occupied {
                pool.release(lane);
            }
        }
    }

    /// Prompt-prefill all `Queued` lanes (selective reset, §3.2: existing
    /// lanes' KV rows are untouched).  Only the queued lanes' rows of the
    /// persistent host mirror are rewritten here — the upload itself is
    /// wholesale (that is `actor_prefill`'s contract), which is exactly why
    /// the mirror must always be current for *every* lane.
    fn prefill_queued(&mut self) -> Result<()> {
        let queued = self.buffer.queued_lanes();
        if queued.is_empty() {
            return Ok(());
        }
        let m = self.engine.manifest().shape.clone();
        let mut prompt_len = vec![1i32; m.lanes];
        let mut reset = vec![0i32; m.lanes];
        for seq in self.buffer.iter() {
            prompt_len[seq.lane] = seq.prompt_len as i32;
        }
        for &lane in &queued {
            let seq = self.buffer.by_lane(lane).expect("queued lane vanished");
            let row = lane * m.s_max;
            self.host_mirror[row..row + m.s_max].fill(0);
            self.host_mirror[row..row + seq.prompt_len]
                .copy_from_slice(&seq.prompt.tokens);
            reset[lane] = 1;
        }
        // paged path: `admit_prompt` already mapped the blocks covering each
        // queued lane's prompt, so the uploaded table routes the prefill KV
        let table = self.block_pool.as_ref().map(|p| p.flat_table(m.lanes));
        match &table {
            Some(t) => self.ops.actor_prefill_paged(
                &mut self.actor_state,
                &self.host_mirror,
                &prompt_len,
                &reset,
                t,
            )?,
            None => self.ops.actor_prefill(
                &mut self.actor_state,
                &self.host_mirror,
                &prompt_len,
                &reset,
            )?,
        }
        for seq in self.buffer.iter_mut() {
            if seq.phase == SeqPhase::Queued {
                seq.phase = SeqPhase::Generating;
            }
        }
        Ok(())
    }

    /// One rolling-admission round at a chunk boundary: park every finished
    /// sequence whose downstream data is complete (freeing its lane), then
    /// admit queued prompts into the free lanes and prefill them (selective
    /// reset — resident lanes' KV rows are untouched).  Returns how many
    /// prompts were admitted.
    ///
    /// Release gate: a lane may be recycled only when nothing downstream
    /// still needs it — the sequence is finished *and* its stream cursor is
    /// drained *and* every sink has applied the lane's data (reward score
    /// present, ref row complete).  With no sinks (monolithic scoring) the
    /// sequence is scored after selection from the parked area, so finished
    /// alone suffices.
    fn rolling_admit(&mut self, step: u64) -> Result<usize> {
        let releasable: Vec<usize> = self
            .buffer
            .iter()
            .filter(|s| {
                s.is_finished()
                    && (self.sinks.is_empty()
                        || (s.unstreamed() == 0
                            && self.sinks.iter().all(|k| k.is_satisfied(s))))
            })
            .map(|s| s.lane)
            .collect();
        for lane in releasable {
            // refused (parked area full) is fine — the lane stays resident
            // and the next boundary retries; pool blocks come back only
            // when the lane really vacates
            if self.buffer.release_lane(lane) {
                if let Some(pool) = &mut self.block_pool {
                    pool.release(lane);
                }
            }
        }
        let mut admitted = 0usize;
        while self.buffer.has_room() && self.pool_can_admit() && self.queue.has_prompt() {
            let Some(qp) = self.queue.pop(self.tick) else { break };
            self.admit_prompt(qp, step, true)?;
            admitted += 1;
        }
        if admitted > 0 {
            self.prefill_queued()?;
        }
        Ok(admitted)
    }

    /// Alg. 1 l.7-16: decode chunks until `target` batch-eligible sequences
    /// finished, fanning the previous chunk out to every downstream stage so
    /// their prefill overlaps the actor's next decode chunk.  Under rolling
    /// admission each chunk boundary also recycles drained lanes into fresh
    /// prompts from the queue; mid-step admits decode in the same grid but
    /// stay ineligible for *this* step's batch, which keeps the saturated
    /// Δ=0 schedule step-equivalent to the legacy fixed-grid loop.
    fn generation_loop(&mut self, chunk: usize, target: usize, step: u64) -> Result<GenStats> {
        let m = self.engine.manifest().shape.clone();
        let rolling = self.cfg.admission_mode.rolling();
        let mut st = GenStats::default();
        // bounded idle wait for traffic: with no live lane and an empty
        // queue, tick the arrival process forward instead of spinning or
        // bailing — but give up after enough expected interarrival times
        // that a dried-up queue cannot stall the step forever
        let mut idle_budget: u64 = match self.queue.arrivals() {
            Arrivals::Poisson { rate } => ((64.0 / rate).ceil() as u64).min(1_000_000),
            Arrivals::Saturated => 0,
        };
        loop {
            if self.buffer.finished_eligible_count() >= target {
                break;
            }
            if rolling {
                st.admitted_mid_step += self.rolling_admit(step)?;
            }
            let mut pos = vec![0i32; m.lanes];
            let mut live = vec![0i32; m.lanes];
            let mut live_count = 0usize;
            for seq in self.buffer.iter() {
                pos[seq.lane] = seq.total_len() as i32;
                if seq.phase == SeqPhase::Generating {
                    live[seq.lane] = 1;
                    live_count += 1;
                }
            }
            if live_count == 0 {
                if rolling && idle_budget > 0 {
                    // idle tick: no decode work, just advance the clock so
                    // pending arrivals can materialize
                    self.tick += 1;
                    self.queue.advance_to(self.tick);
                    st.lane_slots += m.lanes;
                    st.idle_lane_slots += m.lanes;
                    idle_budget -= 1;
                    continue;
                }
                break; // Alg. 1 l.9-11
            }

            // parallel do (Alg. 1 l.12-15): every downstream stage prefills
            // the previous chunk's tokens while the actor decodes the next
            // chunk.  The bounded stage queues allow multiple chunks in
            // flight; responses are drained opportunistically and joined at
            // flush.
            // paged KV: map reserved blocks so every live lane's table
            // covers the positions this chunk can write *before* the device
            // call — accepted tokens must land in mapped blocks (junk past
            // EOS scatters harmlessly into scratch block 0)
            let table = self.grow_for_chunk(chunk);
            st.peak_kv_tokens = st.peak_kv_tokens.max(self.committed_kv_tokens());
            if !self.sinks.is_empty() {
                if let Some(ck) = self.build_stream_chunk(chunk)? {
                    self.fan_out(&ck, table.as_deref())?;
                }
            }
            let out = match &table {
                Some(t) => {
                    self.ops.generate_chunk_paged(&mut self.actor_state, chunk, &pos, &live, t)?
                }
                None => self.ops.generate_chunk(&mut self.actor_state, chunk, &pos, &live)?,
            };
            self.tick += 1;
            self.queue.advance_to(self.tick);
            st.lane_slots += m.lanes;
            st.idle_lane_slots += m.lanes - live_count;
            {
                // fault-tolerant collect: a dead replica surfaces as a
                // `ReplicaFailure`, and its lanes are rerouted + replayed
                // from the retained chunk stream before the loop continues
                let Self { sinks, buffer, block_pool, .. } = self;
                let lanes = buffer.lanes();
                for sink in sinks.iter_mut() {
                    while let Some(fail) = sink.collect_ready_ft(buffer)? {
                        let table = block_pool.as_ref().map(|p| p.flat_table(lanes));
                        sink.failover(buffer, &fail, chunk, table.as_deref())?;
                    }
                }
            }
            st.gen_tokens += self.process_chunk(&out, chunk)?;
        }
        Ok(st)
    }

    /// Fold one decode chunk into the sequences; returns tokens accepted.
    /// Each accepted token is also appended to the lane's row of the host
    /// mirror, keeping it current for the next selective-reset prefill.
    fn process_chunk(&mut self, out: &ChunkOut, chunk: usize) -> Result<usize> {
        let m = self.engine.manifest().shape.clone();
        let (eos, max_new, s_max) = (EOS, self.cfg.max_new_tokens, m.s_max);
        let tick = self.tick;
        let mut accepted = 0usize;
        let mut newly_finished: Vec<usize> = Vec::new();
        for seq in self.buffer.iter_mut() {
            if seq.phase != SeqPhase::Generating {
                continue;
            }
            let lane = seq.lane;
            for j in 0..chunk {
                let tok = out.tokens[lane * chunk + j];
                let logp = out.logps[lane * chunk + j];
                let value = out.values[lane * chunk + j];
                accepted += 1;
                let done = seq.push_token(tok, logp, value, eos, max_new, s_max);
                self.host_mirror[lane * s_max + seq.total_len() - 1] = tok;
                if done {
                    seq.finished_tick = tick;
                    newly_finished.push(lane);
                    break; // tokens past EOS in this chunk are junk
                }
            }
        }
        for lane in newly_finished {
            self.buffer.mark_finished(lane);
        }
        Ok(accepted)
    }

    /// Build the next streamed chunk: up to `chunk` unstreamed tokens per
    /// lane, PAD-filled where idle.  Advances the shared stream cursor, so
    /// call exactly once per fan-out round.  (Lives on [`SeqBuffer`] so the
    /// failover path can replay retained chunks with the same layout.)
    fn build_stream_chunk(&mut self, chunk: usize) -> Result<Option<StreamChunk>> {
        Ok(self.buffer.build_stream_chunk(chunk))
    }

    /// End of Stage 2: drain the remaining unstreamed tokens of finished
    /// sequences and **join** every stage — afterwards each finished
    /// sequence has its reward score and (when the ref stage is active) a
    /// complete streamed ref-logprob row.
    fn flush_streams(&mut self, chunk: usize) -> Result<()> {
        if self.sinks.is_empty() {
            return Ok(());
        }
        loop {
            {
                let Self { sinks, buffer, block_pool, .. } = self;
                let lanes = buffer.lanes();
                for sink in sinks.iter_mut() {
                    while let Some(fail) = sink.join_ft(buffer)? {
                        let table = block_pool.as_ref().map(|p| p.flat_table(lanes));
                        sink.failover(buffer, &fail, chunk, table.as_deref())?;
                    }
                }
            }
            let outstanding = self.buffer.iter().any(|s| {
                s.is_finished()
                    && (s.unstreamed() > 0 || self.sinks.iter().any(|k| !k.is_satisfied(s)))
            });
            if !outstanding {
                return Ok(());
            }
            match self.build_stream_chunk(chunk)? {
                Some(ck) => {
                    // finished sequences' tables already cover total_len()
                    // (grown during generation), so no growth here
                    let table = self
                        .block_pool
                        .as_ref()
                        .map(|p| p.flat_table(self.buffer.lanes()));
                    self.fan_out(&ck, table.as_deref())?;
                }
                None => {
                    // nothing left to stream but a stage is missing data —
                    // cannot happen with the contiguous schedule
                    bail!("finished sequence missing streamed stage data");
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // scoring + updates
    // ------------------------------------------------------------------

    /// Blend rule reward with the reward-model score for each sequence.
    fn score_batch(&mut self, seqs: &[Sequence]) -> Result<Vec<f32>> {
        let m = self.engine.manifest().shape.clone();
        let w = self.cfg.reward_model_weight;

        // reward-model scores: streamed (intra modes) or monolithic
        let rm_scores: Vec<f32> = if self.reward_streamed() {
            seqs.iter()
                .map(|s| s.rm_score.context("missing streamed score"))
                .collect::<Result<_>>()?
        } else if w > 0.0 {
            let worker =
                self.mono_reward.as_mut().context("monolithic reward worker missing")?;
            let mut tokens = vec![0i32; m.lanes * m.s_max];
            let mut last_idx = vec![0i32; m.lanes];
            for (i, seq) in seqs.iter().enumerate() {
                let toks = seq.full_tokens();
                tokens[i * m.s_max..i * m.s_max + toks.len()].copy_from_slice(&toks);
                last_idx[i] = (toks.len() - 1) as i32;
            }
            worker.submit(RewardReq::ScoreFull { tokens, last_idx })?;
            match worker.recv()? {
                RewardResp::FullScores(all) => all[..seqs.len()].to_vec(),
                other => bail!("unexpected reward response {other:?}"),
            }
        } else {
            vec![0.0; seqs.len()]
        };

        Ok(seqs
            .iter()
            .zip(&rm_scores)
            .map(|(seq, &rm)| {
                let text = self.tokenizer.decode_until_eos(&seq.response, 0);
                let rule = rule_reward(&seq.prompt.answer, &text) as f32;
                crate::ppo::reward::blend_score(rm, rule, w)
            })
            .collect())
    }

    /// Standard (synchronous) PPO update on the selected batch.
    fn ppo_step(&mut self, seqs: &[Sequence], scores: &[f32]) -> Result<[f32; 6]> {
        let batch = self.assemble(seqs, scores)?;
        self.apply_update(&batch)
    }

    fn assemble(&mut self, seqs: &[Sequence], scores: &[f32]) -> Result<PpoBatch> {
        let refs: Vec<&Sequence> = seqs.iter().collect();
        let m = self.engine.manifest().shape.clone();
        let n = seqs.len();
        // reference log-probs over the dense batch tokens: already streamed
        // by the ref stage (no post-generation blocking call), or computed
        // monolithically on the fallback / baseline paths
        let ref_logp = if self.ref_streamed() {
            let mut dense = vec![0f32; n * m.s_max];
            for (i, seq) in seqs.iter().enumerate() {
                let len = seq.total_len();
                ensure!(
                    seq.ref_logp.len() >= len,
                    "lane {}: streamed ref logprobs cover {} of {len} positions",
                    seq.lane,
                    seq.ref_logp.len()
                );
                dense[i * m.s_max..i * m.s_max + len].copy_from_slice(&seq.ref_logp[..len]);
            }
            dense
        } else {
            // the AOT entry is fixed at [B, S]; a partial batch pads with
            // zero rows and truncates the result back to the real rows
            let mut tokens = vec![0i32; m.ppo_batch * m.s_max];
            for (i, seq) in seqs.iter().enumerate() {
                let t = seq.full_tokens();
                tokens[i * m.s_max..i * m.s_max + t.len()].copy_from_slice(&t);
            }
            let mut dense = self.ops.ref_logprobs(&tokens)?;
            dense.truncate(n * m.s_max);
            dense
        };
        let mut batch = self.assembler.assemble(&refs, scores, &ref_logp)?;
        // graceful degradation: gae/ppo_update are AOT-compiled for exactly
        // [B, S], so a partial batch is zero-padded up to B — the pad rows
        // carry an all-zero mask and contribute nothing to the update
        if batch.b < m.ppo_batch {
            let s = batch.s;
            batch.tokens.resize(m.ppo_batch * s, 0);
            batch.mask.resize(m.ppo_batch * s, 0.0);
            batch.old_logp.resize(m.ppo_batch * s, 0.0);
            batch.rewards.resize(m.ppo_batch * s, 0.0);
            batch.values.resize(m.ppo_batch * s, 0.0);
            batch.b = m.ppo_batch;
        }
        Ok(batch)
    }

    fn apply_update(&mut self, batch: &PpoBatch) -> Result<[f32; 6]> {
        let (adv, ret) = self.ops.gae(&batch.rewards, &batch.values, &batch.mask)?;
        let mut stats = [0f32; 6];
        for _ in 0..self.cfg.ppo_epochs.max(1) {
            self.update_count += 1;
            stats = self.ops.ppo_update(batch, &adv, &ret, self.update_count)?;
        }
        Ok(stats)
    }

    /// Async staleness-k baseline: enqueue the freshly-scored rollout, apply
    /// the update from k steps ago (off-policy: its `old_logp` came from an
    /// older actor — the convergence risk Figure 2c demonstrates).
    fn async_update(&mut self, seqs: &[Sequence], scores: &[f32]) -> Result<[f32; 6]> {
        let batch = self.assemble(seqs, scores)?;
        let mean_score =
            scores.iter().sum::<f32>() as f64 / scores.len().max(1) as f64;
        self.stale_queue.push_back(PendingUpdate { batch, mean_score });
        if self.stale_queue.len() > self.cfg.staleness {
            let pending = self.stale_queue.pop_front().unwrap();
            self.apply_update(&pending.batch)
        } else {
            Ok([0.0; 6])
        }
    }

    // ------------------------------------------------------------------
    // evaluation (Table 3 substitute)
    // ------------------------------------------------------------------

    /// Exact-match accuracy of the *current* policy on the held-out eval
    /// set (fresh lanes; does not disturb the training buffer, but does
    /// advance the sampling RNG).
    pub fn eval_accuracy(&mut self, n: usize, eval_seed: u64) -> Result<f64> {
        let prompts = self.queue.sampler().eval_set(n, eval_seed);
        let responses = self.generate_responses(&prompts)?;
        let hits = prompts
            .iter()
            .zip(&responses)
            .filter(|(p, r)| crate::data::tasks::exact_match(&p.answer, r))
            .count();
        Ok(hits as f64 / n.max(1) as f64)
    }

    /// One-shot generation for a list of prompts (eval / DPO), processed in
    /// lane-sized groups with a fresh device state.
    pub fn generate_responses(&mut self, prompts: &[crate::data::Prompt]) -> Result<Vec<String>> {
        let m = self.engine.manifest().shape.clone();
        let mut out = Vec::with_capacity(prompts.len());
        for group in prompts.chunks(m.lanes) {
            let mut tokens = vec![0i32; m.lanes * m.s_max];
            let mut prompt_len = vec![1i32; m.lanes];
            // lanes beyond the eval group are dead from the start (reset 0):
            // no garbage single-token prefill, no decode work on lanes that
            // can never finish
            let mut reset = vec![0i32; m.lanes];
            for (lane, p) in group.iter().enumerate() {
                tokens[lane * m.s_max..lane * m.s_max + p.tokens.len()]
                    .copy_from_slice(&p.tokens);
                prompt_len[lane] = p.tokens.len() as i32;
                reset[lane] = 1;
            }
            let mut state = self.ops.fresh_actor_state(&tokens)?;
            self.ops.actor_prefill(&mut state, &tokens, &prompt_len, &reset)?;

            let chunk = self.cur_chunk;
            let mut responses: Vec<Vec<i32>> = vec![Vec::new(); group.len()];
            let mut done = vec![false; group.len()];
            let mut pos: Vec<i32> = prompt_len.clone();
            while !done.iter().all(|&d| d) {
                let live: Vec<i32> = (0..m.lanes)
                    .map(|l| if l < group.len() && !done[l] { 1 } else { 0 })
                    .collect();
                let outc = self.ops.generate_chunk(&mut state, chunk, &pos, &live)?;
                for (lane, resp) in responses.iter_mut().enumerate() {
                    if done[lane] {
                        continue;
                    }
                    for j in 0..chunk {
                        let tok = outc.tokens[lane * chunk + j];
                        resp.push(tok);
                        pos[lane] += 1;
                        if tok == EOS
                            || resp.len() >= self.cfg.max_new_tokens
                            || (pos[lane] as usize) >= m.s_max
                        {
                            done[lane] = true;
                            break;
                        }
                    }
                }
            }
            for resp in responses {
                out.push(self.tokenizer.decode_until_eos(&resp, 0));
            }
        }
        Ok(out)
    }

    /// Mean masked reward of a batch (test hook).
    pub fn batch_reward(batch: &PpoBatch) -> f32 {
        masked_mean(&batch.rewards, &batch.mask)
    }
}

fn mean_or_zero(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Nearest-rank percentile over an unsorted slice (sorts in place; 0.0 when
/// empty) — the telemetry's p95/p99 sequence-length and latency fields.
fn pct_sorted(xs: &mut [f64], q: usize) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[(xs.len() - 1) * q / 100]
}
