//! TOML-subset parser for run configs (serde/toml unavailable offline).
//!
//! Supported syntax — deliberately the subset our configs need:
//!
//! ```toml
//! # comment
//! top_key = 1
//! [section]
//! string = "hello"
//! float = 2.5
//! boolean = true
//! list = [1, 2, 3]
//! strings = ["a", "b"]
//! ```

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A scalar or list value.
#[derive(Clone, Debug, PartialEq)]
pub enum Val {
    Num(f64),
    Str(String),
    Bool(bool),
    NumList(Vec<f64>),
    StrList(Vec<String>),
}

impl Val {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Val::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Val::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Val::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_usize_list(&self) -> Result<Vec<usize>> {
        match self {
            Val::NumList(xs) => xs
                .iter()
                .map(|&x| {
                    if x < 0.0 || x.fract() != 0.0 {
                        Err(anyhow!("expected integer list, got {x}"))
                    } else {
                        Ok(x as usize)
                    }
                })
                .collect(),
            _ => bail!("expected number list, got {self:?}"),
        }
    }
}

/// Parsed document: `sections[""]` holds top-level keys.
pub type Doc = BTreeMap<String, BTreeMap<String, Val>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut section = String::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_val(value.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // only strip # outside of quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_val(s: &str) -> Result<Val> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s == "true" {
        return Ok(Val::Bool(true));
    }
    if s == "false" {
        return Ok(Val::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Val::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated list"))?;
        let items: Vec<&str> =
            inner.split(',').map(str::trim).filter(|x| !x.is_empty()).collect();
        if items.is_empty() {
            return Ok(Val::NumList(vec![]));
        }
        if items[0].starts_with('"') {
            let mut out = Vec::new();
            for item in items {
                match parse_val(item)? {
                    Val::Str(x) => out.push(x),
                    v => bail!("mixed list: expected string, got {v:?}"),
                }
            }
            return Ok(Val::StrList(out));
        }
        let mut out = Vec::new();
        for item in items {
            out.push(
                item.parse::<f64>().map_err(|e| anyhow!("bad number {item:?} in list: {e}"))?,
            );
        }
        return Ok(Val::NumList(out));
    }
    s.parse::<f64>().map(Val::Num).map_err(|e| anyhow!("bad value {s:?}: {e}"))
}

/// Apply `key=value` CLI overrides (`section.key=value` or bare `key=value`).
pub fn apply_overrides(doc: &mut Doc, overrides: &[String]) -> Result<()> {
    for ov in overrides {
        let (path, value) = ov
            .split_once('=')
            .ok_or_else(|| anyhow!("override {ov:?}: expected key=value"))?;
        let val = parse_val(value.trim())?;
        let (section, key) = match path.trim().split_once('.') {
            Some((s, k)) => (s.to_string(), k.to_string()),
            None => (String::new(), path.trim().to_string()),
        };
        doc.entry(section).or_default().insert(key, val);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            top = 5
            [run]           # trailing comment
            mode = "oppo"
            steps = 100
            lr = 2.5e-4
            stream = true
            chunks = [8, 16, 32]
            names = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["top"].as_usize().unwrap(), 5);
        assert_eq!(doc["run"]["mode"].as_str().unwrap(), "oppo");
        assert_eq!(doc["run"]["steps"].as_usize().unwrap(), 100);
        assert!((doc["run"]["lr"].as_f64().unwrap() - 2.5e-4).abs() < 1e-12);
        assert!(doc["run"]["stream"].as_bool().unwrap());
        assert_eq!(doc["run"]["chunks"].as_usize_list().unwrap(), vec![8, 16, 32]);
        assert_eq!(*doc["run"].get("names").unwrap(), Val::StrList(vec!["a".into(), "b".into()]));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc[""]["k"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = [1, \"x\"]").is_err());
        assert!(parse("k = zz").is_err());
    }

    #[test]
    fn overrides_create_and_replace() {
        let mut doc = parse("[run]\nsteps = 1").unwrap();
        apply_overrides(
            &mut doc,
            &["run.steps=9".to_string(), "run.mode=\"trl\"".to_string(), "seed=3".to_string()],
        )
        .unwrap();
        assert_eq!(doc["run"]["steps"].as_usize().unwrap(), 9);
        assert_eq!(doc["run"]["mode"].as_str().unwrap(), "trl");
        assert_eq!(doc[""]["seed"].as_usize().unwrap(), 3);
    }

    #[test]
    fn type_errors_are_reported() {
        let doc = parse("k = 1.5").unwrap();
        assert!(doc[""]["k"].as_usize().is_err());
        assert!(doc[""]["k"].as_str().is_err());
        assert!(doc[""]["k"].as_bool().is_err());
    }
}
