//! Fig. 4 — step-to-reward parity: OPPO must match TRL's reward trajectory
//! at equal step counts (efficiency gains come from wall-clock, not data).
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    let rows = figures::fig4();
    print_table("Fig 4 — step-to-reward parity (reward at 25/50/100% of steps)", &rows);
    save_rows("fig4", &rows).expect("save");
    for r in &rows {
        let (trl, gap) = (r.cells[0].1, r.cells[2].1);
        assert!(gap <= (0.08 * trl.abs()).max(0.06), "{}: gap {gap} too large", r.label);
    }
    println!("shape check passed: trajectories coincide");
}
