//! Small end-to-end smoke: a short full-OPPO training run completes, the
//! policy evaluates, and the reward signal is live (the long-form run is
//! examples/train_rlhf_e2e.rs, recorded in EXPERIMENTS.md).
use oppo::config::TrainConfig;
use oppo::coordinator::OppoScheduler;

#[test]
fn short_oppo_training_run() {
    if !std::path::Path::new("artifacts/manifest.json").exists() { return }
    let cfg = TrainConfig {
        steps: 4,
        task: "arith".into(),
        seed: 11,
        log_every: 0,
        max_new_tokens: 32,
        ..Default::default()
    };
    let mut sched = OppoScheduler::new(cfg).unwrap();
    let acc0 = sched.eval_accuracy(24, 7).unwrap();
    assert!((0.0..=1.0).contains(&acc0));
    let mut deferrals = 0u64;
    for s in 0..4 {
        let rec = sched.run_step(s).unwrap();
        assert!(rec.mean_score.is_finite());
        assert!(rec.wall_s > 0.0);
        deferrals += rec.finished as u64;
    }
    assert!(deferrals > 0);
    let acc1 = sched.eval_accuracy(24, 7).unwrap();
    assert!((0.0..=1.0).contains(&acc1));
}
