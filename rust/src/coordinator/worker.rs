//! The downstream stage workers — reward scoring and reference log-probs —
//! built on the generic [`StagePool`](crate::coordinator::stage) runtime,
//! plus [`StreamSink`], the scheduler-side facade that fans one streamed
//! `[G, C]` chunk out to every active stage.
//!
//! This is the concurrency that realizes §3.1's intra-step overlap: while
//! the actor thread executes `actor_generate_chunk` for chunk *k*, the
//! reward thread executes `reward_prefill_chunk` and the ref thread
//! `ref_prefill_chunk` for chunk *k−1*.  PJRT executes all of them
//! concurrently (thread-safe client), so downstream prefill latency hides
//! behind actor decoding exactly as in the paper's Figure 1b — now for
//! *every* downstream model, not just reward.
//!
//! Each stage is a **pool of replicas**: the spawn path hands the pool a
//! handler *factory*, so every replica constructs its own ops + device
//! state on its own thread (independent parameter buffers, independent KV
//! caches).  Chunks are split lane-wise across the pool with
//! sequence-affinity routing (`lane % replicas`): the replica that prefixed
//! a sequence's earlier chunks holds its KV/seam state, so all later chunks
//! of that sequence must — and do — land on the same replica.
//!
//! Replicas pay off two ways.  They always execute *concurrently* —
//! independent worker threads whose kernels PJRT can run on separate
//! streams/devices.  And when the artifacts ship lane-sliced
//! `{stage}_prefill_chunk_g{G/N}_c{C}` entries for the pool's replica
//! count, each replica also does proportionally *fewer FLOPs*: the pool
//! compacts its owned lanes into a dense `[G/N, C]` grid host-side
//! (see [`StreamChunk::compacted_for_replica`]) and scatters results back
//! through the part's lane-map, so N replicas divide the chunk compute
//! instead of each paying the full masked `[G, C]` kernel.  Non-divisor
//! replica counts (or artifact sets without sliced entries) fall back to
//! the masked full-shape path.  With one replica the split is the identity
//! and the behaviour is exactly the old single-worker path.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::buffer::SeqBuffer;
use crate::coordinator::engine_ops::{RefOps, RefStreamState, RewardOps, RewardState};
use crate::coordinator::stage::{StageHandler, StagePool};
use crate::metrics::StageTiming;
use crate::model::sequence::Sequence;
use crate::runtime::Engine;

/// Which lane positions hold a sequence's *final* token in this chunk —
/// the reward worker returns the score read off at exactly those positions.
#[derive(Clone, Debug)]
pub struct Pick {
    pub lane: usize,
    pub idx_in_chunk: usize,
}

/// One streamed `[G, C]` chunk of actor output, built once per decode
/// iteration and fanned out to every active downstream stage.
#[derive(Clone, Debug)]
pub struct StreamChunk {
    /// chunk size C
    pub c: usize,
    /// row-major [G, C] token chunk (PAD-filled for idle lanes)
    pub tokens: Vec<i32>,
    /// per-lane absolute start position
    pub start: Vec<i32>,
    /// per-lane number of valid tokens in the chunk
    pub n_valid: Vec<i32>,
    /// lanes whose final token lands in this chunk
    pub picks: Vec<Pick>,
}

/// One replica's share of a streamed chunk: a token grid in the replica's
/// own coordinate space plus the row → absolute-lane map the sinks use to
/// scatter scores/log-probs back.  `chunk.picks[*].lane` is a **row index**
/// into `lane_map` — identity on the masked path, the owned-lane list on
/// the compacted path.
#[derive(Clone, Debug)]
pub struct ReplicaPart {
    pub chunk: StreamChunk,
    pub lane_map: Vec<usize>,
}

impl StreamChunk {
    /// Lane count G of this chunk.
    pub fn lanes(&self) -> usize {
        self.start.len()
    }

    /// The sub-chunk replica `r` of `n` must process.  `sliced` picks the
    /// compacted `[G/n, C]` grid (requires the pool's sliced AOT entries);
    /// otherwise the masked full-shape fallback.  Returns `None` when no
    /// owned lane carries valid tokens.
    pub fn for_replica(&self, r: usize, n: usize, sliced: bool) -> Option<ReplicaPart> {
        if sliced && n > 1 {
            self.compacted_for_replica(r, n)
        } else {
            self.masked_for_replica(r, n)
        }
    }

    /// Masked full-shape split: lanes the replica does not own
    /// (`lane % n != r`) are masked dead (`n_valid = 0`, picks dropped).
    /// The stage kernels read results and advance seam state only for
    /// `n_valid > 0` lanes, so unowned lanes cannot corrupt the replica's
    /// per-lane KV/seam data — but the kernel still *computes* the full
    /// `[G, C]` grid, so this path wins only through concurrency.  It is
    /// the fallback when no sliced entry ships (e.g. non-divisor replica
    /// counts).  With `n == 1` this is the identity, which keeps a
    /// one-replica pool bit-compatible with the old single-worker path.
    pub fn masked_for_replica(&self, r: usize, n: usize) -> Option<ReplicaPart> {
        self.masked_for_slots(&[r], n)
    }

    /// Route-aware masked split: the replica owns lane `l` iff `l`'s slot
    /// (`l % n_slots`) is in `slots`.  With the identity route each replica
    /// owns exactly its own slot and this is [`masked_for_replica`]
    /// (Self::masked_for_replica); after a failover reroute a survivor owns
    /// the dead replica's slots too, so its part — including replayed
    /// chunks — covers both lane sets while retired replicas (empty
    /// `slots`) get `None`.
    pub fn masked_for_slots(&self, slots: &[usize], n_slots: usize) -> Option<ReplicaPart> {
        if slots.is_empty() {
            return None;
        }
        let lane_map: Vec<usize> = (0..self.lanes()).collect();
        if n_slots <= 1 {
            return Some(ReplicaPart { chunk: self.clone(), lane_map });
        }
        let mut part = self.clone();
        let mut any = false;
        for (lane, nv) in part.n_valid.iter_mut().enumerate() {
            if slots.contains(&(lane % n_slots)) {
                any = any || *nv > 0;
            } else {
                *nv = 0;
            }
        }
        if !any {
            return None;
        }
        part.picks.retain(|p| slots.contains(&(p.lane % n_slots)));
        Some(ReplicaPart { chunk: part, lane_map })
    }

    /// Host-side lane compaction: the replica's owned lanes packed into a
    /// dense `[G/n, C]` grid for the lane-sliced AOT entries, copying only
    /// owned-lane data (no full-chunk clone).  Row `k` is always absolute
    /// lane `r + k·n` — the map is fixed for the whole run, so the
    /// replica's per-row KV/seam state tracks one lane for its lifetime,
    /// and rows whose lane is idle this chunk ride along with
    /// `n_valid = 0` rather than shifting later rows.  Picks are rewritten
    /// into row coordinates; `lane_map` carries the inverse for the
    /// scatter back to absolute lanes.  Requires `G % n == 0` (sliced
    /// entries are only emitted for divisor replica counts).
    pub fn compacted_for_replica(&self, r: usize, n: usize) -> Option<ReplicaPart> {
        let g = self.lanes();
        debug_assert!(n > 1 && g % n == 0, "compaction needs a divisor replica count");
        let lane_map: Vec<usize> = (r..g).step_by(n).collect();
        if !lane_map.iter().any(|&l| self.n_valid[l] > 0) {
            return None;
        }
        let c = self.c;
        let rows = lane_map.len();
        let mut tokens = Vec::with_capacity(rows * c);
        let mut start = Vec::with_capacity(rows);
        let mut n_valid = Vec::with_capacity(rows);
        for &lane in &lane_map {
            tokens.extend_from_slice(&self.tokens[lane * c..(lane + 1) * c]);
            start.push(self.start[lane]);
            n_valid.push(self.n_valid[lane]);
        }
        let picks = self
            .picks
            .iter()
            .filter(|p| p.lane % n == r)
            .map(|p| Pick { lane: p.lane / n, idx_in_chunk: p.idx_in_chunk })
            .collect();
        Some(ReplicaPart { chunk: StreamChunk { c, tokens, start, n_valid, picks }, lane_map })
    }
}

// ---------------------------------------------------------------------------
// reward stage
// ---------------------------------------------------------------------------

/// Requests to the reward worker.
pub enum RewardReq {
    /// Incremental prefill of one streamed chunk (intra-step overlap).
    /// The grid may be lane-compacted: `picks[*].lane` indexes rows of
    /// `chunk`, and `lane_map` maps rows back to absolute lanes for the
    /// response (identity when the grid is full-shape).
    Stream {
        /// entry name (`reward_prefill_chunk_c{C}`, the sliced
        /// `reward_prefill_chunk_g{R}_c{C}`, or a pallas flavour)
        entry: String,
        chunk: Vec<i32>,
        start: Vec<i32>,
        n_valid: Vec<i32>,
        /// final-token positions (row coordinates) to read scores from
        picks: Vec<Pick>,
        /// row → absolute lane
        lane_map: Vec<usize>,
    },
    /// The paged flavour of `Stream`: KV lives in the replica's pooled
    /// buffer and `table` is the flattened `[G, s_max/block]` block table.
    /// Paged entries are full-G only, so the grid is never compacted —
    /// replicas route masked, and `lane_map` is the identity.
    StreamPaged {
        entry: String,
        chunk: Vec<i32>,
        start: Vec<i32>,
        n_valid: Vec<i32>,
        picks: Vec<Pick>,
        lane_map: Vec<usize>,
        table: Vec<i32>,
    },
    /// Monolithic scoring (baselines / ablation w/o intra).
    ScoreFull { tokens: Vec<i32>, last_idx: Vec<i32> },
    /// Reset the reward KV state (new run / tests).
    Reset,
}

/// Worker responses (tagged and in submission order).
#[derive(Debug)]
pub enum RewardResp {
    /// (lane, score) for each pick in the stream request
    StreamScores(Vec<(usize, f32)>),
    /// all-lane scores for a ScoreFull request
    FullScores(Vec<f32>),
    /// acknowledgement of Reset
    ResetDone,
}

struct RewardHandler {
    ops: RewardOps,
    state: RewardState,
    /// KV rows this replica's state holds (G full-shape, G/N sliced)
    rows: usize,
    /// pooled-KV mode: `state` holds `[P, H, bs, hd]` buffers and requests
    /// must be `StreamPaged` (the dense and paged shapes are incompatible)
    paged: bool,
}

impl RewardHandler {
    fn new(engine: Arc<Engine>, rows: usize, paged: bool) -> Result<Self> {
        let ops = RewardOps::new(engine)?;
        let state = if paged { ops.fresh_paged_state()? } else { ops.fresh_state_rows(rows)? };
        Ok(Self { ops, state, rows, paged })
    }
}

impl StageHandler for RewardHandler {
    type Req = RewardReq;
    type Resp = RewardResp;

    fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        match req {
            RewardReq::Reset => {
                self.state = if self.paged {
                    self.ops.fresh_paged_state()?
                } else {
                    self.ops.fresh_state_rows(self.rows)?
                };
                Ok(RewardResp::ResetDone)
            }
            RewardReq::Stream { entry, chunk, start, n_valid, picks, lane_map } => {
                ensure!(!self.paged, "dense stream request on a paged reward replica");
                let rows = start.len();
                let c = chunk.len() / rows;
                let scores =
                    self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?;
                Ok(RewardResp::StreamScores(
                    picks
                        .iter()
                        .map(|p| (lane_map[p.lane], scores[p.lane * c + p.idx_in_chunk]))
                        .collect(),
                ))
            }
            RewardReq::StreamPaged { entry, chunk, start, n_valid, picks, lane_map, table } => {
                ensure!(self.paged, "paged stream request on a dense reward replica");
                let rows = start.len();
                let c = chunk.len() / rows;
                let scores = self.ops.prefill_chunk_paged(
                    &mut self.state,
                    &entry,
                    &chunk,
                    &start,
                    &n_valid,
                    &table,
                )?;
                Ok(RewardResp::StreamScores(
                    picks
                        .iter()
                        .map(|p| (lane_map[p.lane], scores[p.lane * c + p.idx_in_chunk]))
                        .collect(),
                ))
            }
            RewardReq::ScoreFull { tokens, last_idx } => {
                Ok(RewardResp::FullScores(self.ops.score_full(&tokens, &last_idx)?))
            }
        }
    }
}

/// One replica of a mixed local/remote reward pool: in-process compute or
/// a [`RemoteReplica`](crate::transport::RemoteReplica) behind the framed
/// TCP transport — indistinguishable to the pool either way.
enum RewardBackend {
    Local(RewardHandler),
    Remote(crate::transport::RemoteReplica),
}

impl StageHandler for RewardBackend {
    type Req = RewardReq;
    type Resp = RewardResp;

    fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        match self {
            RewardBackend::Local(h) => h.handle(req),
            RewardBackend::Remote(c) => c.reward(&req),
        }
    }
}

/// Handle to the reward stage — a pool of one or more replicas, each
/// owning an independent `RewardOps` (own parameter buffers, own KV state,
/// built on its own thread by the handler factory).
pub struct RewardWorker {
    pool: StagePool<RewardReq, RewardResp>,
    /// `Some(G/N)` when this pool runs the lane-sliced entries (each
    /// replica's state holds only its compacted rows); `None` → masked
    /// full-shape fallback.
    sliced_rows: Option<usize>,
    /// Pool runs the paged entry family (pooled KV + block tables).
    paged: bool,
}

impl RewardWorker {
    /// Single-replica spawn (the monolithic scorer and simple callers).
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    /// Spawn `replicas` reward workers.  Streamed chunks are routed
    /// `lane % replicas`, so each replica prefills a disjoint lane subset
    /// against its own KV cache.  When the manifest ships lane-sliced
    /// entries for this replica count, each replica sizes its KV state to
    /// its `G/replicas` compacted rows and the pool runs the sliced
    /// kernels; otherwise it falls back to masked full-shape.
    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        Self::spawn_inner(engine, replicas, queue_depth, false)
    }

    /// Spawn a *paged* reward pool: each replica's KV is the pooled
    /// `[P, H, bs, hd]` buffer and streamed chunks arrive as `StreamPaged`
    /// with a block table.  Paged entries are full-G only, so replicas
    /// always route masked (no sliced flavour); requires
    /// [`Manifest::paged_supported`](crate::runtime::manifest::Manifest::paged_supported).
    pub fn spawn_replicated_paged(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        ensure!(
            engine.manifest().paged_supported(),
            "paged reward pool requested but the artifacts ship no paged entries"
        );
        Self::spawn_inner(engine, replicas, queue_depth, true)
    }

    fn spawn_inner(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
        paged: bool,
    ) -> Result<Self> {
        let g = engine.manifest().shape.lanes;
        let sliced_rows = (!paged && replicas > 1 && g % replicas == 0)
            .then(|| g / replicas)
            .filter(|&rows| engine.manifest().sliced_prefill_supported("reward", rows));
        let pool = StagePool::spawn("reward", replicas, queue_depth, |_replica| {
            let engine = engine.clone();
            let rows = sliced_rows.unwrap_or(g);
            move || RewardHandler::new(engine, rows, paged)
        })?;
        Ok(Self { pool, sliced_rows, paged })
    }

    /// Wrap an already-spawned pool (remote/mixed spawn paths and tests).
    /// The pool is treated as masked full-shape and dense — the only split
    /// the failover reroute supports.
    pub fn from_pool(pool: StagePool<RewardReq, RewardResp>) -> Self {
        Self { pool, sliced_rows: None, paged: false }
    }

    /// Spawn a pool whose replicas are all remote (`addrs[r]` hosts replica
    /// `r` behind a `remote-stage` listener).  Engine-free: the remote end
    /// owns the model.  Remote pools are always masked full-shape — failover
    /// reroutes lanes between replicas, which the compacted grid's fixed
    /// row ↔ lane binding cannot express.
    pub fn spawn_remote_pool(
        addrs: &[String],
        queue_depth: usize,
        opts: &crate::transport::ConnectOpts,
    ) -> Result<Self> {
        ensure!(!addrs.is_empty(), "remote reward pool needs at least one address");
        let pool = StagePool::spawn("reward", addrs.len(), queue_depth, |replica| {
            let addr = addrs[replica].clone();
            let opts = opts.clone();
            move || {
                let client = crate::transport::RemoteReplica::connect(
                    &addr, "reward", replica, None, &opts,
                )?;
                Ok(crate::transport::RemoteRewardHandler { client })
            }
        })?;
        Ok(Self::from_pool(pool))
    }

    /// Spawn a mixed pool: `local` in-process replicas (indices
    /// `0..local`) plus one remote replica per address (the highest
    /// indices).  `params` is the raw reward parameter blob distributed to
    /// every remote at connect, digest-verified so remote replicas provably
    /// score with the same weights as local ones.  Mixed pools are always
    /// masked full-shape (see [`spawn_remote_pool`](Self::spawn_remote_pool)).
    pub fn spawn_replicated_remote(
        engine: Arc<Engine>,
        local: usize,
        addrs: &[String],
        queue_depth: usize,
        opts: &crate::transport::ConnectOpts,
        params: Option<Arc<Vec<u8>>>,
    ) -> Result<Self> {
        let total = local + addrs.len();
        ensure!(total >= 1, "mixed reward pool needs at least one replica");
        let g = engine.manifest().shape.lanes;
        let pool = StagePool::spawn("reward", total, queue_depth, |replica| {
            let engine = engine.clone();
            let opts = opts.clone();
            let addr = (replica >= local).then(|| addrs[replica - local].clone());
            let params = params.clone();
            move || {
                if let Some(addr) = addr {
                    let blob = params.as_ref().map(|b| ("reward", b.as_slice()));
                    let client = crate::transport::RemoteReplica::connect(
                        &addr, "reward", replica, blob, &opts,
                    )?;
                    Ok(RewardBackend::Remote(client))
                } else {
                    Ok(RewardBackend::Local(RewardHandler::new(engine, g, false)?))
                }
            }
        })?;
        Ok(Self::from_pool(pool))
    }

    pub fn replicas(&self) -> usize {
        self.pool.replicas()
    }

    /// Compacted rows per replica when the pool runs sliced entries.
    pub fn sliced_rows(&self) -> Option<usize> {
        self.sliced_rows
    }

    /// Slots the pool's route currently sends to `replica`.
    pub fn slots_of(&self, replica: usize) -> Vec<usize> {
        self.pool.slots_of(replica)
    }

    pub fn is_alive(&self, replica: usize) -> bool {
        self.pool.is_alive(replica)
    }

    pub fn alive_count(&self) -> usize {
        self.pool.alive_count()
    }

    /// Retire a dead replica (see [`StagePool::retire`]).
    pub fn retire(&mut self, replica: usize) -> Result<(usize, Vec<usize>)> {
        self.pool.retire(replica)
    }

    /// Does this pool run the paged entry family?
    pub fn paged(&self) -> bool {
        self.paged
    }

    /// The replica owning `lane`'s KV state.
    pub fn replica_for_lane(&self, lane: usize) -> usize {
        self.pool.replica_for_lane(lane)
    }

    /// Enqueue on replica 0 (single-replica / monolithic path).
    pub fn submit(&mut self, req: RewardReq) -> Result<()> {
        self.pool.submit_to(0, req).map(|_| ())
    }

    /// Enqueue on one replica (bounded queue; blocks only under that
    /// replica's backpressure).
    pub fn submit_to(&mut self, replica: usize, req: RewardReq) -> Result<()> {
        self.pool.submit_to(replica, req).map(|_| ())
    }

    /// Two-phase fan-out of per-replica parts (see [`StagePool::fan_out`]).
    pub fn fan_out(&mut self, parts: Vec<(usize, RewardReq)>) -> Result<()> {
        self.pool.fan_out(parts)
    }

    /// Block for the next response from replica 0.
    pub fn recv(&mut self) -> Result<RewardResp> {
        self.pool.recv_from(0).map(|(_, r)| r)
    }

    /// Block for the next response from one replica.
    pub fn recv_from(&mut self, replica: usize) -> Result<RewardResp> {
        self.pool.recv_from(replica).map(|(_, r)| r)
    }

    /// Non-blocking: first ready response from any replica.
    pub fn try_recv_any(&mut self) -> Result<Option<(usize, RewardResp)>> {
        Ok(self.pool.try_recv_any()?.map(|(r, _, resp)| (r, resp)))
    }

    /// Non-blocking receive with per-request errors as values (failover
    /// detection point).
    pub fn try_recv_any_result(
        &mut self,
    ) -> Result<Option<(usize, std::result::Result<RewardResp, String>)>> {
        Ok(self.pool.try_recv_any_result()?.map(|(r, _, resp)| (r, resp)))
    }

    /// Blocking receive from one replica with the per-request error as a
    /// value.
    pub fn recv_from_result(
        &mut self,
        replica: usize,
    ) -> Result<std::result::Result<RewardResp, String>> {
        self.pool.recv_from_result(replica).map(|(_, r)| r)
    }

    pub fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    pub fn in_flight_on(&self, replica: usize) -> usize {
        self.pool.in_flight_on(replica)
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.pool.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// reference stage
// ---------------------------------------------------------------------------

/// Requests to the reference worker.
pub enum RefReq {
    /// Incremental ref-logprob prefill of one streamed chunk.
    Stream { entry: String, chunk: Vec<i32>, start: Vec<i32>, n_valid: Vec<i32> },
    /// The paged flavour: pooled KV + a `[G, s_max/block]` block table.
    StreamPaged {
        entry: String,
        chunk: Vec<i32>,
        start: Vec<i32>,
        n_valid: Vec<i32>,
        table: Vec<i32>,
    },
    /// Reset the ref KV/boundary state (new run / tests).
    Reset,
}

#[derive(Debug)]
pub enum RefResp {
    /// raw [G, C] log-probs for a stream request (garbage at j >= n_valid)
    StreamLogps(Vec<f32>),
    ResetDone,
}

struct RefHandler {
    ops: RefOps,
    state: RefStreamState,
    /// KV/boundary rows this replica's state holds (G or G/N)
    rows: usize,
    /// pooled-KV mode (see `RewardHandler::paged`)
    paged: bool,
}

impl RefHandler {
    fn new(engine: Arc<Engine>, rows: usize, paged: bool) -> Result<Self> {
        let ops = RefOps::new(engine)?;
        let state = if paged { ops.fresh_paged_state()? } else { ops.fresh_state_rows(rows)? };
        Ok(Self { ops, state, rows, paged })
    }
}

impl StageHandler for RefHandler {
    type Req = RefReq;
    type Resp = RefResp;

    fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        match req {
            RefReq::Reset => {
                self.state = if self.paged {
                    self.ops.fresh_paged_state()?
                } else {
                    self.ops.fresh_state_rows(self.rows)?
                };
                Ok(RefResp::ResetDone)
            }
            RefReq::Stream { entry, chunk, start, n_valid } => {
                ensure!(!self.paged, "dense stream request on a paged ref replica");
                Ok(RefResp::StreamLogps(
                    self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?,
                ))
            }
            RefReq::StreamPaged { entry, chunk, start, n_valid, table } => {
                ensure!(self.paged, "paged stream request on a dense ref replica");
                Ok(RefResp::StreamLogps(self.ops.prefill_chunk_paged(
                    &mut self.state,
                    &entry,
                    &chunk,
                    &start,
                    &n_valid,
                    &table,
                )?))
            }
        }
    }
}

/// One replica of a mixed local/remote ref pool (see [`RewardBackend`]).
enum RefBackend {
    Local(RefHandler),
    Remote(crate::transport::RemoteReplica),
}

impl StageHandler for RefBackend {
    type Req = RefReq;
    type Resp = RefResp;

    fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        match self {
            RefBackend::Local(h) => h.handle(req),
            RefBackend::Remote(c) => c.reference(&req),
        }
    }
}

/// Handle to the reference stage — a pool of one or more replicas, each
/// owning an independent `RefOps` plus its own KV + boundary seam state.
pub struct RefWorker {
    pool: StagePool<RefReq, RefResp>,
    /// `Some(G/N)` when this pool runs the lane-sliced entries.
    sliced_rows: Option<usize>,
    /// Pool runs the paged entry family.
    paged: bool,
}

impl RefWorker {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    /// Spawn `replicas` reference workers with sequence-affinity routing
    /// (`lane % replicas` — the boundary log-softmax seam is per-lane state
    /// that must stay on one replica).  Sliced entries are selected exactly
    /// as in [`RewardWorker::spawn_replicated`].
    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        Self::spawn_inner(engine, replicas, queue_depth, false)
    }

    /// Spawn a *paged* ref pool (see [`RewardWorker::spawn_replicated_paged`]).
    pub fn spawn_replicated_paged(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        ensure!(
            engine.manifest().paged_supported(),
            "paged ref pool requested but the artifacts ship no paged entries"
        );
        Self::spawn_inner(engine, replicas, queue_depth, true)
    }

    fn spawn_inner(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
        paged: bool,
    ) -> Result<Self> {
        let g = engine.manifest().shape.lanes;
        let sliced_rows = (!paged && replicas > 1 && g % replicas == 0)
            .then(|| g / replicas)
            .filter(|&rows| engine.manifest().sliced_prefill_supported("ref", rows));
        let pool = StagePool::spawn("ref", replicas, queue_depth, |_replica| {
            let engine = engine.clone();
            let rows = sliced_rows.unwrap_or(g);
            move || RefHandler::new(engine, rows, paged)
        })?;
        Ok(Self { pool, sliced_rows, paged })
    }

    /// Wrap an already-spawned pool (remote/mixed spawn paths and tests) —
    /// masked full-shape and dense (see [`RewardWorker::from_pool`]).
    pub fn from_pool(pool: StagePool<RefReq, RefResp>) -> Self {
        Self { pool, sliced_rows: None, paged: false }
    }

    /// Spawn an all-remote ref pool (see [`RewardWorker::spawn_remote_pool`]).
    pub fn spawn_remote_pool(
        addrs: &[String],
        queue_depth: usize,
        opts: &crate::transport::ConnectOpts,
    ) -> Result<Self> {
        ensure!(!addrs.is_empty(), "remote ref pool needs at least one address");
        let pool = StagePool::spawn("ref", addrs.len(), queue_depth, |replica| {
            let addr = addrs[replica].clone();
            let opts = opts.clone();
            move || {
                let client =
                    crate::transport::RemoteReplica::connect(&addr, "ref", replica, None, &opts)?;
                Ok(crate::transport::RemoteRefHandler { client })
            }
        })?;
        Ok(Self::from_pool(pool))
    }

    /// Spawn a mixed local/remote ref pool (see
    /// [`RewardWorker::spawn_replicated_remote`]).
    pub fn spawn_replicated_remote(
        engine: Arc<Engine>,
        local: usize,
        addrs: &[String],
        queue_depth: usize,
        opts: &crate::transport::ConnectOpts,
        params: Option<Arc<Vec<u8>>>,
    ) -> Result<Self> {
        let total = local + addrs.len();
        ensure!(total >= 1, "mixed ref pool needs at least one replica");
        let g = engine.manifest().shape.lanes;
        let pool = StagePool::spawn("ref", total, queue_depth, |replica| {
            let engine = engine.clone();
            let opts = opts.clone();
            let addr = (replica >= local).then(|| addrs[replica - local].clone());
            let params = params.clone();
            move || {
                if let Some(addr) = addr {
                    let blob = params.as_ref().map(|b| ("ref", b.as_slice()));
                    let client = crate::transport::RemoteReplica::connect(
                        &addr, "ref", replica, blob, &opts,
                    )?;
                    Ok(RefBackend::Remote(client))
                } else {
                    Ok(RefBackend::Local(RefHandler::new(engine, g, false)?))
                }
            }
        })?;
        Ok(Self::from_pool(pool))
    }

    pub fn replicas(&self) -> usize {
        self.pool.replicas()
    }

    /// Slots the pool's route currently sends to `replica`.
    pub fn slots_of(&self, replica: usize) -> Vec<usize> {
        self.pool.slots_of(replica)
    }

    pub fn is_alive(&self, replica: usize) -> bool {
        self.pool.is_alive(replica)
    }

    pub fn alive_count(&self) -> usize {
        self.pool.alive_count()
    }

    /// Retire a dead replica (see [`StagePool::retire`]).
    pub fn retire(&mut self, replica: usize) -> Result<(usize, Vec<usize>)> {
        self.pool.retire(replica)
    }

    /// Compacted rows per replica when the pool runs sliced entries.
    pub fn sliced_rows(&self) -> Option<usize> {
        self.sliced_rows
    }

    /// Does this pool run the paged entry family?
    pub fn paged(&self) -> bool {
        self.paged
    }

    pub fn replica_for_lane(&self, lane: usize) -> usize {
        self.pool.replica_for_lane(lane)
    }

    /// Enqueue on replica 0 (single-replica callers).
    pub fn submit(&mut self, req: RefReq) -> Result<()> {
        self.pool.submit_to(0, req).map(|_| ())
    }

    pub fn submit_to(&mut self, replica: usize, req: RefReq) -> Result<()> {
        self.pool.submit_to(replica, req).map(|_| ())
    }

    /// Two-phase fan-out of per-replica parts (see [`StagePool::fan_out`]).
    pub fn fan_out(&mut self, parts: Vec<(usize, RefReq)>) -> Result<()> {
        self.pool.fan_out(parts)
    }

    /// Block for the next response from replica 0.
    pub fn recv(&mut self) -> Result<RefResp> {
        self.pool.recv_from(0).map(|(_, r)| r)
    }

    pub fn recv_from(&mut self, replica: usize) -> Result<RefResp> {
        self.pool.recv_from(replica).map(|(_, r)| r)
    }

    pub fn try_recv_any(&mut self) -> Result<Option<(usize, RefResp)>> {
        Ok(self.pool.try_recv_any()?.map(|(r, _, resp)| (r, resp)))
    }

    /// Non-blocking receive with per-request errors as values (failover
    /// detection point).
    pub fn try_recv_any_result(
        &mut self,
    ) -> Result<Option<(usize, std::result::Result<RefResp, String>)>> {
        Ok(self.pool.try_recv_any_result()?.map(|(r, _, resp)| (r, resp)))
    }

    /// Blocking receive from one replica with the per-request error as a
    /// value.
    pub fn recv_from_result(
        &mut self,
        replica: usize,
    ) -> Result<std::result::Result<RefResp, String>> {
        self.pool.recv_from_result(replica).map(|(_, r)| r)
    }

    pub fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    pub fn in_flight_on(&self, replica: usize) -> usize {
        self.pool.in_flight_on(replica)
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.pool.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// fan-out facade
// ---------------------------------------------------------------------------

/// Per-request bookkeeping for the ref sink's scatter-back: the response
/// is a raw row-major log-prob grid, so the request's row metadata — and
/// the row → absolute-lane map when the grid is compacted — must ride
/// alongside.
struct RefMeta {
    start: Vec<i32>,
    n_valid: Vec<i32>,
    c: usize,
    /// row → absolute lane (identity on the masked full-shape path)
    lane_map: Vec<usize>,
}

/// Ref sink bookkeeping: responses are raw `[rows, C]` log-prob grids, so
/// the per-request [`RefMeta`] rides a FIFO alongside the in-flight
/// requests — one FIFO **per replica**, because each replica answers
/// strictly in its own submission order while responses from different
/// replicas may interleave (they touch disjoint lane sets).
pub struct RefSink {
    worker: RefWorker,
    meta: Vec<VecDeque<RefMeta>>,
}

impl RefSink {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let worker = RefWorker::spawn_replicated(engine, replicas, queue_depth)?;
        let meta = (0..worker.replicas()).map(|_| VecDeque::new()).collect();
        Ok(Self { worker, meta })
    }

    pub fn spawn_replicated_paged(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let worker = RefWorker::spawn_replicated_paged(engine, replicas, queue_depth)?;
        let meta = (0..worker.replicas()).map(|_| VecDeque::new()).collect();
        Ok(Self { worker, meta })
    }

    /// Wrap an already-spawned worker (remote/mixed spawn paths and tests).
    pub fn from_worker(worker: RefWorker) -> Self {
        let meta = (0..worker.replicas()).map(|_| VecDeque::new()).collect();
        Self { worker, meta }
    }

    fn apply(&mut self, replica: usize, buf: &mut SeqBuffer, logps: Vec<f32>) -> Result<()> {
        let meta = self.meta[replica]
            .pop_front()
            .context("ref stage response without a matching request")?;
        let c = meta.c;
        for (row, &lane) in meta.lane_map.iter().enumerate() {
            let nv = meta.n_valid[row] as usize;
            if nv == 0 {
                continue;
            }
            let seq = buf
                .by_lane_mut(lane)
                .with_context(|| format!("ref response for vacated lane {lane}"))?;
            let st = meta.start[row] as usize;
            ensure!(
                seq.ref_logp.len() == st,
                "ref stream discontinuity on lane {lane}: have {} positions, chunk starts at {st}",
                seq.ref_logp.len()
            );
            seq.ref_logp.extend_from_slice(&logps[row * c..row * c + nv]);
        }
        Ok(())
    }
}

/// A replica's per-request failure surfaced by the `*_ft` receive paths —
/// the scheduler hands it to [`StreamSink::failover`] instead of aborting
/// the step.
#[derive(Debug)]
pub struct ReplicaFailure {
    pub stage: &'static str,
    pub replica: usize,
    pub msg: String,
}

/// Scheduler-side handle to one active downstream stage.  The step loop
/// fans every [`StreamChunk`] out to all sinks and joins them at flush;
/// each sink splits the chunk lane-wise across its replica pool
/// (sequence-affinity routing).  Future stages (critic, remote-node
/// consumers) add a variant here and a worker above, and the scheduler
/// loop stays untouched.
pub enum StreamSink {
    Reward(RewardWorker),
    Ref(RefSink),
}

impl StreamSink {
    pub fn name(&self) -> &'static str {
        match self {
            StreamSink::Reward(_) => "reward",
            StreamSink::Ref(_) => "ref",
        }
    }

    /// Worker replicas behind this stage.
    pub fn replicas(&self) -> usize {
        match self {
            StreamSink::Reward(w) => w.replicas(),
            StreamSink::Ref(s) => s.worker.replicas(),
        }
    }

    /// Replicas still alive (failover retires dead ones permanently).
    pub fn alive_count(&self) -> usize {
        match self {
            StreamSink::Reward(w) => w.alive_count(),
            StreamSink::Ref(s) => s.worker.alive_count(),
        }
    }

    /// Submit one streamed chunk to this stage: one sub-request per replica
    /// that owns any valid lane in the chunk (typed per-stage request),
    /// delivered through the pool's two-phase fan-out — a busy replica
    /// delays only its own feeding (see [`StagePool::fan_out`]).  Pools
    /// whose artifacts ship lane-sliced entries get the compacted
    /// `[G/N, C]` grid + sliced entry name; otherwise the masked
    /// full-shape fallback.
    pub fn submit_chunk(&mut self, ck: &StreamChunk) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                let n = w.replicas();
                let sliced = w.sliced_rows().is_some();
                let mut parts = Vec::new();
                for r in 0..n {
                    // sliced pools never reroute (fixed row ↔ lane binding);
                    // masked pools split by the route so a failover survivor
                    // picks up the dead replica's slots transparently
                    let part = if sliced {
                        ck.for_replica(r, n, true)
                    } else {
                        ck.masked_for_slots(&w.slots_of(r), n)
                    };
                    let Some(part) = part else { continue };
                    let entry = if sliced {
                        format!("reward_prefill_chunk_g{}_c{}", part.lane_map.len(), part.chunk.c)
                    } else {
                        format!("reward_prefill_chunk_c{}", part.chunk.c)
                    };
                    parts.push((
                        r,
                        RewardReq::Stream {
                            entry,
                            chunk: part.chunk.tokens,
                            start: part.chunk.start,
                            n_valid: part.chunk.n_valid,
                            picks: part.chunk.picks,
                            lane_map: part.lane_map,
                        },
                    ));
                }
                w.fan_out(parts)
            }
            StreamSink::Ref(s) => {
                let n = s.worker.replicas();
                let sliced = s.worker.sliced_rows().is_some();
                let mut parts = Vec::new();
                for r in 0..n {
                    let part = if sliced {
                        ck.for_replica(r, n, true)
                    } else {
                        ck.masked_for_slots(&s.worker.slots_of(r), n)
                    };
                    let Some(part) = part else { continue };
                    let entry = if sliced {
                        format!("ref_prefill_chunk_g{}_c{}", part.lane_map.len(), part.chunk.c)
                    } else {
                        format!("ref_prefill_chunk_c{}", part.chunk.c)
                    };
                    // meta rides in per-replica submission order; each
                    // replica gets at most one part per chunk, so pushing at
                    // build time keeps the FIFO aligned whichever fan-out
                    // phase actually enqueues the part
                    s.meta[r].push_back(RefMeta {
                        start: part.chunk.start.clone(),
                        n_valid: part.chunk.n_valid.clone(),
                        c: part.chunk.c,
                        lane_map: part.lane_map,
                    });
                    parts.push((
                        r,
                        RefReq::Stream {
                            entry,
                            chunk: part.chunk.tokens,
                            start: part.chunk.start,
                            n_valid: part.chunk.n_valid,
                        },
                    ));
                }
                s.worker.fan_out(parts)
            }
        }
    }

    /// Does this stage run the paged entry family?
    pub fn paged(&self) -> bool {
        match self {
            StreamSink::Reward(w) => w.paged(),
            StreamSink::Ref(s) => s.worker.paged(),
        }
    }

    /// Submit one streamed chunk with its block table (paged pools only).
    /// Paged entries are full-G, so replicas always get the masked
    /// full-shape split; each part carries a clone of the table — every
    /// replica's pooled KV uses the same lane → block mapping, which is
    /// safe because replicas only *read* rows they own (`n_valid > 0`) and
    /// each writes its private pool buffer.
    pub fn submit_chunk_paged(&mut self, ck: &StreamChunk, table: &[i32]) -> Result<()> {
        ensure!(self.paged(), "submit_chunk_paged on a dense {} pool", self.name());
        match self {
            StreamSink::Reward(w) => {
                let n = w.replicas();
                let mut parts = Vec::new();
                for r in 0..n {
                    let Some(part) = ck.masked_for_slots(&w.slots_of(r), n) else { continue };
                    parts.push((
                        r,
                        RewardReq::StreamPaged {
                            entry: format!("reward_prefill_chunk_paged_c{}", part.chunk.c),
                            chunk: part.chunk.tokens,
                            start: part.chunk.start,
                            n_valid: part.chunk.n_valid,
                            picks: part.chunk.picks,
                            lane_map: part.lane_map,
                            table: table.to_vec(),
                        },
                    ));
                }
                w.fan_out(parts)
            }
            StreamSink::Ref(s) => {
                let n = s.worker.replicas();
                let mut parts = Vec::new();
                for r in 0..n {
                    let Some(part) = ck.masked_for_slots(&s.worker.slots_of(r), n) else {
                        continue;
                    };
                    s.meta[r].push_back(RefMeta {
                        start: part.chunk.start.clone(),
                        n_valid: part.chunk.n_valid.clone(),
                        c: part.chunk.c,
                        lane_map: part.lane_map,
                    });
                    parts.push((
                        r,
                        RefReq::StreamPaged {
                            entry: format!("ref_prefill_chunk_paged_c{}", part.chunk.c),
                            chunk: part.chunk.tokens,
                            start: part.chunk.start,
                            n_valid: part.chunk.n_valid,
                            table: table.to_vec(),
                        },
                    ));
                }
                s.worker.fan_out(parts)
            }
        }
    }

    /// Apply any responses that are already available (non-blocking).
    pub fn collect_ready(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                while let Some((_replica, resp)) = w.try_recv_any()? {
                    apply_reward(buf, resp)?;
                }
            }
            StreamSink::Ref(s) => {
                while let Some((replica, resp)) = s.worker.try_recv_any()? {
                    match resp {
                        RefResp::StreamLogps(lp) => s.apply(replica, buf, lp)?,
                        other => bail!("unexpected ref response {other:?}"),
                    }
                }
            }
        }
        Ok(())
    }

    /// Block until every in-flight response is applied (the flush join),
    /// draining each replica in turn — responses are ordered per replica.
    pub fn join(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                for r in 0..w.replicas() {
                    while w.in_flight_on(r) > 0 {
                        let resp = w.recv_from(r)?;
                        apply_reward(buf, resp)?;
                    }
                }
            }
            StreamSink::Ref(s) => {
                for r in 0..s.worker.replicas() {
                    while s.worker.in_flight_on(r) > 0 {
                        match s.worker.recv_from(r)? {
                            RefResp::StreamLogps(lp) => s.apply(r, buf, lp)?,
                            other => bail!("unexpected ref response {other:?}"),
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Can this stage survive the loss of a replica?  Requires the masked
    /// full-shape split (a compacted grid's row ↔ lane binding cannot be
    /// rerouted) and at least one other live replica to re-home onto.
    pub fn failover_capable(&self) -> bool {
        match self {
            StreamSink::Reward(w) => w.sliced_rows().is_none() && w.alive_count() > 1,
            StreamSink::Ref(s) => s.worker.sliced_rows().is_none() && s.worker.alive_count() > 1,
        }
    }

    /// [`collect_ready`](Self::collect_ready) with failure surfacing: a
    /// per-request error comes back as a [`ReplicaFailure`] when the stage
    /// can fail over, so the caller can retire + replay and keep the step
    /// alive; without a failover path it propagates as an error, as before.
    pub fn collect_ready_ft(&mut self, buf: &mut SeqBuffer) -> Result<Option<ReplicaFailure>> {
        let capable = self.failover_capable();
        match self {
            StreamSink::Reward(w) => {
                while let Some((replica, resp)) = w.try_recv_any_result()? {
                    match resp {
                        Ok(resp) => apply_reward(buf, resp)?,
                        Err(msg) if capable => {
                            return Ok(Some(ReplicaFailure { stage: "reward", replica, msg }))
                        }
                        Err(msg) => bail!("reward stage replica {replica}: {msg}"),
                    }
                }
            }
            StreamSink::Ref(s) => {
                while let Some((replica, resp)) = s.worker.try_recv_any_result()? {
                    match resp {
                        Ok(RefResp::StreamLogps(lp)) => s.apply(replica, buf, lp)?,
                        Ok(other) => bail!("unexpected ref response {other:?}"),
                        Err(msg) => {
                            // the failed request's meta must still leave the
                            // FIFO so later responses stay aligned
                            s.meta[replica].pop_front();
                            if capable {
                                return Ok(Some(ReplicaFailure { stage: "ref", replica, msg }));
                            }
                            bail!("ref stage replica {replica}: {msg}");
                        }
                    }
                }
            }
        }
        Ok(None)
    }

    /// [`join`](Self::join) with failure surfacing (see
    /// [`collect_ready_ft`](Self::collect_ready_ft)).  On a failure the
    /// join stops early — the caller runs failover, then joins again.
    pub fn join_ft(&mut self, buf: &mut SeqBuffer) -> Result<Option<ReplicaFailure>> {
        let capable = self.failover_capable();
        match self {
            StreamSink::Reward(w) => {
                for r in 0..w.replicas() {
                    while w.in_flight_on(r) > 0 {
                        match w.recv_from_result(r)? {
                            Ok(resp) => apply_reward(buf, resp)?,
                            Err(msg) if capable => {
                                return Ok(Some(ReplicaFailure {
                                    stage: "reward",
                                    replica: r,
                                    msg,
                                }))
                            }
                            Err(msg) => bail!("reward stage replica {r}: {msg}"),
                        }
                    }
                }
            }
            StreamSink::Ref(s) => {
                for r in 0..s.worker.replicas() {
                    while s.worker.in_flight_on(r) > 0 {
                        match s.worker.recv_from_result(r)? {
                            Ok(RefResp::StreamLogps(lp)) => s.apply(r, buf, lp)?,
                            Ok(other) => bail!("unexpected ref response {other:?}"),
                            Err(msg) => {
                                s.meta[r].pop_front();
                                if capable {
                                    return Ok(Some(ReplicaFailure {
                                        stage: "ref",
                                        replica: r,
                                        msg,
                                    }));
                                }
                                bail!("ref stage replica {r}: {msg}");
                            }
                        }
                    }
                }
            }
        }
        Ok(None)
    }

    /// Re-home a dead replica's lanes onto a survivor: retire it in the
    /// pool (rerouting its slots, abandoning its in-flight work), roll the
    /// affected lanes' stage progress back, and replay their retained
    /// chunks from the buffer.  The route-aware split in
    /// [`submit_chunk`](Self::submit_chunk) delivers the replayed chunks
    /// only to the survivor, whose kernels rebuild KV/seam state from
    /// position 0 exactly as for a recycled lane — future live chunks then
    /// continue seamlessly from the stream cursor.  Reward lanes that
    /// already hold their score receive no further chunks, so they are not
    /// replayed; unscored lanes replay `with_picks` so a score lost
    /// in flight is re-emitted at the final position.  Pass the block
    /// table on paged pools.
    pub fn failover(
        &mut self,
        buf: &mut SeqBuffer,
        fail: &ReplicaFailure,
        chunk: usize,
        table: Option<&[i32]>,
    ) -> Result<()> {
        ensure!(
            self.failover_capable(),
            "{} stage: failover requested without a failover path",
            self.name()
        );
        ensure!(
            self.paged() == table.is_some(),
            "{} stage: failover table must match the pool's paged mode",
            self.name()
        );
        let n_slots = self.replicas();
        let (lanes, with_picks) = match self {
            StreamSink::Reward(w) => {
                let (_survivor, slots) = w.retire(fail.replica)?;
                let lanes: Vec<usize> = buf
                    .iter()
                    .filter(|s| slots.contains(&(s.lane % n_slots)) && s.rm_score.is_none())
                    .map(|s| s.lane)
                    .collect();
                (lanes, true)
            }
            StreamSink::Ref(s) => {
                let (_survivor, slots) = s.worker.retire(fail.replica)?;
                // in-flight metas of the dead replica die with it
                s.meta[fail.replica].clear();
                let mut lanes = Vec::new();
                for seq in buf.iter_mut() {
                    if slots.contains(&(seq.lane % n_slots)) {
                        // the replay rebuilds the lane's log-probs from
                        // position 0 (apply's continuity check requires it)
                        seq.ref_logp.clear();
                        lanes.push(seq.lane);
                    }
                }
                (lanes, false)
            }
        };
        let replay = buf.replay_chunks(&lanes, chunk, with_picks);
        log::warn!(
            "{} stage: replaying {} retained chunk(s) for {} lane(s) after replica {} died ({})",
            self.name(),
            replay.len(),
            lanes.len(),
            fail.replica,
            fail.msg
        );
        for ck in &replay {
            match table {
                Some(t) => self.submit_chunk_paged(ck, t)?,
                None => self.submit_chunk(ck)?,
            }
        }
        Ok(())
    }

    /// Does this stage hold everything it needs for `seq`?  Checked for
    /// finished sequences when deciding whether the flush loop must keep
    /// streaming.
    pub fn is_satisfied(&self, seq: &Sequence) -> bool {
        match self {
            StreamSink::Reward(_) => seq.rm_score.is_some(),
            StreamSink::Ref(_) => seq.ref_logp.len() >= seq.total_len(),
        }
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        match self {
            StreamSink::Reward(w) => w.timing_delta(),
            StreamSink::Ref(s) => s.worker.timing_delta(),
        }
    }
}

/// Build the `remote-stage` serve backend for one engine-backed replica
/// (full-shape dense rows — remote pools are always masked).  Returns the
/// request processor plus the params sink the serve loop feeds: weights
/// normally arrive over the wire at handshake and (re)build the handler;
/// if the coordinator skips distribution, the first request falls back to
/// the node-local `params_<stage>.bin`.
pub fn engine_serve_backend(
    engine: Arc<Engine>,
    stage: &str,
) -> Result<(crate::transport::Backend, Box<dyn FnMut(&str, &[u8]) -> Result<()> + Send>)> {
    use std::sync::Mutex;
    let g = engine.manifest().shape.lanes;
    match stage {
        "reward" => {
            let slot: Arc<Mutex<Option<RewardHandler>>> = Arc::new(Mutex::new(None));
            let (s1, e1) = (slot.clone(), engine.clone());
            let on_params = Box::new(move |which: &str, data: &[u8]| -> Result<()> {
                ensure!(which == "reward", "reward server got {which:?} params");
                let ops = RewardOps::with_params(e1.clone(), data)?;
                let state = ops.fresh_state_rows(g)?;
                *s1.lock().unwrap() = Some(RewardHandler { ops, state, rows: g, paged: false });
                Ok(())
            });
            let backend = crate::transport::Backend::Reward(Box::new(move |req| {
                let mut guard = slot.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(RewardHandler::new(engine.clone(), g, false)?);
                }
                guard.as_mut().unwrap().handle(req)
            }));
            Ok((backend, on_params))
        }
        "ref" => {
            let slot: Arc<Mutex<Option<RefHandler>>> = Arc::new(Mutex::new(None));
            let (s1, e1) = (slot.clone(), engine.clone());
            let on_params = Box::new(move |which: &str, data: &[u8]| -> Result<()> {
                ensure!(which == "ref", "ref server got {which:?} params");
                let ops = RefOps::with_params(e1.clone(), data)?;
                let state = ops.fresh_state_rows(g)?;
                *s1.lock().unwrap() = Some(RefHandler { ops, state, rows: g, paged: false });
                Ok(())
            });
            let backend = crate::transport::Backend::Ref(Box::new(move |req| {
                let mut guard = slot.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(RefHandler::new(engine.clone(), g, false)?);
                }
                guard.as_mut().unwrap().handle(req)
            }));
            Ok((backend, on_params))
        }
        other => bail!("unknown stage {other:?} (want reward|ref)"),
    }
}

fn apply_reward(buf: &mut SeqBuffer, resp: RewardResp) -> Result<()> {
    match resp {
        RewardResp::StreamScores(scores) => {
            for (lane, score) in scores {
                if let Some(seq) = buf.by_lane_mut(lane) {
                    seq.rm_score = Some(score);
                }
            }
            Ok(())
        }
        other => bail!("unexpected reward response {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk() -> StreamChunk {
        StreamChunk {
            c: 4,
            tokens: (0..6 * 4).map(|x| x as i32).collect(),
            start: vec![0; 6],
            n_valid: vec![4, 0, 2, 4, 1, 3],
            picks: vec![Pick { lane: 0, idx_in_chunk: 3 }, Pick { lane: 4, idx_in_chunk: 0 }],
        }
    }

    #[test]
    fn for_replica_is_the_identity_with_one_replica() {
        let ck = chunk();
        let part = ck.for_replica(0, 1, false).unwrap();
        assert_eq!(part.chunk.n_valid, ck.n_valid);
        assert_eq!(part.chunk.tokens, ck.tokens);
        assert_eq!(part.chunk.picks.len(), ck.picks.len());
        assert_eq!(part.lane_map, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn masked_split_masks_unowned_lanes_and_filters_picks() {
        let ck = chunk();
        let even = ck.for_replica(0, 2, false).unwrap();
        assert_eq!(even.chunk.n_valid, vec![4, 0, 2, 0, 1, 0]);
        assert_eq!(even.chunk.picks.len(), 2, "picks on lanes 0 and 4 are owned");
        assert!(even.chunk.picks.iter().all(|p| p.lane % 2 == 0));
        assert_eq!(even.lane_map, vec![0, 1, 2, 3, 4, 5], "masked lane map is identity");
        let odd = ck.for_replica(1, 2, false).unwrap();
        assert_eq!(odd.chunk.n_valid, vec![0, 0, 0, 4, 0, 3]);
        assert!(odd.chunk.picks.is_empty());
        // the split is a partition: every valid token owned exactly once
        for lane in 0..6 {
            assert_eq!(even.chunk.n_valid[lane] + odd.chunk.n_valid[lane], ck.n_valid[lane]);
        }
    }

    #[test]
    fn for_replica_elides_replicas_with_nothing_to_do() {
        let mut ck = chunk();
        ck.n_valid = vec![4, 0, 2, 0, 1, 0]; // odd lanes all idle
        assert!(ck.for_replica(1, 2, false).is_none(), "no owned valid lane => no request");
        assert!(ck.for_replica(0, 2, false).is_some());
        assert!(ck.for_replica(1, 2, true).is_none(), "compacted path elides too");
        assert!(ck.for_replica(0, 2, true).is_some());
    }

    #[test]
    fn compaction_packs_owned_lanes_and_rewrites_picks() {
        let ck = chunk();
        let even = ck.for_replica(0, 2, true).unwrap();
        assert_eq!(even.lane_map, vec![0, 2, 4], "rows are the owned lanes in order");
        assert_eq!(even.chunk.lanes(), 3);
        assert_eq!(even.chunk.n_valid, vec![4, 2, 1]);
        // tokens copied row-wise from the absolute lanes
        for (row, &lane) in even.lane_map.iter().enumerate() {
            assert_eq!(
                even.chunk.tokens[row * 4..(row + 1) * 4],
                ck.tokens[lane * 4..(lane + 1) * 4]
            );
        }
        // picks rewritten into row coordinates: abs lanes 0, 4 → rows 0, 2
        let rows: Vec<usize> = even.chunk.picks.iter().map(|p| p.lane).collect();
        assert_eq!(rows, vec![0, 2]);
        // the lane map inverts the rewrite
        for (p, orig) in even.chunk.picks.iter().zip(&ck.picks) {
            assert_eq!(even.lane_map[p.lane], orig.lane);
            assert_eq!(p.idx_in_chunk, orig.idx_in_chunk);
        }
        let odd = ck.for_replica(1, 2, true).unwrap();
        assert_eq!(odd.lane_map, vec![1, 3, 5]);
        assert_eq!(odd.chunk.n_valid, vec![0, 4, 3], "idle owned lanes keep their row");
        assert!(odd.chunk.picks.is_empty());
    }

    #[test]
    fn compaction_partitions_every_valid_token() {
        let ck = chunk();
        for n in [2, 3, 6] {
            let mut seen = vec![0i32; 6];
            for r in 0..n {
                let Some(part) = ck.for_replica(r, n, true) else { continue };
                for (row, &lane) in part.lane_map.iter().enumerate() {
                    assert_eq!(lane % n, r, "row owned by the routing rule");
                    seen[lane] += part.chunk.n_valid[row];
                }
            }
            assert_eq!(seen, ck.n_valid, "n={n}");
        }
    }

    #[test]
    fn compaction_row_binding_is_stable_across_chunks() {
        // the same lane must land on the same row every chunk — the
        // replica's KV/seam state is indexed by row
        let mut ck = chunk();
        let first = ck.for_replica(1, 3, true).unwrap();
        ck.n_valid = vec![0, 2, 0, 0, 4, 0]; // different activity pattern
        ck.picks.clear();
        let second = ck.for_replica(1, 3, true).unwrap();
        assert_eq!(first.lane_map, second.lane_map);
        assert_eq!(first.lane_map, vec![1, 4]);
    }
}
