//! Typed wrappers over the AOT entry points + the device-resident state
//! they thread.  One `Ops` instance owns the actor/reference parameters and
//! Adam state; the reward worker owns its own [`RewardOps`] (separate
//! thread, separate params, shared engine — PJRT executes concurrently,
//! which is what realizes intra-step overlap on this backend).
//!
//! Data movement policy (EXPERIMENTS.md §Perf): params, Adam moments, token
//! buffers, and KV caches live on device for the whole run; per chunk only
//! `pos`/`live` (G ints), the RNG key, and the sampled tokens / log-probs /
//! values / scores ([G,C] each) cross the host boundary.

use std::sync::Arc;

use anyhow::{ensure, Result};
use xla::PjRtBuffer;

use crate::model::rollout::PpoBatch;
use crate::runtime::{Engine, ParamSet};

/// Device-resident actor generation state for the G lanes.
pub struct ActorState {
    /// token buffer [G, S] i32
    pub tokens: PjRtBuffer,
    /// per-layer KV caches, [k0, v0, k1, v1, ...] each [G, H, S, hd] f32
    pub kv: Vec<PjRtBuffer>,
}

/// Device-resident reward-model streaming state.
pub struct RewardState {
    pub kv: Vec<PjRtBuffer>,
}

/// Output of one `actor_generate_chunk` call (host side).
pub struct ChunkOut {
    /// sampled tokens, row-major [G, C]
    pub tokens: Vec<i32>,
    /// log-probs of the sampled tokens [G, C]
    pub logps: Vec<f32>,
    /// value estimates [G, C]
    pub values: Vec<f32>,
}

/// Actor-side ops: generation, reference scoring, PPO/DPO updates.
pub struct Ops {
    engine: Arc<Engine>,
    actor: ParamSet,
    refm: ParamSet,
    adam_m: ParamSet,
    adam_v: ParamSet,
    rng_counter: u64,
    seed: u64,
}

impl Ops {
    pub fn new(engine: Arc<Engine>, seed: u64) -> Result<Self> {
        let actor = ParamSet::load(&engine, "actor")?;
        let refm = ParamSet::load(&engine, "ref")?;
        let adam_m = ParamSet::zeros_like(&engine)?;
        let adam_v = ParamSet::zeros_like(&engine)?;
        Ok(Self { engine, actor, refm, adam_m, adam_v, rng_counter: 0, seed })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    fn g(&self) -> usize {
        self.engine.manifest().shape.lanes
    }

    fn s(&self) -> usize {
        self.engine.manifest().shape.s_max
    }

    fn n_kv(&self) -> usize {
        2 * self.engine.manifest().shape.n_layers
    }

    /// Fresh actor state: zero KV caches + an uploaded token buffer.
    pub fn fresh_actor_state(&self, tokens_host: &[i32]) -> Result<ActorState> {
        let (g, s) = (self.g(), self.s());
        ensure!(tokens_host.len() == g * s);
        let shape = self.engine.manifest().shape.kv_shape(g);
        let kv = (0..self.n_kv())
            .map(|_| self.engine.zeros_f32(&shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(ActorState { tokens: self.engine.upload_i32(tokens_host, &[g, s])?, kv })
    }

    /// `actor_prefill`: re-prefill the lanes with `reset != 0` from the
    /// (host-authoritative) token buffer; other lanes keep their KV rows
    /// bit-identical.  Replaces the state's token buffer wholesale — the
    /// host mirror is the source of truth at reset boundaries.
    pub fn actor_prefill(
        &self,
        state: &mut ActorState,
        tokens_host: &[i32],
        prompt_len: &[i32],
        reset: &[i32],
    ) -> Result<()> {
        let (g, s) = (self.g(), self.s());
        ensure!(tokens_host.len() == g * s && prompt_len.len() == g && reset.len() == g);
        let tokens = self.engine.upload_i32(tokens_host, &[g, s])?;
        let plen = self.engine.upload_i32(prompt_len, &[g])?;
        let rst = self.engine.upload_i32(reset, &[g])?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.actor.len() + 3 + self.n_kv());
        args.extend(self.actor.bufs());
        args.push(&tokens);
        args.push(&plen);
        args.push(&rst);
        args.extend(state.kv.iter());
        let outs = self.engine.execute_scoped("actor", "actor_prefill", &args)?;
        state.kv = outs;
        state.tokens = tokens;
        Ok(())
    }

    /// Fresh *paged* actor state: the KV vec holds the pooled per-layer
    /// buffers (`[P, H, bs, hd]`, physical block 0 = scratch) instead of
    /// dense per-lane caches.  Same `ActorState` type — only the shapes and
    /// the entry family differ, so the generation loop stays shared.
    pub fn fresh_actor_state_paged(&self, tokens_host: &[i32]) -> Result<ActorState> {
        let (g, s) = (self.g(), self.s());
        ensure!(tokens_host.len() == g * s);
        let shape = self.engine.manifest().shape.paged_kv_shape();
        let kv = (0..self.n_kv())
            .map(|_| self.engine.zeros_f32(&shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(ActorState { tokens: self.engine.upload_i32(tokens_host, &[g, s])?, kv })
    }

    /// `actor_prefill_paged`: the paged flavour of [`Self::actor_prefill`].
    /// `table` is the host [`crate::coordinator::BlockPool`]'s flattened
    /// `[G, s_max/block]` block table; rows being re-prefilled must already
    /// have their prompt blocks mapped.
    pub fn actor_prefill_paged(
        &self,
        state: &mut ActorState,
        tokens_host: &[i32],
        prompt_len: &[i32],
        reset: &[i32],
        table: &[i32],
    ) -> Result<()> {
        let (g, s) = (self.g(), self.s());
        ensure!(tokens_host.len() == g * s && prompt_len.len() == g && reset.len() == g);
        let tokens = self.engine.upload_i32(tokens_host, &[g, s])?;
        let plen = self.engine.upload_i32(prompt_len, &[g])?;
        let rst = self.engine.upload_i32(reset, &[g])?;
        let tbl = upload_block_table(&self.engine, g, table)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.actor.len() + 4 + self.n_kv());
        args.extend(self.actor.bufs());
        args.push(&tokens);
        args.push(&plen);
        args.push(&rst);
        args.extend(state.kv.iter());
        args.push(&tbl);
        let outs = self.engine.execute_scoped("actor", "actor_prefill_paged", &args)?;
        state.kv = outs;
        state.tokens = tokens;
        Ok(())
    }

    /// `actor_generate_chunk_paged_c{c}`: the paged flavour of
    /// [`Self::generate_chunk`].  The host must have grown every live
    /// lane's table to cover `pos + c` positions before calling.
    pub fn generate_chunk_paged(
        &mut self,
        state: &mut ActorState,
        c: usize,
        pos: &[i32],
        live: &[i32],
        table: &[i32],
    ) -> Result<ChunkOut> {
        let g = self.g();
        ensure!(pos.len() == g && live.len() == g);
        let entry = format!("actor_generate_chunk_paged_c{c}");
        let pos_b = self.engine.upload_i32(pos, &[g])?;
        let live_b = self.engine.upload_i32(live, &[g])?;
        self.rng_counter += 1;
        let key: [u32; 2] = [self.seed as u32, self.rng_counter as u32];
        let key_b = self.engine.upload_u32(&key, &[2])?;
        let tbl = upload_block_table(&self.engine, g, table)?;

        let n_kv = self.n_kv();
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.actor.len() + 5 + n_kv);
        args.extend(self.actor.bufs());
        args.push(&state.tokens);
        args.push(&pos_b);
        args.push(&live_b);
        args.extend(state.kv.iter());
        args.push(&key_b);
        args.push(&tbl);
        let mut outs = self.engine.execute_scoped("actor", &entry, &args)?;

        // outputs mirror the dense entry: tokens', pos', pool' ×n_kv,
        // out_tok, logp, value
        let values_b = outs.pop().unwrap();
        let logps_b = outs.pop().unwrap();
        let toks_b = outs.pop().unwrap();
        let kv: Vec<PjRtBuffer> = outs.drain(2..).collect();
        debug_assert_eq!(kv.len(), n_kv);
        let _pos_out = outs.pop().unwrap();
        state.tokens = outs.pop().unwrap();
        state.kv = kv;

        Ok(ChunkOut {
            tokens: self.engine.download_i32(&toks_b)?,
            logps: self.engine.download_f32(&logps_b)?,
            values: self.engine.download_f32(&values_b)?,
        })
    }

    /// `actor_generate_chunk_c{c}`: decode + sample `c` tokens on every
    /// live lane.  `pos`/`live` are host-managed (tiny uploads); the token
    /// buffer and KV caches stay on device and are swapped in place.
    pub fn generate_chunk(
        &mut self,
        state: &mut ActorState,
        c: usize,
        pos: &[i32],
        live: &[i32],
    ) -> Result<ChunkOut> {
        let g = self.g();
        ensure!(pos.len() == g && live.len() == g);
        let entry = format!("actor_generate_chunk_c{c}");
        let pos_b = self.engine.upload_i32(pos, &[g])?;
        let live_b = self.engine.upload_i32(live, &[g])?;
        // fresh threefry key per call: (seed, counter) is unique
        self.rng_counter += 1;
        let key: [u32; 2] = [self.seed as u32, self.rng_counter as u32];
        let key_b = self.engine.upload_u32(&key, &[2])?;

        let n_kv = self.n_kv();
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.actor.len() + 4 + n_kv);
        args.extend(self.actor.bufs());
        args.push(&state.tokens);
        args.push(&pos_b);
        args.push(&live_b);
        args.extend(state.kv.iter());
        args.push(&key_b);
        let mut outs = self.engine.execute_scoped("actor", &entry, &args)?;

        // outputs: tokens', pos', kv' ×n_kv, out_tok, logp, value
        let values_b = outs.pop().unwrap();
        let logps_b = outs.pop().unwrap();
        let toks_b = outs.pop().unwrap();
        let kv: Vec<PjRtBuffer> = outs.drain(2..).collect();
        debug_assert_eq!(kv.len(), n_kv);
        let _pos_out = outs.pop().unwrap(); // pos is host-managed
        state.tokens = outs.pop().unwrap();
        state.kv = kv;

        Ok(ChunkOut {
            tokens: self.engine.download_i32(&toks_b)?,
            logps: self.engine.download_f32(&logps_b)?,
            values: self.engine.download_f32(&values_b)?,
        })
    }

    /// `ref_logprobs` over a PPO batch's dense tokens — returns `[B, S]`.
    pub fn ref_logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.engine.manifest().shape.ppo_batch;
        let s = self.s();
        ensure!(tokens.len() == b * s);
        let toks = self.engine.upload_i32(tokens, &[b, s])?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.refm.len() + 1);
        args.extend(self.refm.bufs());
        args.push(&toks);
        let outs = self.engine.execute_scoped("ref", "ref_logprobs", &args)?;
        self.engine.download_f32(&outs[0])
    }

    /// `gae` (the L1 Pallas kernel's artifact): rewards/values/mask →
    /// advantage + return buffers, left on device for `ppo_update`.
    pub fn gae(
        &self,
        rewards: &[f32],
        values: &[f32],
        mask: &[f32],
    ) -> Result<(PjRtBuffer, PjRtBuffer)> {
        let b = self.engine.manifest().shape.ppo_batch;
        let s = self.s();
        ensure!(rewards.len() == b * s && values.len() == b * s && mask.len() == b * s);
        let r = self.engine.upload_f32(rewards, &[b, s])?;
        let v = self.engine.upload_f32(values, &[b, s])?;
        let m = self.engine.upload_f32(mask, &[b, s])?;
        let mut outs = self.engine.execute_scoped("train", "gae", &[&r, &v, &m])?;
        let ret = outs.pop().unwrap();
        let adv = outs.pop().unwrap();
        Ok((adv, ret))
    }

    /// `ppo_update`: one optimizer step on the batch (Eq. 2 + Adam).
    /// Swaps the new params/moments in place; returns the 6 training stats.
    pub fn ppo_update(
        &mut self,
        batch: &PpoBatch,
        adv: &PjRtBuffer,
        ret: &PjRtBuffer,
        step: i32,
    ) -> Result<[f32; 6]> {
        let (b, s) = (batch.b, batch.s);
        ensure!(b == self.engine.manifest().shape.ppo_batch && s == self.s());
        let toks = self.engine.upload_i32(&batch.tokens, &[b, s])?;
        let mask = self.engine.upload_f32(&batch.mask, &[b, s])?;
        let old_logp = self.engine.upload_f32(&batch.old_logp, &[b, s])?;
        let step_b = self.engine.scalar_i32(step)?;

        let np = self.actor.len();
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(3 * np + 6);
        args.extend(self.actor.bufs());
        args.extend(self.adam_m.bufs());
        args.extend(self.adam_v.bufs());
        args.push(&toks);
        args.push(&mask);
        args.push(&old_logp);
        args.push(adv);
        args.push(ret);
        args.push(&step_b);
        let mut outs = self.engine.execute_scoped("train", "ppo_update", &args)?;

        let stats_b = outs.pop().unwrap();
        let v: Vec<PjRtBuffer> = outs.drain(2 * np..).collect();
        let m: Vec<PjRtBuffer> = outs.drain(np..).collect();
        let p: Vec<PjRtBuffer> = outs;
        self.actor = ParamSet::from_bufs(&self.engine, p)?;
        self.adam_m = ParamSet::from_bufs(&self.engine, m)?;
        self.adam_v = ParamSet::from_bufs(&self.engine, v)?;

        let stats = self.engine.download_f32(&stats_b)?;
        ensure!(stats.len() == 6);
        Ok([stats[0], stats[1], stats[2], stats[3], stats[4], stats[5]])
    }

    /// `dpo_update`: one DPO step on B (chosen, rejected) pairs.
    #[allow(clippy::too_many_arguments)]
    pub fn dpo_update(
        &mut self,
        chosen: &[i32],
        rejected: &[i32],
        mask_c: &[f32],
        mask_r: &[f32],
        ref_c: &[f32],
        ref_r: &[f32],
        step: i32,
    ) -> Result<[f32; 4]> {
        let b = self.engine.manifest().shape.ppo_batch;
        let s = self.s();
        ensure!(chosen.len() == b * s && rejected.len() == b * s);
        ensure!(ref_c.len() == b && ref_r.len() == b);
        let ch = self.engine.upload_i32(chosen, &[b, s])?;
        let rj = self.engine.upload_i32(rejected, &[b, s])?;
        let mc = self.engine.upload_f32(mask_c, &[b, s])?;
        let mr = self.engine.upload_f32(mask_r, &[b, s])?;
        let rc = self.engine.upload_f32(ref_c, &[b])?;
        let rr = self.engine.upload_f32(ref_r, &[b])?;
        let step_b = self.engine.scalar_i32(step)?;

        let np = self.actor.len();
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(3 * np + 7);
        args.extend(self.actor.bufs());
        args.extend(self.adam_m.bufs());
        args.extend(self.adam_v.bufs());
        for b in [&ch, &rj, &mc, &mr, &rc, &rr, &step_b] {
            args.push(b);
        }
        let mut outs = self.engine.execute_scoped("train", "dpo_update", &args)?;
        let stats_b = outs.pop().unwrap();
        let v: Vec<PjRtBuffer> = outs.drain(2 * np..).collect();
        let m: Vec<PjRtBuffer> = outs.drain(np..).collect();
        self.actor = ParamSet::from_bufs(&self.engine, outs)?;
        self.adam_m = ParamSet::from_bufs(&self.engine, m)?;
        self.adam_v = ParamSet::from_bufs(&self.engine, v)?;
        let stats = self.engine.download_f32(&stats_b)?;
        ensure!(stats.len() == 4);
        Ok([stats[0], stats[1], stats[2], stats[3]])
    }

    /// Download a named actor parameter (tests / eval).
    pub fn actor_param(&self, name: &str) -> Result<Vec<f32>> {
        self.actor.download(&self.engine, name)
    }
}

/// Reward-model ops (owned by the reward worker thread).
pub struct RewardOps {
    engine: Arc<Engine>,
    reward: ParamSet,
}

impl RewardOps {
    pub fn new(engine: Arc<Engine>) -> Result<Self> {
        let reward = ParamSet::load(&engine, "reward")?;
        Ok(Self { engine, reward })
    }

    /// Build from a raw param blob instead of the local `params_reward.bin` —
    /// the serve-mode path, where the coordinator distributed the weights
    /// over the wire at replica spawn.
    pub fn with_params(engine: Arc<Engine>, blob: &[u8]) -> Result<Self> {
        let reward = ParamSet::from_bytes(&engine, blob)?;
        Ok(Self { engine, reward })
    }

    fn g(&self) -> usize {
        self.engine.manifest().shape.lanes
    }

    pub fn fresh_state(&self) -> Result<RewardState> {
        self.fresh_state_rows(self.g())
    }

    /// Fresh KV state sized to `rows` lanes — `G` for the full-shape
    /// entries, `G/N` for a sliced pool replica that only ever sees its
    /// compacted rows.
    pub fn fresh_state_rows(&self, rows: usize) -> Result<RewardState> {
        let shape = self.engine.manifest().shape.kv_shape(rows);
        let n = 2 * self.engine.manifest().shape.n_layers;
        let kv = (0..n).map(|_| self.engine.zeros_f32(&shape)).collect::<Result<Vec<_>>>()?;
        Ok(RewardState { kv })
    }

    /// `reward_prefill_chunk_c{c}` / the sliced `..._g{rows}_c{c}` (or a
    /// `_pallas_` flavour): incremental prefill of one streamed chunk;
    /// returns the per-position scores, row-major over the request's grid.
    /// The grid's row count comes from `start.len()` and must match the
    /// entry's compiled shape and the state's KV rows.
    pub fn prefill_chunk(
        &self,
        state: &mut RewardState,
        entry: &str,
        chunk: &[i32],
        start: &[i32],
        n_valid: &[i32],
    ) -> Result<Vec<f32>> {
        let g = start.len();
        let (ch, st, nv) = upload_stream_chunk(&self.engine, g, chunk, start, n_valid)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.reward.len() + 3 + state.kv.len());
        args.extend(self.reward.bufs());
        args.push(&ch);
        args.push(&st);
        args.push(&nv);
        args.extend(state.kv.iter());
        let mut outs = self.engine.execute_scoped("reward", entry, &args)?;
        let scores_b = outs.pop().unwrap();
        state.kv = outs;
        self.engine.download_f32(&scores_b)
    }

    /// Fresh pooled-KV state for the paged entry family (always full-G:
    /// paged entries never come sliced; replica pools route them masked).
    pub fn fresh_paged_state(&self) -> Result<RewardState> {
        let shape = self.engine.manifest().shape.paged_kv_shape();
        let n = 2 * self.engine.manifest().shape.n_layers;
        let kv = (0..n).map(|_| self.engine.zeros_f32(&shape)).collect::<Result<Vec<_>>>()?;
        Ok(RewardState { kv })
    }

    /// `reward_prefill_chunk_paged_c{c}` (or its `_pallas_` flavour): the
    /// paged flavour of [`Self::prefill_chunk`]; `table` is the flattened
    /// `[G, s_max/block]` block table covering every lane's written prefix.
    pub fn prefill_chunk_paged(
        &self,
        state: &mut RewardState,
        entry: &str,
        chunk: &[i32],
        start: &[i32],
        n_valid: &[i32],
        table: &[i32],
    ) -> Result<Vec<f32>> {
        let g = start.len();
        let (ch, st, nv) = upload_stream_chunk(&self.engine, g, chunk, start, n_valid)?;
        let tbl = upload_block_table(&self.engine, g, table)?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.reward.len() + 4 + state.kv.len());
        args.extend(self.reward.bufs());
        args.push(&ch);
        args.push(&st);
        args.push(&nv);
        args.extend(state.kv.iter());
        args.push(&tbl);
        let mut outs = self.engine.execute_scoped("reward", entry, &args)?;
        let scores_b = outs.pop().unwrap();
        state.kv = outs;
        self.engine.download_f32(&scores_b)
    }

    /// `reward_score_full`: monolithic scoring (baselines + equivalence
    /// oracle).  `last_idx[i]` is the index of sequence i's final token.
    pub fn score_full(&self, tokens: &[i32], last_idx: &[i32]) -> Result<Vec<f32>> {
        let g = self.g();
        let s = self.engine.manifest().shape.s_max;
        ensure!(tokens.len() == g * s && last_idx.len() == g);
        let toks = self.engine.upload_i32(tokens, &[g, s])?;
        let idx = self.engine.upload_i32(last_idx, &[g])?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.reward.len() + 2);
        args.extend(self.reward.bufs());
        args.push(&toks);
        args.push(&idx);
        let outs = self.engine.execute_scoped("reward", "reward_score_full", &args)?;
        self.engine.download_f32(&outs[0])
    }
}

/// Reference-model streaming state: KV caches plus the `[G, V]` boundary
/// log-softmax that carries "what does the ref model predict next" across
/// the chunk seam (see `make_ref_prefill_chunk` in python/compile/model.py).
pub struct RefStreamState {
    pub kv: Vec<PjRtBuffer>,
    pub boundary: PjRtBuffer,
}

/// Reference-model ops (owned by the ref stage worker thread).  The ref
/// model is frozen, so one `ParamSet` loaded at spawn serves the whole run.
pub struct RefOps {
    engine: Arc<Engine>,
    refm: ParamSet,
}

impl RefOps {
    pub fn new(engine: Arc<Engine>) -> Result<Self> {
        let refm = ParamSet::load(&engine, "ref")?;
        Ok(Self { engine, refm })
    }

    /// Serve-mode constructor: upload a wire-distributed param blob (see
    /// [`RewardOps::with_params`]).
    pub fn with_params(engine: Arc<Engine>, blob: &[u8]) -> Result<Self> {
        let refm = ParamSet::from_bytes(&engine, blob)?;
        Ok(Self { engine, refm })
    }

    fn g(&self) -> usize {
        self.engine.manifest().shape.lanes
    }

    pub fn fresh_state(&self) -> Result<RefStreamState> {
        self.fresh_state_rows(self.g())
    }

    /// Fresh KV + boundary state sized to `rows` lanes (`G` full-shape,
    /// `G/N` for a sliced pool replica).
    pub fn fresh_state_rows(&self, rows: usize) -> Result<RefStreamState> {
        let shape = self.engine.manifest().shape.kv_shape(rows);
        let n = 2 * self.engine.manifest().shape.n_layers;
        let kv = (0..n).map(|_| self.engine.zeros_f32(&shape)).collect::<Result<Vec<_>>>()?;
        let vocab = self.engine.manifest().shape.vocab;
        let boundary = self.engine.zeros_f32(&[rows, vocab])?;
        Ok(RefStreamState { kv, boundary })
    }

    /// `ref_prefill_chunk_c{c}` / the sliced `..._g{rows}_c{c}`:
    /// incremental reference log-probs of one streamed chunk; returns
    /// `logp`, row-major over the request's grid, where `logp[r, j]` is
    /// `log P(chunk[r, j] | prefix)` (garbage at `j >= n_valid`, same
    /// contract as the reward flavour).  The row count comes from
    /// `start.len()`.
    pub fn prefill_chunk(
        &self,
        state: &mut RefStreamState,
        entry: &str,
        chunk: &[i32],
        start: &[i32],
        n_valid: &[i32],
    ) -> Result<Vec<f32>> {
        let g = start.len();
        let (ch, st, nv) = upload_stream_chunk(&self.engine, g, chunk, start, n_valid)?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.refm.len() + 4 + state.kv.len());
        args.extend(self.refm.bufs());
        args.push(&ch);
        args.push(&st);
        args.push(&nv);
        args.push(&state.boundary);
        args.extend(state.kv.iter());
        let mut outs = self.engine.execute_scoped("ref", entry, &args)?;
        let logp_b = outs.pop().unwrap();
        let boundary = outs.pop().unwrap();
        state.kv = outs;
        state.boundary = boundary;
        self.engine.download_f32(&logp_b)
    }

    /// Fresh pooled-KV + boundary state for the paged entry family
    /// (always full-G, like the reward flavour).
    pub fn fresh_paged_state(&self) -> Result<RefStreamState> {
        let shape = self.engine.manifest().shape.paged_kv_shape();
        let n = 2 * self.engine.manifest().shape.n_layers;
        let kv = (0..n).map(|_| self.engine.zeros_f32(&shape)).collect::<Result<Vec<_>>>()?;
        let vocab = self.engine.manifest().shape.vocab;
        let boundary = self.engine.zeros_f32(&[self.g(), vocab])?;
        Ok(RefStreamState { kv, boundary })
    }

    /// `ref_prefill_chunk_paged_c{c}`: the paged flavour of
    /// [`Self::prefill_chunk`] — same boundary-seam carry, block-table KV.
    pub fn prefill_chunk_paged(
        &self,
        state: &mut RefStreamState,
        entry: &str,
        chunk: &[i32],
        start: &[i32],
        n_valid: &[i32],
        table: &[i32],
    ) -> Result<Vec<f32>> {
        let g = start.len();
        let (ch, st, nv) = upload_stream_chunk(&self.engine, g, chunk, start, n_valid)?;
        let tbl = upload_block_table(&self.engine, g, table)?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.refm.len() + 5 + state.kv.len());
        args.extend(self.refm.bufs());
        args.push(&ch);
        args.push(&st);
        args.push(&nv);
        args.push(&state.boundary);
        args.extend(state.kv.iter());
        args.push(&tbl);
        let mut outs = self.engine.execute_scoped("ref", entry, &args)?;
        let logp_b = outs.pop().unwrap();
        let boundary = outs.pop().unwrap();
        state.kv = outs;
        state.boundary = boundary;
        self.engine.download_f32(&logp_b)
    }
}

/// Validate and upload one streamed `[G, C]` chunk's host arrays — shared by
/// every chunk-consuming stage.  The config layer guarantees the final chunk
/// window of a maximal sequence fits `s_max`; the per-lane check here is the
/// defense-in-depth backstop, since a clamped scatter would silently
/// overwrite earlier KV rows.
fn upload_stream_chunk(
    engine: &Engine,
    g: usize,
    chunk: &[i32],
    start: &[i32],
    n_valid: &[i32],
) -> Result<(PjRtBuffer, PjRtBuffer, PjRtBuffer)> {
    let c = chunk.len() / g.max(1);
    ensure!(chunk.len() == g * c && start.len() == g && n_valid.len() == g);
    let s_max = engine.manifest().shape.s_max;
    for (lane, (&st, &nv)) in start.iter().zip(n_valid).enumerate() {
        ensure!(
            nv == 0 || (st as usize + c) <= s_max,
            "lane {lane}: chunk [{st}, {st}+{c}) would clamp against s_max {s_max}"
        );
    }
    Ok((
        engine.upload_i32(chunk, &[g, c])?,
        engine.upload_i32(start, &[g])?,
        engine.upload_i32(n_valid, &[g])?,
    ))
}

/// Validate and upload one flattened `[rows, s_max/block]` block table for a
/// paged entry call.  Ids must stay inside the pool; 0 (the scratch block)
/// marks unallocated slots.
fn upload_block_table(engine: &Engine, rows: usize, table: &[i32]) -> Result<PjRtBuffer> {
    let shape = engine.manifest().shape.block_table_shape(rows);
    let pool = engine.manifest().shape.paged_pool_blocks() as i32;
    ensure!(
        table.len() == shape[0] * shape[1],
        "block table has {} ids, want {:?}",
        table.len(),
        shape
    );
    for &b in table {
        ensure!((0..pool).contains(&b), "block id {b} outside pool [0, {pool})");
    }
    engine.upload_i32(table, &shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Arc<Engine>> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("manifest.json")
            .exists()
            .then(|| Arc::new(Engine::load(dir).unwrap()))
    }

    #[test]
    fn generate_chunk_roundtrip_and_determinism() {
        let Some(e) = engine() else { return };
        let m = e.manifest().shape.clone();
        let (g, s) = (m.lanes, m.s_max);
        let c = m.chunk_sizes[0];

        // a trivial prompt in every lane: BOS + "1+1="
        let tok = crate::data::Tokenizer::builtin(m.vocab);
        let mut prompt = vec![1i32];
        prompt.extend(tok.encode("1+1=").unwrap());
        let plen = prompt.len();
        let mut tokens = vec![0i32; g * s];
        for lane in 0..g {
            tokens[lane * s..lane * s + plen].copy_from_slice(&prompt);
        }
        let run = |seed: u64| -> (Vec<i32>, Vec<f32>) {
            let mut ops = Ops::new(e.clone(), seed).unwrap();
            let mut state = ops.fresh_actor_state(&tokens).unwrap();
            ops.actor_prefill(&mut state, &tokens, &vec![plen as i32; g], &vec![1; g]).unwrap();
            let pos = vec![plen as i32; g];
            let live = vec![1i32; g];
            let out = ops.generate_chunk(&mut state, c, &pos, &live).unwrap();
            (out.tokens, out.logps)
        };
        let (t1, l1) = run(7);
        let (t2, l2) = run(7);
        let (t3, _) = run(8);
        assert_eq!(t1.len(), g * c);
        assert_eq!(t1, t2, "same seed must generate identical tokens");
        assert_eq!(l1, l2);
        assert_ne!(t1, t3, "different seeds should diverge");
        // log-probs must be valid probabilities
        assert!(l1.iter().all(|&x| x <= 0.0 && x > -30.0));
    }

    #[test]
    fn reward_streaming_matches_full_scoring() {
        let Some(e) = engine() else { return };
        let m = e.manifest().shape.clone();
        let (g, s) = (m.lanes, m.s_max);
        let c = m.chunk_sizes[1];
        let rops = RewardOps::new(e.clone()).unwrap();

        // ragged synthetic sequences
        let mut tokens = vec![0i32; g * s];
        let mut lens = vec![0i32; g];
        for lane in 0..g {
            let len = 5 + 7 * lane % (2 * c) + 3;
            lens[lane] = len as i32;
            for t in 0..len {
                tokens[lane * s + t] = 3 + ((lane * 7 + t * 13) % (m.vocab - 3)) as i32;
            }
        }
        let last_idx: Vec<i32> = lens.iter().map(|&l| l - 1).collect();
        let full = rops.score_full(&tokens, &last_idx).unwrap();

        // streamed in chunks of c
        let entry = format!("reward_prefill_chunk_c{c}");
        let mut state = rops.fresh_state().unwrap();
        let mut got = vec![f32::NAN; g];
        let max_len = *lens.iter().max().unwrap() as usize;
        let mut startpos = 0usize;
        while startpos < max_len {
            let mut chunk = vec![0i32; g * c];
            let mut starts = vec![0i32; g];
            let mut nvalid = vec![0i32; g];
            for lane in 0..g {
                starts[lane] = startpos as i32;
                let remain = (lens[lane] as usize).saturating_sub(startpos);
                let nv = remain.min(c);
                nvalid[lane] = nv as i32;
                for j in 0..nv {
                    chunk[lane * c + j] = tokens[lane * s + startpos + j];
                }
            }
            let scores = rops.prefill_chunk(&mut state, &entry, &chunk, &starts, &nvalid).unwrap();
            for lane in 0..g {
                let fin = lens[lane] as usize;
                if fin > startpos && fin <= startpos + c {
                    got[lane] = scores[lane * c + (fin - 1 - startpos)];
                }
            }
            startpos += c;
        }
        for lane in 0..g {
            assert!(
                (got[lane] - full[lane]).abs() < 2e-3,
                "lane {lane}: streamed {} vs full {}",
                got[lane],
                full[lane]
            );
        }
    }

    #[test]
    fn sliced_prefill_matches_full_shape_rows() {
        let Some(e) = engine() else { return };
        let m = e.manifest().shape.clone();
        let g = m.lanes;
        if g % 2 != 0 {
            return;
        }
        let rows = g / 2;
        if !e.manifest().sliced_prefill_supported("reward", rows) {
            return; // older artifact set without sliced entries
        }
        let c = m.chunk_sizes[0];
        let rops = RewardOps::new(e.clone()).unwrap();

        let mut chunk = vec![0i32; g * c];
        for (i, t) in chunk.iter_mut().enumerate() {
            *t = 3 + ((i * 13) % (m.vocab - 3)) as i32;
        }
        let starts = vec![0i32; g];
        let nvalid = vec![c as i32; g];
        let mut full_state = rops.fresh_state().unwrap();
        let full = rops
            .prefill_chunk(
                &mut full_state,
                &format!("reward_prefill_chunk_c{c}"),
                &chunk,
                &starts,
                &nvalid,
            )
            .unwrap();

        // compact the even lanes into [rows, c] and run the sliced entry
        let lane_map: Vec<usize> = (0..g).step_by(2).collect();
        let mut sc = vec![0i32; rows * c];
        for (row, &lane) in lane_map.iter().enumerate() {
            sc[row * c..(row + 1) * c].copy_from_slice(&chunk[lane * c..(lane + 1) * c]);
        }
        let mut state = rops.fresh_state_rows(rows).unwrap();
        let sliced = rops
            .prefill_chunk(
                &mut state,
                &format!("reward_prefill_chunk_g{rows}_c{c}"),
                &sc,
                &vec![0i32; rows],
                &vec![c as i32; rows],
            )
            .unwrap();
        for (row, &lane) in lane_map.iter().enumerate() {
            for j in 0..c {
                let (a, b) = (sliced[row * c + j], full[lane * c + j]);
                assert!(
                    (a - b).abs() < 2e-3,
                    "row {row} (lane {lane}) pos {j}: sliced {a} vs full {b}"
                );
            }
        }
    }

    #[test]
    fn ref_streaming_matches_dense_logprobs() {
        let Some(e) = engine() else { return };
        if !e.manifest().ref_prefill_supported() {
            return; // older artifact set without the chunked ref entries
        }
        let m = e.manifest().shape.clone();
        let (g, b, s) = (m.lanes, m.ppo_batch, m.s_max);
        let c = m.chunk_sizes[0];

        // ragged synthetic sequences on the first B lanes (dense ref_logprobs
        // is a [B, S] entry); remaining lanes stay empty (n_valid = 0)
        let mut gen_tokens = vec![0i32; g * s];
        let mut dense_tokens = vec![0i32; b * s];
        let mut lens = vec![0usize; g];
        for lane in 0..b {
            let len = 6 + (lane * 11) % (3 * c);
            lens[lane] = len;
            for t in 0..len {
                let tok = 3 + ((lane * 5 + t * 17) % (m.vocab - 3)) as i32;
                gen_tokens[lane * s + t] = tok;
                dense_tokens[lane * s + t] = tok;
            }
        }
        let ops = Ops::new(e.clone(), 0).unwrap();
        let dense = ops.ref_logprobs(&dense_tokens).unwrap(); // [B, S]

        let rops = RefOps::new(e.clone()).unwrap();
        let mut state = rops.fresh_state().unwrap();
        let entry = format!("ref_prefill_chunk_c{c}");
        let mut got = vec![f32::NAN; g * s];
        let max_len = *lens.iter().max().unwrap();
        let mut startpos = 0usize;
        while startpos < max_len {
            let mut chunk = vec![0i32; g * c];
            let mut starts = vec![0i32; g];
            let mut nvalid = vec![0i32; g];
            for lane in 0..g {
                starts[lane] = startpos as i32;
                let nv = lens[lane].saturating_sub(startpos).min(c);
                nvalid[lane] = nv as i32;
                for j in 0..nv {
                    chunk[lane * c + j] = gen_tokens[lane * s + startpos + j];
                }
            }
            let logp = rops.prefill_chunk(&mut state, &entry, &chunk, &starts, &nvalid).unwrap();
            for lane in 0..g {
                for j in 0..nvalid[lane] as usize {
                    got[lane * s + startpos + j] = logp[lane * c + j];
                }
            }
            startpos += c;
        }
        for lane in 0..b {
            for t in 0..lens[lane] {
                let (a, d) = (got[lane * s + t], dense[lane * s + t]);
                assert!(
                    (a - d).abs() < 2e-3,
                    "lane {lane} pos {t}: streamed {a} vs dense {d}"
                );
            }
        }
    }

    /// Fully-mapped identity block table: lane r's block j -> 1 + r*bpl + j.
    /// Requires the pool to hold a full-s_max table for every lane (true for
    /// auto-sized pools); callers skip when a trimmed pool can't.
    fn identity_table(m: &crate::runtime::manifest::ModelShape) -> Option<Vec<i32>> {
        let bpl = m.paged_blocks_per_lane();
        (m.paged_pool_blocks() >= m.lanes * bpl + 1)
            .then(|| (0..m.lanes * bpl).map(|i| 1 + i as i32).collect())
    }

    #[test]
    fn paged_reward_streaming_matches_dense_streaming() {
        let Some(e) = engine() else { return };
        if !e.manifest().paged_supported() {
            return; // pre-paging artifact set
        }
        let m = e.manifest().shape.clone();
        let Some(table) = identity_table(&m) else { return };
        let (g, s) = (m.lanes, m.s_max);
        let c = m.chunk_sizes[0];
        let rops = RewardOps::new(e.clone()).unwrap();

        let mut tokens = vec![0i32; g * s];
        let mut lens = vec![0usize; g];
        for lane in 0..g {
            let len = 4 + (lane * 9) % (3 * c);
            lens[lane] = len;
            for t in 0..len {
                tokens[lane * s + t] = 3 + ((lane * 7 + t * 13) % (m.vocab - 3)) as i32;
            }
        }
        let dense_entry = format!("reward_prefill_chunk_c{c}");
        let paged_entry = e.manifest().paged_prefill_entry("reward", c).unwrap();
        let mut dstate = rops.fresh_state().unwrap();
        let mut pstate = rops.fresh_paged_state().unwrap();
        let max_len = *lens.iter().max().unwrap();
        let mut startpos = 0usize;
        while startpos < max_len {
            let mut chunk = vec![0i32; g * c];
            let mut starts = vec![0i32; g];
            let mut nvalid = vec![0i32; g];
            for lane in 0..g {
                starts[lane] = startpos as i32;
                let nv = lens[lane].saturating_sub(startpos).min(c);
                nvalid[lane] = nv as i32;
                for j in 0..nv {
                    chunk[lane * c + j] = tokens[lane * s + startpos + j];
                }
            }
            let d =
                rops.prefill_chunk(&mut dstate, &dense_entry, &chunk, &starts, &nvalid).unwrap();
            let p = rops
                .prefill_chunk_paged(&mut pstate, &paged_entry, &chunk, &starts, &nvalid, &table)
                .unwrap();
            for lane in 0..g {
                for j in 0..nvalid[lane] as usize {
                    let (a, b) = (p[lane * c + j], d[lane * c + j]);
                    assert!(
                        (a - b).abs() < 2e-3,
                        "lane {lane} chunk@{startpos} pos {j}: paged {a} vs dense {b}"
                    );
                }
            }
            startpos += c;
        }
    }

    #[test]
    fn paged_generation_matches_dense() {
        let Some(e) = engine() else { return };
        if !e.manifest().paged_supported() {
            return;
        }
        let m = e.manifest().shape.clone();
        let Some(table) = identity_table(&m) else { return };
        let (g, s) = (m.lanes, m.s_max);
        let c = m.chunk_sizes[0];

        let tok = crate::data::Tokenizer::builtin(m.vocab);
        let mut prompt = vec![1i32];
        prompt.extend(tok.encode("2*3=").unwrap());
        let plen = prompt.len();
        let mut tokens = vec![0i32; g * s];
        for lane in 0..g {
            tokens[lane * s..lane * s + plen].copy_from_slice(&prompt);
        }
        let pos = vec![plen as i32; g];
        let live = vec![1i32; g];

        let mut dops = Ops::new(e.clone(), 11).unwrap();
        let mut dstate = dops.fresh_actor_state(&tokens).unwrap();
        dops.actor_prefill(&mut dstate, &tokens, &vec![plen as i32; g], &vec![1; g]).unwrap();
        let dense = dops.generate_chunk(&mut dstate, c, &pos, &live).unwrap();

        let mut pops = Ops::new(e.clone(), 11).unwrap();
        let mut pstate = pops.fresh_actor_state_paged(&tokens).unwrap();
        pops.actor_prefill_paged(&mut pstate, &tokens, &vec![plen as i32; g], &vec![1; g], &table)
            .unwrap();
        let paged = pops.generate_chunk_paged(&mut pstate, c, &pos, &live, &table).unwrap();

        assert_eq!(paged.tokens, dense.tokens, "same seed: paged must sample identically");
        for (a, b) in paged.logps.iter().zip(&dense.logps) {
            assert!((a - b).abs() < 2e-3, "paged logp {a} vs dense {b}");
        }
        for (a, b) in paged.values.iter().zip(&dense.values) {
            assert!((a - b).abs() < 2e-3, "paged value {a} vs dense {b}");
        }
    }

    #[test]
    fn pallas_flavour_matches_jnp_flavour() {
        let Some(e) = engine() else { return };
        let m = e.manifest().shape.clone();
        let Some((pallas_entry, c)) = e
            .manifest()
            .pallas_reward_entry()
            .map(|(n, c)| (n.to_string(), c))
        else {
            return;
        };
        let (g, s) = (m.lanes, m.s_max);
        let rops = RewardOps::new(e.clone()).unwrap();
        let jnp_entry = format!("reward_prefill_chunk_c{c}");

        let mut chunk = vec![0i32; g * c];
        for (i, t) in chunk.iter_mut().enumerate() {
            *t = 3 + ((i * 11) % (m.vocab - 3)) as i32;
        }
        let starts = vec![0i32; g];
        let nvalid = vec![c as i32; g];
        let mut s1 = rops.fresh_state().unwrap();
        let mut s2 = rops.fresh_state().unwrap();
        let a = rops.prefill_chunk(&mut s1, &jnp_entry, &chunk, &starts, &nvalid).unwrap();
        let b = rops.prefill_chunk(&mut s2, &pallas_entry, &chunk, &starts, &nvalid).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-3, "jnp {x} vs pallas {y}");
        }
        let _ = s; // silence unused when artifacts absent
    }
}
