//! Property tests over the coordinator invariants (DESIGN.md §5/§6), using
//! the in-repo randomized harness (`oppo::util::proptest`).

use std::sync::{Arc, Mutex};

use oppo::coordinator::buffer::SeqBuffer;
use oppo::coordinator::stage::{StageHandler, StagePool};
use oppo::ctl::{ChunkController, Controller, DeltaController, HeuristicController, Policy};
use oppo::ctl::{KnobBounds, KnobState, LearnedController, QPolicy, StepTelemetry};
use oppo::coordinator::worker::{Pick, ReplicaPart, StreamChunk};
use oppo::data::tasks::{Prompt, TaskKind};
use oppo::model::sequence::SeqPhase;
use oppo::util::proptest::{forall, forall_vec, Config};
use oppo::util::rng::Rng;

fn prompt(id: u64) -> Prompt {
    Prompt {
        kind: TaskKind::Arith,
        text: "1+1=".into(),
        tokens: vec![1, 5, 40, 5, 44],
        answer: "2".into(),
        id,
    }
}

/// Random buffer op schedule — step-boundary ops plus the rolling-admission
/// ones (mid-step admit, lane release, step-boundary promotion).
#[derive(Clone, Debug)]
enum Op {
    Fill,
    FinishRandom,
    Take(usize),
    SetCapacity(usize),
    AdmitMidStep,
    ReleaseRandom,
    Promote,
}

fn gen_ops(rng: &mut Rng) -> Vec<Op> {
    (0..rng.range_usize(5, 80))
        .map(|_| match rng.range(0, 7) {
            0 => Op::Fill,
            1 => Op::FinishRandom,
            2 => Op::Take(rng.range_usize(1, 9)),
            3 => Op::SetCapacity(rng.range_usize(1, 13)),
            4 => Op::AdmitMidStep,
            5 => Op::ReleaseRandom,
            _ => Op::Promote,
        })
        .collect()
}

#[test]
fn buffer_invariants_hold_under_random_schedules() {
    forall_vec(
        Config { cases: 300, seed: 0xBEEF, shrink_iters: 300 },
        "buffer-invariants",
        gen_ops,
        |ops| {
            let lanes = 12;
            let mut buf = SeqBuffer::new(8, lanes);
            let mut rng = Rng::new(1);
            let mut next_id = 0u64;
            let mut step = 0u64;
            let mut tick = 0u64;
            let mut taken_total = 0usize;
            let mut added_total = 0usize;
            for op in ops {
                tick += 1;
                match op {
                    Op::Fill => {
                        while buf.has_room() {
                            buf.add(prompt(next_id), step).map_err(|e| e.to_string())?;
                            next_id += 1;
                            added_total += 1;
                        }
                    }
                    Op::FinishRandom => {
                        let lanes_unfinished: Vec<usize> =
                            buf.unfinished().map(|s| s.lane).collect();
                        if !lanes_unfinished.is_empty() {
                            let lane = *rng.choice(&lanes_unfinished);
                            if let Some(s) = buf.by_lane_mut(lane) {
                                s.phase = SeqPhase::Generating;
                                s.push_token(2, 0.0, 0.0, 2, 8, 100);
                            }
                            buf.mark_finished(lane);
                        }
                    }
                    Op::Take(b) => {
                        step += 1;
                        // take_finished only selects *eligible* finished
                        // sequences — mid-step admits wait for promotion
                        let eligible_before = buf.finished_eligible_count();
                        let batch = buf.take_finished(*b, step);
                        taken_total += batch.len();
                        if batch.len() != eligible_before.min(*b) {
                            return Err(format!(
                                "take({b}) returned {} of {eligible_before} eligible",
                                batch.len()
                            ));
                        }
                        for seq in &batch {
                            if !seq.is_finished() {
                                return Err("took an unfinished sequence".into());
                            }
                            if seq.mid_step {
                                return Err("took an ineligible mid-step admit".into());
                            }
                        }
                    }
                    Op::SetCapacity(c) => buf.set_capacity(*c),
                    Op::AdmitMidStep => {
                        if buf.has_room() {
                            buf.admit(prompt(next_id), step, tick.saturating_sub(1), tick, true)
                                .map_err(|e| e.to_string())?;
                            next_id += 1;
                            added_total += 1;
                        }
                    }
                    Op::ReleaseRandom => {
                        let finished_lanes: Vec<usize> = buf
                            .iter()
                            .filter(|s| s.is_finished())
                            .map(|s| s.lane)
                            .collect();
                        if !finished_lanes.is_empty() {
                            let lane = *rng.choice(&finished_lanes);
                            // refusal (parked bound) is legal backpressure;
                            // the sequence must stay buffered either way
                            let before = buf.len();
                            buf.release_lane(lane);
                            if buf.len() != before {
                                return Err("release changed in-flight count".into());
                            }
                        }
                    }
                    Op::Promote => buf.promote_admitted(),
                }
                buf.check_invariants().map_err(|e| e.to_string())?;
            }
            // conservation: len() counts lane-resident + parked, so mid-step
            // releases never leak a sequence
            if taken_total + buf.len() != added_total {
                return Err(format!(
                    "conservation violated: took {taken_total} + {} buffered != {added_total} added",
                    buf.len()
                ));
            }
            Ok(())
        },
    );
}

/// Prompt-queue fairness: under any arrival process, pops are FIFO, the
/// queue honours its bound, nothing is lost (arrived = popped + queued +
/// dropped accounting is exact), and — given at-least-one-pop-per-tick
/// service — no admitted prompt waits longer than the queue depth (the
/// "bounded queue ⇒ bounded wait" guarantee behind the SLO accounting).
#[test]
fn prompt_queue_is_fifo_and_waits_are_bounded() {
    use oppo::data::queue::{Arrivals, PromptQueue};
    use oppo::data::sampler::PromptSampler;
    use oppo::data::tasks::Task;
    use oppo::data::tokenizer::Tokenizer;

    forall(
        Config { cases: 120, seed: 0xF1F0, shrink_iters: 200 },
        "queue-fifo-bounded-wait",
        |rng| {
            let rate = rng.range_f64(0.05, 3.0);
            let depth = rng.range_usize(1, 33);
            let seed = rng.range(0, 1_000_000);
            let ticks = rng.range_usize(50, 400);
            (rate, depth, seed, ticks)
        },
        |&(rate, depth, seed, ticks)| {
            let sampler = PromptSampler::new(
                Task::by_name("mixed").ok_or_else(|| "no mixed task".to_string())?,
                Tokenizer::builtin(64),
                24,
                seed,
            );
            let mut q = PromptQueue::new(sampler, Arrivals::Poisson { rate }, depth, seed);
            let mut popped = 0u64;
            let mut last_id: Option<u64> = None;
            let mut last_enq: u64 = 0;
            for tick in 1..=ticks as u64 {
                q.advance_to(tick);
                if q.len() > q.depth() {
                    return Err(format!("queue {} escaped depth {}", q.len(), q.depth()));
                }
                if let Some(p) = q.pop(tick) {
                    popped += 1;
                    if p.enqueued_tick > tick {
                        return Err("popped a prompt from the future".into());
                    }
                    // FIFO in both arrival-time and sampler-stream order
                    if p.enqueued_tick < last_enq {
                        return Err(format!(
                            "FIFO violated: enq {} after {}",
                            p.enqueued_tick, last_enq
                        ));
                    }
                    last_enq = p.enqueued_tick;
                    if let Some(prev) = last_id {
                        if p.prompt.id <= prev {
                            return Err("sampler stream order violated".into());
                        }
                    }
                    last_id = Some(p.prompt.id);
                    // one pop per tick + bound `depth` ⇒ a prompt admitted
                    // at position k < depth drains within depth ticks
                    let wait = tick - p.enqueued_tick;
                    if wait > depth as u64 {
                        return Err(format!("wait {wait} exceeds queue depth {depth}"));
                    }
                }
            }
            if q.arrived() != popped + q.len() as u64 {
                return Err(format!(
                    "conservation violated: {} arrived != {popped} popped + {} queued",
                    q.arrived(),
                    q.len()
                ));
            }
            Ok(())
        },
    );
}

/// Replica-pool routing property: across an arbitrary streamed-chunk
/// schedule, no two chunks of one sequence (lane) may ever reach different
/// replicas — the replica holds that lane's KV/seam state.  Exercises the
/// real [`StagePool`] + [`StreamChunk::for_replica`] path (both the masked
/// full-shape split and, for divisor replica counts, the lane-compacted
/// one) with recording handlers on live worker threads.
#[test]
fn pool_routing_never_splits_a_sequence_across_replicas() {
    struct Recorder {
        replica: usize,
        /// (replica, absolute-lanes-with-valid-tokens) per handled request
        log: Arc<Mutex<Vec<(usize, Vec<usize>)>>>,
    }
    impl StageHandler for Recorder {
        type Req = ReplicaPart;
        type Resp = ();
        fn handle(&mut self, part: ReplicaPart) -> anyhow::Result<()> {
            let lanes: Vec<usize> = part
                .chunk
                .n_valid
                .iter()
                .enumerate()
                .filter(|(_, &nv)| nv > 0)
                .map(|(row, _)| part.lane_map[row])
                .collect();
            self.log.lock().unwrap().push((self.replica, lanes));
            Ok(())
        }
    }

    forall(
        Config { cases: 40, ..Default::default() },
        "pool-affinity",
        |rng| {
            let replicas = rng.range_usize(1, 5);
            let lanes = rng.range_usize(1, 13);
            let c = 4 << rng.range_usize(0, 3);
            let want_sliced = rng.range(0, 2) == 1;
            // per-chunk, per-lane count of valid tokens (0 = idle lane)
            let valid: Vec<Vec<usize>> = (0..rng.range_usize(1, 9))
                .map(|_| (0..lanes).map(|_| rng.range_usize(0, c + 1)).collect())
                .collect();
            (replicas, lanes, c, want_sliced, valid)
        },
        |(replicas, lanes, c, want_sliced, valid)| {
            let (replicas, lanes, c) = (*replicas, *lanes, *c);
            // the compacted split requires a divisor replica count
            let sliced = *want_sliced && lanes % replicas == 0;
            let log: Arc<Mutex<Vec<(usize, Vec<usize>)>>> = Arc::new(Mutex::new(Vec::new()));
            let mut pool: StagePool<ReplicaPart, ()> =
                StagePool::spawn("affinity", replicas, 2, |r| {
                    let log = log.clone();
                    move || Ok(Recorder { replica: r, log })
                })
                .map_err(|e| e.to_string())?;
            for pattern in valid {
                let ck = StreamChunk {
                    c,
                    tokens: vec![0; lanes * c],
                    start: vec![0; lanes],
                    n_valid: pattern.iter().map(|&v| v as i32).collect(),
                    picks: pattern
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v > 0)
                        .map(|(l, &v)| Pick { lane: l, idx_in_chunk: v - 1 })
                        .collect(),
                };
                for r in 0..pool.replicas() {
                    let Some(part) = ck.for_replica(r, pool.replicas(), sliced) else {
                        continue;
                    };
                    for p in &part.chunk.picks {
                        let abs = part.lane_map[p.lane];
                        if pool.replica_for_lane(abs) != r {
                            return Err(format!("pick for lane {abs} routed to replica {r}"));
                        }
                    }
                    pool.submit_to(r, part).map_err(|e| e.to_string())?;
                }
            }
            for r in 0..pool.replicas() {
                while pool.in_flight_on(r) > 0 {
                    pool.recv_from(r).map_err(|e| e.to_string())?;
                }
            }
            // every lane's chunks observed on exactly one replica — and on
            // the replica the routing rule names
            let mut owner: Vec<Option<usize>> = vec![None; lanes];
            for (rep, ls) in log.lock().unwrap().iter() {
                for &l in ls {
                    if l % replicas != *rep {
                        return Err(format!("lane {l} handled by replica {rep}"));
                    }
                    match owner[l] {
                        None => owner[l] = Some(*rep),
                        Some(prev) if prev != *rep => {
                            return Err(format!(
                                "lane {l} split across replicas {prev} and {rep}"
                            ));
                        }
                        _ => {}
                    }
                }
            }
            Ok(())
        },
    );
}

/// Lane-compaction equivalence (DESIGN: lane-sliced stage entries): running
/// a model of the prefill kernel over each replica's compacted `[G/N, C]`
/// grid and scattering results back through the part's lane-map must
/// reproduce the masked full-shape path **exactly** — scores at picks,
/// streamed per-lane log-probs, and the per-lane seam carry — for
/// arbitrary G, divisor N, and multi-chunk schedules with ragged lanes.
#[test]
fn compacted_grids_scatter_back_to_the_masked_results() {
    forall(
        Config { cases: 120, ..Default::default() },
        "compaction-equivalence",
        |rng| {
            let g = rng.range_usize(1, 17);
            let divisors: Vec<usize> = (1..=g).filter(|n| g % n == 0).collect();
            let n = *rng.choice(&divisors);
            let chunks: Vec<(usize, Vec<usize>, Vec<i32>)> = (0..rng.range_usize(1, 7))
                .map(|_| {
                    let c = 2 << rng.range_usize(0, 4); // 2..32
                    let nv: Vec<usize> = (0..g).map(|_| rng.range_usize(0, c + 1)).collect();
                    let toks: Vec<i32> = (0..g * c).map(|_| rng.range(3, 64) as i32).collect();
                    (c, nv, toks)
                })
                .collect();
            (g, n, chunks)
        },
        |(g, n, chunks)| {
            let (g, n) = (*g, *n);
            // kernel model: a grid cell's output depends only on the token
            // and its absolute sequence position — all the real prefill
            // entries see (grid row + start offset) — so a correct
            // compaction is invisible to it and equality is exact
            let cell = |tok: i32, pos: i32| (tok.wrapping_mul(31) ^ pos.wrapping_mul(7)) as f32;

            // cumulative per-lane starts + a pick at each lane's last valid
            // token per chunk (the real stream picks once; re-picking per
            // chunk just checks more scatter paths)
            let mut start_of = vec![0i32; g];
            let mut stream: Vec<StreamChunk> = Vec::new();
            for (c, nv, toks) in chunks {
                let c = *c;
                let n_valid: Vec<i32> = nv.iter().map(|&v| v as i32).collect();
                let picks = nv
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v > 0)
                    .map(|(l, &v)| Pick { lane: l, idx_in_chunk: v - 1 })
                    .collect();
                let start = start_of.clone();
                for l in 0..g {
                    start_of[l] += n_valid[l];
                }
                stream.push(StreamChunk { c, tokens: toks.clone(), start, n_valid, picks });
            }

            // run one path: the same sink-side logic consumes masked and
            // compacted parts — only the grids differ
            let run = |sliced: bool| {
                let mut seam = vec![0f32; g];
                let mut logp: Vec<Vec<f32>> = vec![Vec::new(); g];
                let mut score: Vec<Option<f32>> = vec![None; g];
                for ck in &stream {
                    for r in 0..n {
                        let Some(part) = ck.for_replica(r, n, sliced) else { continue };
                        let pc = &part.chunk;
                        let (rows, c) = (pc.lanes(), pc.c);
                        let mut out = vec![0f32; rows * c];
                        for row in 0..rows {
                            for j in 0..pc.n_valid[row] as usize {
                                out[row * c + j] =
                                    cell(pc.tokens[row * c + j], pc.start[row] + j as i32);
                            }
                        }
                        for p in &pc.picks {
                            score[part.lane_map[p.lane]] = Some(out[p.lane * c + p.idx_in_chunk]);
                        }
                        for row in 0..rows {
                            let nv = pc.n_valid[row] as usize;
                            if nv == 0 {
                                continue;
                            }
                            let lane = part.lane_map[row];
                            logp[lane].extend_from_slice(&out[row * c..row * c + nv]);
                            seam[lane] =
                                cell(pc.tokens[row * c + nv - 1], pc.start[row] + nv as i32 - 1);
                        }
                    }
                }
                (seam, logp, score)
            };
            let (seam_m, logp_m, score_m) = run(false);
            let (seam_c, logp_c, score_c) = run(true);
            for lane in 0..g {
                if score_c[lane] != score_m[lane] {
                    return Err(format!(
                        "lane {lane} score: compacted {:?} vs masked {:?}",
                        score_c[lane], score_m[lane]
                    ));
                }
                if logp_c[lane] != logp_m[lane] {
                    return Err(format!("lane {lane} streamed log-probs diverged"));
                }
                if seam_c[lane] != seam_m[lane] {
                    return Err(format!(
                        "lane {lane} seam: compacted {} vs masked {}",
                        seam_c[lane], seam_m[lane]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn delta_controller_always_within_bounds() {
    forall(
        Config { cases: 200, ..Default::default() },
        "delta-bounds",
        |rng| {
            let lo = rng.range_usize(0, 4);
            let hi = lo + rng.range_usize(1, 12);
            let init = lo + rng.range_usize(0, hi - lo + 1);
            let w = rng.range_usize(1, 6);
            let rewards: Vec<f64> = (0..rng.range_usize(10, 120)).map(|_| rng.normal()).collect();
            let policy = *rng.choice(&[Policy::Eq4, Policy::Alg1Literal, Policy::Fixed]);
            (lo, hi, init, w, rewards, policy)
        },
        |(lo, hi, init, w, rewards, policy)| {
            let mut c = DeltaController::new(*init, *lo, *hi, *w, *policy);
            for (i, &r) in rewards.iter().enumerate() {
                let d = c.observe(i as u64, r);
                if d < *lo || d > *hi {
                    return Err(format!("delta {d} escaped [{lo}, {hi}]"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn chunk_controller_always_emits_a_compiled_variant() {
    forall(
        Config { cases: 150, ..Default::default() },
        "chunk-in-candidates",
        |rng| {
            let mut cands: Vec<usize> =
                (0..rng.range_usize(1, 5)).map(|i| 8 << i).collect();
            cands.dedup();
            let initial = *rng.choice(&cands);
            let probes = rng.range_usize(1, 3);
            let period = cands.len() * probes + rng.range_usize(0, 10);
            let latencies: Vec<f64> =
                (0..rng.range_usize(20, 150)).map(|_| rng.range_f64(0.1, 2.0)).collect();
            (cands, initial, period, probes, latencies)
        },
        |(cands, initial, period, probes, latencies)| {
            let mut ctl =
                ChunkController::new(cands.clone(), *initial, *period, *probes, true);
            for &lat in latencies {
                let c = ctl.chunk();
                if !cands.contains(&c) {
                    return Err(format!("chunk {c} has no compiled executable"));
                }
                ctl.observe_step(lat);
            }
            Ok(())
        },
    );
}

#[test]
fn chunk_controller_converges_to_argmin_latency() {
    forall(
        Config { cases: 40, ..Default::default() },
        "chunk-converges",
        |rng| {
            let n = rng.range_usize(2, 5);
            let cands: Vec<usize> = (0..n).map(|i| 8 << i).collect();
            let best = *rng.choice(&cands);
            let initial = *rng.choice(&cands);
            let probes = 2usize;
            let period = cands.len() * probes;
            (cands, best, initial, probes, period)
        },
        |(cands, best, initial, probes, period)| {
            let mut ctl =
                ChunkController::new(cands.clone(), *initial, *period, *probes, true);
            let mut noise = Rng::new(7);
            // synthetic latency window: V-shaped in log2(chunk) with optimum
            // at `best`; noise amplitude well under the candidate gap
            let latency = |c: usize, n: f64| {
                1.0 + 0.5 * ((c as f64).log2() - (*best as f64).log2()).abs() + 0.01 * n
            };
            for _ in 0..400 {
                let c = ctl.chunk();
                if !cands.contains(&c) {
                    return Err(format!("emitted non-candidate chunk {c}"));
                }
                let n = noise.range_f64(0.0, 1.0);
                ctl.observe_step(latency(c, n));
            }
            // finish any in-progress exploration round, then check the pick
            while ctl.exploring() {
                let c = ctl.chunk();
                ctl.observe_step(latency(c, 0.0));
            }
            if ctl.chunk() != *best {
                return Err(format!("settled on {} (optimum {best})", ctl.chunk()));
            }
            for (_, c) in &ctl.history {
                if !cands.contains(c) {
                    return Err(format!("history has non-candidate {c}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn delta_controller_converges_under_synthetic_reward_phases() {
    forall(
        Config { cases: 60, ..Default::default() },
        "delta-converges",
        |rng| {
            let lo = rng.range_usize(0, 3);
            let hi = lo + rng.range_usize(2, 9);
            let init = lo + rng.range_usize(0, hi - lo + 1);
            let w = rng.range_usize(1, 5);
            (lo, hi, init, w)
        },
        |(lo, hi, init, w)| {
            let mut c = DeltaController::new(*init, *lo, *hi, *w, Policy::Eq4);
            let mut step = 0u64;
            // improving phase: strictly rising reward => Δ climbs to Δ_max
            for i in 0..(20 * *w) {
                let d = c.observe(step, i as f64);
                step += 1;
                if d < *lo || d > *hi {
                    return Err(format!("delta {d} escaped [{lo}, {hi}]"));
                }
            }
            if c.delta() != *hi {
                return Err(format!("improving phase ended at Δ={} (max {hi})", c.delta()));
            }
            // plateau: flat reward => Δ decays back to Δ_min (Eq. 4's
            // "convergence pulls Δ toward Δ_min" behaviour)
            for _ in 0..(30 * *w) {
                let d = c.observe(step, 1e6);
                step += 1;
                if d < *lo || d > *hi {
                    return Err(format!("delta {d} escaped [{lo}, {hi}]"));
                }
            }
            if c.delta() != *lo {
                return Err(format!("plateau ended at Δ={} (min {lo})", c.delta()));
            }
            Ok(())
        },
    );
}

/// Unified-controller contract: ANY action sequence from EITHER
/// `Controller` implementation — the composed heuristics or a learned
/// Q-policy with arbitrary trained table contents — keeps every `Some`
/// chunk verdict inside the compiled candidate set and every `Some` Δ
/// inside `[delta_min, delta_max]`, under arbitrary telemetry streams.
#[test]
fn any_controller_keeps_knobs_inside_compiled_bounds() {
    use oppo::ctl::qpolicy::{QAction, N_ACTIONS, N_STATES};

    forall(
        Config { cases: 120, ..Default::default() },
        "controller-trait-bounds",
        |rng| {
            let n = rng.range_usize(2, 6);
            let cands: Vec<usize> = (0..n).map(|i| 8 << i).collect();
            let initial = *rng.choice(&cands);
            let lo = rng.range_usize(0, 3);
            let hi = lo + rng.range_usize(1, 10);
            let init_delta = lo + rng.range_usize(0, hi - lo + 1);
            let w = rng.range_usize(1, 5);
            let steps = rng.range_usize(20, 120);
            let learned = rng.range_usize(0, 2) == 1;
            let seed = rng.next_u64();
            (cands, initial, lo, hi, init_delta, w, steps, learned, seed)
        },
        |(cands, initial, lo, hi, init_delta, w, steps, learned, seed)| {
            let mut rng = Rng::new(*seed);
            let mut ctl: Box<dyn Controller> = if *learned {
                // arbitrary trained table contents: the verdicts must stay
                // legal no matter what training wrote into the Q-table
                let mut policy = QPolicy::new(*seed, cands.len());
                for _ in 0..rng.range_usize(0, 400) {
                    let s = rng.range_usize(0, N_STATES);
                    let a = QAction::from_index(rng.range_usize(0, N_ACTIONS));
                    policy.update(s, a, rng.normal(), rng.range_usize(0, N_STATES), 0.3, 0.9);
                }
                let bounds = KnobBounds {
                    n_chunks: cands.len(),
                    delta_min: *lo,
                    delta_max: *hi,
                    min_replicas: 1,
                    max_replicas: 4,
                };
                let chunk_idx = cands.iter().position(|c| c == initial).unwrap();
                let init = KnobState {
                    chunk_idx,
                    delta_level: oppo::ctl::level_of(*init_delta, &bounds),
                    replicas: 1,
                };
                Box::new(LearnedController::new(policy, cands.clone(), bounds, init).unwrap())
            } else {
                let probes = rng.range_usize(1, 3);
                let period = cands.len() * probes + rng.range_usize(0, 10);
                let policy = *rng.choice(&[Policy::Eq4, Policy::Alg1Literal, Policy::Fixed]);
                Box::new(HeuristicController::full(
                    ChunkController::new(cands.clone(), *initial, period, probes, true),
                    DeltaController::new(*init_delta, *lo, *hi, *w, policy),
                ))
            };
            for step in 0..*steps {
                let t = StepTelemetry {
                    step: step as u64,
                    wall_s: rng.range_f64(0.05, 3.0),
                    mean_reward: rng.normal(),
                    reward_trend: rng.normal(),
                    util: rng.range_f64(0.0, 1.0),
                    lane_idle_frac: rng.range_f64(0.0, 1.0),
                    queue_depth: rng.range_usize(0, 50),
                    queue_dropped: rng.range_usize(0, 3),
                    ..Default::default()
                };
                ctl.observe(&t);
                let a = ctl.actions();
                if let Some(c) = a.chunk {
                    if !cands.contains(&c) {
                        return Err(format!("chunk {c} has no compiled executable"));
                    }
                }
                match a.delta {
                    Some(d) if d < *lo || d > *hi => {
                        return Err(format!("delta {d} escaped [{lo}, {hi}]"));
                    }
                    _ => {}
                }
            }
            Ok(())
        },
    );
}

/// Paged-KV allocator properties (DESIGN: paged KV): across arbitrary
/// admit / grow / release schedules the pool conserves blocks (free +
/// owned == capacity, enforced by `check_invariants`), never hands one
/// physical block to two live lanes, gates admission exactly on the
/// whole-sequence reservation, and — the device-facing contract — a
/// scatter/gather of every live token through the block table round-trips
/// against a dense per-lane KV mirror, with unreached table slots left
/// pointing at scratch block 0.
#[test]
fn block_pool_invariants_and_table_roundtrip() {
    use oppo::coordinator::BlockPool;

    #[derive(Clone, Debug)]
    enum PoolOp {
        /// (lane-pick, prompt_len, max_new)
        Admit(usize, usize, usize),
        /// (lane-pick, tokens to grow by)
        Grow(usize, usize),
        /// lane-pick
        Release(usize),
    }

    forall(
        Config { cases: 150, seed: 0xB10C, shrink_iters: 300 },
        "block-pool-invariants",
        |rng| {
            let lanes = rng.range_usize(1, 9);
            let block = 1 << rng.range_usize(1, 5); // 2..16 tokens
            let bpl = rng.range_usize(1, 9); // s_max = bpl * block
            // sometimes auto-sized (never defers), sometimes trimmed (defers)
            let pool = match rng.range(0, 2) {
                0 => lanes * bpl + 1,
                _ => rng.range_usize(2, lanes * bpl + 2),
            };
            let s_max = block * bpl;
            let ops: Vec<PoolOp> = (0..rng.range_usize(5, 60))
                .map(|_| match rng.range(0, 5) {
                    0 | 1 => PoolOp::Admit(
                        rng.range_usize(0, lanes),
                        rng.range_usize(1, s_max + 1),
                        rng.range_usize(0, s_max),
                    ),
                    2 | 3 => PoolOp::Grow(rng.range_usize(0, lanes), rng.range_usize(1, block * 3)),
                    _ => PoolOp::Release(rng.range_usize(0, lanes)),
                })
                .collect();
            (lanes, block, bpl, pool, ops)
        },
        |(lanes, block, bpl, pool_blocks, ops)| {
            let (lanes, block, bpl, pool_blocks) = (*lanes, *block, *bpl, *pool_blocks);
            let s_max = block * bpl;
            let mut pool = BlockPool::new(lanes, block, bpl, pool_blocks);
            // host mirror of each lane's live sequence: (covered_tokens, cap)
            let mut live: Vec<Option<(usize, usize)>> = vec![None; lanes];
            for op in ops {
                match *op {
                    PoolOp::Admit(lane, prompt_len, max_new) => {
                        if live[lane].is_some() {
                            continue; // occupied — the scheduler never re-admits
                        }
                        let max_total = (prompt_len + max_new).min(s_max);
                        let fits = pool.can_admit(max_total);
                        let got = pool.admit(lane, prompt_len, max_total);
                        if fits != got.is_ok() {
                            return Err(format!(
                                "can_admit({max_total}) said {fits} but admit {:?}",
                                got.err()
                            ));
                        }
                        if got.is_ok() {
                            live[lane] = Some((prompt_len.max(1), max_total));
                        }
                    }
                    PoolOp::Grow(lane, by) => {
                        if let Some((cur, cap)) = live[lane] {
                            // the scheduler caps growth at the admission
                            // budget, so grow_to must always succeed
                            let to = (cur + by).min(cap);
                            pool.grow_to(lane, to);
                            live[lane] = Some((to.max(cur), cap));
                        }
                    }
                    PoolOp::Release(lane) => {
                        if live[lane].take().is_some() {
                            pool.release(lane);
                            if !pool.table_row(lane).iter().all(|&b| b == 0) {
                                return Err(format!("lane {lane} table not scratch after release"));
                            }
                        }
                    }
                }
                pool.check_invariants();
                // committed accounting: every live lane holds exactly its
                // whole-sequence reservation until release
                let expect: usize = live
                    .iter()
                    .flatten()
                    .map(|&(_, cap)| pool.blocks_needed(cap) * block)
                    .sum();
                if pool.allocated_tokens() != expect {
                    return Err(format!(
                        "allocated {} tokens, reservations say {expect}",
                        pool.allocated_tokens()
                    ));
                }
            }
            // scatter/gather round-trip: write f(lane, pos) for every live
            // token through the table into pooled storage, then gather it
            // back and compare against the dense mirror.  Aliased blocks
            // would make some lane read another's values.
            let table = pool.flat_table(lanes);
            if table.len() != lanes * bpl {
                return Err(format!("flat table len {} != {}", table.len(), lanes * bpl));
            }
            let f = |lane: usize, pos: usize| (lane * s_max + pos + 1) as i64;
            let mut storage = vec![0i64; pool_blocks * block];
            for lane in 0..lanes {
                if let Some((cur, _)) = live[lane] {
                    for pos in 0..cur {
                        let phys = table[lane * bpl + pos / block];
                        if phys == 0 {
                            return Err(format!("live token {pos} of lane {lane} maps to scratch"));
                        }
                        storage[phys as usize * block + pos % block] = f(lane, pos);
                    }
                }
            }
            for lane in 0..lanes {
                if let Some((cur, _)) = live[lane] {
                    for pos in 0..cur {
                        let phys = table[lane * bpl + pos / block] as usize;
                        let got = storage[phys * block + pos % block];
                        if got != f(lane, pos) {
                            return Err(format!(
                                "lane {lane} pos {pos}: gathered {got}, wrote {} — blocks aliased",
                                f(lane, pos)
                            ));
                        }
                    }
                    // slots past the covered prefix stay scratch-0
                    for slot in cur.div_ceil(block)..bpl {
                        if table[lane * bpl + slot] != 0 {
                            return Err(format!("lane {lane} slot {slot} mapped past coverage"));
                        }
                    }
                } else if table[lane * bpl..(lane + 1) * bpl].iter().any(|&b| b != 0) {
                    return Err(format!("vacant lane {lane} still mapped"));
                }
            }
            // drain everything: the pool must return to full capacity
            for lane in 0..lanes {
                if live[lane].take().is_some() {
                    pool.release(lane);
                }
            }
            pool.check_invariants();
            if pool.free_blocks() != pool_blocks - 1 {
                return Err(format!(
                    "{} of {} blocks free after full drain",
                    pool.free_blocks(),
                    pool_blocks - 1
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn sim_deferral_never_exceeds_buffer_depth() {
    forall(
        Config { cases: 30, ..Default::default() },
        "sim-deferral-bound",
        |rng| rng.range(0, 1_000_000),
        |&seed| {
            use oppo::sim::pipeline::{simulate, Pipeline, SimConfig};
            use oppo::sim::presets;
            let setup = presets::stackex_7b_h200();
            let cfg = SimConfig::new(setup.clone(), 40, seed);
            let log = simulate(Pipeline::oppo(), &cfg);
            for r in &log.records {
                if r.finished != setup.batch {
                    return Err(format!("step {} trained on {}", r.step, r.finished));
                }
                if r.deferred > setup.delta_max {
                    return Err(format!(
                        "step {}: {} deferred > Δ_max {}",
                        r.step, r.deferred, setup.delta_max
                    ));
                }
            }
            Ok(())
        },
    );
}
