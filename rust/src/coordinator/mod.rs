//! The OPPO coordinator — the paper's Layer-3 contribution, organized as a
//! multi-stage pipeline runtime.
//!
//! * [`block_pool`] — the host-side paged-KV allocator: a free-list over
//!   fixed-size physical blocks plus per-lane block tables, so rolling
//!   admission gates on free blocks instead of worst-case dense KV;
//! * [`buffer`] — Algorithm 1's `B + Δ` FIFO sequence buffer;
//! * [`delta`] / [`chunkctl`] — deprecated location shims: the dynamic Δ
//!   and chunk-size controllers moved to [`crate::ctl`] behind the
//!   unified `Controller` trait (the scheduler now talks only to the
//!   trait);
//! * [`engine_ops`] — typed wrappers over the AOT entry points with
//!   device-resident state (actor, reward, and reference flavours);
//! * [`stage`] — the generic pipeline-stage worker: tagged requests,
//!   bounded queue with backpressure, per-stage timing, join-on-drop —
//!   plus [`StagePool`], N replicas behind one facade with
//!   sequence-affinity routing;
//! * [`worker`] — the concrete downstream stages (reward scoring,
//!   reference log-probs) plus the fan-out facade the scheduler drives;
//! * [`scheduler`] — the training loop: OPPO, the ablations (no-intra,
//!   no-inter, no-ref-stream), the TRL-style sequential baseline, and
//!   async staleness-k;
//! * [`dpo`] — the DPO generalization (§4.3).

pub mod block_pool;
pub mod buffer;
pub mod chunkctl;
pub mod delta;
pub mod dpo;
pub mod engine_ops;
pub mod scheduler;
pub mod stage;
pub mod worker;

pub use block_pool::BlockPool;
pub use buffer::SeqBuffer;
// controller re-exports: kept so `coordinator::{ChunkController, ...}`
// paths from before the `crate::ctl` move keep compiling for one release
pub use crate::ctl::{ChunkController, DeltaController, Policy};
pub use scheduler::OppoScheduler;
pub use stage::{StageHandler, StagePool, StageStats, StageWorker};
