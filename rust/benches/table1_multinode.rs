//! Table 1 — multi-node step latency: OPPO ≈4.5× faster than TRL on
//! 2 × 4×A100-40GB (cross-node stragglers + comm amplify the gap).
use oppo::eval::{print_table, save_rows, tables};

fn main() {
    let rows = tables::table1();
    print_table("Table 1 — multi-node end-to-end step latency", &rows);
    save_rows("table1", &rows).expect("save");
    let speedup = rows[1].cells[1].1;
    assert!((2.5..8.0).contains(&speedup), "multi-node speedup {speedup} out of band");
    println!("shape check passed: multi-node gap ≈{speedup:.1}× (paper: 4.49×)");
}
