"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference implementation
here, written with plain ``jax.numpy`` ops only.  ``python/tests`` sweeps
shapes/dtypes with hypothesis and asserts ``allclose`` between kernel and
oracle; the AOT pipeline can also lower the model against these references
(``kernel_impl="jnp"``) which is the high-throughput flavour used by the
long end-to-end runs (interpret-mode Pallas trades speed for fidelity to the
TPU schedule — see DESIGN.md §7).

Conventions shared with the kernels:

* Attention operates on a *cache-resident* K/V layout ``[B, H, S, D]`` where
  ``S`` is the maximum sequence length.  Chunk queries ``q`` have shape
  ``[B, H, C, D]`` and correspond to absolute positions
  ``start[b] + i, i < C``.  Query ``i`` attends causally to cache positions
  ``j <= start[b] + i``.  The chunk's own K/V are assumed to have already
  been scattered into the cache by the caller (the L2 model does this),
  which is what makes the prefill *incremental* — the enabler of OPPO's
  intra-step overlap (§3.1 of the paper).
* GAE follows Eq. (1) of the paper with an episodic bootstrap of zero and a
  per-position validity mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf: keeps masked softmax NaN-free


def chunked_prefill_attention(
    q: jax.Array,  # [B, H, C, D] queries for absolute positions start+i
    k_cache: jax.Array,  # [B, H, S, D]
    v_cache: jax.Array,  # [B, H, S, D]
    start: jax.Array,  # [B] int32 absolute position of the chunk's first query
) -> jax.Array:  # [B, H, C, D]
    """Causal attention of a chunk of queries against the full KV cache."""
    b, h, c, d = q.shape
    s = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("bhcd,bhsd->bhcs", q, k_cache) * scale
    qpos = start[:, None, None, None] + jnp.arange(c)[None, None, :, None]
    jpos = jnp.arange(s)[None, None, None, :]
    mask = jpos <= qpos
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhcs,bhsd->bhcd", probs, v_cache)


def decode_attention(
    q: jax.Array,  # [B, H, D] single-position queries
    k_cache: jax.Array,  # [B, H, S, D]
    v_cache: jax.Array,  # [B, H, S, D]
    pos: jax.Array,  # [B] int32 absolute position of the query token
) -> jax.Array:  # [B, H, D]
    """Single-token decode attention: query at ``pos`` attends ``j <= pos``."""
    out = chunked_prefill_attention(q[:, :, None, :], k_cache, v_cache, pos)
    return out[:, :, 0, :]


def gae(
    rewards: jax.Array,  # [B, T]
    values: jax.Array,  # [B, T]
    mask: jax.Array,  # [B, T] 1.0 for valid transition positions
    gamma: float = 1.0,
    lam: float = 0.95,
) -> tuple[jax.Array, jax.Array]:
    """Generalized advantage estimation, Eq. (1) of the paper.

    ``delta_t = r_t + gamma * V(s_{t+1}) * m_{t+1} - V(s_t)`` with
    ``V(s_T) = 0`` (episodic), and the reverse accumulation
    ``A_t = delta_t + gamma * lam * m_{t+1} * A_{t+1}``.
    Returns ``(advantages, returns)`` where ``returns = A + V`` (the value
    target), both zeroed outside the mask.
    """
    b, t = rewards.shape
    next_values = jnp.concatenate([values[:, 1:], jnp.zeros((b, 1), values.dtype)], axis=1)
    next_mask = jnp.concatenate([mask[:, 1:], jnp.zeros((b, 1), mask.dtype)], axis=1)
    deltas = rewards + gamma * next_values * next_mask - values

    def step(carry, xs):
        delta, nm = xs
        adv = delta + gamma * lam * nm * carry
        return adv, adv

    _, advs_rev = jax.lax.scan(
        step,
        jnp.zeros((b,), rewards.dtype),
        (deltas.T[::-1], next_mask.T[::-1]),
    )
    advs = advs_rev[::-1].T * mask
    returns = (advs + values) * mask
    return advs, returns
