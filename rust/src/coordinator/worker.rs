//! The reward-scoring worker: its own OS thread, its own reward-model
//! parameters and KV state, fed streamed chunks over a channel.
//!
//! This is the concurrency that realizes §3.1's intra-step overlap: while
//! the actor thread executes `actor_generate_chunk` for chunk *k*, this
//! thread executes `reward_prefill_chunk` for chunk *k−1*.  PJRT executes
//! both concurrently (thread-safe client), so reward prefill latency hides
//! behind actor decoding exactly as in the paper's Figure 1b.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::engine_ops::RewardOps;
use crate::runtime::Engine;

/// Which lane positions hold a sequence's *final* token in this chunk —
/// the worker returns the score read off at exactly those positions.
#[derive(Clone, Debug)]
pub struct Pick {
    pub lane: usize,
    pub idx_in_chunk: usize,
}

/// Requests to the reward worker.
pub enum RewardReq {
    /// Incremental prefill of one streamed chunk (intra-step overlap).
    Stream {
        /// entry name (`reward_prefill_chunk_c{C}` or the pallas flavour)
        entry: String,
        /// row-major [G, C] token chunk (PAD-filled for idle lanes)
        chunk: Vec<i32>,
        /// per-lane absolute start position
        start: Vec<i32>,
        /// per-lane number of valid tokens in the chunk
        n_valid: Vec<i32>,
        /// final-token positions to read scores from
        picks: Vec<Pick>,
    },
    /// Monolithic scoring (baselines / ablation w/o intra).
    ScoreFull { tokens: Vec<i32>, last_idx: Vec<i32> },
    /// Reset the reward KV state (new run / tests).
    Reset,
    Shutdown,
}

/// Worker responses (one per request, in order).
#[derive(Debug)]
pub enum RewardResp {
    /// (lane, score) for each pick in the stream request
    StreamScores(Vec<(usize, f32)>),
    /// all-lane scores for a ScoreFull request
    FullScores(Vec<f32>),
    /// acknowledgement of Reset
    ResetDone,
    Err(String),
}

/// Handle to the reward worker thread.
pub struct RewardWorker {
    tx: Sender<RewardReq>,
    rx: Receiver<RewardResp>,
    handle: Option<JoinHandle<()>>,
}

impl RewardWorker {
    pub fn spawn(engine: Arc<Engine>) -> Result<Self> {
        let (tx, req_rx) = channel::<RewardReq>();
        let (resp_tx, rx) = channel::<RewardResp>();
        let handle = std::thread::Builder::new()
            .name("reward-worker".into())
            .spawn(move || worker_main(engine, req_rx, resp_tx))
            .context("spawning reward worker")?;
        Ok(Self { tx, rx, handle: Some(handle) })
    }

    /// Enqueue a request (non-blocking); pair with [`Self::recv`].
    pub fn submit(&self, req: RewardReq) -> Result<()> {
        self.tx.send(req).map_err(|_| anyhow::anyhow!("reward worker hung up"))
    }

    /// Block for the next response.
    pub fn recv(&self) -> Result<RewardResp> {
        let resp = self.rx.recv().map_err(|_| anyhow::anyhow!("reward worker hung up"))?;
        if let RewardResp::Err(e) = &resp {
            anyhow::bail!("reward worker error: {e}");
        }
        Ok(resp)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(RewardReq::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RewardWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(RewardReq::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker_main(engine: Arc<Engine>, rx: Receiver<RewardReq>, tx: Sender<RewardResp>) {
    let ops = match RewardOps::new(engine) {
        Ok(o) => o,
        Err(e) => {
            let _ = tx.send(RewardResp::Err(format!("init: {e:#}")));
            return;
        }
    };
    let mut state = match ops.fresh_state() {
        Ok(s) => s,
        Err(e) => {
            let _ = tx.send(RewardResp::Err(format!("state init: {e:#}")));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        let resp = match req {
            RewardReq::Shutdown => break,
            RewardReq::Reset => match ops.fresh_state() {
                Ok(s) => {
                    state = s;
                    RewardResp::ResetDone
                }
                Err(e) => RewardResp::Err(format!("{e:#}")),
            },
            RewardReq::Stream { entry, chunk, start, n_valid, picks } => {
                let g = start.len();
                let c = chunk.len() / g;
                match ops.prefill_chunk(&mut state, &entry, &chunk, &start, &n_valid) {
                    Ok(scores) => RewardResp::StreamScores(
                        picks
                            .iter()
                            .map(|p| (p.lane, scores[p.lane * c + p.idx_in_chunk]))
                            .collect(),
                    ),
                    Err(e) => RewardResp::Err(format!("{e:#}")),
                }
            }
            RewardReq::ScoreFull { tokens, last_idx } => {
                match ops.score_full(&tokens, &last_idx) {
                    Ok(scores) => RewardResp::FullScores(scores),
                    Err(e) => RewardResp::Err(format!("{e:#}")),
                }
            }
        };
        if tx.send(resp).is_err() {
            break;
        }
    }
}
