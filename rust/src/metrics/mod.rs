//! Run metrics: per-step records, deferral accounting (Table 2), and JSON
//! export for the bench harness / examples.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};
use crate::util::stats;

/// One pipeline stage's share of a step (reward / ref / future stages).
/// `busy_s` is time inside the stage's compute, `idle_s` time the stage
/// worker spent waiting for work — the per-stage attribution behind the
/// Fig. 5-style utilization analysis.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageTiming {
    pub name: String,
    /// worker replicas behind this stage (pool size; 1 for a single
    /// worker).  `busy_s`/`idle_s`/`items` are summed across replicas, so
    /// `busy_s` may legitimately exceed the step's wall time when > 1.
    pub replicas: usize,
    pub busy_s: f64,
    pub idle_s: f64,
    /// requests (streamed chunks / scoring calls) the stage processed
    pub items: u64,
}

/// One prompt's latency accounting under rolling admission.  Units are
/// whatever clock the producer runs on: chunk ticks for the coordinator
/// (one tick per `actor_generate_chunk` call), seconds for the simulator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PromptLatency {
    pub prompt_id: u64,
    /// arrival → lane admission (zero under saturated arrivals)
    pub queue_wait: f64,
    /// arrival → generation finished (end-to-end)
    pub e2e: f64,
    /// admitted mid-step (continuous-batching refill) vs at a step boundary
    pub mid_step: bool,
}

/// Run-level SLO percentiles over the per-prompt latencies.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloSummary {
    pub prompts: usize,
    pub queue_wait_p50: f64,
    pub queue_wait_p95: f64,
    pub queue_wait_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p95: f64,
    pub e2e_p99: f64,
}

/// One PPO step's telemetry.
#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: u64,
    /// wall-clock duration of the step (seconds)
    pub wall_s: f64,
    /// cumulative wall-clock since run start (seconds)
    pub elapsed_s: f64,
    /// mean sequence score of the PPO batch (Alg. 1's reward signal)
    pub mean_score: f64,
    /// current overcommitment Δ
    pub delta: usize,
    /// current streaming chunk size C
    pub chunk: usize,
    /// sequences finished this step / left unfinished (deferred)
    pub finished: usize,
    pub deferred: usize,
    /// generated tokens this step (throughput accounting)
    pub gen_tokens: usize,
    /// ppo_update stats: [loss, pg, v_loss, entropy, approx_kl, clip_frac]
    pub train_stats: [f32; 6],
    /// utilization for the step, in (0, 1] when stages ran.  Real runs
    /// report stage-worker utilization — busy/(busy+idle) aggregated over
    /// `stages`; simulator runs report the cluster-level activity model.
    /// 0 = no stage workers (e.g. DPO).
    pub util: f64,
    /// per-stage busy/idle attribution for the step: one row per streaming
    /// sink, plus the monolithic reward scorer when that path is active
    /// (so even the sequential baseline reports a "reward" row); empty when
    /// no stage workers exist (e.g. DPO)
    pub stages: Vec<StageTiming>,
    /// per-prompt latency records for the sequences selected this step
    /// (the coordinator stamps all modes — queue wait is simply zero under
    /// step-synchronous/saturated admission; empty for producers without a
    /// tick clock)
    pub prompt_latencies: Vec<PromptLatency>,
    /// share of lane-chunk decode slots that held no live sequence this
    /// step — the idle-lane waste rolling admission exists to remove
    pub lane_idle_frac: f64,
    /// sequences admitted mid-step this step (continuous-batching refills)
    pub admitted_mid_step: usize,
    /// prompts shed at the admission-queue bound this step
    pub queue_dropped: usize,
    /// peak KV commitment across the step's chunk boundaries, in bytes:
    /// block-rounded pool allocation under paged KV, resident lanes ×
    /// `s_max` rows under dense KV (0 when unreported, e.g. legacy logs)
    pub peak_kv_bytes: u64,
}

/// Whole-run log for one pipeline mode.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub mode: String,
    pub task: String,
    pub seed: u64,
    pub records: Vec<StepRecord>,
    /// deferral histogram: steps-deferred -> request count (Table 2)
    pub deferral_hist: BTreeMap<u64, u64>,
}

impl RunLog {
    pub fn new(mode: &str, task: &str, seed: u64) -> Self {
        Self { mode: mode.into(), task: task.into(), seed, ..Default::default() }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    pub fn record_deferral(&mut self, steps: u64) {
        *self.deferral_hist.entry(steps).or_insert(0) += 1;
    }

    pub fn total_wall_s(&self) -> f64 {
        self.records.last().map(|r| r.elapsed_s).unwrap_or(0.0)
    }

    pub fn scores(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.mean_score).collect()
    }

    /// First elapsed time at which the trailing-`w` mean score reaches
    /// `target` (the paper's *time-to-reward*); None if never.
    pub fn time_to_reward(&self, target: f64, w: usize) -> Option<f64> {
        let scores = self.scores();
        for i in 0..scores.len() {
            let lo = (i + 1).saturating_sub(w);
            if stats::mean(&scores[lo..=i]) >= target {
                return Some(self.records[i].elapsed_s);
            }
        }
        None
    }

    /// First step index at which the trailing-`w` mean score reaches
    /// `target` (the paper's *step-to-reward*).
    pub fn step_to_reward(&self, target: f64, w: usize) -> Option<u64> {
        let scores = self.scores();
        for i in 0..scores.len() {
            let lo = (i + 1).saturating_sub(w);
            if stats::mean(&scores[lo..=i]) >= target {
                return Some(self.records[i].step);
            }
        }
        None
    }

    /// SLO percentiles (p50/p95/p99 queue wait and end-to-end latency)
    /// over every per-prompt latency the run recorded; `None` when the run
    /// produced none (legacy step-synchronous admission).
    pub fn slo_summary(&self) -> Option<SloSummary> {
        let waits: Vec<f64> = self
            .records
            .iter()
            .flat_map(|r| r.prompt_latencies.iter().map(|l| l.queue_wait))
            .collect();
        let e2es: Vec<f64> = self
            .records
            .iter()
            .flat_map(|r| r.prompt_latencies.iter().map(|l| l.e2e))
            .collect();
        if waits.is_empty() {
            return None;
        }
        Some(SloSummary {
            prompts: waits.len(),
            queue_wait_p50: stats::percentile(&waits, 50.0),
            queue_wait_p95: stats::percentile(&waits, 95.0),
            queue_wait_p99: stats::percentile(&waits, 99.0),
            e2e_p50: stats::percentile(&e2es, 50.0),
            e2e_p95: stats::percentile(&e2es, 95.0),
            e2e_p99: stats::percentile(&e2es, 99.0),
        })
    }

    /// Deferral distribution as (steps, share) rows plus the mean —
    /// Table 2's exact format.
    pub fn deferral_distribution(&self) -> (Vec<(u64, f64)>, f64) {
        let total: u64 = self.deferral_hist.values().sum();
        if total == 0 {
            return (vec![], 0.0);
        }
        let rows = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| (k, v as f64 / total as f64))
            .collect();
        let mean = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| k as f64 * v as f64)
            .sum::<f64>()
            / total as f64;
        (rows, mean)
    }

    pub fn to_json(&self) -> Value {
        let records: Vec<Value> = self
            .records
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("step", json::num(r.step as f64)),
                    ("wall_s", json::num(r.wall_s)),
                    ("elapsed_s", json::num(r.elapsed_s)),
                    ("mean_score", json::num(r.mean_score)),
                    ("delta", json::num(r.delta as f64)),
                    ("chunk", json::num(r.chunk as f64)),
                    ("finished", json::num(r.finished as f64)),
                    ("deferred", json::num(r.deferred as f64)),
                    ("gen_tokens", json::num(r.gen_tokens as f64)),
                    ("util", json::num(r.util)),
                    (
                        "train_stats",
                        json::arr_f64(&r.train_stats.map(|x| x as f64)),
                    ),
                    (
                        "stages",
                        Value::Arr(
                            r.stages
                                .iter()
                                .map(|st| {
                                    json::obj(vec![
                                        ("name", json::s(&st.name)),
                                        ("replicas", json::num(st.replicas as f64)),
                                        ("busy_s", json::num(st.busy_s)),
                                        ("idle_s", json::num(st.idle_s)),
                                        ("items", json::num(st.items as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("lane_idle_frac", json::num(r.lane_idle_frac)),
                    ("admitted_mid_step", json::num(r.admitted_mid_step as f64)),
                    ("queue_dropped", json::num(r.queue_dropped as f64)),
                    ("peak_kv_bytes", json::num(r.peak_kv_bytes as f64)),
                    (
                        "prompt_latencies",
                        Value::Arr(
                            r.prompt_latencies
                                .iter()
                                .map(|l| {
                                    json::obj(vec![
                                        ("prompt_id", json::num(l.prompt_id as f64)),
                                        ("queue_wait", json::num(l.queue_wait)),
                                        ("e2e", json::num(l.e2e)),
                                        ("mid_step", Value::Bool(l.mid_step)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let hist: Vec<Value> = self
            .deferral_hist
            .iter()
            .map(|(&k, &v)| json::arr_f64(&[k as f64, v as f64]))
            .collect();
        let slo = match self.slo_summary() {
            Some(s) => json::obj(vec![
                ("prompts", json::num(s.prompts as f64)),
                ("queue_wait_p50", json::num(s.queue_wait_p50)),
                ("queue_wait_p95", json::num(s.queue_wait_p95)),
                ("queue_wait_p99", json::num(s.queue_wait_p99)),
                ("e2e_p50", json::num(s.e2e_p50)),
                ("e2e_p95", json::num(s.e2e_p95)),
                ("e2e_p99", json::num(s.e2e_p99)),
            ]),
            None => Value::Null,
        };
        json::obj(vec![
            ("mode", json::s(&self.mode)),
            ("task", json::s(&self.task)),
            ("seed", json::num(self.seed as f64)),
            ("records", Value::Arr(records)),
            ("deferral_hist", Value::Arr(hist)),
            ("slo", slo),
        ])
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with_scores(scores: &[f64]) -> RunLog {
        let mut log = RunLog::new("oppo", "arith", 0);
        for (i, &sc) in scores.iter().enumerate() {
            log.push(StepRecord {
                step: i as u64,
                wall_s: 1.0,
                elapsed_s: (i + 1) as f64,
                mean_score: sc,
                ..Default::default()
            });
        }
        log
    }

    #[test]
    fn time_and_step_to_reward() {
        let log = log_with_scores(&[0.0, 0.2, 0.5, 0.9, 0.95]);
        assert_eq!(log.time_to_reward(0.85, 1), Some(4.0));
        assert_eq!(log.step_to_reward(0.85, 1), Some(3));
        assert_eq!(log.time_to_reward(2.0, 1), None);
        // windowed: mean of last 2 must reach target
        assert_eq!(log.step_to_reward(0.7, 2), Some(3));
    }

    #[test]
    fn deferral_distribution_matches_counts() {
        let mut log = RunLog::new("oppo", "arith", 0);
        for _ in 0..78 {
            log.record_deferral(0);
        }
        for _ in 0..20 {
            log.record_deferral(1);
        }
        for _ in 0..2 {
            log.record_deferral(3);
        }
        let (rows, mean) = log.deferral_distribution();
        assert_eq!(rows[0].0, 0);
        assert!((rows[0].1 - 0.78).abs() < 1e-9);
        assert!((mean - (20.0 + 6.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let mut log = log_with_scores(&[0.1, 0.4]);
        log.record_deferral(0);
        log.record_deferral(1);
        let v = log.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("mode").unwrap().as_str().unwrap(), "oppo");
        assert_eq!(back.get("records").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn slo_summary_percentiles_and_json() {
        let mut log = RunLog::new("oppo", "mixed", 0);
        assert!(log.slo_summary().is_none(), "no latencies => no summary");
        let lat = |id: u64, w: f64, e: f64| PromptLatency {
            prompt_id: id,
            queue_wait: w,
            e2e: e,
            mid_step: id % 2 == 0,
        };
        log.push(StepRecord {
            step: 0,
            prompt_latencies: (0..50).map(|i| lat(i, i as f64, 10.0 + i as f64)).collect(),
            lane_idle_frac: 0.25,
            admitted_mid_step: 3,
            queue_dropped: 1,
            ..Default::default()
        });
        log.push(StepRecord {
            step: 1,
            prompt_latencies: (50..100).map(|i| lat(i, i as f64, 10.0 + i as f64)).collect(),
            ..Default::default()
        });
        let s = log.slo_summary().unwrap();
        assert_eq!(s.prompts, 100);
        // waits are 0..=99 — percentiles must be ordered and in range
        assert!(s.queue_wait_p50 <= s.queue_wait_p95 && s.queue_wait_p95 <= s.queue_wait_p99);
        assert!((s.queue_wait_p50 - 49.5).abs() < 1.0);
        assert!(s.queue_wait_p99 > 95.0 && s.queue_wait_p99 <= 99.0);
        assert!((s.e2e_p50 - s.queue_wait_p50 - 10.0).abs() < 1e-9);

        let v = log.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        let slo = back.get("slo").unwrap();
        assert_eq!(slo.get("prompts").unwrap().as_usize().unwrap(), 100);
        let rec0 = &back.get("records").unwrap().as_arr().unwrap()[0];
        assert_eq!(rec0.get("admitted_mid_step").unwrap().as_usize().unwrap(), 3);
        assert_eq!(rec0.get("queue_dropped").unwrap().as_usize().unwrap(), 1);
        assert!((rec0.get("lane_idle_frac").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        let lat0 = &rec0.get("prompt_latencies").unwrap().as_arr().unwrap()[0];
        assert!(lat0.get("mid_step").unwrap().as_bool().unwrap());
        // a legacy log still serializes: slo is null
        let legacy = log_with_scores(&[0.1]);
        let v = crate::util::json::parse(&crate::util::json::to_string(&legacy.to_json()))
            .unwrap();
        assert_eq!(*v.get("slo").unwrap(), crate::util::json::Value::Null);
    }

    #[test]
    fn write_json_creates_dirs() {
        let dir = std::env::temp_dir().join("oppo_test_metrics");
        let _ = std::fs::remove_dir_all(&dir);
        let log = log_with_scores(&[0.5]);
        let path = dir.join("nested/run.json");
        log.write_json(&path).unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
