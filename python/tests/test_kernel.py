"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes/parameters; every property asserts
``allclose`` between the interpret-mode Pallas kernel and ``ref.py``.
This is the CORE numerical signal for the kernels that the AOT artifacts
embed (DESIGN.md §2, L1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import attention, decode, gae, ref  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# --------------------------------------------------------------------------
# chunked prefill attention
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    c=st.sampled_from([1, 4, 8, 16]),
    d=st.sampled_from([8, 16, 32]),
    s_blocks=st.integers(2, 5),
    block_k=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_prefill_matches_ref(b, h, c, d, s_blocks, block_k, seed):
    s = s_blocks * block_k
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, ks = jax.random.split(key, 4)
    q = rand(kq, (b, h, c, d))
    k = rand(kk, (b, h, s, d))
    v = rand(kv, (b, h, s, d))
    # starts such that start + c <= s
    start = jax.random.randint(ks, (b,), 0, s - c + 1).astype(jnp.int32)
    out = attention.chunked_prefill_attention(q, k, v, start, block_k=block_k)
    want = ref.chunked_prefill_attention(q, k, v, start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_prefill_is_causal():
    """Future cache rows must not influence the output at all."""
    key = jax.random.PRNGKey(7)
    b, h, c, d, s = 2, 2, 4, 16, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, c, d))
    k = rand(kk, (b, h, s, d))
    v = rand(kv, (b, h, s, d))
    start = jnp.array([3, 10], jnp.int32)
    base = attention.chunked_prefill_attention(q, k, v, start, block_k=8)
    # poison strictly-future rows (> start + c - 1) per batch and re-run
    poise = np.asarray(k).copy()
    poisv = np.asarray(v).copy()
    for i, st_ in enumerate([3, 10]):
        poise[i, :, st_ + c :, :] = 1e6
        poisv[i, :, st_ + c :, :] = -1e6
    out = attention.chunked_prefill_attention(
        q, jnp.asarray(poise), jnp.asarray(poisv), start, block_k=8
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), rtol=1e-5, atol=1e-5)


def test_chunked_prefill_first_position_attends_only_itself():
    """start=0, c=1: softmax over one key -> output == v[0]."""
    key = jax.random.PRNGKey(3)
    b, h, d, s = 1, 2, 8, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, 1, d))
    k = rand(kk, (b, h, s, d))
    v = rand(kv, (b, h, s, d))
    out = attention.chunked_prefill_attention(q, k, v, jnp.zeros((b,), jnp.int32), block_k=8)
    np.testing.assert_allclose(np.asarray(out[0, :, 0]), np.asarray(v[0, :, 0]), rtol=1e-5)


def test_vmem_footprint_flat_in_s():
    a = attention.vmem_footprint_bytes(c=16, d=32, s=128, block_k=32)
    b = attention.vmem_footprint_bytes(c=16, d=32, s=4096, block_k=32)
    assert a == b  # flash schedule: VMEM independent of history length


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    s_blocks=st.integers(1, 5),
    block_k=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_ref(b, h, d, s_blocks, block_k, seed):
    s = s_blocks * block_k
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = rand(kq, (b, h, d))
    k = rand(kk, (b, h, s, d))
    v = rand(kv, (b, h, s, d))
    pos = jax.random.randint(kp, (b,), 0, s).astype(jnp.int32)
    out = decode.decode_attention(q, k, v, pos, block_k=block_k)
    want = ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_equals_chunked_prefill_c1():
    key = jax.random.PRNGKey(11)
    b, h, d, s = 3, 2, 16, 64
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d))
    k = rand(kk, (b, h, s, d))
    v = rand(kv, (b, h, s, d))
    pos = jnp.array([0, 31, 63], jnp.int32)
    a = decode.decode_attention(q, k, v, pos, block_k=16)
    b_ = attention.chunked_prefill_attention(q[:, :, None], k, v, pos, block_k=16)[:, :, 0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# GAE
# --------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 5),
    t=st.integers(1, 48),
    gamma=st.sampled_from([1.0, 0.99, 0.9]),
    lam=st.sampled_from([0.95, 0.9, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gae_matches_ref(b, t, gamma, lam, seed):
    key = jax.random.PRNGKey(seed)
    kr, kv, kl = jax.random.split(key, 3)
    r = rand(kr, (b, t))
    v = rand(kv, (b, t))
    lens = jax.random.randint(kl, (b,), 1, t + 1)
    mask = (jnp.arange(t)[None, :] < lens[:, None]).astype(jnp.float32)
    a1, ret1 = gae.gae(r, v, mask, gamma=gamma, lam=lam)
    a2, ret2 = ref.gae(r, v, mask, gamma=gamma, lam=lam)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret1), np.asarray(ret2), rtol=1e-5, atol=1e-5)


def test_gae_manual_tiny():
    """Hand-computed 3-step episode pins the recurrence down exactly."""
    gamma, lam = 0.5, 0.5
    r = jnp.array([[1.0, 2.0, 3.0]])
    v = jnp.array([[0.5, 1.0, 1.5]])
    m = jnp.ones((1, 3))
    # deltas: d0 = 1 + .5*1 - .5 = 1.0 ; d1 = 2 + .5*1.5 - 1 = 1.75 ; d2 = 3 - 1.5 = 1.5
    # A2 = 1.5 ; A1 = 1.75 + .25*1.5 = 2.125 ; A0 = 1.0 + .25*2.125 = 1.53125
    want = np.array([[1.53125, 2.125, 1.5]])
    a, ret = gae.gae(r, v, m, gamma=gamma, lam=lam)
    np.testing.assert_allclose(np.asarray(a), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ret), want + np.asarray(v), rtol=1e-6)


def test_gae_masked_tail_is_zero():
    r = jnp.ones((2, 8))
    v = jnp.ones((2, 8))
    mask = (jnp.arange(8)[None, :] < jnp.array([[3], [8]])).astype(jnp.float32)
    a, ret = gae.gae(r, v, mask)
    assert np.all(np.asarray(a)[0, 3:] == 0.0)
    assert np.all(np.asarray(ret)[0, 3:] == 0.0)


def test_gae_mask_independence():
    """Values/rewards beyond the mask must not affect the masked prefix."""
    r = jnp.array([[1.0, 2.0, 100.0, -100.0]])
    v = jnp.array([[0.1, 0.2, 50.0, -50.0]])
    m = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    r2 = jnp.array([[1.0, 2.0, 0.0, 0.0]])
    v2 = jnp.array([[0.1, 0.2, 0.0, 0.0]])
    a1, _ = gae.gae(r, v, m)
    a2, _ = gae.gae(r2, v2, m)
    np.testing.assert_allclose(np.asarray(a1)[:, :2], np.asarray(a2)[:, :2], rtol=1e-5)


# --------------------------------------------------------------------------
# MXU / VMEM estimators (structure-level perf model, DESIGN.md §Perf)
# --------------------------------------------------------------------------


def test_mxu_estimate_monotone_in_block():
    vals = [attention.mxu_utilization_estimate(16, 32, bk) for bk in (8, 16, 32, 64, 128)]
    assert all(x <= y + 1e-12 for x, y in zip(vals, vals[1:]))
    assert vals[-1] <= 1.0


@pytest.mark.parametrize("bad_s", [17, 33, 100])
def test_block_k_must_divide_cache(bad_s):
    q = jnp.zeros((1, 1, 4, 8))
    k = jnp.zeros((1, 1, bad_s, 8))
    with pytest.raises(ValueError):
        attention.chunked_prefill_attention(q, k, k, jnp.zeros((1,), jnp.int32), block_k=16)
