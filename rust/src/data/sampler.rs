//! Prompt sampling: an infinite seeded train stream + a disjoint, fixed
//! eval set (the Table 3 substitute measures exact-match on the eval set).

use crate::data::tasks::{Prompt, Task};
use crate::data::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Infinite deterministic stream of prompts for training, plus a held-out
/// eval set drawn from an independent RNG stream.
pub struct PromptSampler {
    task: Task,
    tokenizer: Tokenizer,
    prompt_max: usize,
    rng: Rng,
    next_id: u64,
}

impl PromptSampler {
    pub fn new(task: Task, tokenizer: Tokenizer, prompt_max: usize, seed: u64) -> Self {
        let mut root = Rng::new(seed);
        let rng = root.fork(0x7261696e); // "rain" — train stream
        Self { task, tokenizer, prompt_max, rng, next_id: 0 }
    }

    /// Draw the next training prompt (Alg. 1's `sample_from_dataset()`).
    pub fn next(&mut self) -> Prompt {
        let id = self.next_id;
        self.next_id += 1;
        self.task.sample(&mut self.rng, &self.tokenizer, self.prompt_max, id)
    }

    /// Number of prompts handed out so far.
    pub fn sampled(&self) -> u64 {
        self.next_id
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// A fixed held-out eval set, independent of the training stream (same
    /// seed always yields the same set, regardless of training progress).
    pub fn eval_set(&self, n: usize, seed: u64) -> Vec<Prompt> {
        let mut rng = Rng::new(seed ^ 0xE7A1_5E7);
        (0..n as u64)
            .map(|i| self.task.sample(&mut rng, &self.tokenizer, self.prompt_max, i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;

    fn sampler(seed: u64) -> PromptSampler {
        PromptSampler::new(
            Task::by_name("mixed").unwrap(),
            Tokenizer::builtin(64),
            24,
            seed,
        )
    }

    #[test]
    fn ids_are_sequential() {
        let mut s = sampler(0);
        for want in 0..10 {
            assert_eq!(s.next().id, want);
        }
        assert_eq!(s.sampled(), 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<String> = {
            let mut s = sampler(7);
            (0..20).map(|_| s.next().text).collect()
        };
        let b: Vec<String> = {
            let mut s = sampler(7);
            (0..20).map(|_| s.next().text).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn eval_set_is_stable_and_independent_of_training_position() {
        let mut s = sampler(3);
        let before = s.eval_set(16, 42);
        for _ in 0..100 {
            s.next();
        }
        let after = s.eval_set(16, 42);
        let texts = |ps: &[crate::data::tasks::Prompt]| {
            ps.iter().map(|p| p.text.clone()).collect::<Vec<_>>()
        };
        assert_eq!(texts(&before), texts(&after));
    }

    #[test]
    fn eval_set_differs_from_train_stream() {
        let mut s = sampler(3);
        let eval: std::collections::HashSet<String> =
            s.eval_set(32, 42).into_iter().map(|p| p.text).collect();
        let train: Vec<String> = (0..32).map(|_| s.next().text).collect();
        let overlap = train.iter().filter(|t| eval.contains(*t)).count();
        assert!(overlap < 8, "suspiciously high overlap: {overlap}");
    }
}
