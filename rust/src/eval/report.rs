//! Table formatting + JSON export shared by the bench harness.

use crate::util::json::{self, Value};
use crate::Result;

/// One output row: label + named numeric cells.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub cells: Vec<(String, f64)>,
}

impl Row {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), cells: Vec::new() }
    }

    pub fn cell(mut self, name: &str, value: f64) -> Self {
        self.cells.push((name.to_string(), value));
        self
    }
}

/// Print a fixed-width table in the paper's row/column layout.
pub fn print_table(title: &str, rows: &[Row]) {
    println!("\n=== {title} ===");
    if rows.is_empty() {
        println!("(no rows)");
        return;
    }
    let label_w = rows.iter().map(|r| r.label.len()).max().unwrap().max(8) + 2;
    let headers: Vec<&String> = rows[0].cells.iter().map(|(n, _)| n).collect();
    let col_w = headers.iter().map(|h| h.len().max(10) + 2).collect::<Vec<_>>();
    print!("{:label_w$}", "");
    for (h, w) in headers.iter().zip(&col_w) {
        print!("{h:>w$}");
    }
    println!();
    for row in rows {
        print!("{:label_w$}", row.label);
        for ((_, v), w) in row.cells.iter().zip(&col_w) {
            let text = format_cell(*v);
            print!("{text:>w$}");
        }
        println!();
    }
}

fn format_cell(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 10000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Persist rows as JSON under `target/paper/<name>.json`.
pub fn save_rows(name: &str, rows: &[Row]) -> Result<()> {
    let arr = Value::Arr(
        rows.iter()
            .map(|r| {
                let mut pairs = vec![("label", json::s(&r.label))];
                let cells: Vec<(&str, Value)> =
                    r.cells.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect();
                pairs.extend(cells);
                json::obj(pairs)
            })
            .collect(),
    );
    let dir = std::path::Path::new("target/paper");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.json")), json::to_string(&arr))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_build_and_print() {
        let rows = vec![
            Row::new("TRL").cell("latency_s", 498.30).cell("speedup", 1.0),
            Row::new("OPPO").cell("latency_s", 111.08).cell("speedup", 4.49),
        ];
        print_table("table 1 smoke", &rows);
        assert_eq!(rows[1].cells[1].1, 4.49);
    }

    #[test]
    fn cells_format_reasonably() {
        assert_eq!(format_cell(0.0), "0");
        assert_eq!(format_cell(4.49), "4.49");
        assert_eq!(format_cell(498.3), "498.3");
        assert_eq!(format_cell(0.2345), "0.2345");
        assert_eq!(format_cell(123456.0), "123456");
    }

    #[test]
    fn save_rows_writes_json() {
        let rows = vec![Row::new("x").cell("v", 1.5)];
        save_rows("unit_test_rows", &rows).unwrap();
        let text = std::fs::read_to_string("target/paper/unit_test_rows.json").unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.as_arr().unwrap()[0].get("v").unwrap().as_f64().unwrap(), 1.5);
        let _ = std::fs::remove_file("target/paper/unit_test_rows.json");
    }
}
