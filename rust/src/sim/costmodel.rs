//! Per-stage latency rooflines for transformer inference/training.
//!
//! Stage characters (the paper's §2.2 observation, Fig. 2a):
//!
//! * **decode** — one token per sequence per iteration; every iteration
//!   streams the full weight set (plus KV) through HBM ⇒ bandwidth-bound,
//!   utilization well under 40%;
//! * **prefill / scoring** — processes whole sequences at once ⇒ MXU/tensor
//!   compute-bound, high utilization;
//! * **training** — fwd+bwd (≈3× forward FLOPs) ⇒ compute-bound + an
//!   allreduce term.
//!
//! A per-framework `software_efficiency` scales achievable throughput (TRL's
//! HF-generate loop is far from roofline; that inefficiency is part of what
//! the paper measures).  Calibration notes live in DESIGN.md §1.

use super::gpu::GpuSpec;

/// Transformer size entering the cost model.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    /// total parameters
    pub params: f64,
    pub n_layers: f64,
    pub hidden: f64,
    pub n_heads: f64,
}

impl ModelSpec {
    pub const QWEN25_7B: ModelSpec = ModelSpec {
        name: "Qwen2.5-7B",
        params: 7.6e9,
        n_layers: 28.0,
        hidden: 3584.0,
        n_heads: 28.0,
    };
    pub const QWEN25_3B: ModelSpec = ModelSpec {
        name: "Qwen2.5-3B",
        params: 3.1e9,
        n_layers: 36.0,
        hidden: 2048.0,
        n_heads: 16.0,
    };

    /// bf16 weight bytes.
    pub fn weight_bytes(&self) -> f64 {
        2.0 * self.params
    }

    /// KV-cache bytes for one sequence of `ctx` tokens (bf16, MHA).
    pub fn kv_bytes_per_seq(&self, ctx: f64) -> f64 {
        2.0 * 2.0 * self.n_layers * self.hidden * ctx
    }
}

/// Per-stage cost model over a GPU pool of `n_gpus` identical devices.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    /// tensor-parallel degree for latency-critical ops
    pub tp: f64,
    /// achievable fraction of roofline for this software stack (0, 1]
    pub software_efficiency: f64,
    /// fixed per-kernel-launch / scheduling overhead per decode iteration
    pub iter_overhead_s: f64,
    /// inter-node link bandwidth in Gbit/s for *remote* stage replicas
    /// reached over the framed-TCP transport; 0 ⇒ all replicas in-process
    /// (chunk hand-off stays zero-copy and free)
    pub link_gbps: f64,
    /// one-way link latency per framed message, seconds
    pub link_latency_s: f64,
}

impl CostModel {
    /// Seconds for ONE decode iteration serving `batch` sequences at mean
    /// context `ctx`.  Bandwidth term: weights once + live KV; compute
    /// term: 2·P FLOPs per token.
    pub fn decode_iter(&self, batch: f64, ctx: f64) -> f64 {
        let eff_bw = self.gpu.hbm_gbps * 1e9 * self.tp * self.software_efficiency;
        let bytes = self.model.weight_bytes() + batch * self.model.kv_bytes_per_seq(ctx);
        let mem = bytes / eff_bw;
        let eff_fl = self.gpu.fp16_tflops * 1e12 * self.tp * self.software_efficiency;
        let compute = (2.0 * self.model.params * batch) / eff_fl;
        mem.max(compute) + self.iter_overhead_s
    }

    /// Useful FLOPs executed by one decode iteration (for utilization).
    pub fn decode_iter_flops(&self, batch: f64) -> f64 {
        2.0 * self.model.params * batch
    }

    /// Seconds to prefill `tokens` total tokens (scoring / reference /
    /// value prefill — compute-bound with a quadratic attention term).
    pub fn prefill(&self, tokens: f64, mean_ctx: f64) -> f64 {
        let linear = 2.0 * self.model.params * tokens;
        let attn = 2.0 * self.model.n_layers * self.hidden_sq() * 0.0
            + 4.0 * self.model.n_layers * self.model.hidden * tokens * mean_ctx;
        let eff_fl = self.gpu.fp16_tflops * 1e12 * self.tp * self.software_efficiency;
        let compute = (linear + attn) / eff_fl;
        let mem = self.model.weight_bytes() / (self.gpu.hbm_gbps * 1e9 * self.tp);
        compute.max(mem)
    }

    pub fn prefill_flops(&self, tokens: f64, mean_ctx: f64) -> f64 {
        2.0 * self.model.params * tokens
            + 4.0 * self.model.n_layers * self.model.hidden * tokens * mean_ctx
    }

    /// Per-replica seconds for one member of an N-way **lane-sliced**
    /// prefill pool.  Sliced `[G/N, C]` entry variants hand each replica
    /// only its owned lanes, so the compute term divides by the pool size —
    /// but every replica still streams the full weight set, so the
    /// bandwidth floor of [`CostModel::prefill`] does not divide.  That
    /// floor is the slicing knee: once `compute / N` sinks under it, a
    /// bigger pool buys nothing even on independent devices.
    /// (`min_replicas_actor_bound` reports whichever knee binds first,
    /// this one or the actor.)
    pub fn sliced_prefill(&self, tokens: f64, mean_ctx: f64, replicas: f64) -> f64 {
        self.prefill(tokens / replicas.max(1.0), mean_ctx)
    }

    /// Per-replica seconds when the pool falls back to **masked**
    /// full-shape `[G, C]` entries (non-divisor replica count, or
    /// artifacts predating the sliced variants): each replica executes the
    /// whole grid and discards unowned lanes, so pool FLOPs multiply by N
    /// instead of dividing — replication then pays off only through
    /// overlap on independent execution resources.
    pub fn masked_prefill(&self, tokens: f64, mean_ctx: f64) -> f64 {
        self.prefill(tokens, mean_ctx)
    }

    /// Wall seconds to move one streamed chunk of `tokens` tokens to a
    /// remote replica and its per-position results back (i32 out + f32
    /// back ⇒ 8 bytes per token), including two one-way message latencies.
    /// 0 when no link is configured (in-process hand-off is zero-copy).
    pub fn chunk_transfer(&self, tokens: f64) -> f64 {
        if self.link_gbps <= 0.0 {
            return 0.0;
        }
        2.0 * self.link_latency_s + 8.0 * tokens / (self.link_gbps / 8.0 * 1e9)
    }

    /// Per-replica wall seconds for a **remote** chunk-streamed prefill:
    /// remote pools cannot use lane-sliced grids (failover reroutes lanes
    /// between replicas, which the compacted grid's fixed row ↔ lane
    /// binding cannot express), so each replica pays the full masked grid
    /// plus the wire cost of every chunk it consumes.
    pub fn remote_masked_prefill(&self, tokens: f64, mean_ctx: f64, chunk_tokens: f64) -> f64 {
        let n_chunks = (tokens / chunk_tokens.max(1.0)).ceil().max(1.0);
        self.masked_prefill(tokens, mean_ctx) + n_chunks * self.chunk_transfer(chunk_tokens)
    }

    /// Extra wall seconds a mid-stream replica failure pays: the survivor
    /// re-executes the dead replica's `replay_tokens` retained tokens
    /// through the same remote masked path (chunk replay from the
    /// coordinator's sequence buffer).
    pub fn replay_overhead(&self, replay_tokens: f64, mean_ctx: f64, chunk_tokens: f64) -> f64 {
        if replay_tokens <= 0.0 {
            return 0.0;
        }
        self.remote_masked_prefill(replay_tokens, mean_ctx, chunk_tokens)
    }

    fn hidden_sq(&self) -> f64 {
        self.model.hidden * self.model.hidden
    }

    /// KV bytes one sequence *commits* (not merely fills): under paged
    /// allocation (`block_tokens > 0`) its context rounds up to whole
    /// blocks, capped at the block-rounded row; dense (`block_tokens == 0`)
    /// commits the full `max_len` row for the sequence's whole life — the
    /// worst-case reservation paging exists to avoid.
    pub fn kv_committed_bytes(&self, ctx: f64, max_len: f64, block_tokens: f64) -> f64 {
        if block_tokens <= 0.0 {
            return self.model.kv_bytes_per_seq(max_len);
        }
        let cap = (max_len / block_tokens).ceil() * block_tokens;
        let rounded = ((ctx / block_tokens).ceil().max(1.0) * block_tokens).min(cap);
        self.model.kv_bytes_per_seq(rounded)
    }

    /// Concurrent sequences a KV budget can hold at mean context
    /// `mean_ctx`: the dense bound pays a full `max_len` row per lane, so
    /// paging buys strictly more lanes whenever sequences run shorter than
    /// the row (`decouple lane slots from KV capacity`).
    pub fn max_concurrent_lanes(
        &self,
        budget_bytes: f64,
        mean_ctx: f64,
        max_len: f64,
        block_tokens: f64,
    ) -> f64 {
        (budget_bytes / self.kv_committed_bytes(mean_ctx, max_len, block_tokens)).floor()
    }

    /// Seconds for one optimizer step over `tokens` tokens on `n_gpus`
    /// data-parallel workers (fwd+bwd ≈ 6·P FLOPs per token) plus a ring
    /// allreduce of the gradients over `network_gbps` (0 ⇒ NVLink-local,
    /// modeled inside software_efficiency).
    pub fn train_step(&self, tokens: f64, n_gpus: f64, network_gbps: f64) -> f64 {
        let eff_fl =
            self.gpu.fp16_tflops * 1e12 * n_gpus * self.software_efficiency;
        let compute = 6.0 * self.model.params * tokens / eff_fl;
        let comm = if network_gbps > 0.0 {
            // ring allreduce: 2·(n-1)/n · bytes over the slowest link
            2.0 * (n_gpus - 1.0) / n_gpus * self.model.weight_bytes()
                / (network_gbps / 8.0 * 1e9)
        } else {
            0.0
        };
        compute + comm
    }

    pub fn train_flops(&self, tokens: f64) -> f64 {
        6.0 * self.model.params * tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel {
            model: ModelSpec::QWEN25_7B,
            gpu: GpuSpec::H200,
            tp: 1.0,
            software_efficiency: 0.5,
            iter_overhead_s: 2e-4,
            link_gbps: 0.0,
            link_latency_s: 0.0,
        }
    }

    fn cm_linked() -> CostModel {
        CostModel { link_gbps: 100.0, link_latency_s: 5e-5, ..cm() }
    }

    #[test]
    fn decode_is_bandwidth_bound_at_small_batch() {
        let m = cm();
        // tiny batch: memory term dominates → time ≈ weights / eff_bw
        let t = m.decode_iter(1.0, 512.0);
        let floor = m.model.weight_bytes() / (m.gpu.hbm_gbps * 1e9 * 0.5);
        assert!(t >= floor);
        assert!(t < 3.0 * floor, "t={t}, floor={floor}");
    }

    #[test]
    fn decode_utilization_is_low_prefill_high() {
        let m = cm();
        let b = 16.0;
        let t_dec = m.decode_iter(b, 512.0);
        let util_dec = m.decode_iter_flops(b) / (t_dec * m.gpu.fp16_tflops * 1e12);
        // the Fig. 2a observation: decode well under 40%
        assert!(util_dec < 0.4, "decode util {util_dec}");
        let tokens = 4096.0;
        let t_pre = m.prefill(tokens, 512.0);
        let util_pre = m.prefill_flops(tokens, 512.0) / (t_pre * m.gpu.fp16_tflops * 1e12);
        assert!(util_pre > util_dec * 2.0, "prefill {util_pre} vs decode {util_dec}");
    }

    #[test]
    fn decode_iter_grows_with_batch_and_ctx() {
        let m = cm();
        assert!(m.decode_iter(64.0, 1024.0) > m.decode_iter(8.0, 1024.0));
        assert!(m.decode_iter(8.0, 4096.0) > m.decode_iter(8.0, 256.0));
    }

    #[test]
    fn train_comm_term_matters_across_nodes() {
        let m = cm();
        let local = m.train_step(10_000.0, 8.0, 0.0);
        let cross = m.train_step(10_000.0, 8.0, 100.0); // 100 Gb/s IB
        assert!(cross > local * 1.5, "local {local}, cross {cross}");
    }

    #[test]
    fn sliced_prefill_divides_compute_until_the_bandwidth_floor() {
        let m = cm();
        let (tokens, ctx) = (16_384.0, 512.0);
        let t1 = m.sliced_prefill(tokens, ctx, 1.0);
        assert_eq!(t1, m.prefill(tokens, ctx), "1-replica slice is the full grid");
        assert_eq!(m.masked_prefill(tokens, ctx), t1, "masked replicas pay the full grid");
        let t4 = m.sliced_prefill(tokens, ctx, 4.0);
        assert!(t4 < t1 / 2.0, "4-way slice {t4} vs full {t1}");
        // the weight-streaming floor does not divide: huge pools converge
        // to it instead of scaling compute down forever
        let floor = m.model.weight_bytes() / (m.gpu.hbm_gbps * 1e9 * m.tp);
        let t_big = m.sliced_prefill(tokens, ctx, 4096.0);
        assert!((t_big - floor).abs() <= 1e-12 * floor, "t_big {t_big} vs floor {floor}");
        assert!(t_big > t1 / 4096.0, "the floor must bind before perfect scaling");
    }

    #[test]
    fn paged_commitment_rounds_to_blocks_and_beats_dense() {
        let m = cm();
        let (max_len, bt) = (1024.0, 16.0);
        // dense commits the whole row no matter the context
        assert_eq!(m.kv_committed_bytes(100.0, max_len, 0.0), m.model.kv_bytes_per_seq(max_len));
        // paged commits block-rounded context
        let c = m.kv_committed_bytes(100.0, max_len, bt);
        assert_eq!(c, m.model.kv_bytes_per_seq(112.0)); // ceil(100/16)*16
        // empty sequences still hold one block; full rows cap at the row
        assert_eq!(m.kv_committed_bytes(0.0, max_len, bt), m.model.kv_bytes_per_seq(bt));
        assert_eq!(
            m.kv_committed_bytes(9999.0, max_len, bt),
            m.model.kv_bytes_per_seq(max_len)
        );
        // the same budget holds strictly more short sequences under paging
        let budget = 64.0 * m.model.kv_bytes_per_seq(max_len);
        let dense_lanes = m.max_concurrent_lanes(budget, 100.0, max_len, 0.0);
        let paged_lanes = m.max_concurrent_lanes(budget, 100.0, max_len, bt);
        assert_eq!(dense_lanes, 64.0);
        assert!(
            paged_lanes > dense_lanes,
            "paged {paged_lanes} must exceed the dense lane bound {dense_lanes}"
        );
    }

    #[test]
    fn chunk_transfer_prices_latency_plus_bandwidth() {
        let m = cm();
        assert_eq!(m.chunk_transfer(4096.0), 0.0, "no link configured ⇒ free");
        let l = cm_linked();
        let t = l.chunk_transfer(4096.0);
        let expect = 2.0 * 5e-5 + 8.0 * 4096.0 / (100.0 / 8.0 * 1e9);
        assert!((t - expect).abs() < 1e-15, "t={t} expect={expect}");
        // latency dominates small chunks; bandwidth dominates big ones
        assert!(l.chunk_transfer(16.0) < 2.0 * l.chunk_transfer(8.0));
        assert!(l.chunk_transfer(2e9) > 100.0 * l.chunk_transfer(2e7));
    }

    #[test]
    fn remote_masked_prefill_adds_wire_cost_and_never_slices() {
        let l = cm_linked();
        let (tokens, ctx, chunk) = (16_384.0, 512.0, 512.0);
        let local = l.masked_prefill(tokens, ctx);
        let remote = l.remote_masked_prefill(tokens, ctx, chunk);
        assert!(remote > local, "remote {remote} must exceed local masked {local}");
        let wire = (tokens / chunk) * l.chunk_transfer(chunk);
        assert!((remote - local - wire).abs() < 1e-12 * remote.max(1.0));
        // the remote arm pays the full masked grid: a 4-replica local
        // sliced pool beats one remote replica on compute alone
        assert!(l.sliced_prefill(tokens, ctx, 4.0) < remote);
    }

    #[test]
    fn replay_overhead_scales_with_retained_tokens() {
        let l = cm_linked();
        assert_eq!(l.replay_overhead(0.0, 512.0, 512.0), 0.0);
        let half = l.replay_overhead(4096.0, 512.0, 512.0);
        let full = l.replay_overhead(8192.0, 512.0, 512.0);
        assert!(full > half && half > 0.0);
    }

    #[test]
    fn software_efficiency_scales_latency() {
        let fast = cm();
        let mut slow = cm();
        slow.software_efficiency = 0.1;
        assert!(slow.decode_iter(8.0, 512.0) > 3.0 * fast.decode_iter(8.0, 512.0));
    }
}
