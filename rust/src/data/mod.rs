//! Synthetic RLHF data substrate: tokenizer (mirrored from the AOT
//! manifest), rule-checkable tasks standing in for the paper's datasets
//! (DESIGN.md §1), and prompt samplers.

pub mod queue;
pub mod sampler;
pub mod tasks;
pub mod tokenizer;

pub use queue::{Arrivals, PromptQueue, QueuedPrompt};
pub use sampler::PromptSampler;
pub use tasks::{Prompt, Task, TaskKind};
pub use tokenizer::Tokenizer;
