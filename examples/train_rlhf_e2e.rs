//! End-to-end validation driver (DESIGN.md §4, row E2E): real PPO-RLHF
//! training over the full three-layer stack — Rust coordinator → PJRT →
//! AOT-compiled JAX/Pallas transformer — on the synthetic task corpus.
//!
//! Trains the same policy twice (TRL-style sequential baseline vs full
//! OPPO), logging the reward curve, wall-clock, deferral stats, and held-out
//! exact-match accuracy.  Run recorded in EXPERIMENTS.md.
//!
//! Usage: train_rlhf_e2e [steps] [task] [seed]   (defaults: 150 mixed 0)
use std::sync::Arc;

use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::OppoScheduler;
use oppo::metrics::RunLog;
use oppo::runtime::Engine;

fn main() -> anyhow::Result<()> {
    oppo::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(150);
    let task = args.get(1).cloned().unwrap_or_else(|| "mixed".into());
    let seed: u64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let engine = Arc::new(Engine::load("artifacts")?);
    let mut results: Vec<(String, RunLog, f64, f64)> = Vec::new();

    for mode in [Mode::Sequential, Mode::Oppo] {
        let cfg = TrainConfig {
            mode,
            steps,
            task: task.clone(),
            seed,
            log_every: 10,
            out_dir: Some("target/e2e".into()),
            ..Default::default()
        };
        log::info!("=== {} run: {steps} steps on {task} ===", mode.name());
        let mut sched = OppoScheduler::with_engine(cfg, engine.clone())?;
        let acc_before = sched.eval_accuracy(64, 99)?;
        let t0 = std::time::Instant::now();
        for s in 0..steps as u64 {
            let rec = sched.run_step(s)?;
            if s % 10 == 0 {
                log::info!(
                    "{} step {s}: score={:.3} Δ={} C={} {:.2}s",
                    mode.name(), rec.mean_score, rec.delta, rec.chunk, rec.wall_s
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let acc_after = sched.eval_accuracy(64, 99)?;
        println!(
            "{}: {steps} steps in {:.1}s ({:.2}s/step), eval accuracy {:.1}% -> {:.1}%",
            mode.name(), wall, wall / steps as f64,
            100.0 * acc_before, 100.0 * acc_after
        );
        // hand the log back out of the scheduler via a fresh snapshot
        let log = sched.log().clone();
        log.write_json(format!("target/e2e/{}_{seed}.json", mode.name()))?;
        results.push((mode.name().to_string(), log, wall, acc_after));
    }

    let (seq_name, seq_log, seq_wall, seq_acc) = &results[0];
    let (oppo_name, oppo_log, oppo_wall, oppo_acc) = &results[1];
    println!("\n=== E2E summary ({task}, {steps} steps, seed {seed}) ===");
    let curve = |log: &RunLog| -> String {
        log.records
            .iter()
            .step_by((steps / 10).max(1))
            .map(|r| format!("{:.2}", r.mean_score))
            .collect::<Vec<_>>()
            .join(" ")
    };
    println!("{seq_name:12} wall {seq_wall:7.1}s  acc {:5.1}%  reward curve: {}",
        100.0 * seq_acc, curve(seq_log));
    println!("{oppo_name:12} wall {oppo_wall:7.1}s  acc {:5.1}%  reward curve: {}",
        100.0 * oppo_acc, curve(oppo_log));
    let (rows, mean_def) = oppo_log.deferral_distribution();
    println!("oppo wall-clock speedup: {:.2}x", seq_wall / oppo_wall);
    println!("oppo deferral: {:?} (mean {mean_def:.2})",
        rows.iter().map(|(k, s)| format!("{k}:{:.1}%", 100.0 * s)).collect::<Vec<_>>());
    Ok(())
}
