//! Algorithm 1's sequence buffer: a FIFO holding up to `B + Δ` in-flight
//! sequences, each owning one generation lane for its whole life.
//!
//! Invariants (enforced here, property-tested in `tests/test_props.rs`):
//!
//! * `len() <= capacity()` at all times; capacity is `B + Δ` and tracks Δ
//!   as the controller moves it (shrinking capacity never evicts — it only
//!   stops refills, exactly like `Buffer.set_capacity` in Alg. 1).
//! * every buffered sequence owns a distinct lane `< lanes`;
//! * PPO batches take the **first B finished** sequences in completion
//!   order (completion order, not enqueue order — that is the whole point
//!   of inter-step overlap: fast completions are not blocked on stragglers);
//! * unfinished sequences keep their lane and state across steps
//!   ("partial work is preserved", §3.2).
//!
//! Rolling admission (continuous batching) adds a **parked area**: a
//! finished sequence whose downstream stage data is complete can release
//! its lane mid-step ([`SeqBuffer::release_lane`]) and wait there for
//! batch selection, while a queued prompt takes the lane immediately
//! ([`SeqBuffer::admit`]).  Mid-step admits carry an *eligibility* flag:
//! they cannot enter the current step's PPO batch (otherwise a fast
//! mid-step arrival could displace a sequence the legacy fixed-grid loop
//! would have selected, breaking the Δ=0 equivalence contract); the flag
//! clears at the next step boundary via [`SeqBuffer::promote_admitted`].

use anyhow::{bail, Result};

use crate::coordinator::worker::{Pick, StreamChunk};
use crate::data::tasks::Prompt;
use crate::model::sequence::{SeqPhase, Sequence};

/// The `B + Δ` sequence buffer.
pub struct SeqBuffer {
    seqs: Vec<Sequence>,
    capacity: usize,
    lanes: usize,
    lane_free: Vec<bool>,
    /// monotonically increasing completion stamp
    next_completion: u64,
    /// completion stamp per buffered sequence (u64::MAX = unfinished)
    completed_at: Vec<u64>,
    /// finished sequences that released their lane mid-step (rolling
    /// admission), awaiting batch selection
    parked: Vec<Sequence>,
    /// completion stamp per parked sequence (always a real stamp)
    parked_at: Vec<u64>,
}

impl SeqBuffer {
    pub fn new(capacity: usize, lanes: usize) -> Self {
        assert!(capacity <= lanes, "capacity {capacity} > lanes {lanes}");
        Self {
            seqs: Vec::new(),
            capacity,
            lanes,
            lane_free: vec![true; lanes],
            next_completion: 0,
            completed_at: Vec::new(),
            parked: Vec::new(),
            parked_at: Vec::new(),
        }
    }

    /// In-flight sequences: lane-resident plus parked.
    pub fn len(&self) -> usize {
        self.seqs.len() + self.parked.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty() && self.parked.is_empty()
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Alg. 1 line 25: `Buffer.set_capacity(B + Δ)`.  Never evicts.
    pub fn set_capacity(&mut self, capacity: usize) {
        assert!(capacity <= self.lanes);
        self.capacity = capacity;
    }

    /// Has room for another sequence right now?
    pub fn has_room(&self) -> bool {
        self.seqs.len() < self.capacity
    }

    /// Alg. 1 lines 3-5: admit a prompt, assigning it a free lane.
    /// Returns the lane index.
    pub fn add(&mut self, prompt: Prompt, step: u64) -> Result<usize> {
        if !self.has_room() {
            bail!("buffer full ({}/{})", self.seqs.len(), self.capacity);
        }
        let lane = self
            .lane_free
            .iter()
            .position(|&f| f)
            .ok_or_else(|| anyhow::anyhow!("no free lane (capacity bug)"))?;
        self.lane_free[lane] = false;
        self.seqs.push(Sequence::new(prompt, lane, step));
        self.completed_at.push(u64::MAX);
        Ok(lane)
    }

    /// Rolling admission: admit a prompt with its queue/admission tick
    /// stamps.  `mid_step` marks the sequence ineligible for the current
    /// step's PPO batch (see the module docs); admits append after all
    /// resident sequences, so the chunk-processing iteration order of the
    /// survivors is unchanged.
    pub fn admit(
        &mut self,
        prompt: Prompt,
        step: u64,
        enqueued_tick: u64,
        admitted_tick: u64,
        mid_step: bool,
    ) -> Result<usize> {
        let lane = self.add(prompt, step)?;
        let seq = self.seqs.last_mut().expect("add() just pushed");
        seq.enqueued_tick = enqueued_tick;
        seq.admitted_tick = admitted_tick;
        seq.mid_step = mid_step;
        seq.admitted_mid_step = mid_step;
        Ok(lane)
    }

    /// Rolling admission: release a finished sequence's lane mid-step,
    /// parking the sequence until batch selection.  Order-preserving
    /// (`Vec::remove`, not `swap_remove`): `process_chunk` stamps
    /// same-chunk completions in `seqs` iteration order, and the Δ=0
    /// equivalence contract needs the survivors to keep the order the
    /// legacy loop would have seen.  Returns false — and releases
    /// nothing — if the lane holds no finished, stamped sequence, or the
    /// parked area is at its bound (one slot per lane; the caller simply
    /// retries at a later chunk boundary).
    pub fn release_lane(&mut self, lane: usize) -> bool {
        if self.parked.len() >= self.lanes {
            return false;
        }
        let Some(idx) = self.seqs.iter().position(|s| s.lane == lane) else {
            return false;
        };
        if !self.seqs[idx].is_finished() || self.completed_at[idx] == u64::MAX {
            return false;
        }
        let seq = self.seqs.remove(idx);
        let stamp = self.completed_at.remove(idx);
        self.lane_free[lane] = true;
        self.parked.push(seq);
        self.parked_at.push(stamp);
        true
    }

    /// Step boundary: every mid-step admit becomes batch-eligible.
    pub fn promote_admitted(&mut self) {
        for s in self.seqs.iter_mut().chain(self.parked.iter_mut()) {
            s.mid_step = false;
        }
    }

    /// All sequences still generating (Alg. 1's `get_unfinished`).
    pub fn unfinished(&self) -> impl Iterator<Item = &Sequence> {
        self.seqs.iter().filter(|s| !s.is_finished())
    }

    pub fn unfinished_count(&self) -> usize {
        self.seqs.iter().filter(|s| !s.is_finished()).count()
    }

    pub fn finished_count(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_finished()).count() + self.parked.len()
    }

    /// Finished sequences eligible for the *current* step's PPO batch
    /// (mid-step admits are excluded until promoted) — the rolling
    /// generation loop's stop condition.
    pub fn finished_eligible_count(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_finished() && !s.mid_step).count()
            + self.parked.iter().filter(|s| !s.mid_step).count()
    }

    /// Newly queued sequences that still need prompt prefill.
    pub fn queued_lanes(&self) -> Vec<usize> {
        self.seqs
            .iter()
            .filter(|s| s.phase == SeqPhase::Queued)
            .map(|s| s.lane)
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Sequence> {
        self.seqs.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Sequence> {
        self.seqs.iter_mut()
    }

    pub fn by_lane_mut(&mut self, lane: usize) -> Option<&mut Sequence> {
        self.seqs.iter_mut().find(|s| s.lane == lane)
    }

    pub fn by_lane(&self, lane: usize) -> Option<&Sequence> {
        self.seqs.iter().find(|s| s.lane == lane)
    }

    /// Mark a sequence finished (stamps completion order).
    pub fn mark_finished(&mut self, lane: usize) {
        let stamp = self.next_completion;
        if let Some(idx) = self.seqs.iter().position(|s| s.lane == lane) {
            debug_assert!(self.seqs[idx].is_finished());
            if self.completed_at[idx] == u64::MAX {
                self.completed_at[idx] = stamp;
                self.next_completion += 1;
            }
        }
    }

    /// Alg. 1 line 17: `ppo_batch ← finished[:B]` — take (remove) the first
    /// `b` finished sequences in completion order, freeing their lanes.
    /// `current_step` stamps each sequence's deferral (Table 2).
    /// Returns fewer than `b` only if fewer are finished.
    pub fn take_finished(&mut self, b: usize, current_step: u64) -> Vec<Sequence> {
        // candidates: lane-resident finished + parked (lane already
        // released), merged in completion-stamp order; mid-step admits are
        // ineligible until promoted at the next step boundary
        let mut finished: Vec<(u64, bool, usize)> = Vec::new();
        for (i, s) in self.seqs.iter().enumerate() {
            if s.is_finished() && !s.mid_step {
                debug_assert_ne!(
                    self.completed_at[i],
                    u64::MAX,
                    "finished w/o stamp: lane {}",
                    s.lane
                );
                finished.push((self.completed_at[i], false, i));
            }
        }
        for (i, s) in self.parked.iter().enumerate() {
            if !s.mid_step {
                finished.push((self.parked_at[i], true, i));
            }
        }
        finished.sort();
        let mut selected: Vec<(u64, bool, usize)> = finished.into_iter().take(b).collect();
        // remove highest indices first (swap_remove-safe per pool; removal
        // in one pool never shifts the other), then restore stamp order
        selected.sort_unstable_by(|a, b| b.2.cmp(&a.2));
        let mut out: Vec<(u64, Sequence)> = Vec::with_capacity(selected.len());
        for (stamp, from_parked, idx) in selected {
            let mut seq = if from_parked {
                self.parked_at.swap_remove(idx);
                self.parked.swap_remove(idx)
            } else {
                self.completed_at.swap_remove(idx);
                let seq = self.seqs.swap_remove(idx);
                self.lane_free[seq.lane] = true;
                seq
            };
            seq.deferred_steps = current_step.saturating_sub(seq.enqueued_step);
            out.push((stamp, seq));
        }
        out.sort_by_key(|(stamp, _)| *stamp);
        out.into_iter().map(|(_, s)| s).collect()
    }

    /// Build the next streamed `[G, C]` chunk: up to `chunk` unstreamed
    /// tokens per resident lane, PAD-filled where idle, with a pick at
    /// every sequence whose *final* token lands in this chunk.  Advances
    /// the shared stream cursor, so call exactly once per fan-out round.
    /// `None` when no lane has anything left to stream.
    pub fn build_stream_chunk(&mut self, chunk: usize) -> Option<StreamChunk> {
        let lanes = self.lanes;
        let mut tokens = vec![0i32; lanes * chunk];
        let mut start = vec![0i32; lanes];
        let mut n_valid = vec![0i32; lanes];
        let mut picks = Vec::new();
        let mut any = false;
        for seq in self.seqs.iter_mut() {
            if seq.phase == SeqPhase::Queued {
                continue;
            }
            let lane = seq.lane;
            let total = seq.total_len();
            let streamed = seq.streamed;
            start[lane] = streamed as i32;
            let nv = total.saturating_sub(streamed).min(chunk);
            if nv == 0 {
                continue;
            }
            let full = seq.full_tokens();
            tokens[lane * chunk..lane * chunk + nv].copy_from_slice(&full[streamed..streamed + nv]);
            n_valid[lane] = nv as i32;
            if seq.is_finished() && streamed + nv == total {
                picks.push(Pick { lane, idx_in_chunk: nv - 1 });
            }
            seq.streamed += nv;
            any = true;
        }
        any.then_some(StreamChunk { c: chunk, tokens, start, n_valid, picks })
    }

    /// Replay iterator for failover: rebuild the already-streamed chunk
    /// sequence of `lanes_to_replay` from the retained tokens, **without**
    /// touching the stream cursor.  Round *t* carries each lane's tokens
    /// `[t·C, min((t+1)·C, streamed))` with `start = t·C`, so the replay
    /// starts at position 0 — the lane-recycling reset path the stage
    /// kernels already support — and ends exactly where live streaming
    /// left off, letting future chunks continue seamlessly on the
    /// surviving replica.  With `with_picks`, a fully-streamed finished
    /// sequence re-emits its final-token pick (its in-flight score died
    /// with the replica).  Lanes without a resident sequence are skipped.
    pub fn replay_chunks(
        &self,
        lanes_to_replay: &[usize],
        chunk: usize,
        with_picks: bool,
    ) -> Vec<StreamChunk> {
        let g = self.lanes;
        let max_streamed = lanes_to_replay
            .iter()
            .filter_map(|&l| self.by_lane(l))
            .map(|s| s.streamed)
            .max()
            .unwrap_or(0);
        let rounds = max_streamed.div_ceil(chunk);
        let mut out = Vec::with_capacity(rounds);
        for t in 0..rounds {
            let s0 = t * chunk;
            let mut tokens = vec![0i32; g * chunk];
            let mut start = vec![0i32; g];
            let mut n_valid = vec![0i32; g];
            let mut picks = Vec::new();
            let mut any = false;
            for &lane in lanes_to_replay {
                let Some(seq) = self.by_lane(lane) else { continue };
                if s0 >= seq.streamed {
                    continue;
                }
                let nv = (seq.streamed - s0).min(chunk);
                let full = seq.full_tokens();
                tokens[lane * chunk..lane * chunk + nv].copy_from_slice(&full[s0..s0 + nv]);
                start[lane] = s0 as i32;
                n_valid[lane] = nv as i32;
                if with_picks
                    && seq.is_finished()
                    && seq.streamed == seq.total_len()
                    && s0 + nv == seq.streamed
                {
                    picks.push(Pick { lane, idx_in_chunk: nv - 1 });
                }
                any = true;
            }
            if any {
                out.push(StreamChunk { c: chunk, tokens, start, n_valid, picks });
            }
        }
        out
    }

    /// Consistency check used by the property tests.  Note: `len` may
    /// transiently exceed `capacity` right after the Δ controller shrinks it
    /// (Alg. 1 never evicts); the capacity bound is an *admission* invariant,
    /// checked in `add`.
    pub fn check_invariants(&self) -> Result<()> {
        if self.completed_at.len() != self.seqs.len() {
            bail!(
                "completion stamps out of sync: {} stamps vs {} sequences",
                self.completed_at.len(),
                self.seqs.len()
            );
        }
        let mut seen = vec![false; self.lanes];
        for (i, s) in self.seqs.iter().enumerate() {
            if s.lane >= self.lanes {
                bail!("lane {} out of range", s.lane);
            }
            if seen[s.lane] {
                bail!("duplicate lane {}", s.lane);
            }
            seen[s.lane] = true;
            if self.lane_free[s.lane] {
                bail!("occupied lane {} marked free", s.lane);
            }
            // finished ⇔ stamped: a stamp implies the sequence really
            // finished, and every finished sequence carries its completion
            // stamp (mark_finished ran) — the ordering take_finished sorts
            // by is meaningless if either direction breaks
            let stamped = self.completed_at[i] != u64::MAX;
            if stamped && !s.is_finished() {
                bail!("lane {}: stamped complete but sequence unfinished", s.lane);
            }
            if s.is_finished() && !stamped {
                bail!("lane {}: finished but never stamped (mark_finished missed)", s.lane);
            }
            if stamped && self.completed_at[i] >= self.next_completion {
                bail!(
                    "lane {}: stamp {} not older than next stamp {}",
                    s.lane, self.completed_at[i], self.next_completion
                );
            }
        }
        let occupied = seen.iter().filter(|&&x| x).count();
        let not_free = self.lane_free.iter().filter(|&&f| !f).count();
        if occupied != not_free {
            bail!("lane accounting mismatch: {occupied} occupied vs {not_free} not-free");
        }
        // parked area: stamp-synced, bounded, finished-and-drained only
        if self.parked_at.len() != self.parked.len() {
            bail!(
                "parked stamps out of sync: {} stamps vs {} sequences",
                self.parked_at.len(),
                self.parked.len()
            );
        }
        if self.parked.len() > self.lanes {
            bail!("parked area overflow: {} > {} lanes", self.parked.len(), self.lanes);
        }
        for (i, s) in self.parked.iter().enumerate() {
            if !s.is_finished() {
                bail!("parked sequence (ex-lane {}) not finished", s.lane);
            }
            if self.parked_at[i] == u64::MAX || self.parked_at[i] >= self.next_completion {
                bail!(
                    "parked sequence (ex-lane {}): bad stamp {}",
                    s.lane,
                    self.parked_at[i]
                );
            }
        }
        // completion stamps stay unique across both pools — batch order is
        // undefined if two sequences share one
        let mut stamps: Vec<u64> = self
            .completed_at
            .iter()
            .copied()
            .filter(|&st| st != u64::MAX)
            .chain(self.parked_at.iter().copied())
            .collect();
        stamps.sort_unstable();
        if stamps.windows(2).any(|w| w[0] == w[1]) {
            bail!("duplicate completion stamp across lane/parked pools");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;

    fn prompt(id: u64) -> Prompt {
        Prompt {
            kind: TaskKind::Arith,
            text: "1+1=".into(),
            tokens: vec![1, 5, 40, 5, 44],
            answer: "2".into(),
            id,
        }
    }

    fn finish(buf: &mut SeqBuffer, lane: usize) {
        let s = buf.by_lane_mut(lane).unwrap();
        s.phase = SeqPhase::Generating;
        s.push_token(2, 0.0, 0.0, 2, 8, 100);
        buf.mark_finished(lane);
    }

    #[test]
    fn fill_to_capacity_then_reject() {
        let mut buf = SeqBuffer::new(3, 4);
        for i in 0..3 {
            buf.add(prompt(i), 0).unwrap();
        }
        assert!(!buf.has_room());
        assert!(buf.add(prompt(9), 0).is_err());
        buf.check_invariants().unwrap();
    }

    #[test]
    fn take_finished_respects_completion_order_not_enqueue_order() {
        let mut buf = SeqBuffer::new(4, 4);
        for i in 0..4 {
            buf.add(prompt(i), 0).unwrap();
        }
        // finish in order 2, 0, 3 (lane == enqueue index here)
        finish(&mut buf, 2);
        finish(&mut buf, 0);
        finish(&mut buf, 3);
        let batch = buf.take_finished(2, 1);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].prompt.id, 2); // completed first
        assert_eq!(batch[1].prompt.id, 0);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.finished_count(), 1); // id 3 still buffered
        buf.check_invariants().unwrap();
    }

    #[test]
    fn lanes_are_recycled() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.add(prompt(1), 0).unwrap();
        finish(&mut buf, 0);
        let taken = buf.take_finished(1, 0);
        assert_eq!(taken.len(), 1);
        let lane = buf.add(prompt(2), 1).unwrap();
        assert_eq!(lane, 0); // freed lane reused
        buf.check_invariants().unwrap();
    }

    #[test]
    fn deferral_stamping() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 5).unwrap();
        finish(&mut buf, 0);
        let batch = buf.take_finished(1, 7);
        assert_eq!(batch[0].deferred_steps, 2);
    }

    #[test]
    fn shrinking_capacity_does_not_evict() {
        let mut buf = SeqBuffer::new(4, 4);
        for i in 0..4 {
            buf.add(prompt(i), 0).unwrap();
        }
        buf.set_capacity(2);
        assert_eq!(buf.len(), 4); // over capacity is allowed transiently
        assert!(!buf.has_room());
        // invariant check tolerates the transient only via take; here we
        // simply verify nothing was dropped and no new adds are admitted
        assert!(buf.add(prompt(9), 0).is_err());
    }

    #[test]
    fn invariants_catch_finished_without_stamp() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        let s = buf.by_lane_mut(0).unwrap();
        s.phase = SeqPhase::Generating;
        s.push_token(2, 0.0, 0.0, 2, 8, 100); // EOS => finished
        assert!(buf.check_invariants().is_err(), "finished but unstamped must be caught");
        buf.mark_finished(0);
        buf.check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_stamp_desync() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.check_invariants().unwrap();
        let stamp = buf.completed_at.pop().unwrap();
        assert!(buf.check_invariants().is_err(), "stamp/seq length mismatch must be caught");
        buf.completed_at.push(stamp);
        // a stamp on an unfinished sequence is equally inconsistent
        buf.completed_at[0] = 0;
        buf.next_completion = 1;
        assert!(buf.check_invariants().is_err(), "stamped-but-unfinished must be caught");
    }

    #[test]
    fn take_more_than_finished_returns_what_exists() {
        let mut buf = SeqBuffer::new(3, 3);
        for i in 0..3 {
            buf.add(prompt(i), 0).unwrap();
        }
        finish(&mut buf, 1);
        let batch = buf.take_finished(3, 0);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].prompt.id, 1);
    }

    #[test]
    fn release_parks_and_recycles_the_lane_mid_step() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.add(prompt(1), 0).unwrap();
        finish(&mut buf, 0);
        assert!(buf.release_lane(0), "finished+stamped lane must release");
        buf.check_invariants().unwrap();
        assert_eq!(buf.parked_count(), 1);
        assert_eq!(buf.len(), 2, "parked sequences still count as in-flight");
        // the freed lane is immediately admittable
        let lane = buf.admit(prompt(2), 0, 3, 5, true).unwrap();
        assert_eq!(lane, 0);
        buf.check_invariants().unwrap();
        let s = buf.by_lane(0).unwrap();
        assert_eq!((s.enqueued_tick, s.admitted_tick), (3, 5));
        assert!(s.mid_step && s.admitted_mid_step);
    }

    #[test]
    fn release_refuses_unfinished_vacant_and_overflow() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        assert!(!buf.release_lane(0), "unfinished lane must not release");
        assert!(!buf.release_lane(1), "vacant lane must not release");
        // fill the parked bound (lanes = 2) and verify backpressure
        for round in 0..2u64 {
            finish(&mut buf, 0);
            assert!(buf.release_lane(0));
            buf.admit(prompt(10 + round), 0, 0, 0, true).unwrap();
        }
        finish(&mut buf, 0);
        assert!(!buf.release_lane(0), "parked bound must refuse further releases");
        buf.check_invariants().unwrap();
    }

    #[test]
    fn mid_step_admits_are_ineligible_until_promoted() {
        let mut buf = SeqBuffer::new(2, 2);
        buf.add(prompt(0), 0).unwrap();
        buf.admit(prompt(1), 0, 0, 0, true).unwrap();
        finish(&mut buf, 0); // the step-boundary admit
        finish(&mut buf, 1); // the mid-step admit
        assert_eq!(buf.finished_count(), 2);
        assert_eq!(buf.finished_eligible_count(), 1);
        let batch = buf.take_finished(2, 0);
        assert_eq!(batch.len(), 1, "mid-step admit must not enter this step's batch");
        assert_eq!(batch[0].prompt.id, 0);
        buf.promote_admitted();
        assert_eq!(buf.finished_eligible_count(), 1);
        let batch = buf.take_finished(2, 1);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].prompt.id, 1);
        buf.check_invariants().unwrap();
    }

    #[test]
    fn batch_order_merges_parked_and_resident_by_completion_stamp() {
        let mut buf = SeqBuffer::new(3, 3);
        for i in 0..3 {
            buf.add(prompt(i), 0).unwrap();
        }
        finish(&mut buf, 1); // stamp 0
        assert!(buf.release_lane(1)); // parked
        finish(&mut buf, 0); // stamp 1 (stays lane-resident)
        finish(&mut buf, 2); // stamp 2
        assert!(buf.release_lane(2)); // parked
        let batch = buf.take_finished(3, 0);
        let ids: Vec<u64> = batch.iter().map(|s| s.prompt.id).collect();
        assert_eq!(ids, vec![1, 0, 2], "completion order across pools");
        assert_eq!(buf.len(), 0);
        buf.check_invariants().unwrap();
    }

    #[test]
    fn release_preserves_survivor_iteration_order() {
        let mut buf = SeqBuffer::new(4, 4);
        for i in 0..4 {
            buf.add(prompt(i), 0).unwrap();
        }
        finish(&mut buf, 1);
        assert!(buf.release_lane(1));
        // survivors keep enqueue order (order-preserving removal), so the
        // chunk-processing loop sees the same relative order as before
        let order: Vec<u64> = buf.iter().map(|s| s.prompt.id).collect();
        assert_eq!(order, vec![0, 2, 3]);
        // and a fresh admit appends after all survivors
        buf.admit(prompt(9), 0, 0, 0, true).unwrap();
        let order: Vec<u64> = buf.iter().map(|s| s.prompt.id).collect();
        assert_eq!(order, vec![0, 2, 3, 9]);
        buf.check_invariants().unwrap();
    }
}
