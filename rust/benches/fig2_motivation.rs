//! Fig. 2 — motivation: (a) per-stage GPU utilization across GPU types,
//! (b) long-tail rollout lengths per phase, (c) staleness hurts convergence.
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    for (name, title, rows) in [
        ("fig2a", "Fig 2a — GPU utilization per stage (A40/A100/H200)", figures::fig2a()),
        ("fig2b", "Fig 2b — rollout length distributions", figures::fig2b()),
        ("fig2c", "Fig 2c — async staleness hurts convergence", figures::fig2c()),
    ] {
        print_table(title, &rows);
        save_rows(name, &rows).expect("save");
    }
}
