//! Table regenerators (Tables 1–4).

use crate::eval::figures::{one_run, SEEDS};
use crate::eval::report::Row;
use crate::sim::pipeline::{simulate, steady_state_latency, Pipeline, SimConfig};
use crate::sim::presets;
use crate::util::stats;

/// Table 1 — multi-node end-to-end step latency (2 × 4×A100-40GB).
pub fn table1() -> Vec<Row> {
    let setup = presets::multinode_7b_a100_40();
    let lat = |p: Pipeline| {
        stats::mean(&SEEDS.map(|seed| {
            steady_state_latency(&simulate(p, &SimConfig::new(setup.clone(), 60, seed)))
        }))
    };
    let trl = lat(Pipeline::TrlSequential);
    let oppo = lat(Pipeline::oppo());
    vec![
        Row::new("TRL").cell("mean_latency_s", trl).cell("speedup", 1.0),
        Row::new("OPPO").cell("mean_latency_s", oppo).cell("speedup", trl / oppo),
    ]
}

/// Table 2 — request-deferral distribution under OPPO.
pub fn table2() -> Vec<Row> {
    let setup = presets::stackex_7b_h200();
    let mut merged: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut mean_sum = 0.0;
    for seed in SEEDS {
        let log = one_run(Pipeline::oppo(), &setup, 300, seed);
        let (rows, mean) = log.deferral_distribution();
        for (k, share) in rows {
            *merged.entry(k).or_insert(0.0) += share / SEEDS.len() as f64;
        }
        mean_sum += mean;
    }
    let mut out: Vec<Row> = merged
        .into_iter()
        .map(|(k, share)| {
            Row::new(format!("deferred {k} steps")).cell("share_%", 100.0 * share)
        })
        .collect();
    out.push(Row::new("avg deferred steps").cell("share_%", mean_sum / SEEDS.len() as f64));
    out
}

/// Table 3 (simulator half) — final-reward parity per setup.  The real-
/// compute half (held-out exact-match accuracy of actually-trained
/// policies) lives in `benches/table3_quality.rs`, which needs artifacts.
pub fn table3_sim() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in presets::all_main_setups() {
        let steps = setup.total_steps;
        let fin = |p: Pipeline| {
            stats::mean(&SEEDS.map(|seed| {
                let log = one_run(p, &setup, steps, seed);
                let n = log.records.len();
                stats::mean(
                    &log.records[n - n / 10 - 1..].iter().map(|r| r.mean_score).collect::<Vec<_>>(),
                )
            }))
        };
        let t = fin(Pipeline::TrlSequential);
        let o = fin(Pipeline::oppo());
        rows.push(
            Row::new(setup.name)
                .cell("trl_final", t)
                .cell("oppo_final", o)
                .cell("change", o - t),
        );
    }
    rows
}

/// Table 4 — per-step latency under identical hardware/rollout settings:
/// VeRL DP, VeRL DP+SP, AReaL, OPPO (+ the fully-async VeRL arm from §4.2's
/// text).
pub fn table4() -> Vec<Row> {
    let setup = presets::table4_setup();
    let arms = [
        ("VeRL w/ DP", Pipeline::VerlDp),
        ("VeRL w/ DP+SP", Pipeline::VerlDpSp),
        ("VeRL fully-async w/ SP", Pipeline::VerlAsyncSp),
        ("AReaL", Pipeline::AReal),
        ("OPPO", Pipeline::oppo()),
    ];
    let mut rows = Vec::new();
    let mut oppo_lat = 1.0;
    let mut lats = Vec::new();
    for (name, p) in arms {
        let lat = stats::mean(&SEEDS.map(|seed| {
            steady_state_latency(&simulate(p, &SimConfig::new(setup.clone(), 60, seed)))
        }));
        if name == "OPPO" {
            oppo_lat = lat;
        }
        lats.push((name, lat));
    }
    for (name, lat) in lats {
        rows.push(
            Row::new(name)
                .cell("mean_latency_s", lat)
                .cell("vs_oppo", lat / oppo_lat),
        );
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shows_multinode_gap() {
        let rows = table1();
        let speedup = rows[1].cells[1].1;
        assert!(speedup > 2.5, "multi-node speedup {speedup} too small");
        assert!(speedup < 8.0, "multi-node speedup {speedup} implausible");
    }

    #[test]
    fn table2_mostly_zero_deferral() {
        let rows = table2();
        assert!(rows[0].label.contains("0 steps"));
        assert!(rows[0].cells[0].1 > 60.0, "zero-deferral share {}", rows[0].cells[0].1);
        let avg = rows.last().unwrap().cells[0].1;
        assert!(avg < 1.0, "avg deferral {avg}");
    }

    #[test]
    fn table4_ordering_matches_paper() {
        let rows = table4();
        let get = |name: &str| {
            rows.iter().find(|r| r.label == name).unwrap().cells[0].1
        };
        let dp = get("VeRL w/ DP");
        let dpsp = get("VeRL w/ DP+SP");
        let areal = get("AReaL");
        let oppo = get("OPPO");
        assert!(dp > dpsp && dpsp > areal && areal > oppo,
            "ordering violated: dp={dp:.1} dpsp={dpsp:.1} areal={areal:.1} oppo={oppo:.1}");
        // paper: OPPO beats VeRL-DP by ~1.26×; accept a generous band
        let factor = dp / oppo;
        assert!((1.1..2.5).contains(&factor), "dp/oppo = {factor}");
    }

    #[test]
    fn table3_sim_parity() {
        for row in table3_sim() {
            let change = row.cells[2].1.abs();
            let base = row.cells[0].1.abs().max(0.5);
            assert!(change / base < 0.08, "{}: change {change} too large", row.label);
        }
    }
}
