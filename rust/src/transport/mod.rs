//! Multi-node stage transport: remote replica pools over framed TCP.
//!
//! The replica pools (`coordinator::stage::StagePool`) scale reward/ref
//! scoring within one process; this module puts a wire behind the same
//! submit/recv facade so replicas can live on remote nodes.  The layering:
//!
//! * [`frame`] — length-prefixed binary frames (versioned header, crc32);
//! * [`wire`] — payload codec for the coordinator's own
//!   `RewardReq`/`RewardResp`/`RefReq`/`RefResp` types plus connection
//!   control (handshake, param distribution, heartbeat, per-request
//!   errors);
//! * [`client`] — [`RemoteReplica`], a connection handle with bounded
//!   reconnect-backoff, per-send deadlines, and an idle heartbeat;
//! * [`server`] — the `remote-stage` serve loop hosting one replica
//!   behind a TCP listener;
//! * [`toy`] — deterministic engine-free backends so the whole path
//!   (including failover and chunk replay) runs under tier-1 tests.
//!
//! The in-process replica path is untouched: chunks still move zero-copy
//! through the stage channels; only replicas configured via
//! `connect_addrs` pay the serialization.  [`RemoteRewardHandler`] /
//! [`RemoteRefHandler`] adapt a [`RemoteReplica`] to the [`StageHandler`]
//! trait, so a `StagePool` can mix local and remote replicas and the
//! `lane % replicas` routing — and everything above it — cannot tell them
//! apart.

pub mod client;
pub mod frame;
pub mod server;
pub mod toy;
pub mod wire;

pub use client::{ConnectOpts, RemoteReplica};
pub use server::{serve, Backend, ServerHandle};
pub use toy::{ToyRefBackend, ToyRewardBackend};

use anyhow::Result;

use crate::coordinator::stage::StageHandler;
use crate::coordinator::worker::{RefReq, RefResp, RewardReq, RewardResp};

/// `StageHandler` adapter: one remote reward replica behind the pool's
/// worker thread.  Requests serialize onto the wire; the per-send
/// deadline bounds how long a dead peer can stall the stage queue.
pub struct RemoteRewardHandler {
    pub client: RemoteReplica,
}

impl StageHandler for RemoteRewardHandler {
    type Req = RewardReq;
    type Resp = RewardResp;

    fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        self.client.reward(&req)
    }
}

/// `StageHandler` adapter for a remote ref replica.
pub struct RemoteRefHandler {
    pub client: RemoteReplica,
}

impl StageHandler for RemoteRefHandler {
    type Req = RefReq;
    type Resp = RefResp;

    fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        self.client.reference(&req)
    }
}

/// Parse one `stage@host:port` entry of the `connect_addrs` config knob.
pub fn parse_stage_addr(entry: &str) -> Result<(&str, &str)> {
    let (stage, addr) = entry
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("connect_addrs entry {entry:?} is not stage@host:port"))?;
    anyhow::ensure!(
        stage == "reward" || stage == "ref",
        "connect_addrs entry {entry:?}: stage must be reward or ref"
    );
    anyhow::ensure!(
        addr.contains(':') && !addr.ends_with(':'),
        "connect_addrs entry {entry:?}: address must be host:port"
    );
    Ok((stage, addr))
}

/// Split a comma-separated `connect_addrs` value into per-stage address
/// lists `(reward, ref)`.
pub fn split_connect_addrs(spec: &str) -> Result<(Vec<String>, Vec<String>)> {
    let mut reward = Vec::new();
    let mut reference = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (stage, addr) = parse_stage_addr(entry)?;
        match stage {
            "reward" => reward.push(addr.to_string()),
            _ => reference.push(addr.to_string()),
        }
    }
    Ok((reward, reference))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_addr_parsing() {
        let (rw, rf) =
            split_connect_addrs("reward@10.0.0.2:7070, ref@10.0.0.3:7071,reward@n4:7070").unwrap();
        assert_eq!(rw, vec!["10.0.0.2:7070", "n4:7070"]);
        assert_eq!(rf, vec!["10.0.0.3:7071"]);
        assert!(split_connect_addrs("critic@x:1").is_err());
        assert!(split_connect_addrs("reward@nohost").is_err());
        assert!(split_connect_addrs("").unwrap().0.is_empty());
    }
}
