//! Fig. 5 — GPU-utilization improvement (1.4×–2.1× in the paper).
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    let rows = figures::fig5();
    print_table("Fig 5 — GPU utilization (TRL vs OPPO)", &rows);
    save_rows("fig5", &rows).expect("save");
    for r in &rows {
        let ratio = r.cells[2].1;
        assert!((1.05..2.6).contains(&ratio), "{}: util ratio {ratio} out of band", r.label);
    }
    println!("shape check passed: OPPO lifts utilization on every setup");
}
