//! One-shot driver that regenerates every paper table and figure
//! (equivalent to `cargo bench`, or `oppo figures`): DESIGN.md §4's
//! experiment index end to end.  Results print here and land as JSON in
//! target/paper/.
fn main() -> anyhow::Result<()> {
    oppo::cli::run(&["figures".to_string()])
}
