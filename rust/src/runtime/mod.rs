//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compile once per entry point, and execute from
//! the coordinator's hot path with device-resident state.
//!
//! Adapted from `/opt/xla-example/load_hlo` with two hot-path extensions:
//! untupled execution (`execute_b_untupled`, vendored-crate patch) so
//! recurrent state feeds straight back in as buffers, and thread-safe
//! sharing so the actor/reward workers overlap for real.

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::Engine;
pub use manifest::{EntrySpec, Manifest, ModelShape, ParamSpec, TensorSpec};
pub use params::ParamSet;
