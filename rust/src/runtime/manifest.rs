//! Typed view of `artifacts/manifest.json` — the contract between the AOT
//! compiler (`python/compile/aot.py`) and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Static model/shape configuration baked into the artifacts.
#[derive(Clone, Debug)]
pub struct ModelShape {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub s_max: usize,
    pub prompt_max: usize,
    /// generation lanes G = ppo_batch + delta_max
    pub lanes: usize,
    pub ppo_batch: usize,
    pub chunk_sizes: Vec<usize>,
    pub gamma: f64,
    pub lam: f64,
    pub kl_beta_default: f64,
    /// Tokens per KV block for the paged entry family (must divide `s_max`).
    pub kv_block_size: usize,
    /// Physical blocks in the pooled KV buffer; 0 = auto-size so every lane
    /// can hold a full `s_max` sequence plus the scratch block.
    pub kv_pool_blocks: usize,
}

impl ModelShape {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Shape of one KV cache tensor for `batch` lanes.
    pub fn kv_shape(&self, batch: usize) -> Vec<usize> {
        vec![batch, self.n_heads, self.s_max, self.head_dim()]
    }

    /// KV blocks covering one full-length (`s_max`) lane.
    pub fn paged_blocks_per_lane(&self) -> usize {
        self.s_max / self.kv_block_size
    }

    /// Physical blocks in the pooled KV buffer, scratch block 0 included.
    pub fn paged_pool_blocks(&self) -> usize {
        if self.kv_pool_blocks > 0 {
            self.kv_pool_blocks
        } else {
            self.lanes * self.paged_blocks_per_lane() + 1
        }
    }

    /// Shape of one pooled KV tensor: `[pool, n_heads, block, head_dim]`.
    pub fn paged_kv_shape(&self) -> Vec<usize> {
        vec![self.paged_pool_blocks(), self.n_heads, self.kv_block_size, self.head_dim()]
    }

    /// Shape of an uploaded i32 block table covering `rows` lanes.
    pub fn block_table_shape(&self, rows: usize) -> Vec<usize> {
        vec![rows, self.paged_blocks_per_lane()]
    }

    /// f32 bytes of K + V across all layers for one token of one sequence —
    /// the unit the paged-vs-dense memory accounting is priced in.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.head_dim() * 4
    }

    /// Total parameter count (elements) of one model.
    pub fn approx_params(&self) -> usize {
        let d = self.d_model;
        self.vocab * d + self.s_max * d + self.n_layers * (4 * d * d + 2 * d * self.d_ff) + 4 * d
    }
}

/// One tensor in an entry-point signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One parameter tensor's slot in `params_*.bin`.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// Parsed manifest + artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub shape: ModelShape,
    pub n_params: usize,
    pub param_table: Vec<ParamSpec>,
    pub params_files: BTreeMap<String, String>,
    pub entries: BTreeMap<String, EntrySpec>,
    pub tokenizer: Value,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        if v.get("format_version")?.as_usize()? != 1 {
            bail!("unsupported manifest format_version");
        }

        let cfg = v.get("config")?;
        let shape = ModelShape {
            vocab: cfg.get("vocab")?.as_usize()?,
            d_model: cfg.get("d_model")?.as_usize()?,
            n_heads: cfg.get("n_heads")?.as_usize()?,
            n_layers: cfg.get("n_layers")?.as_usize()?,
            d_ff: cfg.get("d_ff")?.as_usize()?,
            s_max: cfg.get("s_max")?.as_usize()?,
            prompt_max: cfg.get("prompt_max")?.as_usize()?,
            lanes: cfg.get("lanes")?.as_usize()?,
            ppo_batch: cfg.get("ppo_batch")?.as_usize()?,
            chunk_sizes: cfg.get("chunk_sizes")?.as_usize_vec()?,
            gamma: cfg.get("gamma")?.as_f64()?,
            lam: cfg.get("lam")?.as_f64()?,
            kl_beta_default: cfg.opt("kl_beta").map(|x| x.as_f64()).transpose()?.unwrap_or(0.02),
            // older artifact sets predate paging; defaults keep them loadable
            // (paged support is gated on entry presence, not these knobs)
            kv_block_size: cfg
                .opt("kv_block_size")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(16),
            kv_pool_blocks: cfg
                .opt("kv_pool_blocks")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
        };

        let param_table = v
            .get("param_table")?
            .as_arr()?
            .iter()
            .map(|row| {
                Ok(ParamSpec {
                    name: row.get("name")?.as_str()?.to_string(),
                    shape: row.get("shape")?.as_usize_vec()?,
                    offset: row.get("offset")?.as_usize()?,
                    bytes: row.get("bytes")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let params_files = v
            .get("params_files")?
            .as_obj()?
            .iter()
            .map(|(k, f)| Ok((k.clone(), f.as_str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;

        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        Ok(TensorSpec {
                            shape: t.get("shape")?.as_usize_vec()?,
                            dtype: t.get("dtype")?.as_str()?.to_string(),
                        })
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }

        let manifest = Manifest {
            dir,
            shape,
            n_params: v.get("n_params")?.as_usize()?,
            param_table,
            params_files,
            entries,
            tokenizer: v.get("tokenizer")?.clone(),
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        if self.param_table.len() != self.n_params {
            bail!("param_table length {} != n_params {}", self.param_table.len(), self.n_params);
        }
        let mut offset = 0;
        for p in &self.param_table {
            if p.offset != offset {
                bail!("param {} offset {} != expected {offset}", p.name, p.offset);
            }
            let elems: usize = p.shape.iter().product::<usize>().max(1);
            if p.bytes != 4 * elems {
                bail!("param {} bytes {} != 4 * {elems}", p.name, p.bytes);
            }
            offset += p.bytes;
        }
        if self.shape.lanes <= self.shape.ppo_batch {
            bail!("lanes must exceed ppo_batch (need room for Δ)");
        }
        for required in ["actor_prefill", "reward_score_full", "ref_logprobs", "gae", "ppo_update"]
        {
            if !self.entries.contains_key(required) {
                bail!("manifest missing required entry {required:?}");
            }
        }
        for c in &self.shape.chunk_sizes {
            for prefix in ["actor_generate_chunk_c", "reward_prefill_chunk_c"] {
                let name = format!("{prefix}{c}");
                if !self.entries.contains_key(&name) {
                    bail!("manifest missing chunk variant {name:?}");
                }
            }
        }
        // lane-sliced variants must be internally consistent: rows divides
        // lanes, the chunk size is compiled, and each (stage, rows) pair
        // covers every chunk size — a pool commits to the sliced path at
        // spawn time, so partial coverage would strand it mid-run.
        let mut sliced: BTreeMap<(&str, usize), Vec<usize>> = BTreeMap::new();
        for name in self.entries.keys() {
            let Some((stage, rows, c)) = parse_sliced_entry(name) else { continue };
            if rows == 0 || self.shape.lanes % rows != 0 {
                bail!(
                    "sliced entry {name:?}: {rows} rows does not divide lanes {}",
                    self.shape.lanes
                );
            }
            if !self.shape.chunk_sizes.contains(&c) {
                bail!("sliced entry {name:?}: chunk size {c} not in chunk_sizes");
            }
            if !name.contains("_pallas_") {
                sliced.entry((stage, rows)).or_default().push(c);
            }
        }
        let mut want = self.shape.chunk_sizes.clone();
        want.sort_unstable();
        for ((stage, rows), mut cs) in sliced {
            cs.sort_unstable();
            if cs != want {
                bail!(
                    "sliced {stage} prefill at {rows} rows covers chunk sizes \
                     {cs:?}, expected all of {want:?}"
                );
            }
        }
        // paged entries are all-or-nothing: workers and the scheduler commit
        // to the paged path at spawn, so shipping (say) paged reward prefill
        // without paged generation would strand a run mid-step.  Pallas
        // validation flavours are exempt, like the sliced family above.
        let any_paged =
            self.entries.keys().any(|n| n.contains("_paged") && !n.contains("_pallas_"));
        if any_paged {
            if self.shape.kv_block_size == 0 || self.shape.s_max % self.shape.kv_block_size != 0
            {
                bail!(
                    "paged entries present but kv_block_size {} does not divide s_max {}",
                    self.shape.kv_block_size,
                    self.shape.s_max
                );
            }
            let mut family = vec!["actor_prefill_paged".to_string()];
            for c in &self.shape.chunk_sizes {
                family.push(format!("actor_generate_chunk_paged_c{c}"));
                family.push(format!("reward_prefill_chunk_paged_c{c}"));
                if self.ref_prefill_supported() {
                    family.push(format!("ref_prefill_chunk_paged_c{c}"));
                }
            }
            for name in family {
                if !self.entries.contains_key(&name) {
                    bail!("partial paged entry family: missing {name:?}");
                }
            }
            let table = self.shape.block_table_shape(self.shape.lanes);
            let prefill = self.entry("actor_prefill_paged")?;
            let got = &prefill.inputs.last().expect("entry has inputs").shape;
            if *got != table {
                bail!("actor_prefill_paged block table shape {got:?} != {table:?}");
            }
        }
        Ok(())
    }

    /// Total bytes of one params file.
    pub fn params_bytes(&self) -> usize {
        self.param_table.last().map(|p| p.offset + p.bytes).unwrap_or(0)
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("entry {name:?} not in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()))
    }

    /// Do the artifacts ship chunked *reference* prefill for every compiled
    /// chunk size?  Older artifact sets only have dense `ref_logprobs`; the
    /// scheduler falls back to the monolithic path when this is false.
    pub fn ref_prefill_supported(&self) -> bool {
        self.shape
            .chunk_sizes
            .iter()
            .all(|c| self.entries.contains_key(&format!("ref_prefill_chunk_c{c}")))
    }

    /// The Pallas-flavoured reward-prefill entry name, if shipped.
    pub fn pallas_reward_entry(&self) -> Option<(&str, usize)> {
        self.entries.keys().find_map(|k| {
            k.strip_prefix("reward_prefill_chunk_pallas_c")
                .and_then(|c| c.parse::<usize>().ok())
                .map(|c| (k.as_str(), c))
        })
    }

    /// The lane-sliced prefill entry for `stage` ("reward" | "ref") at
    /// `rows` compacted lanes and chunk size `c`, if shipped.
    pub fn sliced_prefill_entry(&self, stage: &str, rows: usize, c: usize) -> Option<String> {
        let name = format!("{stage}_prefill_chunk_g{rows}_c{c}");
        self.entries.contains_key(&name).then_some(name)
    }

    /// Do the artifacts ship sliced `stage` prefill at `rows` for EVERY
    /// compiled chunk size?  Replica pools decide masked-vs-sliced once at
    /// spawn, so the sliced path needs full chunk-size coverage.
    pub fn sliced_prefill_supported(&self, stage: &str, rows: usize) -> bool {
        rows > 0
            && self.shape.chunk_sizes.iter().all(|c| {
                self.entries.contains_key(&format!("{stage}_prefill_chunk_g{rows}_c{c}"))
            })
    }

    /// Do the artifacts ship the paged entry family?  validate() enforces
    /// all-or-nothing coverage, so actor-prefill presence implies the full
    /// set (paged generation + reward, and ref when chunked ref ships).
    pub fn paged_supported(&self) -> bool {
        self.entries.contains_key("actor_prefill_paged")
    }

    /// The paged prefill entry for `stage` ("reward" | "ref") at chunk `c`,
    /// if shipped.  Paged entries are full-G only (no sliced flavours):
    /// replica pools route them via the masked path.
    pub fn paged_prefill_entry(&self, stage: &str, c: usize) -> Option<String> {
        let name = format!("{stage}_prefill_chunk_paged_c{c}");
        self.entries.contains_key(&name).then_some(name)
    }

    /// The Pallas-flavoured paged reward entry, if shipped.
    pub fn pallas_paged_reward_entry(&self) -> Option<(&str, usize)> {
        self.entries.keys().find_map(|k| {
            k.strip_prefix("reward_prefill_chunk_paged_pallas_c")
                .and_then(|c| c.parse::<usize>().ok())
                .map(|c| (k.as_str(), c))
        })
    }

    /// The sliced Pallas reward entry at `rows`, if shipped.
    pub fn pallas_sliced_reward_entry(&self, rows: usize) -> Option<(&str, usize)> {
        let prefix = format!("reward_prefill_chunk_pallas_g{rows}_c");
        self.entries.keys().find_map(|k| {
            k.strip_prefix(prefix.as_str())
                .and_then(|c| c.parse::<usize>().ok())
                .map(|c| (k.as_str(), c))
        })
    }
}

/// Parse `{stage}_prefill_chunk[_pallas]_g{rows}_c{c}` entry names.
fn parse_sliced_entry(name: &str) -> Option<(&'static str, usize, usize)> {
    for stage in ["reward", "ref"] {
        let Some(rest) = name.strip_prefix(stage) else { continue };
        let rest = rest
            .strip_prefix("_prefill_chunk")
            .map(|r| r.strip_prefix("_pallas").unwrap_or(r));
        let Some(rest) = rest.and_then(|r| r.strip_prefix("_g")) else { continue };
        let (rows, c) = rest.split_once("_c")?;
        return match (rows.parse(), c.parse()) {
            (Ok(rows), Ok(c)) => Some((stage, rows, c)),
            _ => None,
        };
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.shape.ppo_batch + 4, m.shape.lanes);
        assert_eq!(m.n_params, m.shape.n_layers * 12 + 6);
        assert!(m.params_bytes() > 0);
        assert!(m.pallas_reward_entry().is_some());
        let gen = m.entry(&format!("actor_generate_chunk_c{}", m.shape.chunk_sizes[0])).unwrap();
        assert_eq!(gen.inputs.len(), m.n_params + 3 + 2 * m.shape.n_layers + 1);
    }

    #[test]
    fn kv_shape_dims() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let kv = m.shape.kv_shape(m.shape.lanes);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv[0], m.shape.lanes);
        assert_eq!(kv[2], m.shape.s_max);
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn sliced_entry_names_parse() {
        assert_eq!(parse_sliced_entry("reward_prefill_chunk_g6_c16"), Some(("reward", 6, 16)));
        assert_eq!(parse_sliced_entry("ref_prefill_chunk_g3_c8"), Some(("ref", 3, 8)));
        assert_eq!(
            parse_sliced_entry("reward_prefill_chunk_pallas_g4_c16"),
            Some(("reward", 4, 16))
        );
        assert_eq!(parse_sliced_entry("reward_prefill_chunk_c16"), None);
        assert_eq!(parse_sliced_entry("reward_prefill_chunk_pallas_c16"), None);
        assert_eq!(parse_sliced_entry("actor_generate_chunk_c8"), None);
        assert_eq!(parse_sliced_entry("reward_prefill_chunk_g_cx"), None);
    }

    #[test]
    fn sliced_entries_ship_for_divisor_replica_counts() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let g = m.shape.lanes;
        for n in 2..=g {
            if g % n != 0 {
                continue;
            }
            let rows = g / n;
            assert!(m.sliced_prefill_supported("reward", rows), "reward rows={rows}");
            assert!(m.sliced_prefill_supported("ref", rows), "ref rows={rows}");
            for c in &m.shape.chunk_sizes {
                let e = m.sliced_prefill_entry("reward", rows, *c).unwrap();
                assert_eq!(m.entry(&e).unwrap().inputs[m.n_params].shape, vec![rows, *c]);
            }
            assert!(m.pallas_sliced_reward_entry(rows).is_some(), "pallas rows={rows}");
        }
        // non-divisor row counts are absent → masked fallback
        assert!(!m.sliced_prefill_supported("reward", g + 1));
        assert!(!m.sliced_prefill_supported("reward", 0));
    }

    #[test]
    fn paged_family_ships_and_is_shaped() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        if !m.paged_supported() {
            return; // pre-paging artifact set
        }
        assert_eq!(m.shape.s_max % m.shape.kv_block_size, 0);
        let pool = m.shape.paged_pool_blocks();
        assert!(pool > 1, "pool must hold the scratch block plus real blocks");
        let kv = m.shape.paged_kv_shape();
        assert_eq!(kv, vec![pool, m.shape.n_heads, m.shape.kv_block_size, m.shape.head_dim()]);
        for c in &m.shape.chunk_sizes {
            assert!(m.paged_prefill_entry("reward", *c).is_some());
            assert!(m.paged_prefill_entry("ref", *c).is_some());
            let e = m.entry(&format!("actor_generate_chunk_paged_c{c}")).unwrap();
            // params + (tokens, pos, live) + pooled kv + key + table
            assert_eq!(e.inputs.len(), m.n_params + 3 + 2 * m.shape.n_layers + 2);
            assert_eq!(e.inputs[m.n_params + 3].shape, kv);
            assert_eq!(
                e.inputs.last().unwrap().shape,
                m.shape.block_table_shape(m.shape.lanes)
            );
        }
        assert!(m.pallas_paged_reward_entry().is_some());
        // paged entries never come sliced — full-G only
        assert!(!m.entries.keys().any(|n| n.contains("_paged_g")));
    }
}
