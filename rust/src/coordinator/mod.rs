//! The OPPO coordinator — the paper's Layer-3 contribution.
//!
//! * [`buffer`] — Algorithm 1's `B + Δ` FIFO sequence buffer;
//! * [`delta`] — the dynamic Δ controller (Eq. 4 / Alg. 1 l.21-27);
//! * [`chunkctl`] — the dynamic chunk-size controller (§3.1);
//! * [`engine_ops`] — typed wrappers over the AOT entry points with
//!   device-resident state;
//! * [`worker`] — the reward-scoring thread (intra-step overlap);
//! * [`scheduler`] — the training loop: OPPO, both ablations, the TRL-style
//!   sequential baseline, and async staleness-k;
//! * [`dpo`] — the DPO generalization (§4.3).

pub mod buffer;
pub mod chunkctl;
pub mod delta;
pub mod dpo;
pub mod engine_ops;
pub mod scheduler;
pub mod worker;

pub use buffer::SeqBuffer;
pub use chunkctl::ChunkController;
pub use delta::{DeltaController, Policy};
pub use scheduler::OppoScheduler;
