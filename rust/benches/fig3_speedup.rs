//! Fig. 3 — OPPO's end-to-end time-to-reward speedup (1.8×–2.8× in the
//! paper) across the four task × hardware setups.
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    let rows = figures::fig3();
    print_table("Fig 3 — time-to-reward speedup (TRL vs OPPO)", &rows);
    save_rows("fig3", &rows).expect("save");
    for r in &rows {
        let speedup = r.cells[2].1;
        assert!((1.5..3.5).contains(&speedup), "{}: speedup {speedup} out of band", r.label);
    }
    println!("shape check passed: all speedups within the paper's band");
}
