//! # OPPO — Accelerating PPO-based RLHF via Pipeline Overlap
//!
//! A ground-up reproduction of the OPPO paper (Yan et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the OPPO coordinator: Algorithm 1's
//!   `B + Δ` FIFO buffer, intra-step chunk streaming from the actor to the
//!   reward model, the dynamic Δ controller (Eq. 4), the dynamic chunk-size
//!   controller, plus every substrate the paper's evaluation needs (a
//!   discrete-event GPU-cluster simulator, baselines for TRL / async RLHF /
//!   VeRL / AReaL schedules, synthetic RLHF tasks, metrics).
//! * **Layer 2** — a JAX transformer (actor + value head, reward model,
//!   reference model) and the PPO/DPO update math, AOT-lowered to HLO text
//!   by `python/compile/aot.py`.
//! * **Layer 1** — Pallas kernels (chunked-prefill attention, decode
//!   attention, GAE) that lower into the same HLO.
//!
//! Python never runs on the training path: [`runtime`] loads the
//! `artifacts/*.hlo.txt` modules through PJRT once and the whole RLHF loop
//! executes from Rust.
//!
//! Start with [`coordinator::OppoScheduler`] for the real-compute training
//! loop, or [`sim::pipeline`] for the paper-scale discrete-event studies
//! that regenerate every figure and table (see DESIGN.md §4 for the map).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod ctl;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod model;
pub mod ppo;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
