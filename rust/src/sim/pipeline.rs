//! Pipeline schedules under study — the simulator's heart.
//!
//! Every schedule steps the same substrate (cost model + length model +
//! reward process); they differ only in *when* stages run and *what* they
//! wait for — which is exactly the paper's claim surface:
//!
//! * [`Pipeline::TrlSequential`] — generate-all → score-all → train
//!   (Fig. 1a);
//! * [`Pipeline::Oppo`] — intra-step streaming + inter-step overcommit
//!   (Fig. 1b, Alg. 1), with both ablation arms and fixed-Δ variants;
//! * [`Pipeline::AsyncStale`] — decoupled stages with staleness k
//!   (Fig. 2c);
//! * [`Pipeline::VerlDp`] / [`Pipeline::VerlDpSp`] /
//!   [`Pipeline::VerlAsyncSp`] — VeRL-style schedules (Table 4);
//! * [`Pipeline::AReal`] — AReaL-style fully-async (Table 4).
//!
//! Generation is simulated event-stepped: between consecutive sequence
//! completions the active set is constant, so time advances in segments of
//! `(remaining_tokens_delta) × decode_iter(active_batch)`.  Decode is
//! bandwidth-bound, so the *longest* active sequence governs stage time —
//! the tail-straggler effect inter-step overlap attacks.

use std::collections::VecDeque;

use crate::ctl::qpolicy::{KnobBounds, KnobState, QPolicy};
use crate::ctl::{
    ControlActions, Controller, DeltaController, HeuristicController, LearnedController, Policy,
    StepTelemetry,
};
use crate::metrics::{PromptLatency, RunLog, StageTiming, StepRecord};
use crate::sim::costmodel::CostModel;
use crate::sim::lengths::LengthModel;
use crate::sim::presets::Setup;
use crate::sim::rewardmodel::RewardProcess;
use crate::util::rng::Rng;

/// A schedule to simulate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pipeline {
    TrlSequential,
    /// full OPPO or an ablation arm; `fixed_delta` disables the controller
    Oppo { intra: bool, inter: bool, fixed_delta: Option<usize> },
    AsyncStale { k: usize },
    VerlDp,
    VerlDpSp,
    VerlAsyncSp,
    AReal,
}

impl Pipeline {
    pub fn oppo() -> Self {
        Pipeline::Oppo { intra: true, inter: true, fixed_delta: None }
    }

    pub fn name(&self) -> String {
        match self {
            Pipeline::TrlSequential => "trl".into(),
            Pipeline::Oppo { intra: true, inter: true, fixed_delta: None } => "oppo".into(),
            Pipeline::Oppo { intra: false, inter: true, fixed_delta: None } => {
                "oppo-no-intra".into()
            }
            Pipeline::Oppo { intra: true, inter: false, .. } => "oppo-no-inter".into(),
            Pipeline::Oppo { fixed_delta: Some(d), .. } => format!("oppo-fixed-d{d}"),
            Pipeline::Oppo { .. } => "oppo-variant".into(),
            Pipeline::AsyncStale { k } => format!("async-k{k}"),
            Pipeline::VerlDp => "verl-dp".into(),
            Pipeline::VerlDpSp => "verl-dp-sp".into(),
            Pipeline::VerlAsyncSp => "verl-async-sp".into(),
            Pipeline::AReal => "areal".into(),
        }
    }
}

/// Admission discipline for the actor lanes (mirrors the coordinator's
/// `config::AdmissionMode`, with the Poisson rate carried inline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimAdmission {
    /// legacy: fill to `B + Δ` at the step boundary only
    Step,
    /// rolling admission, saturated arrivals — a fresh prompt takes every
    /// lane the instant it frees (training parity; zero queue wait)
    RollingSaturated,
    /// rolling admission under Poisson traffic at `rate` prompts/second;
    /// prompts queue (bounded) until a lane frees, and per-prompt queue
    /// wait / end-to-end latency are recorded in the step log
    RollingPoisson { rate: f64 },
}

impl SimAdmission {
    pub fn rolling(&self) -> bool {
        !matches!(self, SimAdmission::Step)
    }
}

/// Which controller arm drives the per-step knobs (the A/B flag's sim
/// counterpart).
#[derive(Clone, Debug, Default)]
pub enum SimController {
    /// The paper's heuristics: Δ via [`DeltaController`] on the dynamic
    /// OPPO arm, chunk size fixed at `chunk_tokens`, replicas fixed.
    #[default]
    Heuristic,
    /// A frozen Q-policy replayed greedily over the same telemetry the
    /// environment trained on (see `sim::env`).
    Learned(QPolicy),
}

/// Simulation run parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub setup: Setup,
    pub steps: usize,
    pub seed: u64,
    /// intra-step streaming chunk size in tokens (paper's Fig. 7b axis)
    pub chunk_tokens: f64,
    /// Δ bounds for the dynamic controller
    pub delta_max: usize,
    pub window: usize,
    /// Δ-update direction convention (the paper specifies both; see
    /// `ctl::delta` module docs — Eq4 is the default)
    pub delta_policy: Policy,
    /// Replicated reward stage (the coordinator's `reward_replicas`):
    /// sequence-affine replicas prefill disjoint lane subsets concurrently
    /// via lane-sliced `[G/N, C]` entries, dividing the prefill *compute*
    /// by the pool size (total useful work is conserved).  The division is
    /// priced through [`CostModel::sliced_prefill`], so the non-dividing
    /// weight-streaming floor caps how far replication scales.
    pub reward_replicas: usize,
    /// Replicated reference stage (the coordinator's `ref_replicas`),
    /// modeled exactly like [`SimConfig::reward_replicas`]: sliced entries
    /// divide the ref-prefill compute while the actor-colocated value
    /// prefill keeps its single worker.
    pub ref_replicas: usize,
    /// lane admission discipline ([`SimAdmission::Step`] reproduces the
    /// legacy boundary-only fill; rolling variants refill lanes at
    /// completion events mid-stage)
    pub admission: SimAdmission,
    /// bound on the Poisson arrival queue — prompts arriving with the
    /// queue at this depth are shed (and counted in `queue_dropped`)
    pub admission_queue_depth: usize,
    /// paged-KV block size in tokens; 0 = dense per-lane rows.  Paging is
    /// a *memory* discipline: the decode schedule is untouched (equal
    /// throughput by construction), but each sequence commits block-rounded
    /// context instead of a worst-case `prompt + max_len` row, which is
    /// what `peak_kv_bytes` measures and rolling admission scales against.
    pub kv_block_tokens: f64,
    /// Remote reward replicas (the coordinator's `connect_addrs` arm):
    /// > 0 switches the streamed reward pool to remote pricing — masked
    /// full-shape grids (remote pools cannot lane-slice; failover reroutes
    /// lanes, which a compacted grid's fixed row ↔ lane binding cannot
    /// express) plus a framed round trip per streamed chunk over the link.
    pub remote_replicas: usize,
    /// inter-node link bandwidth in Gbit/s for the remote arm
    pub link_gbps: f64,
    /// one-way link latency per framed message, seconds
    pub link_latency_s: f64,
    /// controller arm driving Δ / chunk / replica knobs per step
    pub controller: SimController,
}

impl SimConfig {
    pub fn new(setup: Setup, steps: usize, seed: u64) -> Self {
        let delta_max = setup.delta_max;
        Self {
            setup, steps, seed,
            chunk_tokens: 500.0,
            delta_max,
            window: 8,
            delta_policy: Policy::Eq4,
            reward_replicas: 1,
            ref_replicas: 1,
            admission: SimAdmission::Step,
            admission_queue_depth: 256,
            kv_block_tokens: 0.0,
            remote_replicas: 0,
            link_gbps: 100.0,
            link_latency_s: 5e-5,
            controller: SimController::Heuristic,
        }
    }

    /// Drive the run with a frozen learned policy instead of the
    /// heuristics (the `controller = "learned"` arm).
    pub fn learned(mut self, policy: QPolicy) -> Self {
        self.controller = SimController::Learned(policy);
        self
    }

    /// Host the streamed reward pool on `n` remote replicas over a link.
    pub fn remote(mut self, n: usize, link_gbps: f64, link_latency_s: f64) -> Self {
        assert!(link_gbps > 0.0, "the remote arm needs a positive link bandwidth");
        self.remote_replicas = n;
        self.link_gbps = link_gbps;
        self.link_latency_s = link_latency_s;
        self
    }

    /// Switch KV accounting to paged blocks of `block_tokens` tokens.
    pub fn paged(mut self, block_tokens: f64) -> Self {
        assert!(block_tokens > 0.0, "paged KV needs a positive block size");
        self.kv_block_tokens = block_tokens;
        self
    }

    /// Switch to rolling admission with saturated arrivals.
    pub fn rolling_saturated(mut self) -> Self {
        self.admission = SimAdmission::RollingSaturated;
        self
    }

    /// Switch to rolling admission under Poisson traffic.  Pass the
    /// setup's `arrival_rate` for the calibrated default.
    pub fn rolling_poisson(mut self, rate: f64) -> Self {
        assert!(rate > 0.0, "Poisson arrival rate must be positive");
        self.admission = SimAdmission::RollingPoisson { rate };
        self
    }
}

/// One in-flight sequence.
#[derive(Clone, Debug)]
struct GenSeq {
    remaining: f64,
    total_len: f64,
    prompt: f64,
    enq_step: u64,
    /// absolute sim time the prompt arrived (== `admit_t` when admission
    /// is not queued)
    enq_t: f64,
    /// absolute sim time the prompt took a lane
    admit_t: f64,
    id: u64,
}

/// Outcome of one generation stage.
struct GenOutcome {
    time: f64,
    /// total tokens decoded this stage (all lanes)
    tokens: f64,
    finished: Vec<GenSeq>,
    /// ∫ (lanes − active) dt over the stage, in lane·seconds — the idle
    /// capacity rolling admission exists to reclaim
    idle_lane_s: f64,
    /// max over decode segments of Σ_active committed KV bytes (dense: a
    /// full `max_row` per lane; paged: block-rounded sequence length)
    peak_kv_bytes: f64,
}

/// Event-stepped decode: advance until `stop_finished` sequences complete
/// (or all).  Mutates `active` (finished removed, survivors decremented).
/// `lanes` is the lane capacity idle accounting is measured against.
fn run_generation(
    active: &mut Vec<GenSeq>,
    stop_finished: usize,
    lanes: usize,
    cm: &CostModel,
    per_gpu_shards: f64,
    max_row: f64,
    kv_block_tokens: f64,
) -> GenOutcome {
    let mut time = 0.0;
    let mut tokens = 0.0;
    let mut idle_lane_s = 0.0;
    let mut peak_kv_bytes = 0.0f64;
    let mut finished = Vec::new();
    while !active.is_empty() && finished.len() < stop_finished {
        let committed: f64 = active
            .iter()
            .map(|s| cm.kv_committed_bytes(s.prompt + s.total_len, max_row, kv_block_tokens))
            .sum();
        peak_kv_bytes = peak_kv_bytes.max(committed);
        let min_rem = active.iter().map(|s| s.remaining).fold(f64::INFINITY, f64::min);
        let batch = active.len() as f64 / per_gpu_shards.max(1.0);
        let mean_ctx = active.iter().map(|s| s.prompt + s.total_len - s.remaining).sum::<f64>()
            / active.len() as f64;
        let t_iter = cm.decode_iter(batch, mean_ctx);
        time += min_rem * t_iter;
        tokens += min_rem * active.len() as f64;
        idle_lane_s += (lanes as f64 - active.len() as f64).max(0.0) * min_rem * t_iter;
        for s in active.iter_mut() {
            s.remaining -= min_rem;
        }
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= 1e-9 {
                finished.push(active.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }
    // simultaneous completions can overshoot the stop target (ties at the
    // truncation cap); the overflow stays buffered and joins the next
    // step's first-B selection — exactly Alg. 1's `finished[:B]`
    while finished.len() > stop_finished {
        let mut seq = finished.pop().unwrap();
        seq.remaining = 0.0;
        active.push(seq);
    }
    GenOutcome { time, tokens, finished, idle_lane_s, peak_kv_bytes }
}

/// Poisson arrival stream state, persistent across steps: prompts keep
/// arriving while scoring/training runs, queueing (bounded) until the next
/// generation stage admits them — so recorded queue waits include the
/// inter-stage dead time, exactly like a serving queue in front of a
/// training loop.
struct ArrivalState {
    /// absolute time of the next (not yet materialized) arrival
    next: f64,
    /// arrival times of prompts waiting for a lane, FIFO
    queue: VecDeque<f64>,
    depth: usize,
    dropped: u64,
}

impl ArrivalState {
    fn new(depth: usize, rate: f64, rng: &mut Rng) -> Self {
        Self { next: rng.exp(rate), queue: VecDeque::new(), depth, dropped: 0 }
    }

    /// Materialize every arrival up to absolute time `t`.
    fn drain_until(&mut self, t: f64, rate: f64, rng: &mut Rng) {
        while self.next <= t {
            if self.queue.len() < self.depth {
                self.queue.push_back(self.next);
            } else {
                self.dropped += 1;
            }
            self.next += rng.exp(rate);
        }
    }
}

/// What one rolling generation stage produced beyond [`GenOutcome`].
struct RollExtra {
    /// prompts admitted after the stage started (mid-step refills)
    admitted_mid: usize,
    /// per-prompt latency records for the sequences that finished
    latencies: Vec<PromptLatency>,
}

/// Event-stepped decode with **rolling admission**: every completion (and,
/// under Poisson traffic, every arrival) event refills free lanes
/// immediately, so the decode batch stays full instead of draining toward
/// the stop target.  Admission order is FIFO; the stop condition is the
/// first `stop_finished` completions, matching `SeqBuffer::take_finished`.
/// Survivors (including partially-decoded mid-step admits) stay in
/// `active` and carry to the next step — rolling admission generalizes
/// inter-step overlap.
#[allow(clippy::too_many_arguments)]
fn run_generation_rolling(
    active: &mut Vec<GenSeq>,
    stop_finished: usize,
    lanes: usize,
    cm: &CostModel,
    per_gpu_shards: f64,
    admission: SimAdmission,
    arr: &mut ArrivalState,
    lengths: &LengthModel,
    progress: f64,
    prompt_len: f64,
    step: u64,
    now: f64,
    next_id: &mut u64,
    rng: &mut Rng,
    max_row: f64,
    kv_block_tokens: f64,
) -> (GenOutcome, RollExtra) {
    let mut time = 0.0;
    let mut tokens = 0.0;
    let mut idle_lane_s = 0.0;
    let mut peak_kv_bytes = 0.0f64;
    let mut finished: Vec<GenSeq> = Vec::new();
    let mut latencies: Vec<PromptLatency> = Vec::new();
    let mut admitted_mid = 0usize;

    let admit = |active: &mut Vec<GenSeq>,
                     enq_t: f64,
                     admit_t: f64,
                     next_id: &mut u64,
                     rng: &mut Rng| {
        let len = lengths.sample(rng, progress);
        active.push(GenSeq {
            remaining: len,
            total_len: len,
            prompt: prompt_len,
            enq_step: step,
            enq_t,
            admit_t,
            id: *next_id,
        });
        *next_id += 1;
    };

    while finished.len() < stop_finished {
        // ---- admission: fill every free lane ----
        match admission {
            SimAdmission::RollingSaturated => {
                while active.len() < lanes {
                    let t = now + time;
                    admit(active, t, t, next_id, rng);
                    if time > 0.0 {
                        admitted_mid += 1;
                    }
                }
            }
            SimAdmission::RollingPoisson { rate } => {
                arr.drain_until(now + time, rate, rng);
                while active.len() < lanes {
                    let Some(enq_t) = arr.queue.pop_front() else { break };
                    admit(active, enq_t, now + time, next_id, rng);
                    if time > 0.0 {
                        admitted_mid += 1;
                    }
                }
            }
            SimAdmission::Step => unreachable!("rolling generation under Step admission"),
        }

        if active.is_empty() {
            // starved: idle-advance to the next arrival (Poisson only —
            // saturated admission always fills above)
            let SimAdmission::RollingPoisson { .. } = admission else {
                break;
            };
            let jump = (arr.next - (now + time)).max(0.0);
            idle_lane_s += lanes as f64 * jump;
            time = arr.next - now;
            continue;
        }

        // ---- advance to the next completion or (if a lane is free and
        //      traffic pending) the next arrival ----
        let committed: f64 = active
            .iter()
            .map(|s| cm.kv_committed_bytes(s.prompt + s.total_len, max_row, kv_block_tokens))
            .sum();
        peak_kv_bytes = peak_kv_bytes.max(committed);
        let min_rem = active.iter().map(|s| s.remaining).fold(f64::INFINITY, f64::min);
        let batch = active.len() as f64 / per_gpu_shards.max(1.0);
        let mean_ctx = active.iter().map(|s| s.prompt + s.total_len - s.remaining).sum::<f64>()
            / active.len() as f64;
        let t_iter = cm.decode_iter(batch, mean_ctx);
        let mut dt = min_rem * t_iter;
        if let SimAdmission::RollingPoisson { .. } = admission {
            if active.len() < lanes {
                let arrival_dt = arr.next - (now + time);
                if arrival_dt > 0.0 && arrival_dt < dt {
                    dt = arrival_dt;
                }
            }
        }
        let tok_per_lane = dt / t_iter;
        time += dt;
        tokens += tok_per_lane * active.len() as f64;
        idle_lane_s += (lanes as f64 - active.len() as f64).max(0.0) * dt;
        for s in active.iter_mut() {
            s.remaining -= tok_per_lane;
        }
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= 1e-9 && finished.len() < stop_finished {
                let s = active.swap_remove(i);
                let finish_t = now + time;
                latencies.push(PromptLatency {
                    prompt_id: s.id,
                    queue_wait: (s.admit_t - s.enq_t).max(0.0),
                    e2e: (finish_t - s.enq_t).max(0.0),
                    mid_step: s.admit_t > now + 1e-12,
                });
                finished.push(s);
            } else {
                i += 1;
            }
        }
    }
    (
        GenOutcome { time, tokens, finished, idle_lane_s, peak_kv_bytes },
        RollExtra { admitted_mid, latencies },
    )
}

/// Per-step knob settings a controller arm resolved for one step — what
/// [`SimCore::step`] actually runs with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimKnobs {
    /// intra-step streaming chunk size in tokens
    pub chunk_tokens: f64,
    /// inter-step overcommit Δ (ignored by non-inter schedules)
    pub delta: usize,
    /// streamed reward-pool size
    pub reward_replicas: usize,
}

/// The simulator's stepping core: every loop-carried piece of the old
/// monolithic `simulate` (cost models, carried lanes, the arrival queue,
/// the reward process, the deterministic rng) behind a per-step API, so
/// the control loop can be driven externally — by [`simulate`]'s
/// controller arm, or one action at a time by `sim::env::PipelineEnv`
/// during Q-policy training.  Each [`SimCore::step`] runs with explicit
/// [`SimKnobs`] and publishes a [`StepTelemetry`] snapshot, the same type
/// every [`Controller`] consumes.
pub struct SimCore {
    pipeline: Pipeline,
    cfg: SimConfig,
    rng: Rng,
    reward: RewardProcess,
    log: RunLog,
    gen_cm: CostModel,
    score_cm: CostModel,
    train_cm: CostModel,
    b: usize,
    carried: Vec<GenSeq>,
    fixed_delta: usize,
    rolling: bool,
    arr: ArrivalState,
    next_id: u64,
    max_row: f64,
    elapsed: f64,
    step: u64,
    last_mean_score: f64,
    telemetry: StepTelemetry,
}

impl SimCore {
    pub fn new(pipeline: Pipeline, cfg: &SimConfig) -> Self {
        let cfg = cfg.clone();
        let su = &cfg.setup;
        let mut rng = Rng::new(cfg.seed ^ 0x51D);
        let reward = RewardProcess::new(su.reward, cfg.seed);
        let log = RunLog::new(&pipeline.name(), su.name, cfg.seed);

        let gen_cm = CostModel {
            model: su.model,
            gpu: su.cluster.gpu,
            tp: 1.0,
            software_efficiency: su.gen_eff * pipeline_gen_eff_factor(pipeline),
            iter_overhead_s: su.iter_overhead_s,
            link_gbps: 0.0,
            link_latency_s: 0.0,
        };
        let score_cm = CostModel {
            model: su.model,
            gpu: su.cluster.gpu,
            tp: su.cluster.n_score.max(1) as f64,
            software_efficiency: su.score_eff,
            iter_overhead_s: 0.0,
            link_gbps: cfg.link_gbps,
            link_latency_s: cfg.link_latency_s,
        };
        let train_cm = CostModel {
            model: su.model,
            gpu: su.cluster.gpu,
            tp: 1.0,
            software_efficiency: su.train_eff,
            iter_overhead_s: 0.0,
            link_gbps: 0.0,
            link_latency_s: 0.0,
        };

        let fixed_delta = match pipeline {
            Pipeline::Oppo { inter: true, fixed_delta: Some(d), .. } => d,
            _ => 0,
        };
        // rolling admission applies to the schedules whose generation loop
        // the coordinator owns; the VeRL/AReaL arms model other frameworks'
        // fixed dispatch and keep step-boundary admission whatever the knob
        // says
        let rolling = cfg.admission.rolling()
            && !matches!(
                pipeline,
                Pipeline::VerlDp | Pipeline::VerlDpSp | Pipeline::VerlAsyncSp | Pipeline::AReal
            );
        let arr = match cfg.admission {
            SimAdmission::RollingPoisson { rate } if rolling => {
                ArrivalState::new(cfg.admission_queue_depth, rate, &mut rng)
            }
            _ => ArrivalState {
                next: f64::INFINITY,
                queue: VecDeque::new(),
                depth: cfg.admission_queue_depth,
                dropped: 0,
            },
        };
        // densest possible KV row: a full prompt plus the longest decode
        // the length model can emit — what a dense cache must reserve per
        // lane
        let max_row = su.prompt_len + su.lengths.max_len;
        let b = su.batch;

        Self {
            pipeline,
            rng,
            reward,
            log,
            gen_cm,
            score_cm,
            train_cm,
            b,
            carried: Vec::new(),
            fixed_delta,
            rolling,
            arr,
            next_id: 0,
            max_row,
            elapsed: 0.0,
            step: 0,
            last_mean_score: 0.0,
            telemetry: StepTelemetry::default(),
            cfg,
        }
    }

    /// Resolve a controller verdict against the config defaults: `None`
    /// knobs fall back to `chunk_tokens` / the schedule's fixed Δ /
    /// `reward_replicas` from the config.
    pub fn knobs_from(&self, a: &ControlActions) -> SimKnobs {
        SimKnobs {
            chunk_tokens: a.chunk.map(|c| c as f64).unwrap_or(self.cfg.chunk_tokens),
            delta: a.delta.unwrap_or(self.fixed_delta),
            reward_replicas: a.reward_replicas.unwrap_or(self.cfg.reward_replicas),
        }
    }

    /// Knobs with no controller opinions (the config defaults).
    pub fn default_knobs(&self) -> SimKnobs {
        self.knobs_from(&ControlActions::default())
    }

    /// Telemetry snapshot of the last completed step (zeros before the
    /// first step).
    pub fn telemetry(&self) -> &StepTelemetry {
        &self.telemetry
    }

    /// Steps run so far.
    pub fn steps_run(&self) -> u64 {
        self.step
    }

    /// Consume the core, returning the accumulated run log.
    pub fn finish(self) -> RunLog {
        self.log
    }

    /// One PPO step of the schedule under the given knobs.
    pub fn step(&mut self, knobs: &SimKnobs) {
        let SimCore {
            pipeline,
            cfg,
            rng,
            reward,
            log,
            gen_cm,
            score_cm,
            train_cm,
            b,
            carried,
            rolling,
            arr,
            next_id,
            max_row,
            elapsed,
            step,
            last_mean_score,
            telemetry,
            ..
        } = self;
        let pipeline = *pipeline;
        let su = &cfg.setup;
        let b = *b;
        let rolling = *rolling;
        let max_row = *max_row;
        let step_idx = *step;
        let progress = step_idx as f64 / su.total_steps.max(1) as f64;
        let dropped_before = arr.dropped;

        // ---- admit prompts ----
        let (intra, inter) = match pipeline {
            Pipeline::Oppo { intra, inter, .. } => (intra, inter),
            _ => (false, false),
        };
        // Δ only applies to inter-step overlap; the controller arm (or the
        // schedule's fixed Δ) already resolved the value into the knobs
        let delta = if inter { knobs.delta } else { 0 };
        if !rolling {
            let want = (b + delta).saturating_sub(carried.len());
            for _ in 0..want {
                let len = su.lengths.sample(rng, progress);
                carried.push(GenSeq {
                    remaining: len,
                    total_len: len,
                    prompt: su.prompt_len,
                    enq_step: step_idx,
                    enq_t: *elapsed,
                    admit_t: *elapsed,
                    id: *next_id,
                });
                *next_id += 1;
            }
        }

        // ---- generation ----
        let shards = su.cluster.n_gen as f64;
        let lanes = (b + delta).max(1);
        let stop = if rolling || inter { b } else { carried.len() };
        let mut lane_idle_s = 0.0;
        let mut peak_kv = 0.0f64;
        let mut roll_extra = RollExtra { admitted_mid: 0, latencies: Vec::new() };
        let (mut gen_time, gen_tokens, finished) = if rolling {
            let (out, extra) = run_generation_rolling(
                carried,
                stop,
                lanes,
                gen_cm,
                shards,
                cfg.admission,
                arr,
                &su.lengths,
                progress,
                su.prompt_len,
                step_idx,
                *elapsed,
                next_id,
                rng,
                max_row,
                cfg.kv_block_tokens,
            );
            lane_idle_s = out.idle_lane_s;
            peak_kv = out.peak_kv_bytes;
            roll_extra = extra;
            (out.time, out.tokens, out.finished)
        } else {
            match pipeline {
                Pipeline::VerlDp | Pipeline::VerlDpSp | Pipeline::VerlAsyncSp => {
                    // data-parallel shards with a stage barrier at the slowest
                    let mut shard_seqs: Vec<Vec<GenSeq>> =
                        (0..su.cluster.n_gen).map(|_| Vec::new()).collect();
                    for (i, s) in carried.drain(..).enumerate() {
                        shard_seqs[i % su.cluster.n_gen].push(s);
                    }
                    let sp = matches!(pipeline, Pipeline::VerlDpSp | Pipeline::VerlAsyncSp);
                    let mut max_t = 0.0f64;
                    let mut toks = 0.0;
                    let mut fin = Vec::new();
                    let mut shard_rows: Vec<(f64, usize, f64)> = Vec::new();
                    for mut shard in shard_seqs {
                        let n = shard.len();
                        let out = run_generation(
                            &mut shard,
                            n,
                            n.max(1),
                            gen_cm,
                            1.0,
                            max_row,
                            cfg.kv_block_tokens,
                        );
                        // shards decode concurrently: their peaks add
                        peak_kv += out.peak_kv_bytes;
                        let mut t = out.time;
                        if sp {
                            // sequence parallelism accelerates the tail segment
                            // (longest-minus-median decoded at sp_gain speedup)
                            let med_frac = 0.55;
                            t = t * med_frac + t * (1.0 - med_frac) / su.sp_gain;
                        }
                        shard_rows.push((t, n, out.idle_lane_s));
                        max_t = max_t.max(t);
                        toks += out.tokens;
                        fin.extend(out.finished);
                    }
                    // barrier idle: each shard's lanes sit empty from its own
                    // finish until the slowest shard's
                    for (t, n, idle) in shard_rows {
                        lane_idle_s += idle + (max_t - t) * n as f64;
                    }
                    (max_t, toks, fin)
                }
                Pipeline::AReal => {
                    // AReaL interrupts the extreme tail (device-level rollout
                    // interruption) and resumes later — cut at ~93% completion
                    let stop_at = ((carried.len() * 97) / 100).max(1);
                    let n = carried.len().max(1);
                    let out = run_generation(
                        carried,
                        stop_at,
                        n,
                        gen_cm,
                        shards,
                        max_row,
                        cfg.kv_block_tokens,
                    );
                    lane_idle_s = out.idle_lane_s;
                    peak_kv = out.peak_kv_bytes;
                    (out.time, out.tokens, out.finished)
                }
                _ => {
                    let n = carried.len().max(1);
                    let out = run_generation(
                        carried,
                        stop,
                        n,
                        gen_cm,
                        shards,
                        max_row,
                        cfg.kv_block_tokens,
                    );
                    lane_idle_s = out.idle_lane_s;
                    peak_kv = out.peak_kv_bytes;
                    (out.time, out.tokens, out.finished)
                }
            }
        };
        let decode_wall = gen_time;

        // intra-step streaming: per-chunk dispatch overhead + colocation
        // contention inflate generation slightly (the Fig. 7b tradeoff)
        let total_tokens: f64 =
            finished.iter().map(|s| s.prompt + s.total_len).sum::<f64>().max(1.0);
        let mean_seq = total_tokens / finished.len().max(1) as f64;
        let p95_seq = {
            let mut lens: Vec<f64> =
                finished.iter().map(|s| s.prompt + s.total_len).collect();
            lens.sort_by(|a, b| a.partial_cmp(b).unwrap());
            lens.get(lens.len().saturating_sub(1).min(lens.len() * 95 / 100))
                .copied()
                .unwrap_or(mean_seq)
        };
        if intra && su.use_reward_model {
            let n_chunks = (total_tokens / knobs.chunk_tokens).max(1.0);
            gen_time += n_chunks * su.chunk_overhead_s;
            if su.cluster.colocated_scoring {
                gen_time *= 1.0 + su.colocation_contention;
            }
        }

        // ---- scoring ----
        // N sequence-affine replicas prefill disjoint lane subsets
        // concurrently through sliced [G/N, C] entries: compute divides by
        // the pool size, useful work does not, and the weight-streaming
        // floor inside `sliced_prefill` caps the division.  Only the
        // *streamed* stages are pooled in the coordinator, so non-intra
        // schedules (monolithic scoring) keep a single worker.
        let replicas = if intra { knobs.reward_replicas.max(1) as f64 } else { 1.0 };
        let reward_prefill_work =
            if su.use_reward_model { score_cm.prefill(total_tokens, mean_seq) } else { 0.0 };
        let reward_prefill = if !su.use_reward_model {
            0.0
        } else if intra && cfg.remote_replicas > 0 {
            // remote pools run masked full-shape grids — failover reroutes a
            // dead replica's lanes onto a survivor, which a compacted grid's
            // fixed row ↔ lane binding cannot express — so the pool overlaps
            // but does not divide FLOPs, and every streamed chunk pays a
            // framed round trip over the link.
            score_cm.remote_masked_prefill(total_tokens, mean_seq, knobs.chunk_tokens)
        } else {
            score_cm.sliced_prefill(total_tokens, mean_seq, replicas)
        };
        // third pipeline stage: reference-model prefill, costed separately
        // from the actor-colocated value prefill (their sum equals the old
        // combined ref+value term exactly).  The ref pool divides the same
        // way the reward pool does; value keeps its single actor-colocated
        // worker.
        let ref_replicas = if intra { cfg.ref_replicas.max(1) as f64 } else { 1.0 };
        let ref_prefill_work =
            train_cm.prefill(total_tokens, mean_seq) / su.cluster.n_gen as f64;
        let ref_prefill = train_cm.sliced_prefill(total_tokens, mean_seq, ref_replicas)
            / su.cluster.n_gen as f64;
        let value_prefill = ref_prefill_work;
        let ref_value_prefill = ref_prefill + value_prefill;
        let (exposed_reward, hidden_reward) = if intra && su.use_reward_model {
            // streamed scoring drains during the generation window.  Exposed:
            // (a) the final chunk of the last straggler, and (b) sequences
            // shorter than one chunk, which cannot stream incrementally at
            // all — the Fig. 7b right-side penalty.
            let coarse_frac = (0.8 * knobs.chunk_tokens / p95_seq).clamp(0.0, 1.0);
            let last_chunk = score_cm.prefill(knobs.chunk_tokens.min(mean_seq), mean_seq);
            let exposed = (reward_prefill * coarse_frac + last_chunk).min(reward_prefill);
            let hidden = (reward_prefill - exposed).min(gen_time);
            (reward_prefill - hidden, hidden)
        } else {
            (reward_prefill, 0.0)
        };
        let (exposed_rv, _hidden_rv) = if intra {
            let hidden = (0.85 * ref_value_prefill).min((gen_time - hidden_reward).max(0.0));
            (ref_value_prefill - hidden, hidden)
        } else {
            (ref_value_prefill, 0.0)
        };
        let score_time = exposed_reward + exposed_rv;

        // ---- training ----
        let train_time = train_cm.train_step(
            total_tokens,
            su.cluster.n_gen as f64,
            su.cluster.train_network_gbps(),
        );

        // ---- compose step latency by schedule ----
        // inter-step overlap hides most of the fixed overhead (weight
        // sync/broadcast proceeds while carried lanes keep decoding)
        let const_s = if inter { su.step_const_s * 0.4 } else { su.step_const_s };
        let (step_time, staleness) = match pipeline {
            Pipeline::TrlSequential
            | Pipeline::VerlDp
            | Pipeline::VerlDpSp
            | Pipeline::Oppo { .. } => {
                (gen_time + score_time + train_time + const_s, 0.0)
            }
            Pipeline::AsyncStale { k } => {
                let t = gen_time.max(score_time + train_time) + const_s;
                (t, k as f64)
            }
            Pipeline::VerlAsyncSp => {
                (gen_time.max(score_time + train_time) + const_s, 1.0)
            }
            Pipeline::AReal => {
                // interruptible async generation with sync/recovery overhead
                let t = (gen_time.max(score_time + train_time)) * (1.0 + su.areal_sync_overhead)
                    + const_s;
                (t, 1.0)
            }
        };

        // ---- utilization (nvidia-smi-style activity model; Fig. 2a/5) ----
        // decode activity: intrinsically low (bandwidth-bound) and further
        // diluted as lanes drain during the tail
        let gen_iters = finished
            .iter()
            .map(|s| s.total_len)
            .fold(0.0f64, f64::max)
            .max(1.0);
        let act_frac = (gen_tokens / (gen_iters * (b + delta) as f64)).clamp(0.05, 1.0);
        let decode_act = 0.95 * (0.15 + 0.85 * act_frac);
        let n_gen = su.cluster.n_gen as f64;
        let n_score = su.cluster.n_score as f64;
        let total_gpus = su.cluster.total_gpus() as f64;
        let mut busy = gen_time * n_gen * decode_act;
        // hidden/exposed are wall-time; × replicas recovers the conserved
        // total scoring work the pool performed
        busy += hidden_reward * replicas * n_score.max(1.0) * 0.85; // streamed scoring inside gen window
        busy += exposed_reward * replicas * n_score.max(1.0) * 0.85;
        // ref+value busy from conserved work (not wall): the ref pool's
        // replicas jointly perform ref_prefill_work whatever the pool size
        busy += (ref_prefill_work + value_prefill) * n_gen * 0.75;
        busy += train_time * n_gen * 0.70;
        busy += const_s * total_gpus * 0.05;
        let util_val = (busy / (step_time * total_gpus)).min(1.0);

        // ---- reward process ----
        let deferrals: Vec<u64> =
            finished.iter().map(|s| step_idx.saturating_sub(s.enq_step)).collect();
        let mean_deferral =
            deferrals.iter().sum::<u64>() as f64 / deferrals.len().max(1) as f64;
        for &d in &deferrals {
            log.record_deferral(d);
        }
        // OPPO's first-B selection induces a tiny, bounded composition bias
        let bias = if inter { 0.01 * mean_deferral } else { 0.0 };
        let mean_score = reward.advance(staleness, bias);

        *elapsed += step_time;
        // busy/idle follow the StageTiming contract: both are summed across
        // a pool's replicas, so a pooled row's wall budget is
        // replicas × step_time (keeps busy/(busy+idle) a true utilization)
        let stage_row = |name: &str, replicas: usize, busy: f64, items: u64| StageTiming {
            name: name.to_string(),
            replicas,
            busy_s: busy,
            idle_s: (replicas as f64 * step_time - busy).max(0.0),
            items,
        };
        let n_fin = finished.len() as u64;
        let lane_idle_frac =
            (lane_idle_s / (lanes as f64 * decode_wall).max(1e-12)).clamp(0.0, 1.0);
        let queue_dropped = (arr.dropped - dropped_before) as usize;
        let (queue_wait_p99, e2e_p99) = {
            let mut qs: Vec<f64> = roll_extra.latencies.iter().map(|l| l.queue_wait).collect();
            let mut es: Vec<f64> = roll_extra.latencies.iter().map(|l| l.e2e).collect();
            (pct_sorted(&mut qs, 99), pct_sorted(&mut es, 99))
        };
        log.push(StepRecord {
            step: step_idx,
            wall_s: step_time,
            elapsed_s: *elapsed,
            mean_score,
            delta,
            chunk: knobs.chunk_tokens as usize,
            finished: finished.len(),
            deferred: carried.len(),
            gen_tokens: gen_tokens as usize,
            train_stats: [0.0; 6],
            util: util_val,
            stages: vec![
                stage_row("actor", 1, gen_time, n_fin),
                stage_row("reward", replicas as usize, reward_prefill_work, n_fin),
                stage_row("ref", ref_replicas as usize, ref_prefill_work, n_fin),
                stage_row("value", 1, value_prefill, n_fin),
                stage_row("train", 1, train_time, 1),
            ],
            prompt_latencies: roll_extra.latencies,
            lane_idle_frac,
            admitted_mid_step: roll_extra.admitted_mid,
            queue_dropped,
            peak_kv_bytes: peak_kv as u64,
        });

        // the observation every Controller implementation sees for this step
        *telemetry = StepTelemetry {
            step: step_idx,
            wall_s: step_time,
            mean_reward: mean_score,
            reward_trend: if step_idx == 0 { 0.0 } else { mean_score - *last_mean_score },
            util: util_val,
            lane_idle_frac,
            queue_depth: arr.queue.len(),
            queue_dropped,
            finished: n_fin as usize,
            gen_tokens: gen_tokens as usize,
            chunk: knobs.chunk_tokens as usize,
            delta,
            mean_seq_len: mean_seq,
            p95_seq_len: p95_seq,
            queue_wait_p99,
            e2e_p99,
        };
        *last_mean_score = mean_score;
        *step += 1;

        // non-inter pipelines never carry work across steps (except AReaL,
        // whose interrupted rollouts resume, and rolling admission, whose
        // mid-step admits are partial work by design)
        if !inter && !rolling && !matches!(pipeline, Pipeline::AReal) {
            carried.clear();
        }
    }
}

/// In-place percentile over an unsorted slice (0 for an empty one).
fn pct_sorted(xs: &mut [f64], q: usize) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() - 1) * q) / 100]
}

/// Widest reward-pool size the learned controller may explore when the
/// config doesn't already ask for more.
const MAX_LEARNED_REPLICAS: usize = 4;

/// Index of the configured `chunk_tokens` inside [`chunk_candidates`] — the
/// learned arm's starting chunk.
pub const DEFAULT_CHUNK_IDX: usize = 2;

/// Chunk-size grid the learned arm walks: the configured `chunk_tokens`
/// bracketed by two halvings and two doublings (the Fig. 7b sweep axis),
/// clamped at 1 token on the low end.
pub fn chunk_candidates(cfg: &SimConfig) -> Vec<usize> {
    let c = cfg.chunk_tokens.max(1.0) as usize;
    vec![(c / 4).max(1), (c / 2).max(1), c, c * 2, c * 4]
}

/// Knob bounds the learned arm must respect under this config.
pub fn learned_bounds(cfg: &SimConfig, n_chunks: usize) -> KnobBounds {
    KnobBounds {
        n_chunks,
        delta_min: 0,
        delta_max: cfg.delta_max,
        min_replicas: 1,
        max_replicas: cfg.reward_replicas.max(MAX_LEARNED_REPLICAS),
    }
}

/// Build the controller arm [`simulate`] drives: the paper's heuristics
/// (dynamic Δ for inter-enabled OPPO, config defaults otherwise) or a
/// frozen learned Q-policy ([`SimController::Learned`]).
pub fn build_controller(pipeline: Pipeline, cfg: &SimConfig) -> Box<dyn Controller> {
    match &cfg.controller {
        SimController::Learned(policy) => {
            let candidates = chunk_candidates(cfg);
            let bounds = learned_bounds(cfg, candidates.len());
            let initial = KnobState {
                chunk_idx: DEFAULT_CHUNK_IDX,
                delta_level: crate::ctl::level_of((cfg.delta_max / 2).max(1), &bounds),
                replicas: cfg.reward_replicas.max(1),
            };
            Box::new(
                LearnedController::new(policy.clone(), candidates, bounds, initial)
                    .expect("sim chunk grid always matches its bounds"),
            )
        }
        SimController::Heuristic => match pipeline {
            Pipeline::Oppo { inter: true, fixed_delta: None, .. } => {
                Box::new(HeuristicController::delta_only(DeltaController::new(
                    (cfg.delta_max / 2).max(1),
                    0,
                    cfg.delta_max,
                    cfg.window,
                    cfg.delta_policy,
                )))
            }
            _ => Box::new(HeuristicController::default()),
        },
    }
}

/// Simulate `cfg.steps` PPO steps of `pipeline`; returns a [`RunLog`] whose
/// `wall_s` is simulated seconds.  The control loop is explicit: a
/// [`Controller`] (heuristic or learned, per `cfg.controller`) observes
/// each step's [`StepTelemetry`] and its actions become the next step's
/// [`SimKnobs`].
pub fn simulate(pipeline: Pipeline, cfg: &SimConfig) -> RunLog {
    let mut ctl = build_controller(pipeline, cfg);
    let mut core = SimCore::new(pipeline, cfg);
    for _ in 0..cfg.steps {
        let knobs = core.knobs_from(&ctl.actions());
        core.step(&knobs);
        ctl.observe(core.telemetry());
    }
    core.finish()
}

/// Framework-level generation efficiency relative to the setup baseline
/// (TRL's HF-generate loop is the 1.0 reference; vLLM-based stacks decode
/// considerably faster, which Table 4 prices in).
fn pipeline_gen_eff_factor(p: Pipeline) -> f64 {
    match p {
        Pipeline::VerlDp | Pipeline::VerlDpSp | Pipeline::VerlAsyncSp | Pipeline::AReal => 1.35,
        _ => 1.0,
    }
}

/// Sweep reward-replica counts and return the smallest pool size at which
/// streamed scoring is **actor-bound**: adding one more replica improves
/// OPPO's steady-state step latency by less than `tol` (relative).  This is
/// the planning question the replica pool answers — "how many scorer
/// replicas until the actor is the bottleneck again?"  With lane-sliced
/// entries the sweep prices per-replica compute as `G/N` through
/// [`CostModel::sliced_prefill`], so the returned knee also reflects the
/// weight-streaming floor that slicing cannot divide — whichever bound
/// (actor window or bandwidth floor) binds first ends the sweep.  Returns
/// `max_replicas` if the knee is never reached within the sweep.
pub fn min_replicas_actor_bound(cfg: &SimConfig, max_replicas: usize, tol: f64) -> usize {
    let lat = |n: usize| {
        let mut c = cfg.clone();
        c.reward_replicas = n;
        steady_state_latency(&simulate(Pipeline::oppo(), &c))
    };
    let mut prev = lat(1);
    for r in 2..=max_replicas {
        let cur = lat(r);
        if (prev - cur) / prev.max(1e-12) < tol {
            return r - 1;
        }
        prev = cur;
    }
    max_replicas.max(1)
}

/// Mean per-step latency over the last half of a run (warm steady state).
pub fn steady_state_latency(log: &RunLog) -> f64 {
    let n = log.records.len();
    let tail = &log.records[n / 2..];
    tail.iter().map(|r| r.wall_s).sum::<f64>() / tail.len().max(1) as f64
}

/// Mean utilization over the last half of a run.
pub fn steady_state_util(log: &RunLog) -> f64 {
    let n = log.records.len();
    let tail = &log.records[n / 2..];
    tail.iter().map(|r| r.util).sum::<f64>() / tail.len().max(1) as f64
}

/// `(dense, paged)` bound on concurrently resident lanes for a setup: KV
/// budget is the gen pool's HBM minus one weight replica per GPU; a dense
/// lane commits the worst-case `prompt + max_len` row for its whole life
/// while a paged lane commits only its block-rounded median context — the
/// "scale lanes, not memory" headline number for the bench harness.
pub fn kv_lane_bounds(cfg: &SimConfig, block_tokens: f64) -> (f64, f64) {
    let su = &cfg.setup;
    let cm = CostModel {
        model: su.model,
        gpu: su.cluster.gpu,
        tp: 1.0,
        software_efficiency: su.gen_eff,
        iter_overhead_s: su.iter_overhead_s,
        link_gbps: 0.0,
        link_latency_s: 0.0,
    };
    let per_gpu = (su.cluster.gpu.mem_gb * 1e9 - su.model.weight_bytes()).max(0.0);
    let budget = per_gpu * su.cluster.n_gen as f64;
    let mean_ctx = su.prompt_len + su.lengths.median(0.5);
    let max_row = su.prompt_len + su.lengths.max_len;
    (
        cm.max_concurrent_lanes(budget, mean_ctx, max_row, 0.0),
        cm.max_concurrent_lanes(budget, mean_ctx, max_row, block_tokens),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::presets;

    fn quick(pipeline: Pipeline, steps: usize, seed: u64) -> RunLog {
        let cfg = SimConfig::new(presets::stackex_7b_h200(), steps, seed);
        simulate(pipeline, &cfg)
    }

    #[test]
    fn oppo_steps_are_faster_than_trl() {
        let trl = quick(Pipeline::TrlSequential, 60, 1);
        let oppo = quick(Pipeline::oppo(), 60, 1);
        let ratio = steady_state_latency(&trl) / steady_state_latency(&oppo);
        assert!(
            (1.5..4.0).contains(&ratio),
            "per-step speedup {ratio} out of the paper's plausible band"
        );
    }

    #[test]
    fn ablations_order_correctly() {
        // paper Fig. 6: inter-only > intra-only, both > 1, full > each
        let trl = steady_state_latency(&quick(Pipeline::TrlSequential, 60, 2));
        let intra = steady_state_latency(&quick(
            Pipeline::Oppo { intra: true, inter: false, fixed_delta: None },
            60,
            2,
        ));
        let inter = steady_state_latency(&quick(
            Pipeline::Oppo { intra: false, inter: true, fixed_delta: None },
            60,
            2,
        ));
        let full = steady_state_latency(&quick(Pipeline::oppo(), 60, 2));
        assert!(trl / intra > 1.05, "intra-only speedup {}", trl / intra);
        assert!(trl / inter > trl / intra, "inter should beat intra");
        assert!(trl / full >= trl / inter * 0.98, "full {} vs inter {}", trl / full, trl / inter);
    }

    #[test]
    fn oppo_improves_utilization() {
        let trl = steady_state_util(&quick(Pipeline::TrlSequential, 60, 3));
        let oppo = steady_state_util(&quick(Pipeline::oppo(), 60, 3));
        assert!(oppo > trl * 1.2, "util {trl} -> {oppo}");
    }

    #[test]
    fn async_staleness_hurts_final_reward() {
        let sync = quick(Pipeline::TrlSequential, 600, 4);
        let stale = quick(Pipeline::AsyncStale { k: 5 }, 600, 4);
        let last = |l: &RunLog| l.records.last().unwrap().mean_score;
        assert!(last(&stale) < last(&sync) - 0.05, "{} vs {}", last(&stale), last(&sync));
    }

    #[test]
    fn oppo_preserves_step_to_reward() {
        let trl = quick(Pipeline::TrlSequential, 400, 5);
        let oppo = quick(Pipeline::oppo(), 400, 5);
        let t = trl.step_to_reward(3.5, 5);
        let o = oppo.step_to_reward(3.5, 5);
        let (t, o) = (t.expect("trl reaches 3.5") as f64, o.expect("oppo reaches 3.5") as f64);
        assert!((o - t).abs() / t < 0.25, "step-to-reward diverged: trl {t} oppo {o}");
    }

    #[test]
    fn most_requests_not_deferred() {
        let oppo = quick(Pipeline::oppo(), 200, 6);
        let (rows, mean) = oppo.deferral_distribution();
        assert!(!rows.is_empty());
        let zero_share = rows.iter().find(|(k, _)| *k == 0).map(|(_, s)| *s).unwrap_or(0.0);
        assert!(zero_share > 0.6, "zero-deferral share {zero_share}");
        assert!(mean < 1.0, "mean deferral {mean}");
    }

    #[test]
    fn table4_ordering() {
        let lat = |p| steady_state_latency(&quick(p, 60, 7));
        let dp = lat(Pipeline::VerlDp);
        let dpsp = lat(Pipeline::VerlDpSp);
        let areal = lat(Pipeline::AReal);
        let oppo = lat(Pipeline::oppo());
        assert!(dp > dpsp, "DP {dp} !> DP+SP {dpsp}");
        assert!(dpsp > areal, "DP+SP {dpsp} !> AReaL {areal}");
        assert!(areal > oppo, "AReaL {areal} !> OPPO {oppo}");
    }

    #[test]
    fn step_records_carry_per_stage_attribution() {
        let log = quick(Pipeline::oppo(), 20, 11);
        for r in &log.records {
            let names: Vec<&str> = r.stages.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(names, vec!["actor", "reward", "ref", "value", "train"]);
            for st in &r.stages {
                assert!(st.busy_s >= 0.0 && st.idle_s >= 0.0, "{st:?}");
                assert!(
                    st.busy_s <= r.wall_s + 1e-9,
                    "stage {} busy {} exceeds step {}",
                    st.name, st.busy_s, r.wall_s
                );
            }
        }
    }

    #[test]
    fn reward_replicas_cut_exposed_scoring_until_actor_bound() {
        let base = SimConfig::new(presets::stackex_7b_h200(), 60, 13);
        let lat = |n: usize| {
            let mut c = base.clone();
            c.reward_replicas = n;
            steady_state_latency(&simulate(Pipeline::oppo(), &c))
        };
        let l1 = lat(1);
        let l2 = lat(2);
        let l16 = lat(16);
        assert!(l2 < l1, "2 replicas must beat 1: {l1} -> {l2}");
        assert!(l16 <= l2, "more replicas never slow the step: {l2} -> {l16}");
        // the knee exists and marks where streaming goes actor-bound: the
        // next replica past it buys less than the tolerance
        let knee = min_replicas_actor_bound(&base, 16, 0.01);
        assert!((1..=16).contains(&knee), "knee {knee}");
        let (lk, lk1) = (lat(knee), lat(knee + 1));
        assert!(
            (lk - lk1) / lk < 0.01,
            "one replica past the knee ({knee}) still bought {:.3}%",
            100.0 * (lk - lk1) / lk
        );
    }

    #[test]
    fn replicas_do_not_speed_up_non_streamed_baselines() {
        // only the streamed reward stage is pooled; monolithic baselines
        // keep their single scorer whatever the knob says
        let mut cfg = SimConfig::new(presets::stackex_7b_h200(), 30, 17);
        let base = steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg));
        cfg.reward_replicas = 8;
        let pooled = steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg));
        assert_eq!(base, pooled, "baseline latency must ignore reward_replicas");
    }

    #[test]
    fn replica_pool_conserves_scoring_work_in_step_records() {
        let mut cfg = SimConfig::new(presets::stackex_7b_h200(), 20, 11);
        cfg.reward_replicas = 4;
        let pooled = simulate(Pipeline::oppo(), &cfg);
        cfg.reward_replicas = 1;
        let single = simulate(Pipeline::oppo(), &cfg);
        for (p, s) in pooled.records.iter().zip(&single.records) {
            let find = |log: &StepRecord, name: &str| -> StageTiming {
                log.stages.iter().find(|st| st.name == name).unwrap().clone()
            };
            let rp = find(p, "reward");
            let rs = find(s, "reward");
            assert_eq!(rp.replicas, 4);
            assert_eq!(rs.replicas, 1);
            // busy records total pool work, which replication must conserve
            assert!((rp.busy_s - rs.busy_s).abs() < 1e-9, "{} vs {}", rp.busy_s, rs.busy_s);
            // and the pooled step is never slower
            assert!(p.wall_s <= s.wall_s + 1e-9);
        }
    }

    #[test]
    fn ref_replicas_divide_ref_prefill_like_the_reward_pool() {
        let base = SimConfig::new(presets::stackex_7b_h200(), 60, 19);
        let lat = |n: usize| {
            let mut c = base.clone();
            c.ref_replicas = n;
            steady_state_latency(&simulate(Pipeline::oppo(), &c))
        };
        let l1 = lat(1);
        let l4 = lat(4);
        assert!(l4 < l1, "4 ref replicas must beat 1: {l1} -> {l4}");
        let l16 = lat(16);
        assert!(l16 <= l4, "more ref replicas never slow the step: {l4} -> {l16}");
    }

    #[test]
    fn ref_replicas_do_not_speed_up_non_streamed_baselines() {
        let mut cfg = SimConfig::new(presets::stackex_7b_h200(), 30, 17);
        let base = steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg));
        cfg.ref_replicas = 6;
        let pooled = steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg));
        assert_eq!(base, pooled, "baseline latency must ignore ref_replicas");
    }

    #[test]
    fn ref_pool_conserves_prefill_work_and_records_replicas() {
        let mut cfg = SimConfig::new(presets::stackex_7b_h200(), 20, 23);
        cfg.ref_replicas = 3;
        let pooled = simulate(Pipeline::oppo(), &cfg);
        cfg.ref_replicas = 1;
        let single = simulate(Pipeline::oppo(), &cfg);
        for (p, s) in pooled.records.iter().zip(&single.records) {
            let find = |r: &StepRecord, name: &str| -> StageTiming {
                r.stages.iter().find(|st| st.name == name).unwrap().clone()
            };
            let rp = find(p, "ref");
            let rs = find(s, "ref");
            assert_eq!(rp.replicas, 3);
            assert_eq!(rs.replicas, 1);
            // busy records total pool work, which replication must conserve
            assert!((rp.busy_s - rs.busy_s).abs() < 1e-9, "{} vs {}", rp.busy_s, rs.busy_s);
            assert!(p.wall_s <= s.wall_s + 1e-9);
        }
    }

    #[test]
    fn remote_reward_arm_pays_wire_cost_but_keeps_the_overlap_win() {
        let base = SimConfig::new(presets::stackex_7b_h200(), 60, 31);
        let lat = |c: &SimConfig| steady_state_latency(&simulate(Pipeline::oppo(), c));
        let mut local = base.clone();
        local.reward_replicas = 2;
        let mut remote = base.clone().remote(2, 100.0, 5e-5);
        remote.reward_replicas = 2;
        let (l, r) = (lat(&local), lat(&remote));
        // masked full-shape grids + per-chunk framing: the remote pool
        // overlaps but does not divide FLOPs, so it can only be slower
        assert!(r >= l, "remote arm cannot beat local slicing: {l} -> {r}");
        // ...yet still far better than giving up the intra-step overlap
        let trl = steady_state_latency(&simulate(Pipeline::TrlSequential, &base));
        assert!(r < trl, "remote streaming must still beat sequential: {r} vs {trl}");
        // a fatter link converges toward the local masked cost
        let mut fat = base.clone().remote(2, 100_000.0, 1e-7);
        fat.reward_replicas = 2;
        let f = lat(&fat);
        assert!(f <= r, "more bandwidth never slows the step: {r} -> {f}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = quick(Pipeline::oppo(), 30, 9);
        let b = quick(Pipeline::oppo(), 30, 9);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.wall_s, y.wall_s);
            assert_eq!(x.mean_score, y.mean_score);
        }
    }

    fn tail_mean(log: &RunLog, f: impl Fn(&StepRecord) -> f64) -> f64 {
        let n = log.records.len();
        let tail = &log.records[n / 2..];
        tail.iter().map(f).sum::<f64>() / tail.len().max(1) as f64
    }

    #[test]
    fn rolling_saturated_eliminates_lane_idle_and_decodes_more() {
        let base = SimConfig::new(presets::stackex_7b_h200(), 40, 29);
        let step_sync = simulate(Pipeline::oppo(), &base);
        let rolling = simulate(Pipeline::oppo(), &base.clone().rolling_saturated());
        let idle_sync = tail_mean(&step_sync, |r| r.lane_idle_frac);
        let idle_roll = tail_mean(&rolling, |r| r.lane_idle_frac);
        assert!(idle_sync > 0.0, "step-sync drains lanes toward the stop target");
        assert!(
            idle_roll < idle_sync,
            "rolling admission must cut lane idle: {idle_sync} -> {idle_roll}"
        );
        assert!(idle_roll < 1e-9, "saturated refill keeps every lane busy");
        // full lanes decode more tokens per step (the reclaimed capacity)
        let tok_sync = tail_mean(&step_sync, |r| r.gen_tokens as f64);
        let tok_roll = tail_mean(&rolling, |r| r.gen_tokens as f64);
        assert!(tok_roll > tok_sync, "reclaimed lanes must decode: {tok_sync} -> {tok_roll}");
        // saturated arrivals: admission happens the instant a lane frees
        assert!(tail_mean(&rolling, |r| r.admitted_mid_step as f64) > 0.0);
        assert!(tail_mean(&rolling, |r| {
            r.prompt_latencies.iter().map(|l| l.queue_wait).sum::<f64>()
        }) == 0.0);
    }

    #[test]
    fn rolling_poisson_reports_slo_percentiles() {
        let su = presets::traffic_7b_h200();
        let rate = su.arrival_rate;
        let cfg = SimConfig::new(su, 40, 31).rolling_poisson(rate);
        let log = simulate(Pipeline::oppo(), &cfg);
        let slo = log.slo_summary().expect("rolling poisson must record latencies");
        assert!(slo.prompts > 0);
        assert!(slo.queue_wait_p99 >= slo.queue_wait_p50);
        assert!(slo.e2e_p99 >= slo.e2e_p50);
        assert!(slo.e2e_p50 > 0.0, "end-to-end latency must be positive");
        // queueing delay is real under calibrated traffic — but only at the
        // tail: arrivals queue during score/train dead time, while the
        // median prompt lands in a free lane the instant it arrives
        assert!(slo.queue_wait_p99 > 0.0, "p99 queue wait {}", slo.queue_wait_p99);
        assert!(slo.queue_wait_p99 > slo.queue_wait_p50);
        // the traffic preset offers 1.5 prompts/s against ~2.6/s of decode
        // capacity, so the run is arrival-bound: completions track the
        // Poisson rate and the depth-256 queue never sheds.  Lane idle here
        // is arrival starvation, not scheduler inefficiency, so no idle
        // ordering vs the step-sync loop is asserted — that property only
        // holds when arrivals saturate, and
        // `rolling_saturated_eliminates_lane_idle_and_decodes_more` pins it
        // in that regime.
        let elapsed: f64 = log.records.iter().map(|r| r.wall_s).sum();
        let thr = slo.prompts as f64 / elapsed.max(1e-12);
        assert!(
            thr > 0.9 * rate && thr <= 1.05 * rate,
            "undersaturated run must complete at the offered rate: {thr} vs {rate}"
        );
        let dropped: usize = log.records.iter().map(|r| r.queue_dropped).sum();
        assert_eq!(dropped, 0, "depth-256 queue must not shed at 1.5 prompts/s");
    }

    #[test]
    fn rolling_poisson_bounded_queue_sheds_under_overload() {
        let mut su = presets::traffic_7b_h200();
        su.arrival_rate *= 50.0; // crush the queue
        let rate = su.arrival_rate;
        let mut cfg = SimConfig::new(su, 20, 37).rolling_poisson(rate);
        cfg.admission_queue_depth = 64;
        let log = simulate(Pipeline::oppo(), &cfg);
        let dropped: usize = log.records.iter().map(|r| r.queue_dropped).sum();
        assert!(dropped > 0, "overload with a depth-64 queue must shed prompts");
    }

    #[test]
    fn rolling_is_deterministic_per_seed() {
        let su = presets::traffic_7b_h200();
        let rate = su.arrival_rate;
        let mk = || {
            let cfg = SimConfig::new(presets::traffic_7b_h200(), 25, 41).rolling_poisson(rate);
            simulate(Pipeline::oppo(), &cfg)
        };
        let a = mk();
        let b = mk();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.wall_s, y.wall_s);
            assert_eq!(x.prompt_latencies, y.prompt_latencies);
            assert_eq!(x.queue_dropped, y.queue_dropped);
        }
    }

    #[test]
    fn verl_arms_ignore_the_admission_knob() {
        let base = SimConfig::new(presets::stackex_7b_h200(), 20, 43);
        let a = simulate(Pipeline::VerlDp, &base);
        let b = simulate(Pipeline::VerlDp, &base.clone().rolling_saturated());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.wall_s, y.wall_s, "VeRL arms model fixed dispatch");
        }
    }

    #[test]
    fn paged_arm_same_schedule_less_kv() {
        // paging is a memory discipline, not a scheduling one: the paged
        // arm must reproduce the dense arm's timing and token counts
        // exactly while committing far less peak KV (the ISSUE's >= 40%
        // reduction at equal streamed-chunk throughput, on the traffic
        // preset under rolling Poisson admission)
        let su = presets::traffic_7b_h200();
        let rate = su.arrival_rate;
        let dense_cfg = SimConfig::new(su, 30, 47).rolling_poisson(rate);
        let paged_cfg = dense_cfg.clone().paged(64.0);
        let dense = simulate(Pipeline::oppo(), &dense_cfg);
        let paged = simulate(Pipeline::oppo(), &paged_cfg);
        assert_eq!(dense.records.len(), paged.records.len());
        let mut dense_peak = 0u64;
        let mut paged_peak = 0u64;
        for (d, p) in dense.records.iter().zip(&paged.records) {
            assert_eq!(d.wall_s, p.wall_s, "paging must not change the schedule");
            assert_eq!(d.gen_tokens, p.gen_tokens, "paging must not change throughput");
            dense_peak = dense_peak.max(d.peak_kv_bytes);
            paged_peak = paged_peak.max(p.peak_kv_bytes);
        }
        assert!(dense_peak > 0 && paged_peak > 0, "both arms must report peak KV");
        assert!(
            (paged_peak as f64) <= 0.6 * dense_peak as f64,
            "paged peak {paged_peak} not <= 60% of dense {dense_peak}"
        );
    }

    #[test]
    fn paged_lane_bound_exceeds_dense() {
        // the headline of the PR: with block-rounded commitment the same
        // HBM budget holds strictly more concurrent lanes than the dense
        // one-full-row-per-lane bound
        let cfg = SimConfig::new(presets::traffic_7b_h200(), 10, 7);
        let (dense, paged) = kv_lane_bounds(&cfg, 64.0);
        assert!(dense >= 1.0, "H200 must hold at least one dense lane");
        assert!(
            paged > dense,
            "paged lane bound {paged} must exceed dense {dense}"
        );
    }
}
