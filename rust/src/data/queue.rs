//! Bounded prompt queue with pluggable arrival processes — the admission
//! front-end of the rolling (continuous-batching) scheduler.
//!
//! The step-synchronous loop pulled straight from [`PromptSampler`] at step
//! boundaries; rolling admission instead drains this queue the moment a
//! lane frees up mid-generation.  Time is measured in **chunk ticks** (one
//! tick per `actor_generate_chunk` call — the scheduler's only clock), so
//! per-prompt queue-wait is exactly "ticks between arrival and admission".
//!
//! Two arrival processes:
//!
//! * [`Arrivals::Saturated`] — training parity: a fresh prompt is always
//!   available the instant a lane asks for one, with zero queue wait.
//!   Prompts are synthesized on demand from the sampler, so the sampled
//!   prompt stream is identical to the legacy direct-pull loop.
//! * [`Arrivals::Poisson`] — traffic simulation: prompts arrive at
//!   `rate` per tick (Knuth sampling over the deterministic [`Rng`]);
//!   the queue is bounded at `depth` and arrivals past the bound are
//!   *dropped* (counted, reported per step) — serving semantics, where
//!   backpressure at admission is load shedding, not a deadlock.

use std::collections::VecDeque;

use crate::data::sampler::PromptSampler;
use crate::data::tasks::Prompt;
use crate::util::rng::Rng;

/// Prompt arrival process driving the queue.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrivals {
    /// A prompt is always available on demand (zero queue wait).
    Saturated,
    /// Poisson arrivals at `rate` prompts per chunk tick.
    Poisson { rate: f64 },
}

/// A prompt waiting for a lane, stamped with its arrival tick.
#[derive(Clone, Debug)]
pub struct QueuedPrompt {
    pub prompt: Prompt,
    pub enqueued_tick: u64,
}

/// Bounded FIFO prompt queue fed by an arrival process.
pub struct PromptQueue {
    sampler: PromptSampler,
    arrivals: Arrivals,
    depth: usize,
    queue: VecDeque<QueuedPrompt>,
    rng: Rng,
    /// last tick whose arrivals have been materialized
    tick_seen: u64,
    /// total prompts that arrived (admitted to the queue)
    arrived: u64,
    /// arrivals shed because the queue was full
    dropped_bound: u64,
    /// arrivals shed by the admission-time length guard: the prompt could
    /// never finish within the lane budget (`prompt_len + max_new > s_max`)
    dropped_oversize: u64,
    /// max admissible prompt tokens (`s_max - max_new`); `usize::MAX`
    /// until [`Self::set_length_guard`] installs the bound
    max_prompt_tokens: usize,
}

impl PromptQueue {
    pub fn new(sampler: PromptSampler, arrivals: Arrivals, depth: usize, seed: u64) -> Self {
        assert!(depth >= 1, "queue depth must be >= 1");
        if let Arrivals::Poisson { rate } = arrivals {
            assert!(rate > 0.0, "poisson arrival rate must be > 0");
        }
        Self {
            sampler,
            arrivals,
            depth,
            queue: VecDeque::new(),
            rng: Rng::new(seed ^ 0x61726976), // "ariv"
            tick_seen: 0,
            arrived: 0,
            dropped_bound: 0,
            dropped_oversize: 0,
            max_prompt_tokens: usize::MAX,
        }
    }

    /// Install the admission-time length guard: prompts longer than
    /// `max_prompt_tokens` (i.e. `prompt_len + max_new > s_max`) are shed
    /// at enqueue with their own drop reason, instead of wasting a lane and
    /// failing the mid-chunk clamp check after admission.
    pub fn set_length_guard(&mut self, max_prompt_tokens: usize) {
        assert!(max_prompt_tokens >= 1, "length guard must admit some prompt");
        self.max_prompt_tokens = max_prompt_tokens;
    }

    /// Materialize all arrivals up to and including `tick`.  No-op for
    /// `Saturated` (prompts are synthesized on demand in [`Self::pop`]).
    pub fn advance_to(&mut self, tick: u64) {
        let Arrivals::Poisson { rate } = self.arrivals else {
            self.tick_seen = self.tick_seen.max(tick);
            return;
        };
        while self.tick_seen < tick {
            self.tick_seen += 1;
            for _ in 0..poisson(&mut self.rng, rate) {
                if self.queue.len() >= self.depth {
                    self.dropped_bound += 1;
                    continue;
                }
                let prompt = self.sampler.next();
                if prompt.tokens.len() > self.max_prompt_tokens {
                    self.dropped_oversize += 1;
                    continue;
                }
                self.queue.push_back(QueuedPrompt {
                    prompt,
                    enqueued_tick: self.tick_seen,
                });
                self.arrived += 1;
            }
        }
    }

    /// Is a prompt available right now (without advancing time)?
    pub fn has_prompt(&self) -> bool {
        match self.arrivals {
            Arrivals::Saturated => true,
            Arrivals::Poisson { .. } => !self.queue.is_empty(),
        }
    }

    /// Take the next prompt, FIFO.  `tick` is the current chunk tick; the
    /// returned stamp is the prompt's arrival tick (== `tick` under
    /// `Saturated`, so its queue wait is zero by construction).
    pub fn pop(&mut self, tick: u64) -> Option<QueuedPrompt> {
        match self.arrivals {
            Arrivals::Saturated => {
                // synthesized on demand: shed oversize draws like Poisson
                // enqueue does, with a retry bound so a sampler that only
                // produces oversize prompts cannot spin forever
                for _ in 0..64 {
                    let prompt = self.sampler.next();
                    if prompt.tokens.len() > self.max_prompt_tokens {
                        self.dropped_oversize += 1;
                        continue;
                    }
                    self.arrived += 1;
                    return Some(QueuedPrompt { prompt, enqueued_tick: tick });
                }
                None
            }
            Arrivals::Poisson { .. } => self.queue.pop_front(),
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn arrivals(&self) -> Arrivals {
        self.arrivals
    }

    /// Total prompts shed so far, for any reason (queue bound + length
    /// guard).  [`Self::dropped_oversize`] breaks out the guard's share.
    pub fn dropped(&self) -> u64 {
        self.dropped_bound + self.dropped_oversize
    }

    /// Prompts shed by the admission-time length guard specifically.
    pub fn dropped_oversize(&self) -> u64 {
        self.dropped_oversize
    }

    /// Total prompts that entered the queue (or were synthesized) so far.
    pub fn arrived(&self) -> u64 {
        self.arrived
    }

    /// The underlying sampler (eval sets are drawn through it so the
    /// held-out stream stays shared with the training stream).
    pub fn sampler(&self) -> &PromptSampler {
        &self.sampler
    }
}

/// Knuth's Poisson sampler — exact for the small per-tick rates we use.
fn poisson(rng: &mut Rng, rate: f64) -> usize {
    let l = (-rate).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        if k >= 10_000 {
            return k; // unreachable at sane rates; bounds the loop regardless
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;
    use crate::data::tokenizer::Tokenizer;

    fn queue(arrivals: Arrivals, depth: usize, seed: u64) -> PromptQueue {
        let sampler = PromptSampler::new(
            Task::by_name("mixed").unwrap(),
            Tokenizer::builtin(64),
            24,
            seed,
        );
        PromptQueue::new(sampler, arrivals, depth, seed)
    }

    #[test]
    fn saturated_always_ready_with_zero_wait() {
        let mut q = queue(Arrivals::Saturated, 4, 7);
        for tick in 0..20u64 {
            q.advance_to(tick);
            assert!(q.has_prompt());
            let p = q.pop(tick).unwrap();
            assert_eq!(p.enqueued_tick, tick, "saturated arrivals never wait");
        }
        // ids are the sampler's sequential stream — same prompts the legacy
        // direct-pull loop would have drawn
        let mut q2 = queue(Arrivals::Saturated, 4, 7);
        assert_eq!(q2.pop(0).unwrap().prompt.id, 0);
        assert_eq!(q2.pop(0).unwrap().prompt.id, 1);
        assert_eq!(q.dropped(), 0);
    }

    #[test]
    fn poisson_is_fifo_and_bounded() {
        let mut q = queue(Arrivals::Poisson { rate: 1.5 }, 5, 11);
        let mut last_id = None;
        let mut popped = 0u64;
        for tick in 1..=400u64 {
            q.advance_to(tick);
            assert!(q.len() <= q.depth(), "queue escaped its bound");
            if tick % 3 == 0 {
                if let Some(p) = q.pop(tick) {
                    assert!(p.enqueued_tick <= tick);
                    if let Some(prev) = last_id {
                        assert!(p.prompt.id > prev, "FIFO order violated");
                    }
                    last_id = Some(p.prompt.id);
                    popped += 1;
                }
            }
        }
        // at rate 1.5/tick with service 1/3 ticks the bound must shed load
        assert!(q.dropped() > 0, "overloaded queue never dropped");
        assert!(popped > 0 && q.arrived() > 0);
        // conservation: everything that arrived is popped or still queued
        assert_eq!(q.arrived(), popped + q.len() as u64);
    }

    #[test]
    fn poisson_arrivals_are_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut q = queue(Arrivals::Poisson { rate: 0.7 }, 64, seed);
            q.advance_to(200);
            (q.arrived(), q.dropped(), q.len())
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5).0, 0, "rate 0.7 over 200 ticks must arrive something");
    }

    #[test]
    fn length_guard_sheds_oversize_prompts_at_enqueue() {
        // guard below the sampler's minimum prompt length: every arrival
        // is shed with the oversize reason, none enter the queue
        let mut q = queue(Arrivals::Poisson { rate: 1.0 }, 8, 13);
        q.set_length_guard(1);
        q.advance_to(100);
        assert_eq!(q.len(), 0);
        assert!(q.dropped_oversize() > 0, "oversize arrivals must be counted");
        assert_eq!(q.arrived(), 0);
        assert!(q.dropped() >= q.dropped_oversize(), "dropped() includes the guard");
        // saturated arrivals give up after the retry bound instead of spinning
        let mut s = queue(Arrivals::Saturated, 8, 13);
        s.set_length_guard(1);
        assert!(s.pop(0).is_none());
        assert!(s.dropped_oversize() > 0);
        // a permissive guard admits normally
        let mut ok = queue(Arrivals::Saturated, 8, 13);
        ok.set_length_guard(64);
        assert!(ok.pop(0).is_some());
        assert_eq!(ok.dropped_oversize(), 0);
    }

    #[test]
    fn advance_is_incremental_not_replayed() {
        let mut a = queue(Arrivals::Poisson { rate: 0.9 }, 1024, 3);
        let mut b = queue(Arrivals::Poisson { rate: 0.9 }, 1024, 3);
        a.advance_to(150);
        for t in 0..=150u64 {
            b.advance_to(t); // tick-by-tick must equal one big jump
        }
        assert_eq!(a.arrived(), b.arrived());
        assert_eq!(a.len(), b.len());
    }
}
