"""AOT artifact integrity: manifest schema, param table, HLO files.

Validates the build products the Rust runtime consumes (shape contracts in
DESIGN.md §2).  Runs against ``artifacts/`` if present (the default build);
otherwise lowers the smoke preset into a temp dir.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
PYDIR = os.path.dirname(HERE)
REPO = os.path.dirname(PYDIR)
ARTIFACTS = os.path.join(REPO, "artifacts")


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    if os.path.exists(os.path.join(ARTIFACTS, "manifest.json")):
        return ARTIFACTS
    out = str(tmp_path_factory.mktemp("artifacts"))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, "--preset", "smoke"],
        cwd=PYDIR, check=True,
    )
    return out


@pytest.fixture(scope="module")
def manifest(art_dir):
    with open(os.path.join(art_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_core_fields(manifest):
    assert manifest["format_version"] == 1
    cfg = manifest["config"]
    for k in ("vocab", "d_model", "n_heads", "n_layers", "s_max",
              "prompt_max", "lanes", "ppo_batch", "chunk_sizes"):
        assert k in cfg, k
    assert cfg["lanes"] > cfg["ppo_batch"]  # G = B + delta_max


def test_all_entry_files_exist(manifest, art_dir):
    for name, e in manifest["entries"].items():
        path = os.path.join(art_dir, e["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} does not look like HLO text"


def test_expected_entries_present(manifest):
    cfg = manifest["config"]
    names = set(manifest["entries"])
    want = {"actor_prefill", "reward_score_full", "ref_logprobs",
            "actor_forward_full", "gae", "ppo_update", "dpo_update"}
    for c in cfg["chunk_sizes"]:
        want.add(f"actor_generate_chunk_c{c}")
        want.add(f"reward_prefill_chunk_c{c}")
        want.add(f"ref_prefill_chunk_c{c}")
    missing = want - names
    assert not missing, missing
    # the Pallas validation flavour must ship too
    assert "gae_pallas" in names
    assert any(n.startswith("reward_prefill_chunk_pallas_c") for n in names)


def test_sliced_entries_cover_divisor_replica_counts(manifest):
    cfg = manifest["config"]
    names = set(manifest["entries"])
    g = cfg["lanes"]
    rows_set = {g // n for n in range(2, g + 1) if g % n == 0}
    for rows in rows_set:
        for c in cfg["chunk_sizes"]:
            assert f"reward_prefill_chunk_g{rows}_c{c}" in names
            assert f"ref_prefill_chunk_g{rows}_c{c}" in names
        # sliced pallas flavour at the mid chunk size
        assert any(
            n.startswith(f"reward_prefill_chunk_pallas_g{rows}_c") for n in names
        )


def test_paged_entries_present(manifest):
    cfg = manifest["config"]
    names = set(manifest["entries"])
    assert "actor_prefill_paged" in names
    for c in cfg["chunk_sizes"]:
        assert f"actor_generate_chunk_paged_c{c}" in names
        assert f"reward_prefill_chunk_paged_c{c}" in names
        assert f"ref_prefill_chunk_paged_c{c}" in names
    assert any(n.startswith("reward_prefill_chunk_paged_pallas_c") for n in names)
    # paged entries are full-G only: no sliced paged flavours
    assert not any("_paged_g" in n for n in names)


def test_paged_entry_shapes(manifest):
    cfg = manifest["config"]
    g, bs = cfg["lanes"], cfg["kv_block_size"]
    assert cfg["s_max"] % bs == 0
    nblk = cfg["s_max"] // bs
    pool = cfg["kv_pool_blocks"] or g * nblk + 1
    hd = cfg["d_model"] // cfg["n_heads"]
    np_ = manifest["n_params"]
    l2 = 2 * cfg["n_layers"]
    e = manifest["entries"]["actor_prefill_paged"]
    # params + (tokens, prompt_len, reset) + pool kv + block table
    assert len(e["inputs"]) == np_ + 3 + l2 + 1
    assert e["inputs"][np_ + 3]["shape"] == [pool, cfg["n_heads"], bs, hd]
    assert e["inputs"][-1]["shape"] == [g, nblk]
    assert e["inputs"][-1]["dtype"] == "int32"
    assert len(e["outputs"]) == l2
    assert e["outputs"][0]["shape"] == [pool, cfg["n_heads"], bs, hd]
    c0 = cfg["chunk_sizes"][0]
    gen = manifest["entries"][f"actor_generate_chunk_paged_c{c0}"]
    # params + (tokens, pos, live) + pool kv + key + table
    assert len(gen["inputs"]) == np_ + 3 + l2 + 2
    assert len(gen["outputs"]) == 2 + l2 + 3
    assert gen["outputs"][2]["shape"] == [pool, cfg["n_heads"], bs, hd]
    ref = manifest["entries"][f"ref_prefill_chunk_paged_c{c0}"]
    assert len(ref["inputs"]) == np_ + 4 + l2 + 1
    assert len(ref["outputs"]) == l2 + 2


def test_sliced_entry_shapes_are_row_sized(manifest):
    cfg = manifest["config"]
    g, c0 = cfg["lanes"], cfg["chunk_sizes"][0]
    rows = max(g // n for n in range(2, g + 1) if g % n == 0)
    e = manifest["entries"][f"reward_prefill_chunk_g{rows}_c{c0}"]
    np_ = manifest["n_params"]
    assert e["inputs"][np_]["shape"] == [rows, c0]       # chunk
    assert e["inputs"][np_ + 1]["shape"] == [rows]       # start
    assert e["inputs"][np_ + 3]["shape"][0] == rows      # kv batch dim
    assert e["outputs"][-1]["shape"] == [rows, c0]       # scores
    ref = manifest["entries"][f"ref_prefill_chunk_g{rows}_c{c0}"]
    assert ref["inputs"][np_ + 3]["shape"] == [rows, cfg["vocab"]]  # boundary
    assert ref["outputs"][-2]["shape"] == [rows, cfg["vocab"]]
    assert ref["outputs"][-1]["shape"] == [rows, c0]


def test_param_table_contiguous_and_sized(manifest, art_dir):
    table = manifest["param_table"]
    offset = 0
    for row in table:
        assert row["offset"] == offset
        n_elems = int(np.prod(row["shape"])) if row["shape"] else 1
        assert row["bytes"] == 4 * n_elems
        offset += row["bytes"]
    for f in manifest["params_files"].values():
        assert os.path.getsize(os.path.join(art_dir, f)) == offset


def test_ref_params_equal_actor_init(manifest, art_dir):
    a = open(os.path.join(art_dir, manifest["params_files"]["actor"]), "rb").read()
    r = open(os.path.join(art_dir, manifest["params_files"]["ref"]), "rb").read()
    w = open(os.path.join(art_dir, manifest["params_files"]["reward"]), "rb").read()
    assert a == r, "reference model must be the frozen initial actor"
    assert a != w, "reward model must be independently initialized"


def test_entry_io_arity(manifest):
    cfg = manifest["config"]
    np_ = manifest["n_params"]
    l2 = 2 * cfg["n_layers"]
    e = manifest["entries"]
    c0 = cfg["chunk_sizes"][0]
    assert len(e["actor_prefill"]["inputs"]) == np_ + 3 + l2
    assert len(e["actor_prefill"]["outputs"]) == l2
    gen = e[f"actor_generate_chunk_c{c0}"]
    assert len(gen["inputs"]) == np_ + 3 + l2 + 1
    assert len(gen["outputs"]) == 2 + l2 + 3
    upd = e["ppo_update"]
    assert len(upd["inputs"]) == 3 * np_ + 6
    assert len(upd["outputs"]) == 3 * np_ + 1
    # chunked ref prefill: params + (chunk, start, n_valid, boundary) + kv
    ref = e[f"ref_prefill_chunk_c{c0}"]
    assert len(ref["inputs"]) == np_ + 4 + l2
    assert len(ref["outputs"]) == l2 + 2  # kv' + boundary' + logp
    g, v = cfg["lanes"], cfg["vocab"]
    assert ref["outputs"][-2]["shape"] == [g, v]   # boundary'
    assert ref["outputs"][-1]["shape"] == [g, c0]  # logp


def test_generate_chunk_output_shapes(manifest):
    cfg = manifest["config"]
    g, s = cfg["lanes"], cfg["s_max"]
    for c in cfg["chunk_sizes"]:
        outs = manifest["entries"][f"actor_generate_chunk_c{c}"]["outputs"]
        assert outs[0]["shape"] == [g, s]          # tokens
        assert outs[1]["shape"] == [g]             # pos
        assert outs[-3]["shape"] == [g, c]         # out_tok
        assert outs[-2]["shape"] == [g, c]         # logp
        assert outs[-1]["shape"] == [g, c]         # value


def test_tokenizer_table(manifest):
    tok = manifest["tokenizer"]
    table = tok["table"]
    assert len(table) == manifest["config"]["vocab"]
    assert table[tok["pad"]] == "<pad>"
    assert table[tok["bos"]] == "<bos>"
    assert table[tok["eos"]] == "<eos>"
    assert len(set(table)) == len(table)
    # the synthetic task alphabet must be present
    for ch in "0123456789+-*= ":
        assert ch in table, repr(ch)


def test_fingerprint_written(art_dir):
    fp = open(os.path.join(art_dir, "aot_fingerprint.txt")).read().strip()
    assert len(fp.splitlines()[0]) == 64
