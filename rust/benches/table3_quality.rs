//! Table 3 — final model quality parity.
//!
//! Two halves:
//! * simulator: final-reward parity per setup (always runs);
//! * real compute: train two policies (sequential TRL-style vs OPPO) on the
//!   synthetic tasks for the same number of PPO steps and compare held-out
//!   exact-match accuracy — the lm-eval substitute (needs `make artifacts`).
use std::sync::Arc;

use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::OppoScheduler;
use oppo::eval::{print_table, save_rows, tables, Row};
use oppo::runtime::Engine;

fn main() {
    let sim_rows = tables::table3_sim();
    print_table("Table 3 (simulator) — final reward parity", &sim_rows);
    save_rows("table3_sim", &sim_rows).expect("save");

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts missing — skipping the real-compute half; run `make artifacts`)");
        return;
    }
    let engine = Arc::new(Engine::load("artifacts").expect("engine"));
    let steps = 25;
    let mut rows = Vec::new();
    for task in ["arith", "sort"] {
        let mut accs = Vec::new();
        for mode in [Mode::Sequential, Mode::Oppo] {
            let cfg = TrainConfig {
                mode,
                steps,
                task: task.into(),
                seed: 7,
                log_every: 0,
                ..Default::default()
            };
            let mut sched = OppoScheduler::with_engine(cfg, engine.clone()).expect("sched");
            for s in 0..steps as u64 {
                sched.run_step(s).expect("step");
            }
            let acc = sched.eval_accuracy(48, 1234).expect("eval");
            accs.push(acc);
        }
        rows.push(
            Row::new(format!("{task} exact-match"))
                .cell("trl_acc_%", 100.0 * accs[0])
                .cell("oppo_acc_%", 100.0 * accs[1])
                .cell("change_pp", 100.0 * (accs[1] - accs[0])),
        );
    }
    print_table("Table 3 (real compute) — held-out accuracy after equal steps", &rows);
    save_rows("table3_real", &rows).expect("save");
    for r in &rows {
        assert!(r.cells[2].1.abs() < 25.0, "{}: quality diverged", r.label);
    }
    println!("shape check passed: OPPO does not sacrifice final quality");
}
