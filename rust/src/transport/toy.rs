//! Deterministic CPU stage backends — engine-free remote replicas.
//!
//! The real remote-stage server hosts an engine-backed `RewardOps` /
//! `RefOps` replica, which needs compiled artifacts.  These toy backends
//! implement the *same streaming contract* as pure host arithmetic, so the
//! transport layer — framing, routing, heartbeat, failover, chunk replay —
//! is exercised end-to-end by tier-1 tests (and the CLI's
//! `remote-stage --backend toy`) on any machine.
//!
//! Contract mirrored from the engine handlers, masked full-shape path:
//!
//! * per-row streaming state advances only where `n_valid > 0`;
//! * a chunk must start exactly where the row's state left off
//!   (`start == pos`) — **except** `start == 0`, which resets the row (the
//!   lane-recycling path rolling admission already relies on, and exactly
//!   what chunk replay after a failover produces);
//! * reward: a score per position, deterministic in the full token prefix;
//!   picks read scores at final-token positions, scattered through
//!   `lane_map`;
//! * ref: a log-prob per position, deterministic in (token, position).
//!
//! The discontinuity check makes these backends as order-strict as the
//! real KV/seam state: a replay that skipped or reordered chunks would
//! error, not silently produce matching scores.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::coordinator::worker::{RefReq, RefResp, RewardReq, RewardResp};

/// Deterministic per-position score: a decaying fold over the token
/// prefix.  Everything stays in f32 so the value is bit-reproducible
/// across runs and across replicas.
fn fold(acc: f32, token: i32, pos: usize) -> f32 {
    acc * 0.93f32 + (token as f32) * 1e-3 + (pos as f32) * 1e-4
}

fn score_of(acc: f32) -> f32 {
    (acc * 0.11f32).sin()
}

/// Deterministic ref log-prob for (token, absolute position).
fn ref_logp_of(token: i32, pos: usize) -> f32 {
    -((token as f32) * 7e-4 + (pos as f32) * 3e-3 + 1.0).ln()
}

/// Engine-free reward replica: per-row `(pos, acc)` streaming state.
#[derive(Default)]
pub struct ToyRewardBackend {
    rows: HashMap<usize, (usize, f32)>,
}

impl ToyRewardBackend {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        match req {
            RewardReq::Reset => {
                self.rows.clear();
                Ok(RewardResp::ResetDone)
            }
            RewardReq::Stream { chunk, start, n_valid, picks, lane_map, .. }
            | RewardReq::StreamPaged { chunk, start, n_valid, picks, lane_map, .. } => {
                let rows = start.len();
                ensure!(rows > 0 && chunk.len() % rows == 0, "malformed chunk grid");
                let c = chunk.len() / rows;
                let mut scores = vec![0f32; rows * c];
                for row in 0..rows {
                    let nv = n_valid[row] as usize;
                    if nv == 0 {
                        continue;
                    }
                    let st = start[row] as usize;
                    let entry = self.rows.entry(row).or_insert((0, 0.0));
                    if st == 0 {
                        *entry = (0, 0.0); // lane recycled or chunk replay
                    }
                    let (pos, acc) = *entry;
                    if st != pos {
                        bail!("toy reward discontinuity on row {row}: at {pos}, chunk starts {st}");
                    }
                    let mut acc = acc;
                    for j in 0..nv {
                        acc = fold(acc, chunk[row * c + j], st + j);
                        scores[row * c + j] = score_of(acc);
                    }
                    *entry = (st + nv, acc);
                }
                Ok(RewardResp::StreamScores(
                    picks
                        .iter()
                        .map(|p| (lane_map[p.lane], scores[p.lane * c + p.idx_in_chunk]))
                        .collect(),
                ))
            }
            RewardReq::ScoreFull { tokens, last_idx } => {
                // monolithic scoring over [G, S]: fold each row's prefix up
                // to its final token — the dense cross-check for tests
                let g = last_idx.len();
                ensure!(g > 0 && tokens.len() % g == 0, "malformed full grid");
                let s = tokens.len() / g;
                let mut out = Vec::with_capacity(g);
                for row in 0..g {
                    let mut acc = 0f32;
                    for j in 0..=(last_idx[row] as usize).min(s - 1) {
                        acc = fold(acc, tokens[row * s + j], j);
                    }
                    out.push(score_of(acc));
                }
                Ok(RewardResp::FullScores(out))
            }
        }
    }
}

/// Engine-free ref replica: per-row position cursor (the log-prob itself
/// is position-local, but the cursor enforces stream continuity exactly
/// like the real boundary-seam state).
#[derive(Default)]
pub struct ToyRefBackend {
    rows: HashMap<usize, usize>,
}

impl ToyRefBackend {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        match req {
            RefReq::Reset => {
                self.rows.clear();
                Ok(RefResp::ResetDone)
            }
            RefReq::Stream { chunk, start, n_valid, .. }
            | RefReq::StreamPaged { chunk, start, n_valid, .. } => {
                let rows = start.len();
                ensure!(rows > 0 && chunk.len() % rows == 0, "malformed chunk grid");
                let c = chunk.len() / rows;
                let mut logps = vec![0f32; rows * c];
                for row in 0..rows {
                    let nv = n_valid[row] as usize;
                    if nv == 0 {
                        continue;
                    }
                    let st = start[row] as usize;
                    let pos = self.rows.entry(row).or_insert(0);
                    if st == 0 {
                        *pos = 0;
                    }
                    if st != *pos {
                        bail!("toy ref discontinuity on row {row}: at {pos}, chunk starts {st}");
                    }
                    for j in 0..nv {
                        logps[row * c + j] = ref_logp_of(chunk[row * c + j], st + j);
                    }
                    *pos = st + nv;
                }
                Ok(RefResp::StreamLogps(logps))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::Pick;

    #[test]
    fn streamed_matches_full_and_enforces_continuity() {
        let tokens: Vec<i32> = (1..=8).collect();
        // stream one row in two chunks of 4; pick the final position
        let mut b = ToyRewardBackend::new();
        let r1 = b.handle(RewardReq::Stream {
            entry: String::new(),
            chunk: tokens[0..4].to_vec(),
            start: vec![0],
            n_valid: vec![4],
            picks: vec![],
            lane_map: vec![0],
        });
        assert!(r1.is_ok());
        let RewardResp::StreamScores(s2) = b
            .handle(RewardReq::Stream {
                entry: String::new(),
                chunk: tokens[4..8].to_vec(),
                start: vec![4],
                n_valid: vec![4],
                picks: vec![Pick { lane: 0, idx_in_chunk: 3 }],
                lane_map: vec![0],
            })
            .unwrap()
        else {
            panic!("expected scores")
        };
        let RewardResp::FullScores(full) =
            b.handle(RewardReq::ScoreFull { tokens: tokens.clone(), last_idx: vec![7] }).unwrap()
        else {
            panic!("expected full scores")
        };
        assert_eq!(s2, vec![(0, full[0])]);
        // continuity: skipping a chunk errors (state is at 8, start 12)
        let err = b.handle(RewardReq::Stream {
            entry: String::new(),
            chunk: vec![1; 4],
            start: vec![12],
            n_valid: vec![4],
            picks: vec![],
            lane_map: vec![0],
        });
        assert!(err.is_err());
        // start == 0 resets (replay path) and reproduces the same score
        let RewardResp::StreamScores(replay) = b
            .handle(RewardReq::Stream {
                entry: String::new(),
                chunk: tokens.clone(),
                start: vec![0],
                n_valid: vec![8],
                picks: vec![Pick { lane: 0, idx_in_chunk: 7 }],
                lane_map: vec![0],
            })
            .unwrap()
        else {
            panic!("expected scores")
        };
        assert_eq!(replay, vec![(0, full[0])]);
    }
}
