//! Minimal JSON: a recursive-descent parser (for `artifacts/manifest.json`)
//! and a writer (for metrics / bench exports).  Hand-rolled because serde is
//! not in the offline crate set (DESIGN.md §2).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.  Numbers are kept as f64 (the manifest only holds
/// shapes/offsets well below 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("not an integer: {x}");
        }
        Ok(x as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]` (shape vectors in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value().context("parsing JSON")?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(m)),
                c => bail!("expected ',' or '}}' in object, got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(v)),
                c => bail!("expected ',' or ']' in array, got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape digit {c:?}"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                b if b < 0x80 => s.push(b as char),
                b => {
                    // multi-byte UTF-8: copy the raw continuation bytes
                    let start = self.pos - 1;
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    for _ in 1..len {
                        self.bump()?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|e| anyhow!("bad utf8 in string: {e}"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = text.parse().map_err(|e| anyhow!("bad number {text:?}: {e}"))?;
        Ok(Value::Num(x))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serialize a [`Value`] compactly.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, x);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, x);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for export code.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

pub fn num(x: f64) -> Value {
    Value::Num(x)
}

pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\n");
        assert!(v.get("b").unwrap().get("d").unwrap().as_bool().unwrap());
        assert_eq!(*v.get("e").unwrap(), Value::Null);
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_scientific_and_negative() {
        let v = parse("[1e3, -2.5e-2, 0.0]").unwrap();
        let xs = v.as_arr().unwrap();
        assert_eq!(xs[0].as_f64().unwrap(), 1000.0);
        assert!((xs[1].as_f64().unwrap() + 0.025).abs() < 1e-12);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"héllo → world\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → world");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn usize_vec_and_errors() {
        let v = parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
        assert!(parse("[-1]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = obj(vec![("k\n", s("v\t\""))]);
        assert_eq!(to_string(&v), "{\"k\\n\":\"v\\t\\\"\"}");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert_eq!(v.get("format_version").unwrap().as_usize().unwrap(), 1);
            assert!(v.get("entries").unwrap().as_obj().unwrap().len() >= 10);
        }
    }
}
