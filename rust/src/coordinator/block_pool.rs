//! Host-side paged-KV allocator: a fixed pool of `kv_block_size`-token
//! physical blocks shared by all lanes, with a per-lane block table mapping
//! logical block index -> physical block id.
//!
//! Physical block 0 is reserved as a *scratch sink*: unallocated table slots
//! point at it, so device-side gather reads garbage (masked off by the
//! attention start/pos masks, the same GIGO contract dense caches rely on
//! past `n_valid`) and scatter writes from unreached positions collide there
//! harmlessly.  Real allocations hand out blocks `1..pool_blocks`.
//!
//! Allocation policy is *reservation-based*: `admit` reserves every block
//! the lane could ever need (`ceil(min(s_max, prompt_len + max_new) /
//! block)`) up front, so `grow_to` at chunk boundaries can never fail
//! mid-generation — rolling admission gates on whole-sequence feasibility,
//! which is exactly the "defer admits when the pool is near empty" behaviour
//! the scheduler wants.  Reserved-but-unmapped blocks sit in the lane's
//! private reserve list and only enter the table (becoming visible to the
//! device) as the sequence actually grows past block boundaries.

use anyhow::{ensure, Result};

/// Free-list allocator over `pool_blocks` physical KV blocks with per-lane
/// block tables sized `blocks_per_lane` (= `s_max / kv_block_size`).
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    blocks_per_lane: usize,
    pool_blocks: usize,
    /// Unowned physical block ids (never contains 0, the scratch block).
    free: Vec<u32>,
    /// Per-lane table rows; 0 marks an unallocated slot (scratch sink).
    tables: Vec<Vec<i32>>,
    /// Per-lane reserved-but-unmapped blocks, popped into the table by grow.
    reserves: Vec<Vec<u32>>,
}

impl BlockPool {
    /// `pool_blocks` counts the scratch block; usable capacity is one less.
    pub fn new(
        lanes: usize,
        block_size: usize,
        blocks_per_lane: usize,
        pool_blocks: usize,
    ) -> Self {
        assert!(block_size > 0 && blocks_per_lane > 0);
        assert!(pool_blocks >= 2, "pool needs scratch block 0 plus at least one real block");
        BlockPool {
            block_size,
            blocks_per_lane,
            pool_blocks,
            free: (1..pool_blocks as u32).rev().collect(),
            tables: vec![vec![0; blocks_per_lane]; lanes],
            reserves: vec![Vec::new(); lanes],
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Physical blocks currently on the free list (excludes reserves).
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks a sequence of up to `max_total` tokens needs end to end.
    pub fn blocks_needed(&self, max_total: usize) -> usize {
        max_total.div_ceil(self.block_size).min(self.blocks_per_lane).max(1)
    }

    /// Can a prompt that may reach `max_total` tokens be admitted now?
    pub fn can_admit(&self, max_total: usize) -> bool {
        self.free.len() >= self.blocks_needed(max_total)
    }

    /// Reserve the lane's whole-sequence block budget and map the blocks
    /// covering the first `prompt_len` tokens into its table.
    pub fn admit(&mut self, lane: usize, prompt_len: usize, max_total: usize) -> Result<()> {
        ensure!(
            self.tables[lane].iter().all(|&b| b == 0) && self.reserves[lane].is_empty(),
            "lane {lane} admitted while still holding blocks"
        );
        let needed = self.blocks_needed(max_total);
        ensure!(
            self.free.len() >= needed,
            "pool exhausted: lane {lane} needs {needed} blocks, {} free",
            self.free.len()
        );
        let at = self.free.len() - needed;
        self.reserves[lane] = self.free.split_off(at);
        self.grow_to(lane, prompt_len.max(1));
        Ok(())
    }

    /// Map reserved blocks so the table covers `tokens` positions.  Always
    /// succeeds within the admission reservation; panics on a bookkeeping
    /// bug (growing past what `admit` reserved).
    pub fn grow_to(&mut self, lane: usize, tokens: usize) {
        let want = tokens.div_ceil(self.block_size).min(self.blocks_per_lane);
        let have = self.tables[lane].iter().filter(|&&b| b != 0).count();
        for slot in have..want {
            let b = self.reserves[lane]
                .pop()
                .unwrap_or_else(|| panic!("lane {lane} grew past its reservation"));
            self.tables[lane][slot] = b as i32;
        }
    }

    /// Return all of the lane's blocks (mapped + reserved) to the free list.
    pub fn release(&mut self, lane: usize) {
        for slot in self.tables[lane].iter_mut() {
            if *slot != 0 {
                self.free.push(*slot as u32);
                *slot = 0;
            }
        }
        self.free.append(&mut self.reserves[lane]);
    }

    /// The lane's table row, scratch-0 in unallocated slots.
    pub fn table_row(&self, lane: usize) -> &[i32] {
        &self.tables[lane]
    }

    /// Flattened `[rows, blocks_per_lane]` table for upload (row r = lane r).
    pub fn flat_table(&self, rows: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(rows * self.blocks_per_lane);
        for lane in 0..rows {
            out.extend_from_slice(&self.tables[lane]);
        }
        out
    }

    /// Tokens of KV the pool has committed (mapped + reserved), block-rounded.
    pub fn allocated_tokens(&self) -> usize {
        let total = self.pool_blocks - 1 - self.free.len();
        total * self.block_size
    }

    /// Conservation + aliasing invariants; used by tests and debug asserts.
    pub fn check_invariants(&self) {
        let mut seen = vec![false; self.pool_blocks];
        seen[0] = true; // scratch is permanently "owned" by everyone
        let mut count = 0usize;
        let mut claim = |b: u32, what: &str| {
            assert!((b as usize) < seen.len(), "{what}: block {b} out of range");
            assert!(b != 0, "{what}: scratch block 0 must never be owned");
            assert!(!seen[b as usize], "{what}: block {b} owned twice");
            seen[b as usize] = true;
        };
        for &b in &self.free {
            claim(b, "free list");
            count += 1;
        }
        for (lane, table) in self.tables.iter().enumerate() {
            let mut past_end = false;
            for &b in table {
                if b == 0 {
                    past_end = true;
                    continue;
                }
                assert!(!past_end, "lane {lane} table has a hole before block {b}");
                claim(b as u32, "table");
                count += 1;
            }
            for &b in &self.reserves[lane] {
                claim(b, "reserve");
                count += 1;
            }
        }
        assert_eq!(count, self.pool_blocks - 1, "blocks leaked or double-freed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        // 8 lanes x 4 blocks of 16 tokens, auto-sized pool (+1 scratch)
        BlockPool::new(8, 16, 4, 8 * 4 + 1)
    }

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut p = pool();
        assert_eq!(p.free_blocks(), 32);
        p.admit(3, 10, 64).unwrap(); // reserves 4, maps 1
        p.check_invariants();
        assert_eq!(p.free_blocks(), 28);
        assert_eq!(p.table_row(3).iter().filter(|&&b| b != 0).count(), 1);
        p.grow_to(3, 17); // second block
        assert_eq!(p.table_row(3).iter().filter(|&&b| b != 0).count(), 2);
        p.grow_to(3, 17); // idempotent
        assert_eq!(p.table_row(3).iter().filter(|&&b| b != 0).count(), 2);
        p.grow_to(3, 64); // full
        assert_eq!(p.table_row(3).iter().filter(|&&b| b != 0).count(), 4);
        p.check_invariants();
        p.release(3);
        p.check_invariants();
        assert_eq!(p.free_blocks(), 32);
        assert!(p.table_row(3).iter().all(|&b| b == 0));
    }

    #[test]
    fn short_sequences_reserve_less() {
        let mut p = pool();
        // prompt 5 + max_new 20 = 25 tokens -> 2 blocks, not 4
        p.admit(0, 5, 25).unwrap();
        assert_eq!(p.free_blocks(), 30);
        // all 8 lanes together use half the pool — the other half could
        // back 8 more lanes if the table had rows for them
        for lane in 1..8 {
            assert!(p.can_admit(25));
            p.admit(lane, 5, 25).unwrap();
        }
        assert_eq!(p.free_blocks(), 32 - 16);
        p.check_invariants();
    }

    #[test]
    fn admission_gates_on_free_blocks() {
        let mut p = BlockPool::new(4, 16, 4, 6); // 5 usable blocks
        p.admit(0, 1, 64).unwrap(); // takes 4
        assert!(!p.can_admit(64));
        assert!(p.can_admit(16)); // a 1-block sequence still fits
        assert!(p.admit(1, 1, 64).is_err());
        p.check_invariants(); // failed admit must not leak
        p.release(0);
        assert!(p.can_admit(64));
    }

    #[test]
    fn release_returns_reserved_blocks_too() {
        let mut p = pool();
        p.admit(0, 1, 64).unwrap(); // maps 1, reserves 3 more
        assert_eq!(p.free_blocks(), 28);
        p.release(0); // early EOS: all 4 come back
        assert_eq!(p.free_blocks(), 32);
        p.check_invariants();
    }

    #[test]
    fn flat_table_is_row_major_lane_order() {
        let mut p = pool();
        p.admit(0, 16, 32).unwrap();
        p.admit(1, 1, 16).unwrap();
        let flat = p.flat_table(2);
        assert_eq!(flat.len(), 8);
        assert_eq!(&flat[..4], p.table_row(0));
        assert_eq!(&flat[4..], p.table_row(1));
        assert!(flat[0] != 0 && flat[1] == 0); // 16 tokens -> 1 block
    }
}
