//! The paper's §3.1 invariance claim, tested over real compute: intra-step
//! streaming must not change what is learned.  With Δ = 0 and a shared
//! seed, the streamed (OppoNoInter) and monolithic (Sequential) pipelines
//! generate identical tokens and produce near-identical step rewards; the
//! only difference is *when* the reward model runs.
use std::sync::Arc;

use once_cell::sync::Lazy;
use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::OppoScheduler;
use oppo::runtime::Engine;

static ENGINE: Lazy<Option<Arc<Engine>>> = Lazy::new(|| {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
});

fn one_step(mode: Mode, seed: u64) -> oppo::metrics::StepRecord {
    let cfg = TrainConfig {
        mode,
        steps: 1,
        task: "mixed".into(),
        seed,
        log_every: 0,
        max_new_tokens: 48,
        ..Default::default()
    };
    let mut sched = OppoScheduler::with_engine(cfg, ENGINE.clone().unwrap()).unwrap();
    sched.run_step(0).unwrap()
}

#[test]
fn streamed_scoring_equals_monolithic_scoring() {
    if ENGINE.is_none() { return }
    for seed in [3u64, 17] {
        let streamed = one_step(Mode::OppoNoInter, seed);
        let monolithic = one_step(Mode::Sequential, seed);
        // identical sampled tokens => identical token counts
        assert_eq!(
            streamed.gen_tokens, monolithic.gen_tokens,
            "seed {seed}: generation diverged"
        );
        // scores come from two different HLO programs (incremental vs dense
        // attention) — identical up to float re-association
        assert!(
            (streamed.mean_score - monolithic.mean_score).abs() < 2e-3,
            "seed {seed}: streamed {} vs monolithic {}",
            streamed.mean_score,
            monolithic.mean_score
        );
        // and the PPO update saw the same losses
        for (a, b) in streamed.train_stats.iter().zip(&monolithic.train_stats) {
            assert!((a - b).abs() < 2e-2, "train stats diverged: {a} vs {b}");
        }
    }
}

#[test]
fn intra_overlap_streams_while_generating() {
    if ENGINE.is_none() { return }
    // in streamed mode the reward worker processed chunks during the step —
    // indirectly visible as identical results with a different exec count
    let engine = ENGINE.clone().unwrap();
    let before: u64 = engine
        .stats_snapshot()
        .iter()
        .filter(|(n, _, _)| n.starts_with("reward_prefill_chunk"))
        .map(|(_, c, _)| *c)
        .sum();
    let _ = one_step(Mode::OppoNoInter, 23);
    let after: u64 = engine
        .stats_snapshot()
        .iter()
        .filter(|(n, _, _)| n.starts_with("reward_prefill_chunk"))
        .map(|(_, c, _)| *c)
        .sum();
    assert!(after > before, "no incremental prefill calls recorded");
}
