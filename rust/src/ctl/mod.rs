//! Pipeline controllers behind one `Controller` API.
//!
//! Before this module the scheduler threaded two ad-hoc call sites: the
//! chunk controller took raw step seconds (`observe_step(step_secs)`) while
//! the Δ controller took windowed rewards (`observe(step, mean_reward)`),
//! and the simulator hand-rolled a third variant.  Every controller — the
//! paper's heuristics (§3.1 chunk-size exploration, §3.2 / Alg. 1 Δ trend
//! following) and the learned Q-policy arm — now consumes one typed
//! [`StepTelemetry`] snapshot per step and emits one [`ControlActions`]
//! verdict, so the scheduler, the simulator, and the training environment
//! cannot drift apart in what they feed the control loop.
//!
//! * [`chunkctl`] — the dynamic chunk-size controller (§3.1);
//! * [`delta`] — the dynamic Δ controller (Eq. 4 / Alg. 1 l.21-27);
//! * [`qpolicy`] — the tabular Q-policy: state binning, the ε-greedy
//!   learner, and the versioned frozen-artifact format;
//! * [`HeuristicController`] — both paper heuristics composed behind the
//!   trait (the `controller = "heuristic"` arm);
//! * [`LearnedController`] — a frozen [`qpolicy::QPolicy`] replaying
//!   greedy actions (the `controller = "learned"` arm).

pub mod chunkctl;
pub mod delta;
pub mod qpolicy;

pub use chunkctl::ChunkController;
pub use delta::{DeltaController, Policy};
pub use qpolicy::{delta_of, level_of, KnobBounds, KnobState, QAction, QPolicy};

/// One step's worth of pipeline observations, assembled once by whoever
/// owns the loop (the scheduler or the simulator) and fed to every
/// controller.  Also the learned policy's environment observation — the
/// sim trains on exactly what the runtime later reports.
///
/// Producers fill what they can measure and leave the rest at the
/// `Default` zeros; consumers must tolerate missing (zero) fields.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepTelemetry {
    /// PPO step index the snapshot describes.
    pub step: u64,
    /// Wall-clock seconds of the step (simulated seconds in the sim).
    pub wall_s: f64,
    /// Mean batch reward — the convergence proxy the Δ trend runs on.
    pub mean_reward: f64,
    /// `mean_reward` minus the previous step's (0.0 on the first step).
    pub reward_trend: f64,
    /// Downstream stage-worker utilization, busy/(busy+idle) in [0, 1].
    pub util: f64,
    /// Actor lane idle fraction during generation, in [0, 1].
    pub lane_idle_frac: f64,
    /// Prompts waiting in the admission queue after the step.
    pub queue_depth: usize,
    /// Prompts shed by the bounded queue during the step.
    pub queue_dropped: usize,
    /// Sequences that finished and entered the training batch.
    pub finished: usize,
    /// Tokens decoded during the step (all lanes).
    pub gen_tokens: usize,
    /// Chunk size the step ran with.
    pub chunk: usize,
    /// Overcommit Δ the step ran with.
    pub delta: usize,
    /// Mean finished-sequence length (prompt + response tokens).
    pub mean_seq_len: f64,
    /// 95th-percentile finished-sequence length.
    pub p95_seq_len: f64,
    /// Per-step p99 queue-wait among finished prompts (ticks or sim
    /// seconds; 0.0 when not measured).
    pub queue_wait_p99: f64,
    /// Per-step p99 enqueue-to-finish latency (0.0 when not measured).
    pub e2e_p99: f64,
}

/// A controller's knob verdict for the *next* step.  `None` means "no
/// opinion — keep whatever the loop is using"; a `Some` chunk must come
/// from the compiled candidate set and a `Some` Δ must respect the
/// configured bounds (property-tested for every implementation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ControlActions {
    /// Chunk size in tokens (an element of the candidate set).
    pub chunk: Option<usize>,
    /// Overcommit Δ.
    pub delta: Option<usize>,
    /// Reward-replica pool size.  Only the simulator can act on this
    /// mid-run (the runtime spawns its pools once); the scheduler ignores
    /// it by design.
    pub reward_replicas: Option<usize>,
}

/// The unified control-loop interface: digest one step's telemetry, then
/// report the knobs the next step should run with.
pub trait Controller {
    /// Feed the snapshot of the step that just finished.
    fn observe(&mut self, t: &StepTelemetry);
    /// Knobs for the next step (stable between `observe` calls).
    fn actions(&self) -> ControlActions;
}

/// The paper's heuristics behind the trait: an optional [`ChunkController`]
/// fed `wall_s` and an optional [`DeltaController`] fed `mean_reward`,
/// exactly the two legacy call sites — composing them here is what lets
/// the scheduler and the simulator talk only to [`Controller`].
#[derive(Clone, Debug, Default)]
pub struct HeuristicController {
    chunk: Option<ChunkController>,
    delta: Option<DeltaController>,
}

impl HeuristicController {
    pub fn new(chunk: Option<ChunkController>, delta: Option<DeltaController>) -> Self {
        Self { chunk, delta }
    }

    /// Both knobs under heuristic control (the scheduler's arm).
    pub fn full(chunk: ChunkController, delta: DeltaController) -> Self {
        Self { chunk: Some(chunk), delta: Some(delta) }
    }

    /// Δ-only control (the simulator's legacy arm: chunk size is a fixed
    /// config knob there).
    pub fn delta_only(delta: DeltaController) -> Self {
        Self { chunk: None, delta: Some(delta) }
    }

    /// The wrapped chunk controller (introspection for tests/benches).
    pub fn chunk_ctl(&self) -> Option<&ChunkController> {
        self.chunk.as_ref()
    }

    /// The wrapped Δ controller (introspection for tests/benches).
    pub fn delta_ctl(&self) -> Option<&DeltaController> {
        self.delta.as_ref()
    }
}

impl Controller for HeuristicController {
    fn observe(&mut self, t: &StepTelemetry) {
        if let Some(d) = &mut self.delta {
            d.observe(t.step, t.mean_reward);
        }
        if let Some(c) = &mut self.chunk {
            c.observe_step(t.wall_s);
        }
    }

    fn actions(&self) -> ControlActions {
        ControlActions {
            chunk: self.chunk.as_ref().map(|c| c.chunk()),
            delta: self.delta.as_ref().map(|d| d.delta()),
            reward_replicas: None,
        }
    }
}

/// A frozen Q-policy replayed greedily: every step it bins the telemetry
/// into a table state, looks up the argmax action, and nudges its knob
/// state by the action's discrete adjustments — the same
/// [`KnobState::apply`] the training environment used, so train-time and
/// deploy-time action semantics cannot diverge.
#[derive(Clone, Debug)]
pub struct LearnedController {
    policy: QPolicy,
    bounds: KnobBounds,
    /// chunk-size candidates (compiled `c{C}` entries at runtime; the
    /// sweep grid in the sim), indexed by `knobs.chunk_idx`
    candidates: Vec<usize>,
    knobs: KnobState,
}

impl LearnedController {
    /// `initial` must already satisfy `bounds`; `candidates` must be
    /// non-empty and is the set `actions().chunk` draws from.
    pub fn new(
        policy: QPolicy,
        candidates: Vec<usize>,
        bounds: KnobBounds,
        initial: KnobState,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!candidates.is_empty(), "learned controller needs chunk candidates");
        anyhow::ensure!(
            bounds.n_chunks == candidates.len(),
            "policy bounds cover {} chunk candidates but {} were supplied",
            bounds.n_chunks,
            candidates.len()
        );
        let mut knobs = initial;
        knobs.clamp(&bounds);
        Ok(Self { policy, bounds, candidates, knobs })
    }

    /// Current knob state (test / introspection hook).
    pub fn knobs(&self) -> &KnobState {
        &self.knobs
    }
}

impl Controller for LearnedController {
    fn observe(&mut self, t: &StepTelemetry) {
        let s = qpolicy::encode_state(t, &self.knobs, &self.bounds);
        let a = self.policy.best_action(s);
        self.knobs.apply(a, &self.bounds);
    }

    fn actions(&self) -> ControlActions {
        ControlActions {
            chunk: Some(self.candidates[self.knobs.chunk_idx.min(self.candidates.len() - 1)]),
            delta: Some(self.knobs.delta(&self.bounds)),
            reward_replicas: Some(self.knobs.replicas),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn telem(step: u64, wall_s: f64, reward: f64) -> StepTelemetry {
        StepTelemetry { step, wall_s, mean_reward: reward, ..Default::default() }
    }

    #[test]
    fn heuristic_merges_both_legacy_controllers() {
        let chunk = ChunkController::new(vec![8, 16], 16, 4, 1, false);
        let delta = DeltaController::new(2, 0, 8, 2, Policy::Eq4);
        let mut h = HeuristicController::full(chunk, delta);
        let a0 = h.actions();
        assert_eq!(a0.chunk, Some(16));
        assert_eq!(a0.delta, Some(2));
        assert_eq!(a0.reward_replicas, None);
        for step in 0..20 {
            h.observe(&telem(step, 1.0, step as f64)); // improving reward
        }
        assert!(h.actions().delta.unwrap() > 2, "Δ should grow on an improving trend");
        assert_eq!(h.actions().chunk, Some(16), "non-adaptive chunk never moves");
    }

    #[test]
    fn heuristic_delta_only_has_no_chunk_opinion() {
        let mut h =
            HeuristicController::delta_only(DeltaController::new(1, 0, 4, 2, Policy::Fixed));
        h.observe(&telem(0, 1.0, 0.5));
        let a = h.actions();
        assert_eq!(a.chunk, None);
        assert_eq!(a.delta, Some(1));
    }

    #[test]
    fn trait_matches_legacy_call_sites_exactly() {
        // the trait port must be behaviorally invisible: drive the same
        // reward/latency streams through both the raw controllers and the
        // composed trait object and require identical knob trajectories
        let mut raw_chunk = ChunkController::new(vec![4, 16, 64], 64, 6, 2, true);
        let mut raw_delta = DeltaController::new(2, 0, 8, 3, Policy::Eq4);
        let mut h = HeuristicController::full(
            ChunkController::new(vec![4, 16, 64], 64, 6, 2, true),
            DeltaController::new(2, 0, 8, 3, Policy::Eq4),
        );
        let mut rng = crate::util::rng::Rng::new(0xC011);
        for step in 0..300u64 {
            let wall = rng.range_f64(0.5, 2.0);
            let reward = rng.normal();
            raw_delta.observe(step, reward);
            raw_chunk.observe_step(wall);
            h.observe(&telem(step, wall, reward));
            let a = h.actions();
            assert_eq!(a.chunk, Some(raw_chunk.chunk()));
            assert_eq!(a.delta, Some(raw_delta.delta()));
        }
    }

    #[test]
    fn learned_controller_stays_inside_bounds() {
        let bounds = KnobBounds {
            n_chunks: 3,
            delta_min: 1,
            delta_max: 5,
            min_replicas: 1,
            max_replicas: 2,
        };
        let policy = QPolicy::new(0, bounds.n_chunks);
        let init = KnobState { chunk_idx: 1, delta_level: 2, replicas: 1 };
        let mut c = LearnedController::new(policy, vec![8, 16, 32], bounds, init).unwrap();
        for step in 0..100 {
            c.observe(&telem(step, 1.0, 0.1 * step as f64));
            let a = c.actions();
            assert!([8, 16, 32].contains(&a.chunk.unwrap()));
            let d = a.delta.unwrap();
            assert!((1..=5).contains(&d), "Δ {d} escaped [1, 5]");
            let r = a.reward_replicas.unwrap();
            assert!((1..=2).contains(&r));
        }
    }

    #[test]
    fn learned_controller_rejects_candidate_mismatch() {
        let bounds = KnobBounds {
            n_chunks: 4,
            delta_min: 0,
            delta_max: 4,
            min_replicas: 1,
            max_replicas: 1,
        };
        let policy = QPolicy::new(0, bounds.n_chunks);
        assert!(LearnedController::new(policy, vec![8, 16], bounds, KnobState::default())
            .is_err());
    }
}
