//! `RemoteReplica` — the coordinator-side handle to one remote stage
//! replica, shaped as a [`StageHandler`] so it slots into a [`StagePool`]
//! beside in-process replicas and the `lane % replicas` routing cannot
//! tell them apart.
//!
//! Failure semantics (the contract the failover path builds on):
//!
//! * **connect**: bounded exponential backoff (`attempts` tries) — a
//!   replica that is not up at spawn is a spawn error, not a run error;
//! * **per-send deadline**: every request runs under read/write timeouts;
//!   a stalled replica is indistinguishable from a dead one and is treated
//!   as dead;
//! * **heartbeat**: a background thread pings the *idle* connection every
//!   `heartbeat_ms` (it skips the beat when a request holds the socket —
//!   traffic is its own liveness proof), so a silently dropped peer flips
//!   the replica to dead between requests instead of at the next send;
//! * **death is permanent**: a mid-stream transport fault poisons the
//!   replica (`dead` flag) because its KV/seam state is unrecoverable —
//!   there is no transparent reconnect.  Every subsequent request fails
//!   fast, the pool retires the replica, and its lanes are re-homed onto
//!   a survivor by replaying their retained chunks (see
//!   `StreamSink::failover`).
//!
//! A handler error on the server (`ErrMsg` frame) is *not* death: it
//! propagates as the per-request error, exactly like an in-process
//! handler error, and the connection keeps serving.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{crc32, read_frame, write_frame};
use super::wire::{self, kind, Hello, Params};
use crate::coordinator::worker::{RefReq, RefResp, RewardReq, RewardResp};

/// Connection tuning for one remote replica.
#[derive(Clone, Debug)]
pub struct ConnectOpts {
    /// connect attempts before giving up (exponential backoff between)
    pub attempts: u32,
    /// first backoff; doubles per retry
    pub base_backoff_ms: u64,
    /// per-send write deadline
    pub send_timeout_ms: u64,
    /// per-request response deadline (covers the remote prefill itself)
    pub recv_timeout_ms: u64,
    /// idle-connection heartbeat period; 0 disables the heartbeat thread
    pub heartbeat_ms: u64,
}

impl Default for ConnectOpts {
    fn default() -> Self {
        Self {
            attempts: 5,
            base_backoff_ms: 50,
            send_timeout_ms: 5_000,
            recv_timeout_ms: 30_000,
            heartbeat_ms: 500,
        }
    }
}

struct Inner {
    stream: Mutex<TcpStream>,
    dead: AtomicBool,
    addr: String,
    nonce: AtomicU64,
}

impl Inner {
    fn mark_dead(&self, why: &str) {
        if !self.dead.swap(true, Ordering::SeqCst) {
            log::warn!("remote replica {} marked dead: {why}", self.addr);
        }
    }

    /// One request/response exchange under the socket lock.  Any transport
    /// fault poisons the replica before returning the error.
    fn exchange(&self, send_kind: u8, payload: &[u8], want: u8) -> Result<Vec<u8>> {
        if self.dead.load(Ordering::SeqCst) {
            bail!("remote replica {} is dead", self.addr);
        }
        let mut stream = self.stream.lock().unwrap_or_else(|e| e.into_inner());
        if self.dead.load(Ordering::SeqCst) {
            bail!("remote replica {} is dead", self.addr);
        }
        let result = (|| -> Result<(u8, Vec<u8>)> {
            write_frame(&mut *stream, send_kind, payload)?;
            read_frame(&mut *stream)
        })();
        match result {
            Ok((k, resp)) if k == want => Ok(resp),
            Ok((k, resp)) if k == kind::ERR => {
                // per-request handler error; the connection stays healthy
                bail!("remote {}: {}", self.addr, wire::decode_err(&resp)?)
            }
            Ok((k, _)) => {
                self.mark_dead(&format!("protocol violation: frame kind {k}, wanted {want}"));
                bail!("remote replica {} protocol violation (kind {k})", self.addr)
            }
            Err(e) => {
                self.mark_dead(&format!("{e:#}"));
                bail!("remote replica {} connection lost: {e:#}", self.addr)
            }
        }
    }
}

/// Client handle to one remote stage replica (see module docs).
pub struct RemoteReplica {
    inner: Arc<Inner>,
    /// duplicate handle used only to `shutdown` the socket on drop, which
    /// unblocks a heartbeat stuck in a blocking read without waiting out
    /// its deadline
    shutdown_handle: Option<TcpStream>,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Option<std::thread::JoinHandle<()>>,
}

impl RemoteReplica {
    /// Connect with bounded backoff, handshake the stage name, and (when
    /// `params` is given) distribute the parameter blob, verifying the
    /// server's digest ack against the local bytes.
    pub fn connect(
        addr: &str,
        stage: &str,
        replica: usize,
        params: Option<(&str, &[u8])>,
        opts: &ConnectOpts,
    ) -> Result<Self> {
        let mut last_err = None;
        let mut stream = None;
        for attempt in 0..opts.attempts.max(1) {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => {
                    last_err = Some(e);
                    let backoff = opts.base_backoff_ms.saturating_mul(1 << attempt.min(6));
                    std::thread::sleep(Duration::from_millis(backoff));
                }
            }
        }
        let stream = stream.with_context(|| {
            format!(
                "connecting to remote {stage} replica at {addr} ({} attempts): {:?}",
                opts.attempts, last_err
            )
        })?;
        stream.set_nodelay(true).ok();
        stream.set_write_timeout(Some(Duration::from_millis(opts.send_timeout_ms.max(1)))).ok();
        stream.set_read_timeout(Some(Duration::from_millis(opts.recv_timeout_ms.max(1)))).ok();
        let shutdown_handle = stream.try_clone().ok();
        let inner = Arc::new(Inner {
            stream: Mutex::new(stream),
            dead: AtomicBool::new(false),
            addr: addr.to_string(),
            nonce: AtomicU64::new(0),
        });

        // handshake before the heartbeat starts (single-threaded socket use)
        let hello = Hello { stage: stage.to_string(), replica: replica as u32 };
        inner
            .exchange(kind::HELLO, &wire::encode_hello(&hello), kind::HELLO_ACK)
            .context("stage handshake")?;
        if let Some((which, data)) = params {
            let p = Params { which: which.to_string(), data: data.to_vec() };
            let ack = inner
                .exchange(kind::PARAMS, &wire::encode_params(&p), kind::PARAMS_ACK)
                .context("param distribution")?;
            let remote_crc = wire::decode_params_ack(&ack)?;
            let local_crc = crc32(data);
            if remote_crc != local_crc {
                bail!(
                    "param digest mismatch for {which:?}: local {local_crc:#010x}, \
                     remote {remote_crc:#010x} — replica would score with different weights"
                );
            }
        }

        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_thread = (opts.heartbeat_ms > 0).then(|| {
            let (inner2, stop2) = (inner.clone(), hb_stop.clone());
            let period = Duration::from_millis(opts.heartbeat_ms);
            std::thread::spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(period);
                    if stop2.load(Ordering::SeqCst) || inner2.dead.load(Ordering::SeqCst) {
                        break;
                    }
                    // only beat an *idle* connection: an in-flight request
                    // holds the lock and is its own liveness proof
                    let Ok(mut stream) = inner2.stream.try_lock() else { continue };
                    let nonce = inner2.nonce.fetch_add(1, Ordering::Relaxed);
                    let beat = (|| -> Result<()> {
                        write_frame(&mut *stream, kind::PING, &wire::encode_nonce(nonce))?;
                        let (k, payload) = read_frame(&mut *stream)?;
                        if k != kind::PONG || wire::decode_nonce(&payload)? != nonce {
                            bail!("bad pong");
                        }
                        Ok(())
                    })();
                    if let Err(e) = beat {
                        inner2.mark_dead(&format!("heartbeat failed: {e:#}"));
                        break;
                    }
                }
            })
        });
        Ok(Self { inner, shutdown_handle, hb_stop, hb_thread })
    }

    pub fn addr(&self) -> &str {
        &self.inner.addr
    }

    /// Has a transport fault permanently poisoned this replica?
    pub fn is_dead(&self) -> bool {
        self.inner.dead.load(Ordering::SeqCst)
    }

    /// One reward request against the remote replica.
    pub fn reward(&self, req: &RewardReq) -> Result<RewardResp> {
        let payload = wire::encode_reward_req(req);
        let resp = self.inner.exchange(kind::REWARD_REQ, &payload, kind::REWARD_RESP)?;
        wire::decode_reward_resp(&resp)
    }

    /// One ref request against the remote replica.
    pub fn reference(&self, req: &RefReq) -> Result<RefResp> {
        let resp =
            self.inner.exchange(kind::REF_REQ, &wire::encode_ref_req(req), kind::REF_RESP)?;
        wire::decode_ref_resp(&resp)
    }
}

impl Drop for RemoteReplica {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        self.inner.dead.store(true, Ordering::SeqCst);
        // unblock a heartbeat mid-read instead of waiting out its deadline
        if let Some(s) = &self.shutdown_handle {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.hb_thread.take() {
            let _ = t.join();
        }
    }
}
