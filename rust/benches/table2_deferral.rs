//! Table 2 — request-deferral distribution: the vast majority immediate,
//! nearly all the rest delayed exactly one step (paper: 78.5% / 20.2% /
//! 0.2% / 1.1%, mean 0.24).
use oppo::eval::{print_table, save_rows, tables};

fn main() {
    let rows = tables::table2();
    print_table("Table 2 — deferral distribution under OPPO", &rows);
    save_rows("table2", &rows).expect("save");
    assert!(rows[0].cells[0].1 > 60.0, "zero-deferral share too small");
    let avg = rows.last().unwrap().cells[0].1;
    assert!(avg < 1.0, "avg deferral {avg} too large");
    println!("shape check passed: deferral is rare and shallow (avg {avg:.2})");
}
