//! Deterministic PRNG (SplitMix64 core) + the distributions the simulator
//! and samplers need.  Every stochastic component in the crate threads one
//! of these explicitly so runs are reproducible from a single seed
//! (the paper averages 5 seeded runs; so do the benches).

/// SplitMix64: tiny, fast, excellent statistical quality for simulation use.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zeros fixed point and decorrelate small seeds
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD1B54A32D192ED03) }
    }

    /// Derive an independent stream (for per-worker / per-lane RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the given *underlying* normal parameters — the
    /// paper's Figure 2b response-length shape.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto (heavy tail) with scale `x_m` and shape `alpha`.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / self.f64().max(1e-300).powf(1.0 / alpha)
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_is_heavy_tailed() {
        let mut r = Rng::new(5);
        let n = 30_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal(5.0, 1.0)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[n / 2];
        let p99 = sorted[n * 99 / 100];
        // p99 / median for sigma=1 lognormal ~ exp(2.33) ≈ 10.3
        assert!(p99 / median > 5.0, "tail ratio {}", p99 / median);
    }

    #[test]
    fn pareto_lower_bound() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn weighted_prefers_heavy_weight() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
