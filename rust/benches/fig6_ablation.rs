//! Fig. 6 — design-component breakdown: intra-only ≈1.2–1.3×, inter-only
//! ≈1.6–2.06×, full OPPO largest; final rewards unchanged.
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    let rows = figures::fig6();
    print_table("Fig 6 — ablation breakdown (time-to-reward + final reward)", &rows);
    save_rows("fig6", &rows).expect("save");
    // per-setup ordering: trl < intra-only < inter-only < full (speedup)
    for chunk in rows.chunks(4) {
        let s: Vec<f64> = chunk.iter().map(|r| r.cells[1].1).collect();
        assert!(s[1] > 1.05, "intra-only speedup {}", s[1]);
        assert!(s[2] > s[1], "inter {} !> intra {}", s[2], s[1]);
        assert!(s[3] >= s[2] * 0.95, "full {} vs inter {}", s[3], s[2]);
    }
    println!("shape check passed: ablation ordering matches the paper");
}
