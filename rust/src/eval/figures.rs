//! Figure regenerators (Figs. 2–7).  Each function prints the same series
//! the paper plots and returns the rows for JSON export / assertions.
//! All stochastic results average [`SEEDS`] independent runs, matching the
//! paper's 5-run protocol.

use crate::eval::report::Row;
use crate::metrics::RunLog;
use crate::sim::costmodel::CostModel;
use crate::sim::gpu::GpuSpec;
use crate::sim::pipeline::{simulate, steady_state_latency, steady_state_util, Pipeline, SimConfig};
use crate::sim::presets::{self, Setup};
use crate::sim::rewardmodel::RewardProcess;
use crate::util::rng::Rng;
use crate::util::stats;

/// Seeds per configuration (the paper averages 5 independent runs).
pub const SEEDS: [u64; 5] = [11, 22, 33, 44, 55];

fn mean_over_seeds(f: impl Fn(u64) -> f64) -> f64 {
    stats::mean(&SEEDS.map(f))
}

/// Average time-to-reward (seconds) for a pipeline on a setup.
pub fn time_to_reward(pipeline: Pipeline, setup: &Setup, steps: usize) -> f64 {
    mean_over_seeds(|seed| {
        let cfg = SimConfig::new(setup.clone(), steps, seed);
        let log = simulate(pipeline, &cfg);
        log.time_to_reward(setup.target_reward, 8)
            .unwrap_or_else(|| log.total_wall_s() * 1.5) // censored: never reached
    })
}

/// One simulated run (first seed) — for curve-shaped outputs.
pub fn one_run(pipeline: Pipeline, setup: &Setup, steps: usize, seed: u64) -> RunLog {
    simulate(pipeline, &SimConfig::new(setup.clone(), steps, seed))
}

// ---------------------------------------------------------------------------
// Figure 2 — motivation
// ---------------------------------------------------------------------------

/// Fig. 2a: per-stage GPU utilization across GPU generations (FLOP
/// efficiency of each stage under the roofline model).
pub fn fig2a() -> Vec<Row> {
    let mut rows = Vec::new();
    for gpu in [GpuSpec::A40, GpuSpec::A100_80, GpuSpec::H200] {
        let cm = CostModel {
            model: crate::sim::ModelSpec::QWEN25_7B,
            gpu,
            tp: 1.0,
            software_efficiency: 0.5,
            iter_overhead_s: 2e-4,
            link_gbps: 0.0,
            link_latency_s: 0.0,
        };
        let batch = 16.0;
        let ctx = 768.0;
        let t_dec = cm.decode_iter(batch, ctx);
        let util_dec = cm.decode_iter_flops(batch) / (t_dec * gpu.fp16_tflops * 1e12);
        let tokens = batch * ctx;
        let t_pre = cm.prefill(tokens, ctx);
        let util_pre = cm.prefill_flops(tokens, ctx) / (t_pre * gpu.fp16_tflops * 1e12);
        let t_train = cm.train_step(tokens, 1.0, 0.0);
        let util_train = cm.train_flops(tokens) / (t_train * gpu.fp16_tflops * 1e12);
        rows.push(
            Row::new(gpu.name)
                .cell("gen_util_%", 100.0 * util_dec)
                .cell("score_util_%", 100.0 * util_pre)
                .cell("train_util_%", 100.0 * util_train),
        );
    }
    rows
}

/// Fig. 2b: rollout-length distribution (warm-up vs converged phase).
pub fn fig2b() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in presets::all_main_setups() {
        for (phase, p) in [("warmup", 0.0), ("converged", 1.0)] {
            let mut rng = Rng::new(7);
            let xs = setup.lengths.sample_batch(&mut rng, p, 20_000);
            rows.push(
                Row::new(format!("{} {phase}", setup.name))
                    .cell("p50", stats::percentile(&xs, 50.0))
                    .cell("p90", stats::percentile(&xs, 90.0))
                    .cell("p99", stats::percentile(&xs, 99.0))
                    .cell("max", stats::max(&xs))
                    .cell(
                        "tail_p99/p50",
                        stats::percentile(&xs, 99.0) / stats::percentile(&xs, 50.0),
                    ),
            );
        }
    }
    rows
}

/// Fig. 2c: asynchrony (staleness) hurts step-to-reward and final quality.
pub fn fig2c() -> Vec<Row> {
    let setup = presets::stackex_7b_h200();
    let mut rows = Vec::new();
    for k in [0usize, 1, 5] {
        let final_r = mean_over_seeds(|seed| {
            let mut p = RewardProcess::new(setup.reward, seed);
            (0..600).map(|_| p.advance(k as f64, 0.0)).fold(0.0, |_, r| r)
        });
        let step_to_35 = mean_over_seeds(|seed| {
            let mut p = RewardProcess::new(setup.reward, seed);
            for s in 0..2000 {
                if p.advance(k as f64, 0.0) >= 3.5 {
                    return s as f64;
                }
            }
            2000.0
        });
        rows.push(
            Row::new(if k == 0 { "sync".into() } else { format!("staleness-{k}") })
                .cell("reward@600", final_r)
                .cell("steps_to_3.5", step_to_35),
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 3 — end-to-end time-to-reward speedup
// ---------------------------------------------------------------------------

pub fn fig3() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in presets::all_main_setups() {
        let steps = setup.total_steps + setup.total_steps / 2;
        let trl = time_to_reward(Pipeline::TrlSequential, &setup, steps);
        let oppo = time_to_reward(Pipeline::oppo(), &setup, steps);
        rows.push(
            Row::new(setup.name)
                .cell("trl_min", trl / 60.0)
                .cell("oppo_min", oppo / 60.0)
                .cell("speedup", trl / oppo),
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 4 — step-to-reward parity
// ---------------------------------------------------------------------------

pub fn fig4() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in presets::all_main_setups() {
        let steps = setup.total_steps;
        let at = |pipeline: Pipeline, frac: f64| {
            mean_over_seeds(|seed| {
                let log = one_run(pipeline, &setup, steps, seed);
                let idx = ((steps as f64 * frac) as usize).min(steps - 1);
                stats::mean(
                    &log.records[idx.saturating_sub(4)..=idx]
                        .iter()
                        .map(|r| r.mean_score)
                        .collect::<Vec<_>>(),
                )
            })
        };
        for frac in [0.25, 0.5, 1.0] {
            let t = at(Pipeline::TrlSequential, frac);
            let o = at(Pipeline::oppo(), frac);
            rows.push(
                Row::new(format!("{} @{:.0}%", setup.name, frac * 100.0))
                    .cell("trl_reward", t)
                    .cell("oppo_reward", o)
                    .cell("abs_gap", (t - o).abs()),
            );
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 5 — GPU utilization
// ---------------------------------------------------------------------------

pub fn fig5() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in presets::all_main_setups() {
        let steps = 80;
        let util = |p: Pipeline| {
            mean_over_seeds(|seed| steady_state_util(&one_run(p, &setup, steps, seed)))
        };
        let t = util(Pipeline::TrlSequential);
        let o = util(Pipeline::oppo());
        rows.push(
            Row::new(setup.name)
                .cell("trl_util_%", 100.0 * t)
                .cell("oppo_util_%", 100.0 * o)
                .cell("ratio", o / t),
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 6 — ablation breakdown
// ---------------------------------------------------------------------------

pub fn fig6() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in [presets::stackex_7b_h200(), presets::stackex_3b_a100()] {
        let steps = setup.total_steps + setup.total_steps / 2;
        let arms = [
            ("trl", Pipeline::TrlSequential),
            ("oppo-no-inter (intra only)", Pipeline::Oppo {
                intra: true, inter: false, fixed_delta: None,
            }),
            ("oppo-no-intra (inter only)", Pipeline::Oppo {
                intra: false, inter: true, fixed_delta: None,
            }),
            ("oppo (full)", Pipeline::oppo()),
        ];
        let trl_time = time_to_reward(Pipeline::TrlSequential, &setup, steps);
        for (name, p) in arms {
            let t = time_to_reward(p, &setup, steps);
            let final_r = mean_over_seeds(|seed| {
                one_run(p, &setup, steps, seed).records.last().unwrap().mean_score
            });
            rows.push(
                Row::new(format!("{} / {name}", setup.name))
                    .cell("time_to_reward_min", t / 60.0)
                    .cell("speedup", trl_time / t)
                    .cell("final_reward", final_r),
            );
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 7 — adaptation ablations
// ---------------------------------------------------------------------------

/// Fig. 7a: fixed Δ ∈ {4, 8} vs dynamic Δ.
pub fn fig7a() -> Vec<Row> {
    let setup = presets::stackex_3b_a100();
    let steps = setup.total_steps;
    let arms = [
        ("fixed Δ=4", Pipeline::Oppo { intra: true, inter: true, fixed_delta: Some(4) }),
        ("fixed Δ=8", Pipeline::Oppo { intra: true, inter: true, fixed_delta: Some(8) }),
        ("dynamic Δ", Pipeline::oppo()),
    ];
    let mut rows = Vec::new();
    for (name, p) in arms {
        let t = time_to_reward(p, &setup, steps + steps / 2);
        let final_r = mean_over_seeds(|seed| {
            one_run(p, &setup, steps, seed).records.last().unwrap().mean_score
        });
        rows.push(
            Row::new(name)
                .cell("time_to_reward_min", t / 60.0)
                .cell("final_reward", final_r),
        );
    }
    // the paper-internal sign discrepancy (DESIGN.md §4b): Alg. 1's literal
    // Δ-update direction, for comparison against the Eq. (4) default
    let t_lit = stats::mean(&SEEDS.map(|seed| {
        let mut cfg = SimConfig::new(setup.clone(), steps + steps / 2, seed);
        cfg.delta_policy = crate::ctl::Policy::Alg1Literal;
        let log = simulate(Pipeline::oppo(), &cfg);
        log.time_to_reward(setup.target_reward, 8)
            .unwrap_or_else(|| log.total_wall_s() * 1.5)
    }));
    rows.push(
        Row::new("dynamic Δ (Alg.1-literal sign)")
            .cell("time_to_reward_min", t_lit / 60.0)
            .cell("final_reward", rows.last().map(|r| r.cells[1].1).unwrap_or(0.0)),
    );
    rows
}

/// Fig. 7b: chunk size vs mean step latency (the U-shape).
pub fn fig7b() -> Vec<Row> {
    let mut rows = Vec::new();
    for setup in [presets::stackex_7b_h200(), presets::stackex_3b_a100()] {
        for chunk in [100.0, 500.0, 1000.0, 3000.0] {
            let lat = mean_over_seeds(|seed| {
                let mut cfg = SimConfig::new(setup.clone(), 60, seed);
                cfg.chunk_tokens = chunk;
                steady_state_latency(&simulate(Pipeline::oppo(), &cfg))
            });
            rows.push(
                Row::new(format!("{} C={}", setup.name, chunk as usize))
                    .cell("step_latency_s", lat),
            );
        }
    }
    rows
}
