#!/usr/bin/env python3
"""Cross-PR benchmark trajectory over the committed BENCH_*.json snapshots.

Each PR commits one pinned-seed snapshot (BENCH_6.json, BENCH_7.json, ...);
this script lines them up and renders ASCII trajectories of the headline
metrics per scenario, so a perf regression shows up as a kink in the chart
rather than a number buried in a JSON diff.  Tolerant of missing scenarios
and keys — older snapshots predate newer metrics (e.g. lane_idle_frac_mean
and the SLO block only exist from BENCH_7 on).

Usage:
  python3 scripts/plot_bench.py              # chart everything found
  python3 scripts/plot_bench.py --check      # exit non-zero on structural
                                             # problems in the newest snapshot
  python3 scripts/plot_bench.py --dir /path  # snapshots live elsewhere

Stdlib only (no matplotlib in CI).
"""

import argparse
import glob
import json
import os
import re
import sys

# (scenario-level key, display label, lower-is-better)
METRICS = [
    ("step_wall_s_mean", "step wall (s)", True),
    ("util_mean", "utilization", False),
    ("gen_tokens_per_s", "gen tok/s", False),
    ("lane_idle_frac_mean", "lane idle frac", True),
    ("peak_kv_bytes", "peak KV (bytes)", True),
]
SLO_KEYS = ["queue_wait_p50", "queue_wait_p99", "e2e_p50", "e2e_p99"]
BAR_WIDTH = 40

# BENCH_* indices that are intentionally absent from the committed
# sequence.  PR 7 shipped without landing its pinned-seed snapshot; that
# gap is a recorded fact of the trajectory, not a regression for --check
# to re-flag on every subsequent PR.  Indices NOT listed here still fail
# the sequence check, so new gaps keep getting caught.
KNOWN_GAPS = {7}


def load_snapshots(root):
    """[(pr_number, path, doc)] sorted by PR number."""
    out = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(path))
        if not m:
            continue
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {path}: {e}", file=sys.stderr)
            continue
        out.append((int(m.group(1)), path, doc))
    return sorted(out)


def series(snaps, scenario, key):
    """[(pr, value)] for one scenario-level metric, skipping absences."""
    pts = []
    for pr, _path, doc in snaps:
        v = doc.get("scenarios", {}).get(scenario, {}).get(key)
        if isinstance(v, (int, float)):
            pts.append((pr, float(v)))
    return pts


def bar_chart(title, pts, lower_better):
    if not pts:
        return
    print(f"  {title}")
    hi = max(v for _, v in pts)
    for pr, v in pts:
        w = 0 if hi <= 0 else int(round(BAR_WIDTH * v / hi))
        mark = ""
        best = min(pts, key=lambda p: p[1]) if lower_better else max(pts, key=lambda p: p[1])
        if (pr, v) == best and len(pts) > 1:
            mark = "  <- best"
        print(f"    PR{pr:>3} | {'#' * w:<{BAR_WIDTH}} {v:.4g}{mark}")


def chart_all(snaps):
    scenarios = []
    for _pr, _path, doc in snaps:
        for name in doc.get("scenarios", {}):
            if name not in scenarios:
                scenarios.append(name)
    for sc in scenarios:
        printed = False
        for key, label, lower in METRICS:
            pts = series(snaps, sc, key)
            if not pts:
                continue
            if not printed:
                print(f"\n== scenario: {sc} ==")
                printed = True
            bar_chart(label, pts, lower)
        # SLO percentiles (flattened from the nested block)
        for k in SLO_KEYS:
            pts = []
            for pr, _path, doc in snaps:
                slo = doc.get("scenarios", {}).get(sc, {}).get("slo")
                if isinstance(slo, dict) and isinstance(slo.get(k), (int, float)):
                    pts.append((pr, float(slo[k])))
            if pts:
                if not printed:
                    print(f"\n== scenario: {sc} ==")
                    printed = True
                bar_chart(f"slo {k} (ticks)", pts, True)
    # repo-level trajectory
    pts = [
        (pr, float(doc["sliced_knee_reward_replicas"]))
        for pr, _path, doc in snaps
        if isinstance(doc.get("sliced_knee_reward_replicas"), (int, float))
    ]
    if pts:
        print("\n== repo-level ==")
        bar_chart("sliced knee (reward replicas)", pts, True)


def check_sequence(snaps):
    """Gaps in the committed BENCH_* index sequence, as error strings.

    The trajectory is only meaningful if every PR since the first snapshot
    landed one — a missing index means a PR shipped without refreshing the
    pinned-seed runner, which is exactly the drift --check exists to catch.
    Indices in KNOWN_GAPS are recorded as intentionally absent and only
    noted, not failed.
    """
    prs = [pr for pr, _path, _doc in snaps]
    missing = [i for i in range(prs[0], prs[-1] + 1) if i not in prs]
    allowed = [i for i in missing if i in KNOWN_GAPS]
    if allowed:
        known = ", ".join(f"BENCH_{i}.json" for i in allowed)
        print(f"note: known gap(s) in the snapshot sequence: {known} "
              f"(allowlisted in KNOWN_GAPS)")
    missing = [i for i in missing if i not in KNOWN_GAPS]
    if missing:
        gaps = ", ".join(f"BENCH_{i}.json" for i in missing)
        return [
            f"snapshot sequence has gaps: {gaps} missing between "
            f"BENCH_{prs[0]}.json and BENCH_{prs[-1]}.json"
        ]
    return []


def check_latest(snaps):
    """Structural sanity of the newest snapshot; returns error strings."""
    errors = []
    pr, path, doc = snaps[-1]
    scen = doc.get("scenarios")
    if not isinstance(scen, dict) or not scen:
        return [f"{path}: no scenarios block"]
    for name, sc in scen.items():
        for key in ("step_wall_s_mean", "util_mean", "gen_tokens_per_s"):
            if not isinstance(sc.get(key), (int, float)):
                errors.append(f"{path}: scenarios.{name}.{key} missing/non-numeric")
    if pr >= 7:
        # rolling-admission era: the continuous-batching arms must report
        # lane idle and the Poisson arm SLO percentiles.  The strict idle
        # ordering (rolling below its step-sync baseline) is only asserted
        # for the *saturated* pair — saturated arrivals refill every freed
        # lane, so residual idle is pure scheduler inefficiency.  The
        # Poisson arm is calibrated *under* decode capacity (1.5 prompts/s
        # offered vs ~2.6/s served), so its lane idle is dominated by
        # arrival starvation and legitimately exceeds the step-sync
        # baseline (which synthesizes a full batch at every boundary
        # regardless of traffic); for that arm --check instead requires
        # idle to be reported and the bounded queue to shed nothing.
        pairs = [
            ("oppo_x1", "oppo_rolling_saturated", True),
            ("traffic_stepsync", "traffic_rolling_poisson", False),
        ]
        for base_name, roll_name, ordered in pairs:
            base, roll = scen.get(base_name), scen.get(roll_name)
            if base is None or roll is None:
                errors.append(f"{path}: missing scenario pair {base_name}/{roll_name}")
                continue
            bi, ri = base.get("lane_idle_frac_mean"), roll.get("lane_idle_frac_mean")
            if not isinstance(bi, (int, float)) or not isinstance(ri, (int, float)):
                errors.append(
                    f"{path}: lane_idle_frac_mean missing on {base_name}/{roll_name}"
                )
            elif ordered and not ri < bi:
                errors.append(
                    f"{path}: rolling lane idle {ri:.4g} not below "
                    f"step-sync baseline {bi:.4g} ({roll_name} vs {base_name})"
                )
        poisson = scen.get("traffic_rolling_poisson", {})
        if isinstance(poisson.get("queue_dropped"), (int, float)) and poisson["queue_dropped"] > 0:
            errors.append(
                f"{path}: undersaturated Poisson arm shed "
                f"{poisson['queue_dropped']} prompts (queue misconfigured?)"
            )
        slo = poisson.get("slo")
        if not isinstance(slo, dict):
            errors.append(f"{path}: traffic_rolling_poisson.slo missing")
        else:
            for k in ("queue_wait_p50", "queue_wait_p99", "e2e_p50", "e2e_p99"):
                if not isinstance(slo.get(k), (int, float)):
                    errors.append(f"{path}: traffic_rolling_poisson.slo.{k} missing")
    if pr >= 8:
        # paged-KV era: the paged arm must exist, throughput must match the
        # dense arm exactly (paging is memory accounting, not scheduling),
        # peak KV must drop by the ISSUE's >= 40%, and the freed memory must
        # buy strictly more concurrent lanes than the dense bound
        paged_kv = doc.get("paged_kv")
        if not isinstance(paged_kv, dict):
            errors.append(f"{path}: paged_kv block missing")
        else:
            for k in (
                "dense_peak_kv_bytes",
                "paged_peak_kv_bytes",
                "peak_kv_reduction",
                "dense_max_lanes",
                "paged_max_lanes",
            ):
                if not isinstance(paged_kv.get(k), (int, float)):
                    errors.append(f"{path}: paged_kv.{k} missing/non-numeric")
            red = paged_kv.get("peak_kv_reduction")
            if isinstance(red, (int, float)) and red < 0.4:
                errors.append(
                    f"{path}: paged peak-KV reduction {red:.4g} below the 40% floor"
                )
            dl, pl = paged_kv.get("dense_max_lanes"), paged_kv.get("paged_max_lanes")
            if isinstance(dl, (int, float)) and isinstance(pl, (int, float)) and not pl > dl:
                errors.append(
                    f"{path}: paged lane bound {pl:.4g} not above dense {dl:.4g}"
                )
            if paged_kv.get("equal_throughput") is not True:
                errors.append(f"{path}: paged arm did not match dense throughput")
        dense_sc = scen.get("traffic_rolling_poisson")
        paged_sc = scen.get("traffic_rolling_paged")
        if paged_sc is None:
            errors.append(f"{path}: traffic_rolling_paged scenario missing")
        elif isinstance(dense_sc, dict):
            dp = dense_sc.get("peak_kv_bytes")
            pp = paged_sc.get("peak_kv_bytes")
            if not isinstance(dp, (int, float)) or not isinstance(pp, (int, float)):
                errors.append(f"{path}: peak_kv_bytes missing on the traffic arms")
            elif not pp < dp:
                errors.append(
                    f"{path}: paged scenario peak {pp:.4g} not below dense {dp:.4g}"
                )
    if pr >= 9:
        # multi-node transport era: the snapshot must price the remote
        # replica arm against its local sliced twin from the cost model's
        # link terms.  Remote replicas run masked full-shape grids (the
        # price of chunk-replay failover), so the modelled remote arm must
        # cost at least its local twin; replay overhead is a fraction of
        # one remote pass.  The frame codec MB/s pair is host-measured and
        # may be committed as null from a toolchain-less runner, but the
        # keys must exist so a refresh lands in the right place.
        tr = doc.get("transport")
        if not isinstance(tr, dict):
            errors.append(f"{path}: transport block missing")
        else:
            for k in (
                "link_gbps",
                "link_latency_s",
                "chunk_transfer_s",
                "local_sliced_prefill_s",
                "remote_masked_prefill_s",
                "remote_over_local",
                "replay_overhead_s",
                "replay_overhead_frac",
            ):
                if not isinstance(tr.get(k), (int, float)):
                    errors.append(f"{path}: transport.{k} missing/non-numeric")
            rol = tr.get("remote_over_local")
            if isinstance(rol, (int, float)) and not rol >= 1.0:
                errors.append(
                    f"{path}: remote arm {rol:.4g}x cheaper than its local "
                    f"sliced twin (link terms not applied?)"
                )
            frac = tr.get("replay_overhead_frac")
            if isinstance(frac, (int, float)) and not 0.0 < frac <= 1.0:
                errors.append(
                    f"{path}: replay_overhead_frac {frac:.4g} outside (0, 1]"
                )
            for k in ("frame_encode_mb_s", "frame_decode_mb_s"):
                if k not in tr:
                    errors.append(f"{path}: transport.{k} key missing")
                elif tr[k] is not None and not isinstance(tr[k], (int, float)):
                    errors.append(f"{path}: transport.{k} neither null nor numeric")
    if pr >= 10:
        # learned-controller era: the snapshot must price the frozen
        # Q-policy (trained at the CI-pinned episodes/seed) against the
        # heuristic controllers on both benchmark presets, and the learned
        # arm must match or beat heuristic step throughput on each — the
        # same floor the CI train-smoke asserts on a fresh training run.
        lc = doc.get("learned_controller")
        if not isinstance(lc, dict):
            errors.append(f"{path}: learned_controller block missing")
        else:
            for k in ("episodes", "seed", "visited_cells"):
                if not isinstance(lc.get(k), (int, float)):
                    errors.append(f"{path}: learned_controller.{k} missing/non-numeric")
            art = lc.get("artifact")
            if not isinstance(art, dict) or not isinstance(art.get("version"), (int, float)):
                errors.append(f"{path}: learned_controller.artifact missing/invalid")
            arms = lc.get("arms")
            if not isinstance(arms, list):
                errors.append(f"{path}: learned_controller.arms missing")
            else:
                seen = set()
                for arm in arms:
                    name = arm.get("preset", "?")
                    seen.add(name)
                    for k in ("heuristic_steps_per_s", "learned_steps_per_s", "speedup"):
                        if not isinstance(arm.get(k), (int, float)):
                            errors.append(
                                f"{path}: learned_controller arm {name}: {k} "
                                f"missing/non-numeric"
                            )
                    sp = arm.get("speedup")
                    if isinstance(sp, (int, float)) and sp < 1.0:
                        errors.append(
                            f"{path}: learned controller loses to the heuristic on "
                            f"{name} (speedup {sp:.4f} < 1.0)"
                        )
                for want in ("stackex_7b_h200", "traffic_7b_h200"):
                    if want not in seen:
                        errors.append(
                            f"{path}: learned_controller.arms missing preset {want}"
                        )
    return errors


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None, help="directory holding BENCH_*.json")
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the newest snapshot's structure; non-zero exit on problems",
    )
    args = ap.parse_args()
    root = args.dir or os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    snaps = load_snapshots(root)
    if not snaps:
        print(f"no BENCH_*.json snapshots under {root}", file=sys.stderr)
        return 1
    print(f"found {len(snaps)} snapshot(s): " + ", ".join(p for _, p, _ in [(n, os.path.basename(p), d) for n, p, d in snaps]))
    chart_all(snaps)
    if args.check:
        errors = check_sequence(snaps) + check_latest(snaps)
        if errors:
            print("\ncheck FAILED:", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        print("\ncheck OK: newest snapshot is structurally sound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
