//! The paper's §3.1 invariance claim, tested over real compute: intra-step
//! streaming must not change what is learned.  With Δ = 0 and a shared
//! seed, the streamed (OppoNoInter) and monolithic (Sequential) pipelines
//! generate identical tokens and produce near-identical step rewards; the
//! only difference is *when* the reward model runs.
use std::sync::Arc;

use once_cell::sync::Lazy;
use oppo::config::{AdmissionMode, Mode, TrainConfig};
use oppo::coordinator::worker::{RewardReq, RewardResp, RewardWorker};
use oppo::coordinator::OppoScheduler;
use oppo::runtime::Engine;

static ENGINE: Lazy<Option<Arc<Engine>>> = Lazy::new(|| {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
});

fn one_step(mode: Mode, seed: u64) -> oppo::metrics::StepRecord {
    let cfg = TrainConfig {
        mode,
        steps: 1,
        task: "mixed".into(),
        seed,
        log_every: 0,
        max_new_tokens: 48,
        ..Default::default()
    };
    let mut sched = OppoScheduler::with_engine(cfg, ENGINE.clone().unwrap()).unwrap();
    sched.run_step(0).unwrap()
}

#[test]
fn streamed_scoring_equals_monolithic_scoring() {
    if ENGINE.is_none() { return }
    for seed in [3u64, 17] {
        let streamed = one_step(Mode::OppoNoInter, seed);
        let monolithic = one_step(Mode::Sequential, seed);
        // identical sampled tokens => identical token counts
        assert_eq!(
            streamed.gen_tokens, monolithic.gen_tokens,
            "seed {seed}: generation diverged"
        );
        // scores come from two different HLO programs (incremental vs dense
        // attention) — identical up to float re-association
        assert!(
            (streamed.mean_score - monolithic.mean_score).abs() < 2e-3,
            "seed {seed}: streamed {} vs monolithic {}",
            streamed.mean_score,
            monolithic.mean_score
        );
        // and the PPO update saw the same losses
        for (a, b) in streamed.train_stats.iter().zip(&monolithic.train_stats) {
            assert!((a - b).abs() < 2e-2, "train stats diverged: {a} vs {b}");
        }
    }
}

#[test]
fn streamed_ref_stage_matches_monolithic_ref_path() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    if !engine.manifest().ref_prefill_supported() {
        return; // older artifact set without chunked ref entries
    }
    // Mode::Oppo streams reference logprobs chunk-by-chunk during decoding;
    // Mode::OppoNoRef is the ablation arm that computes them with the
    // monolithic post-generation ref_logprobs call.  Same seed => identical
    // sampled tokens and identical reward scores; the ref logprobs come
    // from two different HLO programs, so the PPO update must agree to
    // float re-association tolerance.
    for seed in [5u64, 29] {
        let streamed = one_step(Mode::Oppo, seed);
        let monolithic = one_step(Mode::OppoNoRef, seed);
        assert_eq!(
            streamed.gen_tokens, monolithic.gen_tokens,
            "seed {seed}: generation diverged"
        );
        assert!(
            (streamed.mean_score - monolithic.mean_score).abs() < 1e-6,
            "seed {seed}: scores diverged (reward path is identical in both modes): \
             {} vs {}",
            streamed.mean_score,
            monolithic.mean_score
        );
        for (a, b) in streamed.train_stats.iter().zip(&monolithic.train_stats) {
            assert!((a - b).abs() < 2e-2, "seed {seed}: train stats diverged: {a} vs {b}");
        }
    }
}

fn rolling_cfg(mode: Mode, admission: AdmissionMode, steps: u64, seed: u64) -> TrainConfig {
    TrainConfig {
        mode,
        admission_mode: admission,
        steps,
        task: "mixed".into(),
        seed,
        log_every: 0,
        max_new_tokens: 48,
        ..Default::default()
    }
}

/// The rolling-admission equivalence contract: with saturated arrivals and
/// Δ = 0 the continuous-batching loop must be *score-equivalent* to the
/// legacy step-synchronous loop — same prompt stream, same selected batch
/// rows, same update.  Mid-step admits may decode extra tokens inside the
/// same chunks (so `gen_tokens` legitimately differs); what must match is
/// everything the PPO update sees.  Per-lane threefry sampling keeps the
/// original lanes' token streams untouched by extra live lanes.
#[test]
fn saturated_rolling_at_delta_zero_matches_the_step_loop() {
    if ENGINE.is_none() { return }
    for seed in [3u64, 17] {
        let run = |admission: AdmissionMode| {
            let cfg = rolling_cfg(Mode::OppoNoInter, admission, 1, seed);
            let mut sched =
                OppoScheduler::with_engine(cfg, ENGINE.clone().unwrap()).unwrap();
            sched.run_step(0).unwrap()
        };
        let step = run(AdmissionMode::Step);
        let roll = run(AdmissionMode::Saturated);
        // the reward path executes the identical program over identical
        // batch rows — row-independent kernels, so near-bit-identical
        assert!(
            (step.mean_score - roll.mean_score).abs() < 1e-6,
            "seed {seed}: step-sync {} vs rolling {}",
            step.mean_score,
            roll.mean_score
        );
        for (a, b) in step.train_stats.iter().zip(&roll.train_stats) {
            assert!(
                (a - b).abs() < 1e-4,
                "seed {seed}: train stats diverged: {a} vs {b}"
            );
        }
        // saturated arrivals never wait — the SLO accounting must agree
        for lat in &roll.prompt_latencies {
            assert_eq!(lat.queue_wait, 0.0, "saturated admission recorded a queue wait");
        }
    }
}

/// Mid-step admits change lane ownership while the streamed reward/ref
/// stages are in flight; their streamed per-sequence results must still be
/// identical (to float re-association) to a dense post-hoc recompute —
/// i.e. the seam resets on lane reuse never leak one sequence's state into
/// the next owner.
#[test]
fn mid_step_admits_stream_scores_equal_dense_recompute() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    let m = engine.manifest().shape.clone();
    let cfg = rolling_cfg(Mode::Oppo, AdmissionMode::Saturated, 4, 11);
    let mut sched = OppoScheduler::with_engine(cfg, engine.clone()).unwrap();
    let ref_streamed = sched.ref_streamed();
    let ops = oppo::coordinator::engine_ops::Ops::new(engine.clone(), 0).unwrap();
    let mut worker = RewardWorker::spawn(engine.clone(), 2).unwrap();
    let mut saw_mid_step = false;
    for step in 0..4u64 {
        sched.run_step(step).unwrap();
        let selected: Vec<_> = sched.last_selected().to_vec();
        assert!(!selected.is_empty(), "step {step}: empty batch under saturation");
        saw_mid_step |= selected.iter().any(|s| s.admitted_mid_step);

        // dense reward recompute over the selected rows
        let mut tokens = vec![0i32; m.lanes * m.s_max];
        let mut last_idx = vec![0i32; m.lanes];
        for (i, seq) in selected.iter().enumerate() {
            let t = seq.full_tokens();
            tokens[i * m.s_max..i * m.s_max + t.len()].copy_from_slice(&t);
            last_idx[i] = (t.len() - 1) as i32;
        }
        worker.submit(RewardReq::ScoreFull { tokens, last_idx }).unwrap();
        let dense_scores = match worker.recv().unwrap() {
            RewardResp::FullScores(all) => all,
            other => panic!("unexpected reward response {other:?}"),
        };
        for (i, seq) in selected.iter().enumerate() {
            let streamed = seq.rm_score.expect("selected sequence unscored");
            assert!(
                (streamed - dense_scores[i]).abs() < 2e-3,
                "step {step} lane {}: streamed score {streamed} vs dense {} \
                 (mid-step: {})",
                seq.lane,
                dense_scores[i],
                seq.admitted_mid_step
            );
        }

        // dense ref recompute (when the ref stage streams)
        if ref_streamed {
            let mut tokens = vec![0i32; m.ppo_batch * m.s_max];
            for (i, seq) in selected.iter().enumerate() {
                let t = seq.full_tokens();
                tokens[i * m.s_max..i * m.s_max + t.len()].copy_from_slice(&t);
            }
            let dense = ops.ref_logprobs(&tokens).unwrap();
            for (i, seq) in selected.iter().enumerate() {
                let len = seq.total_len();
                assert!(seq.ref_logp.len() >= len, "streamed ref coverage short");
                for p in 0..len {
                    let (a, b) = (seq.ref_logp[p], dense[i * m.s_max + p]);
                    assert!(
                        (a - b).abs() < 5e-3,
                        "step {step} lane {} pos {p}: streamed ref {a} vs dense {b} \
                         (mid-step: {})",
                        seq.lane,
                        seq.admitted_mid_step
                    );
                }
            }
        }
    }
    assert!(
        saw_mid_step,
        "4 saturated rolling steps never admitted mid-step — release gate stuck"
    );
}

#[test]
fn intra_overlap_streams_while_generating() {
    if ENGINE.is_none() { return }
    // in streamed mode the reward worker processed chunks during the step —
    // indirectly visible as identical results with a different exec count
    let engine = ENGINE.clone().unwrap();
    let counts = |prefix: &str| -> u64 {
        engine
            .stats_snapshot()
            .iter()
            .filter(|(n, _, _)| n.starts_with(prefix))
            .map(|(_, c, _)| *c)
            .sum()
    };
    let reward_before = counts("reward_prefill_chunk");
    let ref_before = counts("ref_prefill_chunk");
    let _ = one_step(Mode::OppoNoInter, 23);
    assert!(
        counts("reward_prefill_chunk") > reward_before,
        "no incremental reward prefill calls recorded"
    );
    if engine.manifest().ref_prefill_supported() {
        assert!(
            counts("ref_prefill_chunk") > ref_before,
            "no incremental ref prefill calls recorded"
        );
        // per-stage scope attribution is live too
        assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "ref" && *c > 0));
    }
    assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "reward" && *c > 0));
    assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "actor" && *c > 0));
}
