//! The downstream stage workers — reward scoring and reference log-probs —
//! built on the generic [`StagePool`](crate::coordinator::stage) runtime,
//! plus [`StreamSink`], the scheduler-side facade that fans one streamed
//! `[G, C]` chunk out to every active stage.
//!
//! This is the concurrency that realizes §3.1's intra-step overlap: while
//! the actor thread executes `actor_generate_chunk` for chunk *k*, the
//! reward thread executes `reward_prefill_chunk` and the ref thread
//! `ref_prefill_chunk` for chunk *k−1*.  PJRT executes all of them
//! concurrently (thread-safe client), so downstream prefill latency hides
//! behind actor decoding exactly as in the paper's Figure 1b — now for
//! *every* downstream model, not just reward.
//!
//! Each stage is a **pool of replicas**: the spawn path hands the pool a
//! handler *factory*, so every replica constructs its own ops + device
//! state on its own thread (independent parameter buffers, independent KV
//! caches).  Chunks are split lane-wise across the pool with
//! sequence-affinity routing (`lane % replicas`): the replica that prefixed
//! a sequence's earlier chunks holds its KV/seam state, so all later chunks
//! of that sequence must — and do — land on the same replica.  Replicas pay
//! off through *concurrency* — independent worker threads whose kernels
//! PJRT can execute on separate streams/devices — not by shrinking each
//! replica's per-chunk FLOPs (the fixed-shape entries compute all `[G, C]`
//! positions; see `StreamChunk::for_replica`).  With one replica the split
//! is the identity and the behaviour is exactly the old single-worker path.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::buffer::SeqBuffer;
use crate::coordinator::engine_ops::{RefOps, RefStreamState, RewardOps, RewardState};
use crate::coordinator::stage::{StageHandler, StagePool};
use crate::metrics::StageTiming;
use crate::model::sequence::Sequence;
use crate::runtime::Engine;

/// Which lane positions hold a sequence's *final* token in this chunk —
/// the reward worker returns the score read off at exactly those positions.
#[derive(Clone, Debug)]
pub struct Pick {
    pub lane: usize,
    pub idx_in_chunk: usize,
}

/// One streamed `[G, C]` chunk of actor output, built once per decode
/// iteration and fanned out to every active downstream stage.
#[derive(Clone, Debug)]
pub struct StreamChunk {
    /// chunk size C
    pub c: usize,
    /// row-major [G, C] token chunk (PAD-filled for idle lanes)
    pub tokens: Vec<i32>,
    /// per-lane absolute start position
    pub start: Vec<i32>,
    /// per-lane number of valid tokens in the chunk
    pub n_valid: Vec<i32>,
    /// lanes whose final token lands in this chunk
    pub picks: Vec<Pick>,
}

impl StreamChunk {
    /// The sub-chunk replica `r` of `n` must process.  Lanes the replica
    /// does not own (`lane % n != r`) are masked dead (`n_valid = 0`, picks
    /// dropped): the stage kernels read results and advance seam state only
    /// for `n_valid > 0` lanes, so unowned lanes cannot corrupt the
    /// replica's per-lane KV/seam data.  Note the current AOT entries still
    /// *compute* the full `[G, C]` grid regardless of the mask — replicas
    /// win by executing concurrently on independent resources (threads /
    /// PJRT streams / devices), not by doing fewer FLOPs each; lane-sliced
    /// `[G/n, C]` entries that skip the dead lanes are a ROADMAP item.
    /// Returns `None` when no owned lane carries valid tokens.  With
    /// `n == 1` this is the identity, which keeps a one-replica pool
    /// bit-compatible with the old single-worker path.
    pub fn for_replica(&self, r: usize, n: usize) -> Option<StreamChunk> {
        if n <= 1 {
            return Some(self.clone());
        }
        let mut part = self.clone();
        let mut any = false;
        for (lane, nv) in part.n_valid.iter_mut().enumerate() {
            if lane % n == r {
                any = any || *nv > 0;
            } else {
                *nv = 0;
            }
        }
        if !any {
            return None;
        }
        part.picks.retain(|p| p.lane % n == r);
        Some(part)
    }
}

// ---------------------------------------------------------------------------
// reward stage
// ---------------------------------------------------------------------------

/// Requests to the reward worker.
pub enum RewardReq {
    /// Incremental prefill of one streamed chunk (intra-step overlap).
    Stream {
        /// entry name (`reward_prefill_chunk_c{C}` or the pallas flavour)
        entry: String,
        chunk: Vec<i32>,
        start: Vec<i32>,
        n_valid: Vec<i32>,
        /// final-token positions to read scores from
        picks: Vec<Pick>,
    },
    /// Monolithic scoring (baselines / ablation w/o intra).
    ScoreFull { tokens: Vec<i32>, last_idx: Vec<i32> },
    /// Reset the reward KV state (new run / tests).
    Reset,
}

/// Worker responses (tagged and in submission order).
#[derive(Debug)]
pub enum RewardResp {
    /// (lane, score) for each pick in the stream request
    StreamScores(Vec<(usize, f32)>),
    /// all-lane scores for a ScoreFull request
    FullScores(Vec<f32>),
    /// acknowledgement of Reset
    ResetDone,
}

struct RewardHandler {
    ops: RewardOps,
    state: RewardState,
}

impl StageHandler for RewardHandler {
    type Req = RewardReq;
    type Resp = RewardResp;

    fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        match req {
            RewardReq::Reset => {
                self.state = self.ops.fresh_state()?;
                Ok(RewardResp::ResetDone)
            }
            RewardReq::Stream { entry, chunk, start, n_valid, picks } => {
                let g = start.len();
                let c = chunk.len() / g;
                let scores =
                    self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?;
                Ok(RewardResp::StreamScores(
                    picks
                        .iter()
                        .map(|p| (p.lane, scores[p.lane * c + p.idx_in_chunk]))
                        .collect(),
                ))
            }
            RewardReq::ScoreFull { tokens, last_idx } => {
                Ok(RewardResp::FullScores(self.ops.score_full(&tokens, &last_idx)?))
            }
        }
    }
}

/// Handle to the reward stage — a pool of one or more replicas, each
/// owning an independent `RewardOps` (own parameter buffers, own KV state,
/// built on its own thread by the handler factory).
pub struct RewardWorker {
    pool: StagePool<RewardReq, RewardResp>,
}

impl RewardWorker {
    /// Single-replica spawn (the monolithic scorer and simple callers).
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    /// Spawn `replicas` reward workers.  Streamed chunks are routed
    /// `lane % replicas`, so each replica prefills a disjoint lane subset
    /// against its own KV cache.
    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let pool = StagePool::spawn("reward", replicas, queue_depth, |_replica| {
            let engine = engine.clone();
            move || {
                let ops = RewardOps::new(engine)?;
                let state = ops.fresh_state()?;
                Ok(RewardHandler { ops, state })
            }
        })?;
        Ok(Self { pool })
    }

    pub fn replicas(&self) -> usize {
        self.pool.replicas()
    }

    /// The replica owning `lane`'s KV state.
    pub fn replica_for_lane(&self, lane: usize) -> usize {
        self.pool.replica_for_lane(lane)
    }

    /// Enqueue on replica 0 (single-replica / monolithic path).
    pub fn submit(&mut self, req: RewardReq) -> Result<()> {
        self.pool.submit_to(0, req).map(|_| ())
    }

    /// Enqueue on one replica (bounded queue; blocks only under that
    /// replica's backpressure).
    pub fn submit_to(&mut self, replica: usize, req: RewardReq) -> Result<()> {
        self.pool.submit_to(replica, req).map(|_| ())
    }

    /// Two-phase fan-out of per-replica parts (see [`StagePool::fan_out`]).
    pub fn fan_out(&mut self, parts: Vec<(usize, RewardReq)>) -> Result<()> {
        self.pool.fan_out(parts)
    }

    /// Block for the next response from replica 0.
    pub fn recv(&mut self) -> Result<RewardResp> {
        self.pool.recv_from(0).map(|(_, r)| r)
    }

    /// Block for the next response from one replica.
    pub fn recv_from(&mut self, replica: usize) -> Result<RewardResp> {
        self.pool.recv_from(replica).map(|(_, r)| r)
    }

    /// Non-blocking: first ready response from any replica.
    pub fn try_recv_any(&mut self) -> Result<Option<(usize, RewardResp)>> {
        Ok(self.pool.try_recv_any()?.map(|(r, _, resp)| (r, resp)))
    }

    pub fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    pub fn in_flight_on(&self, replica: usize) -> usize {
        self.pool.in_flight_on(replica)
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.pool.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// reference stage
// ---------------------------------------------------------------------------

/// Requests to the reference worker.
pub enum RefReq {
    /// Incremental ref-logprob prefill of one streamed chunk.
    Stream { entry: String, chunk: Vec<i32>, start: Vec<i32>, n_valid: Vec<i32> },
    /// Reset the ref KV/boundary state (new run / tests).
    Reset,
}

#[derive(Debug)]
pub enum RefResp {
    /// raw [G, C] log-probs for a stream request (garbage at j >= n_valid)
    StreamLogps(Vec<f32>),
    ResetDone,
}

struct RefHandler {
    ops: RefOps,
    state: RefStreamState,
}

impl StageHandler for RefHandler {
    type Req = RefReq;
    type Resp = RefResp;

    fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        match req {
            RefReq::Reset => {
                self.state = self.ops.fresh_state()?;
                Ok(RefResp::ResetDone)
            }
            RefReq::Stream { entry, chunk, start, n_valid } => Ok(RefResp::StreamLogps(
                self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?,
            )),
        }
    }
}

/// Handle to the reference stage — a pool of one or more replicas, each
/// owning an independent `RefOps` plus its own KV + boundary seam state.
pub struct RefWorker {
    pool: StagePool<RefReq, RefResp>,
}

impl RefWorker {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    /// Spawn `replicas` reference workers with sequence-affinity routing
    /// (`lane % replicas` — the boundary log-softmax seam is per-lane state
    /// that must stay on one replica).
    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let pool = StagePool::spawn("ref", replicas, queue_depth, |_replica| {
            let engine = engine.clone();
            move || {
                let ops = RefOps::new(engine)?;
                let state = ops.fresh_state()?;
                Ok(RefHandler { ops, state })
            }
        })?;
        Ok(Self { pool })
    }

    pub fn replicas(&self) -> usize {
        self.pool.replicas()
    }

    pub fn replica_for_lane(&self, lane: usize) -> usize {
        self.pool.replica_for_lane(lane)
    }

    /// Enqueue on replica 0 (single-replica callers).
    pub fn submit(&mut self, req: RefReq) -> Result<()> {
        self.pool.submit_to(0, req).map(|_| ())
    }

    pub fn submit_to(&mut self, replica: usize, req: RefReq) -> Result<()> {
        self.pool.submit_to(replica, req).map(|_| ())
    }

    /// Two-phase fan-out of per-replica parts (see [`StagePool::fan_out`]).
    pub fn fan_out(&mut self, parts: Vec<(usize, RefReq)>) -> Result<()> {
        self.pool.fan_out(parts)
    }

    /// Block for the next response from replica 0.
    pub fn recv(&mut self) -> Result<RefResp> {
        self.pool.recv_from(0).map(|(_, r)| r)
    }

    pub fn recv_from(&mut self, replica: usize) -> Result<RefResp> {
        self.pool.recv_from(replica).map(|(_, r)| r)
    }

    pub fn try_recv_any(&mut self) -> Result<Option<(usize, RefResp)>> {
        Ok(self.pool.try_recv_any()?.map(|(r, _, resp)| (r, resp)))
    }

    pub fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    pub fn in_flight_on(&self, replica: usize) -> usize {
        self.pool.in_flight_on(replica)
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.pool.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// fan-out facade
// ---------------------------------------------------------------------------

/// Ref sink bookkeeping: responses are raw `[G, C]` log-prob grids, so the
/// per-request `(start, n_valid, c)` metadata rides a FIFO alongside the
/// in-flight requests — one FIFO **per replica**, because each replica
/// answers strictly in its own submission order while responses from
/// different replicas may interleave (they touch disjoint lane sets).
pub struct RefSink {
    worker: RefWorker,
    meta: Vec<VecDeque<(Vec<i32>, Vec<i32>, usize)>>,
}

impl RefSink {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Self::spawn_replicated(engine, 1, queue_depth)
    }

    pub fn spawn_replicated(
        engine: Arc<Engine>,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let worker = RefWorker::spawn_replicated(engine, replicas, queue_depth)?;
        let meta = (0..worker.replicas()).map(|_| VecDeque::new()).collect();
        Ok(Self { worker, meta })
    }

    fn apply(&mut self, replica: usize, buf: &mut SeqBuffer, logps: Vec<f32>) -> Result<()> {
        let (start, n_valid, c) = self.meta[replica]
            .pop_front()
            .context("ref stage response without a matching request")?;
        for lane in 0..start.len() {
            let nv = n_valid[lane] as usize;
            if nv == 0 {
                continue;
            }
            let seq = buf
                .by_lane_mut(lane)
                .with_context(|| format!("ref response for vacated lane {lane}"))?;
            let st = start[lane] as usize;
            ensure!(
                seq.ref_logp.len() == st,
                "ref stream discontinuity on lane {lane}: have {} positions, chunk starts at {st}",
                seq.ref_logp.len()
            );
            seq.ref_logp.extend_from_slice(&logps[lane * c..lane * c + nv]);
        }
        Ok(())
    }
}

/// Scheduler-side handle to one active downstream stage.  The step loop
/// fans every [`StreamChunk`] out to all sinks and joins them at flush;
/// each sink splits the chunk lane-wise across its replica pool
/// (sequence-affinity routing).  Future stages (critic, remote-node
/// consumers) add a variant here and a worker above, and the scheduler
/// loop stays untouched.
pub enum StreamSink {
    Reward(RewardWorker),
    Ref(RefSink),
}

impl StreamSink {
    pub fn name(&self) -> &'static str {
        match self {
            StreamSink::Reward(_) => "reward",
            StreamSink::Ref(_) => "ref",
        }
    }

    /// Worker replicas behind this stage.
    pub fn replicas(&self) -> usize {
        match self {
            StreamSink::Reward(w) => w.replicas(),
            StreamSink::Ref(s) => s.worker.replicas(),
        }
    }

    /// Submit one streamed chunk to this stage: one sub-request per replica
    /// that owns any valid lane in the chunk (typed per-stage request),
    /// delivered through the pool's two-phase fan-out — a busy replica
    /// delays only its own feeding (see [`StagePool::fan_out`]).
    pub fn submit_chunk(&mut self, ck: &StreamChunk) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                let n = w.replicas();
                let mut parts = Vec::new();
                for r in 0..n {
                    let Some(part) = ck.for_replica(r, n) else { continue };
                    parts.push((
                        r,
                        RewardReq::Stream {
                            entry: format!("reward_prefill_chunk_c{}", part.c),
                            chunk: part.tokens,
                            start: part.start,
                            n_valid: part.n_valid,
                            picks: part.picks,
                        },
                    ));
                }
                w.fan_out(parts)
            }
            StreamSink::Ref(s) => {
                let n = s.worker.replicas();
                let mut parts = Vec::new();
                for r in 0..n {
                    let Some(part) = ck.for_replica(r, n) else { continue };
                    // meta rides in per-replica submission order; each
                    // replica gets at most one part per chunk, so pushing at
                    // build time keeps the FIFO aligned whichever fan-out
                    // phase actually enqueues the part
                    s.meta[r].push_back((part.start.clone(), part.n_valid.clone(), part.c));
                    parts.push((
                        r,
                        RefReq::Stream {
                            entry: format!("ref_prefill_chunk_c{}", part.c),
                            chunk: part.tokens,
                            start: part.start,
                            n_valid: part.n_valid,
                        },
                    ));
                }
                s.worker.fan_out(parts)
            }
        }
    }

    /// Apply any responses that are already available (non-blocking).
    pub fn collect_ready(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                while let Some((_replica, resp)) = w.try_recv_any()? {
                    apply_reward(buf, resp)?;
                }
            }
            StreamSink::Ref(s) => {
                while let Some((replica, resp)) = s.worker.try_recv_any()? {
                    match resp {
                        RefResp::StreamLogps(lp) => s.apply(replica, buf, lp)?,
                        other => bail!("unexpected ref response {other:?}"),
                    }
                }
            }
        }
        Ok(())
    }

    /// Block until every in-flight response is applied (the flush join),
    /// draining each replica in turn — responses are ordered per replica.
    pub fn join(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                for r in 0..w.replicas() {
                    while w.in_flight_on(r) > 0 {
                        let resp = w.recv_from(r)?;
                        apply_reward(buf, resp)?;
                    }
                }
            }
            StreamSink::Ref(s) => {
                for r in 0..s.worker.replicas() {
                    while s.worker.in_flight_on(r) > 0 {
                        match s.worker.recv_from(r)? {
                            RefResp::StreamLogps(lp) => s.apply(r, buf, lp)?,
                            other => bail!("unexpected ref response {other:?}"),
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Does this stage hold everything it needs for `seq`?  Checked for
    /// finished sequences when deciding whether the flush loop must keep
    /// streaming.
    pub fn is_satisfied(&self, seq: &Sequence) -> bool {
        match self {
            StreamSink::Reward(_) => seq.rm_score.is_some(),
            StreamSink::Ref(_) => seq.ref_logp.len() >= seq.total_len(),
        }
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        match self {
            StreamSink::Reward(w) => w.timing_delta(),
            StreamSink::Ref(s) => s.worker.timing_delta(),
        }
    }
}

fn apply_reward(buf: &mut SeqBuffer, resp: RewardResp) -> Result<()> {
    match resp {
        RewardResp::StreamScores(scores) => {
            for (lane, score) in scores {
                if let Some(seq) = buf.by_lane_mut(lane) {
                    seq.rm_score = Some(score);
                }
            }
            Ok(())
        }
        other => bail!("unexpected reward response {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk() -> StreamChunk {
        StreamChunk {
            c: 4,
            tokens: (0..6 * 4).map(|x| x as i32).collect(),
            start: vec![0; 6],
            n_valid: vec![4, 0, 2, 4, 1, 3],
            picks: vec![Pick { lane: 0, idx_in_chunk: 3 }, Pick { lane: 4, idx_in_chunk: 0 }],
        }
    }

    #[test]
    fn for_replica_is_the_identity_with_one_replica() {
        let ck = chunk();
        let part = ck.for_replica(0, 1).unwrap();
        assert_eq!(part.n_valid, ck.n_valid);
        assert_eq!(part.tokens, ck.tokens);
        assert_eq!(part.picks.len(), ck.picks.len());
    }

    #[test]
    fn for_replica_masks_unowned_lanes_and_filters_picks() {
        let ck = chunk();
        let even = ck.for_replica(0, 2).unwrap();
        assert_eq!(even.n_valid, vec![4, 0, 2, 0, 1, 0]);
        assert_eq!(even.picks.len(), 2, "picks on lanes 0 and 4 are owned");
        assert!(even.picks.iter().all(|p| p.lane % 2 == 0));
        let odd = ck.for_replica(1, 2).unwrap();
        assert_eq!(odd.n_valid, vec![0, 0, 0, 4, 0, 3]);
        assert!(odd.picks.is_empty());
        // the split is a partition: every valid token owned exactly once
        for lane in 0..6 {
            assert_eq!(even.n_valid[lane] + odd.n_valid[lane], ck.n_valid[lane]);
        }
    }

    #[test]
    fn for_replica_elides_replicas_with_nothing_to_do() {
        let mut ck = chunk();
        ck.n_valid = vec![4, 0, 2, 0, 1, 0]; // odd lanes all idle
        assert!(ck.for_replica(1, 2).is_none(), "no owned valid lane => no request");
        assert!(ck.for_replica(0, 2).is_some());
    }
}
