//! Pinned-seed performance snapshot → `BENCH_6.json`.
//!
//! Runs the deterministic simulator on the paper's main preset at a fixed
//! seed and emits a machine-readable snapshot of the metrics this repo's
//! perf work is judged by: per-stage busy/idle attribution, steady-state
//! step wall time, streamed-chunk throughput, and the lane-slicing knee
//! (`min_replicas_actor_bound`).  The sim sections are bit-reproducible on
//! any machine — same seed, same numbers — so the committed snapshot diffs
//! cleanly against a re-run; the `host` section (peak RSS, runner wall
//! time) is machine-dependent and refreshed by each local run.
//!
//! Usage:
//!   cargo bench --bench bench_snapshot              # writes ../BENCH_6.json
//!   cargo bench --bench bench_snapshot -- --out /tmp/snap.json

use std::time::Instant;

use oppo::eval::{print_table, Row};
use oppo::metrics::RunLog;
use oppo::sim::pipeline::{min_replicas_actor_bound, simulate, Pipeline, SimConfig};
use oppo::sim::presets;
use oppo::util::json::{self, Value};

const SEED: u64 = 600;
const STEPS: usize = 60;
const KNEE_MAX: usize = 8;
const KNEE_TOL: f64 = 0.02;

fn cfg(reward_replicas: usize, ref_replicas: usize) -> SimConfig {
    let mut c = SimConfig::new(presets::stackex_7b_h200(), STEPS, SEED);
    c.reward_replicas = reward_replicas;
    c.ref_replicas = ref_replicas;
    c
}

/// Steady-state (last-half) aggregates for one run, as a JSON scenario
/// block plus a human table row.
fn scenario(name: &str, log: &RunLog) -> (Value, Row) {
    let tail = &log.records[log.records.len() / 2..];
    let n = tail.len() as f64;
    let (mut wall, mut util, mut chunks, mut gen_tokens) = (0.0, 0.0, 0.0, 0.0);
    for r in tail {
        wall += r.wall_s;
        util += r.util;
        chunks += r.gen_tokens as f64 / r.chunk.max(1) as f64;
        gen_tokens += r.gen_tokens as f64;
    }
    let mut stages = Vec::new();
    for (i, st0) in tail[0].stages.iter().enumerate() {
        let (mut busy, mut idle) = (0.0, 0.0);
        let mut items = 0u64;
        for r in tail {
            busy += r.stages[i].busy_s;
            idle += r.stages[i].idle_s;
            items += r.stages[i].items;
        }
        stages.push(json::obj(vec![
            ("name", json::s(&st0.name)),
            ("replicas", json::num(st0.replicas as f64)),
            ("busy_s_mean", json::num(busy / n)),
            ("idle_s_mean", json::num(idle / n)),
            ("util", json::num(busy / (busy + idle).max(1e-12))),
            ("items", json::num(items as f64)),
        ]));
    }
    let v = json::obj(vec![
        ("mode", json::s(&log.mode)),
        ("step_wall_s_mean", json::num(wall / n)),
        ("util_mean", json::num(util / n)),
        ("streamed_chunks_per_s", json::num(chunks / wall)),
        ("gen_tokens_per_s", json::num(gen_tokens / wall)),
        ("stages", Value::Arr(stages)),
    ]);
    let row = Row::new(name)
        .cell("step_s", wall / n)
        .cell("util", util / n)
        .cell("chunks_ps", chunks / wall)
        .cell("tok_ps", gen_tokens / wall);
    (v, row)
}

fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn main() {
    let mut out_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            out_path = args.next();
        }
        // anything else (--bench, harness flags) is cargo's — ignore
    }
    let out_path = out_path
        .unwrap_or_else(|| format!("{}/../BENCH_6.json", env!("CARGO_MANIFEST_DIR")));

    let t0 = Instant::now();
    let scenarios: [(&str, Pipeline, usize, usize); 3] = [
        ("trl", Pipeline::TrlSequential, 1, 1),
        ("oppo_x1", Pipeline::oppo(), 1, 1),
        ("oppo_reward4_ref2", Pipeline::oppo(), 4, 2),
    ];
    let mut rows = Vec::new();
    let mut svals = Vec::new();
    for (name, p, rr, fr) in scenarios {
        let log = simulate(p, &cfg(rr, fr));
        let (v, row) = scenario(name, &log);
        svals.push((name, v));
        rows.push(row);
    }
    let knee = min_replicas_actor_bound(&cfg(1, 1), KNEE_MAX, KNEE_TOL);

    let host = json::obj(vec![
        ("note", json::s("machine-dependent; refreshed by each local run")),
        (
            "peak_rss_kb",
            peak_rss_kb().map(|k| json::num(k as f64)).unwrap_or(Value::Null),
        ),
        ("snapshot_wall_ms", json::num(t0.elapsed().as_secs_f64() * 1e3)),
    ]);
    let doc = json::obj(vec![
        ("bench", json::s("bench_snapshot")),
        ("preset", json::s("stackex-7b-h200")),
        ("seed", json::num(SEED as f64)),
        ("steps", json::num(STEPS as f64)),
        ("tail_steps", json::num((STEPS - STEPS / 2) as f64)),
        ("chunk_tokens", json::num(cfg(1, 1).chunk_tokens)),
        ("scenarios", json::obj(svals)),
        ("sliced_knee_reward_replicas", json::num(knee as f64)),
        ("host", host),
    ]);
    let text = json::to_string(&doc) + "\n";
    std::fs::write(&out_path, &text).expect("write snapshot");

    print_table("BENCH_6 snapshot (stackex-7b-h200, seed 600, last-half means)", &rows);
    println!("sliced knee: {knee} reward replicas (tol {KNEE_TOL})");
    println!("wrote {out_path}");
}
