"""Pallas chunked-prefill attention — the kernel behind intra-step overlap.

OPPO (§3.1) streams actor output in chunks to the reward model so scoring
prefill proceeds *incrementally* while the actor keeps decoding.  The compute
hot-spot of that design is "attend a chunk of C new queries against the
KV cache accumulated so far".  On the authors' GPUs this is chunked prefill
against paged KV; here it is restated for the TPU memory hierarchy
(DESIGN.md §7):

* the chunk's Q tile (``C×D``) is small and lives in VMEM for the whole
  kernel invocation;
* the KV history streams HBM→VMEM in ``BLOCK_K``-sized blocks expressed via
  the grid / ``pl.load`` schedule (the analogue of the paper's threadblock
  tiling);
* a flash-attention style running softmax (m/l carries) bounds the working
  set to ``C × BLOCK_K`` regardless of history length, so VMEM stays flat as
  the sequence grows — precisely the property that keeps incremental prefill
  cheap for late chunks;
* the two matmuls per block (``q @ k.T`` and ``p @ v``) are the MXU-shaped
  work; the causal masking is cheap VPU work.
* blocks strictly beyond the chunk's last absolute position are *skipped*
  (dynamic ``fori_loop`` bound), so early chunks do not pay for the full
  ``S_max`` cache scan.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Interpret mode runs the
identical schedule with numpy semantics, so correctness transfers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default KV block: multiples of the 128-lane TPU tile on the sequence dim.
DEFAULT_BLOCK_K = 32


def _prefill_kernel(start_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch*head) program: C queries vs the blocked KV history."""
    c, d = q_ref.shape[1], q_ref.shape[2]
    # int indices into refs are rejected by interpret-mode discharge on this
    # jax version; read the whole (1, ...) block and squeeze instead.
    start = start_ref[...][0]
    q = q_ref[...][0].astype(jnp.float32) * scale  # [C, D] — VMEM-resident Q tile

    m0 = jnp.full((c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((c,), jnp.float32)
    acc0 = jnp.zeros((c, d), jnp.float32)

    # Only blocks that contain positions <= start + C - 1 participate:
    # the flash loop's dynamic trip count — skip the untouched cache tail.
    last_pos = start + c - 1
    n_blocks = (last_pos // block_k) + 1

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (slice(None), pl.dslice(kb * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (slice(None), pl.dslice(kb * block_k, block_k), slice(None)))[0]
        scores = q @ k.astype(jnp.float32).T  # [C, BLOCK_K] — MXU matmul 1
        jpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (c, block_k), 1)
        qpos = start + jax.lax.broadcasted_iota(jnp.int32, (c, block_k), 0)
        scores = jnp.where(jpos <= qpos, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=1))
        alpha = jnp.exp(m - m_new)  # m starts at NEG_INF => alpha=0 first time
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(jpos <= qpos, p, 0.0)
        l_new = alpha * l + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)  # MXU matmul 2
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_k",))
def chunked_prefill_attention(
    q: jax.Array,  # [B, H, C, D]
    k_cache: jax.Array,  # [B, H, S, D]
    v_cache: jax.Array,  # [B, H, S, D]
    start: jax.Array,  # [B] int32
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Pallas chunked-prefill attention; semantics match ``ref.chunked_prefill_attention``."""
    b, h, c, d = q.shape
    s = k_cache.shape[2]
    if s % block_k != 0:
        raise ValueError(f"cache length {s} must be a multiple of block_k={block_k}")
    scale = 1.0 / (d**0.5)

    # Collapse (B, H) into the grid; each program owns one head's chunk.
    qf = q.reshape(b * h, c, d)
    kf = k_cache.reshape(b * h, s, d)
    vf = v_cache.reshape(b * h, s, d)
    startf = jnp.repeat(start.astype(jnp.int32), h)  # [B*H]

    out = pl.pallas_call(
        functools.partial(_prefill_kernel, block_k=block_k, scale=scale),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),  # start (scalar per program)
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),  # q tile
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # k history (blocked via pl.load)
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # v history
        ],
        out_specs=pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, c, d), q.dtype),
        interpret=True,
    )(startf, qf, kf, vf)
    return out.reshape(b, h, c, d)


def vmem_footprint_bytes(c: int, d: int, s: int, block_k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one program (DESIGN.md §Perf).

    Q tile + one K block + one V block + softmax carries + accumulator.
    Independent of ``s`` — that is the point of the flash schedule.
    """
    q_tile = c * d * dtype_bytes
    kv_block = 2 * block_k * d * dtype_bytes
    carries = (2 * c + c * block_k) * 4
    acc = c * d * 4
    del s
    return q_tile + kv_block + carries + acc


def mxu_utilization_estimate(c: int, d: int, block_k: int) -> float:
    """Fraction of MXU-shaped work per block, vs the 128×128 systolic tile.

    Both matmuls are (C×D)·(D×BLOCK_K) and (C×BLOCK_K)·(BLOCK_K×D); the MXU
    processes 128×128 tiles, so efficiency is the product of the dimension
    fill ratios (clamped at 1).  Used for the §Perf block-shape sweep.
    """
    fill = lambda n: min(n / 128.0, 1.0)
    mm1 = fill(c) * fill(d) * fill(block_k)
    mm2 = fill(c) * fill(block_k) * fill(d)
    return 0.5 * (mm1 + mm2)
