//! The paper's §3.1 invariance claim, tested over real compute: intra-step
//! streaming must not change what is learned.  With Δ = 0 and a shared
//! seed, the streamed (OppoNoInter) and monolithic (Sequential) pipelines
//! generate identical tokens and produce near-identical step rewards; the
//! only difference is *when* the reward model runs.
use std::sync::Arc;

use once_cell::sync::Lazy;
use oppo::config::{Mode, TrainConfig};
use oppo::coordinator::OppoScheduler;
use oppo::runtime::Engine;

static ENGINE: Lazy<Option<Arc<Engine>>> = Lazy::new(|| {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
});

fn one_step(mode: Mode, seed: u64) -> oppo::metrics::StepRecord {
    let cfg = TrainConfig {
        mode,
        steps: 1,
        task: "mixed".into(),
        seed,
        log_every: 0,
        max_new_tokens: 48,
        ..Default::default()
    };
    let mut sched = OppoScheduler::with_engine(cfg, ENGINE.clone().unwrap()).unwrap();
    sched.run_step(0).unwrap()
}

#[test]
fn streamed_scoring_equals_monolithic_scoring() {
    if ENGINE.is_none() { return }
    for seed in [3u64, 17] {
        let streamed = one_step(Mode::OppoNoInter, seed);
        let monolithic = one_step(Mode::Sequential, seed);
        // identical sampled tokens => identical token counts
        assert_eq!(
            streamed.gen_tokens, monolithic.gen_tokens,
            "seed {seed}: generation diverged"
        );
        // scores come from two different HLO programs (incremental vs dense
        // attention) — identical up to float re-association
        assert!(
            (streamed.mean_score - monolithic.mean_score).abs() < 2e-3,
            "seed {seed}: streamed {} vs monolithic {}",
            streamed.mean_score,
            monolithic.mean_score
        );
        // and the PPO update saw the same losses
        for (a, b) in streamed.train_stats.iter().zip(&monolithic.train_stats) {
            assert!((a - b).abs() < 2e-2, "train stats diverged: {a} vs {b}");
        }
    }
}

#[test]
fn streamed_ref_stage_matches_monolithic_ref_path() {
    if ENGINE.is_none() { return }
    let engine = ENGINE.clone().unwrap();
    if !engine.manifest().ref_prefill_supported() {
        return; // older artifact set without chunked ref entries
    }
    // Mode::Oppo streams reference logprobs chunk-by-chunk during decoding;
    // Mode::OppoNoRef is the ablation arm that computes them with the
    // monolithic post-generation ref_logprobs call.  Same seed => identical
    // sampled tokens and identical reward scores; the ref logprobs come
    // from two different HLO programs, so the PPO update must agree to
    // float re-association tolerance.
    for seed in [5u64, 29] {
        let streamed = one_step(Mode::Oppo, seed);
        let monolithic = one_step(Mode::OppoNoRef, seed);
        assert_eq!(
            streamed.gen_tokens, monolithic.gen_tokens,
            "seed {seed}: generation diverged"
        );
        assert!(
            (streamed.mean_score - monolithic.mean_score).abs() < 1e-6,
            "seed {seed}: scores diverged (reward path is identical in both modes): \
             {} vs {}",
            streamed.mean_score,
            monolithic.mean_score
        );
        for (a, b) in streamed.train_stats.iter().zip(&monolithic.train_stats) {
            assert!((a - b).abs() < 2e-2, "seed {seed}: train stats diverged: {a} vs {b}");
        }
    }
}

#[test]
fn intra_overlap_streams_while_generating() {
    if ENGINE.is_none() { return }
    // in streamed mode the reward worker processed chunks during the step —
    // indirectly visible as identical results with a different exec count
    let engine = ENGINE.clone().unwrap();
    let counts = |prefix: &str| -> u64 {
        engine
            .stats_snapshot()
            .iter()
            .filter(|(n, _, _)| n.starts_with(prefix))
            .map(|(_, c, _)| *c)
            .sum()
    };
    let reward_before = counts("reward_prefill_chunk");
    let ref_before = counts("ref_prefill_chunk");
    let _ = one_step(Mode::OppoNoInter, 23);
    assert!(
        counts("reward_prefill_chunk") > reward_before,
        "no incremental reward prefill calls recorded"
    );
    if engine.manifest().ref_prefill_supported() {
        assert!(
            counts("ref_prefill_chunk") > ref_before,
            "no incremental ref prefill calls recorded"
        );
        // per-stage scope attribution is live too
        assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "ref" && *c > 0));
    }
    assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "reward" && *c > 0));
    assert!(engine.scope_snapshot().iter().any(|(s, c, _)| s == "actor" && *c > 0));
}
