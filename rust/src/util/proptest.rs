//! In-repo randomized property-testing harness (proptest is not in the
//! offline crate set).  Deterministic seed-derived cases + linear input
//! shrinking for `Vec`-shaped inputs; on failure the reporting includes the
//! failing seed so a case can be replayed exactly.
//!
//! Used by the coordinator invariant suites (`rust/tests/test_props.rs`):
//! buffer capacity/FIFO order, first-B-completion selection, Δ-controller
//! bounds, chunk-controller accounting, simulator conservation laws.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// max shrink attempts after a failure
    pub shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC0FFEE, shrink_iters: 200 }
    }
}

/// Outcome of one property check.
pub type CheckResult = Result<(), String>;

/// Run `prop` against `cases` randomly generated inputs.
///
/// `gen` draws an input from an [`Rng`]; `prop` returns `Err(reason)` on
/// violation.  Panics with a replayable report on the first failure.
pub fn forall<T, G, P>(cfg: Config, name: &str, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CheckResult,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed:#x}):\n  \
                 reason: {reason}\n  input: {input:?}"
            );
        }
    }
}

/// Like [`forall`] but with list-shaped inputs, shrunk on failure by
/// repeatedly dropping elements while the property still fails — reports the
/// (locally) minimal counterexample.
pub fn forall_vec<T, G, P>(cfg: Config, name: &str, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> Vec<T>,
    P: FnMut(&[T]) -> CheckResult,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(first_reason) = prop(&input) {
            let (min_input, reason) =
                shrink_vec(input, first_reason, cfg.shrink_iters, &mut rng, &mut prop);
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed:#x}):\n  \
                 reason: {reason}\n  minimal input ({} elems): {min_input:?}",
                min_input.len()
            );
        }
    }
}

fn shrink_vec<T, P>(
    mut input: Vec<T>,
    mut reason: String,
    iters: usize,
    rng: &mut Rng,
    prop: &mut P,
) -> (Vec<T>, String)
where
    T: Clone,
    P: FnMut(&[T]) -> CheckResult,
{
    for _ in 0..iters {
        if input.len() <= 1 {
            break;
        }
        // try dropping a random contiguous span (halves first, then singles)
        let span = (input.len() / 2).max(1);
        let start = rng.range_usize(0, input.len() - span + 1);
        let mut candidate = input.clone();
        candidate.drain(start..start + span);
        match prop(&candidate) {
            Err(r) => {
                input = candidate;
                reason = r;
            }
            Ok(()) => {
                // span too aggressive; try dropping a single element
                let i = rng.range_usize(0, input.len());
                let mut one = input.clone();
                one.remove(i);
                if let Err(r) = prop(&one) {
                    input = one;
                    reason = r;
                }
            }
        }
    }
    (input, reason)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            Config { cases: 50, ..Default::default() },
            "sum-commutes",
            |r| (r.range(0, 100), r.range(0, 100)),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn failing_property_panics_with_seed() {
        forall(
            Config::default(),
            "always-fails",
            |r| r.range(0, 10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // property: no vector contains a 7 — counterexample should shrink
        // down to (nearly) a single element.
        let result = std::panic::catch_unwind(|| {
            forall_vec(
                Config { cases: 10, seed: 42, shrink_iters: 500 },
                "no-sevens",
                |r| (0..r.range_usize(5, 60)).map(|_| r.range(0, 10)).collect::<Vec<u64>>(),
                |xs| {
                    if xs.contains(&7) {
                        Err("found 7".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the minimal report should be tiny (a few elements at most)
        let n: usize = msg
            .split("minimal input (")
            .nth(1)
            .unwrap()
            .split(" elems")
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(n <= 3, "shrink left {n} elems: {msg}");
    }
}
