//! Quickstart: a short OPPO training run over real AOT-compiled compute.
use oppo::config::TrainConfig;
use oppo::coordinator::OppoScheduler;

fn main() -> anyhow::Result<()> {
    oppo::util::logging::init();
    let cfg = TrainConfig { steps: 3, log_every: 1, ..Default::default() };
    let sched = OppoScheduler::new(cfg)?;
    let log = sched.run()?;
    println!("ran {} steps, final score {:.3}, total {:.1}s",
        log.records.len(),
        log.records.last().unwrap().mean_score,
        log.total_wall_s());
    Ok(())
}
