//! Multi-node stage transport tests: frame/wire codec properties
//! (round-trip + corruption), loopback remote replica pools over real TCP,
//! and the chunk-replay failover path — a forced mid-stream disconnect
//! must leave scores and ref log-probs identical to a no-failure run.
//!
//! Everything runs engine-free on the deterministic toy backends
//! (`oppo::transport::toy`); the last test repeats the failover check on
//! engine-backed replicas when compiled artifacts are present.

use std::sync::Arc;

use oppo::coordinator::buffer::SeqBuffer;
use oppo::coordinator::worker::{
    engine_serve_backend, Pick, RefReq, RefResp, RefSink, RefWorker, RewardReq, RewardResp,
    RewardWorker, StreamSink,
};
use oppo::data::tasks::{Prompt, TaskKind};
use oppo::model::sequence::SeqPhase;
use oppo::runtime::Engine;
use oppo::transport::frame::{read_frame, write_frame, MAGIC, VERSION};
use oppo::transport::{
    wire, Backend, ConnectOpts, RemoteReplica, ServerHandle, ToyRefBackend, ToyRewardBackend,
};
use oppo::util::proptest::{forall, Config};
use oppo::util::rng::Rng;

// ---------------------------------------------------------------------------
// frame codec properties
// ---------------------------------------------------------------------------

#[test]
fn frames_round_trip_arbitrary_payloads() {
    forall(
        Config { cases: 200, ..Default::default() },
        "frame-round-trip",
        |rng: &mut Rng| {
            let n = rng.range_usize(0, 4096);
            let kind = rng.range(0, 256) as u8;
            let payload: Vec<u8> = (0..n).map(|_| rng.range(0, 256) as u8).collect();
            (kind, payload)
        },
        |(kind, payload)| {
            let mut buf = Vec::new();
            write_frame(&mut buf, *kind, payload).map_err(|e| format!("write: {e}"))?;
            // a second frame proves the reader leaves the stream aligned
            write_frame(&mut buf, kind.wrapping_add(1), b"tail").unwrap();
            let mut r = &buf[..];
            let (k, p) = read_frame(&mut r).map_err(|e| format!("read: {e}"))?;
            if k != *kind || &p != payload {
                return Err("first frame mutated in transit".into());
            }
            let (k2, p2) = read_frame(&mut r).map_err(|e| format!("read tail: {e}"))?;
            if k2 != kind.wrapping_add(1) || p2 != b"tail" {
                return Err("second frame mutated in transit".into());
            }
            Ok(())
        },
    );
}

#[test]
fn corrupted_frames_error_cleanly_never_panic() {
    forall(
        Config { cases: 300, ..Default::default() },
        "frame-corruption",
        |rng: &mut Rng| {
            let n = rng.range_usize(1, 256);
            let payload: Vec<u8> = (0..n).map(|_| rng.range(0, 256) as u8).collect();
            let mut buf = Vec::new();
            write_frame(&mut buf, 7, &payload).unwrap();
            write_frame(&mut buf, 8, b"second").unwrap();
            // corrupt one byte of the first frame, or truncate the stream
            if rng.bool(0.5) {
                let at = rng.range_usize(0, 14 + n);
                buf[at] ^= 1u8 << rng.range(0, 8);
                (buf, at, false)
            } else {
                let cut = rng.range_usize(0, 14 + n);
                buf.truncate(cut);
                (buf, cut, true)
            }
        },
        |(buf, _at, truncated)| {
            let mut r = &buf[..];
            match read_frame(&mut r) {
                // a bit flip can land in the unchecked `kind` byte — then
                // the frame still reads; either way the stream must stay
                // aligned and the second frame must decode
                Ok(_) if !truncated => {}
                Ok(_) => return Err("truncated stream produced a frame".into()),
                Err(_) if *truncated => return Ok(()),
                Err(e) => {
                    // clean error; a payload/crc fault keeps alignment, a
                    // header fault (magic/version/len) is a hard desync and
                    // the caller drops the connection — both are fine, the
                    // property is simply "no panic, no garbage frame"
                    let msg = format!("{e:#}");
                    if !(msg.contains("crc")
                        || msg.contains("magic")
                        || msg.contains("version")
                        || msg.contains("truncated")
                        || msg.contains("MAX_PAYLOAD"))
                    {
                        return Err(format!("unclassified frame error: {msg}"));
                    }
                    return Ok(());
                }
            }
            let (k2, p2) = read_frame(&mut r).map_err(|e| format!("lost alignment: {e}"))?;
            if k2 != 8 || p2 != b"second" {
                return Err("second frame corrupted by first frame's fault".into());
            }
            Ok(())
        },
    );
}

#[test]
fn version_and_magic_mismatch_rejected_before_payload() {
    let mut buf = Vec::new();
    write_frame(&mut buf, 3, b"payload").unwrap();
    assert_eq!(&buf[0..4], &MAGIC);
    let mut newer = buf.clone();
    newer[4] = VERSION + 1;
    assert!(format!("{:#}", read_frame(&mut &newer[..]).unwrap_err()).contains("version"));
    let mut foreign = buf;
    foreign[0..4].copy_from_slice(b"HTTP");
    assert!(format!("{:#}", read_frame(&mut &foreign[..]).unwrap_err()).contains("magic"));
}

// ---------------------------------------------------------------------------
// wire codec properties (arbitrary chunk shapes)
// ---------------------------------------------------------------------------

fn arb_reward_req(rng: &mut Rng) -> RewardReq {
    let rows = rng.range_usize(1, 9);
    let c = rng.range_usize(1, 9);
    let grid = |rng: &mut Rng| -> Vec<i32> {
        (0..rows * c).map(|_| rng.range(0, 2000) as i32 - 1000).collect()
    };
    let lanes =
        |rng: &mut Rng| -> Vec<i32> { (0..rows).map(|_| rng.range(0, 64) as i32).collect() };
    let picks = |rng: &mut Rng| -> Vec<Pick> {
        (0..rng.range_usize(0, 4))
            .map(|_| Pick { lane: rng.range_usize(0, rows), idx_in_chunk: rng.range_usize(0, c) })
            .collect()
    };
    match rng.range(0, 4) {
        0 => RewardReq::Stream {
            entry: format!("reward_prefill_chunk_c{c}"),
            chunk: grid(rng),
            start: lanes(rng),
            n_valid: lanes(rng),
            picks: picks(rng),
            lane_map: (0..rows).map(|_| rng.range_usize(0, 64)).collect(),
        },
        1 => RewardReq::StreamPaged {
            entry: format!("reward_prefill_chunk_paged_c{c}"),
            chunk: grid(rng),
            start: lanes(rng),
            n_valid: lanes(rng),
            picks: picks(rng),
            lane_map: (0..rows).collect(),
            table: (0..rows * 4).map(|_| rng.range(0, 128) as i32 - 1).collect(),
        },
        2 => RewardReq::ScoreFull { tokens: grid(rng), last_idx: lanes(rng) },
        _ => RewardReq::Reset,
    }
}

fn arb_ref_req(rng: &mut Rng) -> RefReq {
    let rows = rng.range_usize(1, 9);
    let c = rng.range_usize(1, 9);
    let grid: Vec<i32> = (0..rows * c).map(|_| rng.range(0, 2000) as i32 - 1000).collect();
    let lanes: Vec<i32> = (0..rows).map(|_| rng.range(0, 64) as i32).collect();
    match rng.range(0, 3) {
        0 => RefReq::Stream {
            entry: format!("ref_prefill_chunk_c{c}"),
            chunk: grid,
            start: lanes.clone(),
            n_valid: lanes,
        },
        1 => RefReq::StreamPaged {
            entry: format!("ref_prefill_chunk_paged_c{c}"),
            chunk: grid,
            start: lanes.clone(),
            n_valid: lanes,
            table: (0..rows * 4).map(|_| rng.range(0, 128) as i32 - 1).collect(),
        },
        _ => RefReq::Reset,
    }
}

/// The codecs are deterministic, so byte equality of
/// `encode(decode(encode(x)))` and `encode(x)` is structural equality
/// without demanding `PartialEq` on the request enums.
#[test]
fn wire_requests_round_trip_over_arbitrary_shapes() {
    forall(
        Config { cases: 300, ..Default::default() },
        "wire-reward-req-round-trip",
        arb_reward_req,
        |req| {
            let bytes = wire::encode_reward_req(req);
            let back = wire::decode_reward_req(&bytes).map_err(|e| format!("{e:#}"))?;
            if wire::encode_reward_req(&back) != bytes {
                return Err("re-encode differs".into());
            }
            Ok(())
        },
    );
    forall(
        Config { cases: 300, ..Default::default() },
        "wire-ref-req-round-trip",
        arb_ref_req,
        |req| {
            let bytes = wire::encode_ref_req(req);
            let back = wire::decode_ref_req(&bytes).map_err(|e| format!("{e:#}"))?;
            if wire::encode_ref_req(&back) != bytes {
                return Err("re-encode differs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn wire_responses_round_trip() {
    forall(
        Config { cases: 200, ..Default::default() },
        "wire-resp-round-trip",
        |rng: &mut Rng| {
            let n = rng.range_usize(0, 32);
            let scores: Vec<(usize, f32)> =
                (0..n).map(|_| (rng.range_usize(0, 64), rng.range_f64(-2.0, 2.0) as f32)).collect();
            let logps: Vec<f32> = (0..n).map(|_| rng.range_f64(-20.0, 0.0) as f32).collect();
            (scores, logps)
        },
        |(scores, logps)| {
            let b = wire::encode_reward_resp(&RewardResp::StreamScores(scores.clone()));
            match wire::decode_reward_resp(&b).map_err(|e| format!("{e:#}"))? {
                RewardResp::StreamScores(s) if &s == scores => {}
                other => return Err(format!("reward resp mutated: {other:?}")),
            }
            let b = wire::encode_ref_resp(&RefResp::StreamLogps(logps.clone()));
            match wire::decode_ref_resp(&b).map_err(|e| format!("{e:#}"))? {
                RefResp::StreamLogps(l) if &l == logps => {}
                other => return Err(format!("ref resp mutated: {other:?}")),
            }
            Ok(())
        },
    );
}

#[test]
fn truncated_wire_payloads_error_cleanly() {
    let mut rng = Rng::new(0x7A11);
    for _ in 0..100 {
        let req = arb_reward_req(&mut rng);
        let bytes = wire::encode_reward_req(&req);
        for cut in 0..bytes.len() {
            // must be a clean Err (or, for a prefix that happens to parse,
            // an Ok) — never a panic or an over-allocation
            let _ = wire::decode_reward_req(&bytes[..cut]);
        }
    }
}

// ---------------------------------------------------------------------------
// loopback remote pools (toy backends over real TCP)
// ---------------------------------------------------------------------------

fn toy_reward_server() -> ServerHandle {
    let mut b = ToyRewardBackend::new();
    ServerHandle::spawn(Backend::Reward(Box::new(move |req| b.handle(req)))).expect("spawn")
}

fn toy_ref_server() -> ServerHandle {
    let mut b = ToyRefBackend::new();
    ServerHandle::spawn(Backend::Ref(Box::new(move |req| b.handle(req)))).expect("spawn")
}

fn test_opts() -> ConnectOpts {
    // no heartbeat: requests are the only socket traffic, so every test
    // observes failures deterministically at a request boundary
    ConnectOpts { attempts: 3, base_backoff_ms: 10, heartbeat_ms: 0, ..Default::default() }
}

fn prompt(id: u64) -> Prompt {
    Prompt {
        kind: TaskKind::Arith,
        text: "1+1=".into(),
        tokens: vec![1, 5, 40, 5, 44],
        answer: "2".into(),
        id,
    }
}

/// Seed `lanes` finished sequences with deterministic pseudo-random
/// responses (3..=17 tokens) so several chunk rounds stream per lane.
fn seeded_buffer(lanes: usize, seed: u64) -> SeqBuffer {
    let mut buf = SeqBuffer::new(lanes, lanes);
    let mut rng = Rng::new(seed);
    for i in 0..lanes {
        let lane = buf.add(prompt(i as u64), 0).unwrap();
        let seq = buf.by_lane_mut(lane).unwrap();
        seq.response = (0..rng.range_usize(3, 18)).map(|_| rng.range(2, 90) as i32).collect();
        seq.phase = SeqPhase::Finished;
        buf.mark_finished(lane);
    }
    buf
}

/// Drain one sink's ready responses, running failover on every surfaced
/// replica death (the scheduler's `collect_ready_ft` loop).
fn collect_ft(sink: &mut StreamSink, buf: &mut SeqBuffer, chunk: usize) {
    while let Some(fail) = sink.collect_ready_ft(buf).expect("collect") {
        sink.failover(buf, &fail, chunk, None).expect("failover");
    }
}

/// Join one sink to empty, running failover on surfaced deaths (the
/// scheduler's `join_ft` flush loop).
fn join_ft(sink: &mut StreamSink, buf: &mut SeqBuffer, chunk: usize) {
    loop {
        match sink.join_ft(buf).expect("join") {
            Some(fail) => sink.failover(buf, &fail, chunk, None).expect("failover"),
            None => break,
        }
    }
}

/// Stream the whole buffer through the sinks; optionally kill servers
/// after submitting round `kill_after_round` (mid-stream, with requests in
/// flight).  Returns `(rm_scores, ref_logps)` per lane.
fn run_streaming(
    buf: &mut SeqBuffer,
    sinks: &mut Vec<StreamSink>,
    chunk: usize,
    kill: Option<(usize, Vec<&ServerHandle>)>,
) -> (Vec<Option<f32>>, Vec<Vec<f32>>) {
    let mut round = 0usize;
    while let Some(ck) = buf.build_stream_chunk(chunk) {
        for sink in sinks.iter_mut() {
            sink.submit_chunk(&ck).expect("submit");
        }
        if let Some((at, handles)) = &kill {
            if round == *at {
                for h in handles {
                    h.kill();
                }
            }
        }
        for sink in sinks.iter_mut() {
            collect_ft(sink, buf, chunk);
        }
        round += 1;
    }
    for sink in sinks.iter_mut() {
        join_ft(sink, buf, chunk);
    }
    let lanes = buf.lanes();
    let mut scores = vec![None; lanes];
    let mut logps = vec![Vec::new(); lanes];
    for seq in buf.iter() {
        scores[seq.lane] = seq.rm_score;
        let n = seq.total_len().min(seq.ref_logp.len());
        logps[seq.lane] = seq.ref_logp[..n].to_vec();
    }
    (scores, logps)
}

#[test]
fn remote_toy_pools_stream_scores_and_logps() {
    let (rw0, rw1) = (toy_reward_server(), toy_reward_server());
    let (rf0, rf1) = (toy_ref_server(), toy_ref_server());
    let opts = test_opts();
    let reward = RewardWorker::spawn_remote_pool(
        &[rw0.addr.clone(), rw1.addr.clone()],
        4,
        &opts,
    )
    .expect("reward pool");
    let refw = RefWorker::spawn_remote_pool(&[rf0.addr.clone(), rf1.addr.clone()], 4, &opts)
        .expect("ref pool");
    let mut sinks = vec![StreamSink::Reward(reward), StreamSink::Ref(RefSink::from_worker(refw))];

    let lanes = 6;
    let chunk = 5;
    let mut buf = seeded_buffer(lanes, 0xFEED);
    let expect_tokens: Vec<Vec<i32>> =
        (0..lanes).map(|l| buf.by_lane(l).unwrap().full_tokens()).collect();
    let (scores, logps) = run_streaming(&mut buf, &mut sinks, chunk, None);

    // ground truth from a fresh toy backend's monolithic scorer
    let s = expect_tokens.iter().map(Vec::len).max().unwrap();
    let mut grid = vec![0i32; lanes * s];
    let mut last = vec![0i32; lanes];
    for (l, toks) in expect_tokens.iter().enumerate() {
        grid[l * s..l * s + toks.len()].copy_from_slice(toks);
        last[l] = toks.len() as i32 - 1;
    }
    let mut oracle = ToyRewardBackend::new();
    let RewardResp::FullScores(full) =
        oracle.handle(RewardReq::ScoreFull { tokens: grid, last_idx: last }).unwrap()
    else {
        panic!("expected full scores");
    };
    for l in 0..lanes {
        let got = scores[l].expect("every finished lane is scored");
        assert!((got - full[l]).abs() <= 1e-6, "lane {l}: streamed {got} vs full {}", full[l]);
        assert_eq!(logps[l].len(), expect_tokens[l].len(), "lane {l} ref coverage");
        assert!(logps[l].iter().all(|v| v.is_finite() && *v < 0.0), "lane {l} logps sane");
    }
}

#[test]
fn forced_disconnect_fails_over_with_identical_scores() {
    let chunk = 5;
    let lanes = 6;

    // no-failure baseline
    let baseline = {
        let (rw0, rw1) = (toy_reward_server(), toy_reward_server());
        let (rf0, rf1) = (toy_ref_server(), toy_ref_server());
        let opts = test_opts();
        let reward =
            RewardWorker::spawn_remote_pool(&[rw0.addr.clone(), rw1.addr.clone()], 4, &opts)
                .unwrap();
        let refw =
            RefWorker::spawn_remote_pool(&[rf0.addr.clone(), rf1.addr.clone()], 4, &opts).unwrap();
        let mut sinks =
            vec![StreamSink::Reward(reward), StreamSink::Ref(RefSink::from_worker(refw))];
        let mut buf = seeded_buffer(lanes, 0xFA11);
        run_streaming(&mut buf, &mut sinks, chunk, None)
    };

    // same run, but one reward replica and one ref replica are forcibly
    // disconnected with requests in flight — their lanes must be rerouted
    // to the survivors and replayed from the retained chunk stream
    let failed = {
        let (rw0, rw1) = (toy_reward_server(), toy_reward_server());
        let (rf0, rf1) = (toy_ref_server(), toy_ref_server());
        let opts = test_opts();
        let reward =
            RewardWorker::spawn_remote_pool(&[rw0.addr.clone(), rw1.addr.clone()], 4, &opts)
                .unwrap();
        let refw =
            RefWorker::spawn_remote_pool(&[rf0.addr.clone(), rf1.addr.clone()], 4, &opts).unwrap();
        let mut sinks =
            vec![StreamSink::Reward(reward), StreamSink::Ref(RefSink::from_worker(refw))];
        let mut buf = seeded_buffer(lanes, 0xFA11);
        let (s, l) = run_streaming(&mut buf, &mut sinks, chunk, Some((1, vec![&rw0, &rf1])));
        // the pools really did lose a replica
        assert_eq!(sinks[0].alive_count(), 1, "reward replica 0 must be retired");
        assert_eq!(sinks[1].alive_count(), 1, "ref replica 1 must be retired");
        (s, l)
    };

    for lane in 0..lanes {
        let (b, f) = (baseline.0[lane].unwrap(), failed.0[lane].unwrap());
        assert!(
            (b - f).abs() <= 1e-6,
            "lane {lane}: failover score {f} diverged from no-failure {b}"
        );
        assert_eq!(
            baseline.1[lane].len(),
            failed.1[lane].len(),
            "lane {lane}: ref coverage diverged"
        );
        for (i, (b, f)) in baseline.1[lane].iter().zip(&failed.1[lane]).enumerate() {
            assert!(
                (b - f).abs() <= 1e-6,
                "lane {lane} pos {i}: failover logp {f} diverged from {b}"
            );
        }
    }
}

#[test]
fn single_survivor_pool_propagates_failure_as_error() {
    // one replica: no failover path, a death must surface as Err, not hang
    let rw = toy_reward_server();
    let opts = test_opts();
    let reward = RewardWorker::spawn_remote_pool(&[rw.addr.clone()], 4, &opts).unwrap();
    let mut sink = StreamSink::Reward(reward);
    let mut buf = seeded_buffer(3, 0xDEAD);
    let ck = buf.build_stream_chunk(4).unwrap();
    sink.submit_chunk(&ck).unwrap();
    rw.kill();
    // drain; with requests in flight against a dead sole replica, join
    // must return the replica error
    let err = loop {
        match sink.join_ft(&mut buf) {
            Ok(None) => {
                // the kill may have raced the response; submit again so the
                // next round hits the dead socket
                if let Some(ck) = buf.build_stream_chunk(4) {
                    sink.submit_chunk(&ck).unwrap();
                } else {
                    panic!("sole-replica death never surfaced");
                }
            }
            Ok(Some(_)) => panic!("no failover path exists with one replica"),
            Err(e) => break e,
        }
    };
    assert!(format!("{err:#}").contains("replica"), "{err:#}");
}

#[test]
fn stage_handshake_rejects_wrong_stage_and_verifies_params_digest() {
    let rw = toy_reward_server();
    let opts = test_opts();
    // wrong stage name is refused at handshake
    let err = RemoteReplica::connect(&rw.addr, "ref", 0, None, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("stage"), "{err:#}");
    // param distribution round-trips with a digest ack (the test server's
    // sink accepts anything; the digest still proves bytes arrived intact)
    let blob: Vec<u8> = (0..4096u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
    let client =
        RemoteReplica::connect(&rw.addr, "reward", 0, Some(("reward", &blob)), &opts).unwrap();
    assert!(!client.is_dead());
    // the connection is fully usable after the param handshake
    match client.reward(&RewardReq::Reset).unwrap() {
        RewardResp::ResetDone => {}
        other => panic!("unexpected response {other:?}"),
    }
}

#[test]
fn heartbeat_marks_silently_dropped_peer_dead() {
    let rw = toy_reward_server();
    let opts = ConnectOpts {
        attempts: 2,
        base_backoff_ms: 10,
        heartbeat_ms: 20,
        ..Default::default()
    };
    let client = RemoteReplica::connect(&rw.addr, "reward", 0, None, &opts).unwrap();
    assert!(!client.is_dead());
    rw.kill();
    // the idle heartbeat must flip the replica dead without any request
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while !client.is_dead() {
        assert!(std::time::Instant::now() < deadline, "heartbeat never noticed the drop");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let err = client.reward(&RewardReq::Reset).unwrap_err();
    assert!(format!("{err:#}").contains("dead"), "{err:#}");
}

// ---------------------------------------------------------------------------
// engine-gated variant (compiled artifacts present)
// ---------------------------------------------------------------------------

fn engine() -> Option<Arc<Engine>> {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| Arc::new(Engine::load("artifacts").expect("engine")))
}

#[test]
fn engine_backed_failover_matches_no_failure_run() {
    let Some(e) = engine() else { return };
    if e.manifest().paged_supported() {
        // remote pools are masked dense-row only; paged artifacts gate out
        return;
    }
    let lanes = e.manifest().shape.lanes;
    let chunk = 4;
    let spawn_pair = || {
        let (b0, _p0) = engine_serve_backend(e.clone(), "reward").expect("backend");
        let (b1, _p1) = engine_serve_backend(e.clone(), "reward").expect("backend");
        (ServerHandle::spawn(b0).unwrap(), ServerHandle::spawn(b1).unwrap())
    };
    let run = |kill: bool| {
        let (s0, s1) = spawn_pair();
        let opts = test_opts();
        let reward =
            RewardWorker::spawn_remote_pool(&[s0.addr.clone(), s1.addr.clone()], 4, &opts)
                .unwrap();
        let mut sinks = vec![StreamSink::Reward(reward)];
        let mut buf = seeded_buffer(lanes, 0xE61E);
        let kill_spec = kill.then(|| (1, vec![&s0]));
        let (scores, _) = run_streaming(&mut buf, &mut sinks, chunk, kill_spec);
        scores
    };
    let baseline = run(false);
    let failed = run(true);
    for lane in 0..lanes {
        let (Some(b), Some(f)) = (baseline[lane], failed[lane]) else {
            panic!("lane {lane} unscored");
        };
        assert!(
            (b - f).abs() <= 1e-4,
            "lane {lane}: engine failover score {f} diverged from {b}"
        );
    }
}
