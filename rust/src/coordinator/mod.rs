//! The OPPO coordinator — the paper's Layer-3 contribution, organized as a
//! multi-stage pipeline runtime.
//!
//! * [`block_pool`] — the host-side paged-KV allocator: a free-list over
//!   fixed-size physical blocks plus per-lane block tables, so rolling
//!   admission gates on free blocks instead of worst-case dense KV;
//! * [`buffer`] — Algorithm 1's `B + Δ` FIFO sequence buffer;
//! * [`delta`] — the dynamic Δ controller (Eq. 4 / Alg. 1 l.21-27);
//! * [`chunkctl`] — the dynamic chunk-size controller (§3.1);
//! * [`engine_ops`] — typed wrappers over the AOT entry points with
//!   device-resident state (actor, reward, and reference flavours);
//! * [`stage`] — the generic pipeline-stage worker: tagged requests,
//!   bounded queue with backpressure, per-stage timing, join-on-drop —
//!   plus [`StagePool`], N replicas behind one facade with
//!   sequence-affinity routing;
//! * [`worker`] — the concrete downstream stages (reward scoring,
//!   reference log-probs) plus the fan-out facade the scheduler drives;
//! * [`scheduler`] — the training loop: OPPO, the ablations (no-intra,
//!   no-inter, no-ref-stream), the TRL-style sequential baseline, and
//!   async staleness-k;
//! * [`dpo`] — the DPO generalization (§4.3).

pub mod block_pool;
pub mod buffer;
pub mod chunkctl;
pub mod delta;
pub mod dpo;
pub mod engine_ops;
pub mod scheduler;
pub mod stage;
pub mod worker;

pub use block_pool::BlockPool;
pub use buffer::SeqBuffer;
pub use chunkctl::ChunkController;
pub use delta::{DeltaController, Policy};
pub use scheduler::OppoScheduler;
pub use stage::{StageHandler, StagePool, StageStats, StageWorker};
