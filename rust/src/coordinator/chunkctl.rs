//! Deprecated location shim (kept for one release): the dynamic
//! chunk-size controller moved to [`crate::ctl::chunkctl`] when the
//! controllers were unified behind the [`crate::ctl::Controller`] trait.

/// Moved to [`crate::ctl::ChunkController`].
#[deprecated(note = "the controllers moved: use crate::ctl::ChunkController")]
pub type ChunkController = crate::ctl::ChunkController;
