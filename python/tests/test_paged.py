"""Paged KV semantics: gather/scatter round-trips and paged == dense.

The paged entry family is the enabling change for block-granular KV
allocation (lane slots decoupled from KV capacity).  Its correctness
contract is exact: wherever the block table covers a lane's written rows,
the paged flavour must reproduce the dense flavour — generation tokens,
log-probs, values, streamed reward scores, and streamed ref log-probs all
agree, and the reserved scratch block (physical block 0) must never leak
into valid outputs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    d_model=64, n_heads=2, n_layers=2, d_ff=128, s_max=64, prompt_max=8,
    lanes=4, ppo_batch=4, chunk_sizes=(4, 8), temperature=1.0,
    kv_block_size=16,
)
NBLK = CFG.kv_blocks_per_lane  # 4
POOL = CFG.kv_pool_size        # lanes * nblk + 1 scratch


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(42))


def fresh_dense_kv(batch):
    shape = (batch, CFG.n_heads, CFG.s_max, CFG.head_dim)
    return [jnp.zeros(shape, jnp.float32) for _ in range(2 * CFG.n_layers)]


def fresh_pool_kv():
    shape = (POOL, CFG.n_heads, CFG.kv_block_size, CFG.head_dim)
    return [jnp.zeros(shape, jnp.float32) for _ in range(2 * CFG.n_layers)]


def full_table(g=None, perm=None):
    """A fully-allocated table: lane r's block j -> physical 1 + r*NBLK + j,
    optionally shuffled through ``perm`` over the non-scratch blocks."""
    g = g or CFG.lanes
    ids = np.arange(g * NBLK)
    if perm is not None:
        ids = perm[ids]
    return jnp.asarray(1 + ids.reshape(g, NBLK), jnp.int32)


def make_prompts(key, g=None):
    g = g or CFG.lanes
    toks = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    toks = toks.at[:, 0].set(M.BOS)
    prompt_len = jnp.full((g,), CFG.prompt_max, jnp.int32)
    return toks, prompt_len


def test_gather_scatter_roundtrip_arbitrary_tables():
    """scatter(dense) then gather must reproduce dense for any permutation
    table — the layout-equivalence half of the BlockPool invariants."""
    rng = np.random.default_rng(0)
    g = CFG.lanes
    for trial in range(5):
        perm = rng.permutation(g * NBLK)
        table = full_table(perm=perm)
        dense = jnp.asarray(
            rng.standard_normal((g, CFG.n_heads, CFG.s_max, CFG.head_dim)),
            jnp.float32,
        )
        pool = jnp.zeros((POOL, CFG.n_heads, CFG.kv_block_size, CFG.head_dim))
        pool = M.paged_scatter(CFG, pool, table, dense)
        back = M.paged_gather(CFG, pool, table)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(dense))
        # scratch block 0 untouched by a fully-allocated table
        np.testing.assert_array_equal(np.asarray(pool[0]), 0.0)


def test_paged_generate_matches_dense(params):
    """Same key, same prompts: paged generation must reproduce the dense
    flavour's tokens exactly and logps/values numerically."""
    key = jax.random.PRNGKey(0)
    tokens, prompt_len = make_prompts(key)
    reset = jnp.ones((CFG.lanes,), jnp.int32)
    flat = M.flatten_params(CFG, params)
    live = jnp.ones((CFG.lanes,), jnp.int32)
    raw = jax.random.key_data(jax.random.PRNGKey(9)).astype(jnp.uint32)
    c = 4

    kv = fresh_dense_kv(CFG.lanes)
    kv = list(M.make_actor_prefill(CFG)(*flat, tokens, prompt_len, reset, *kv))
    dres = M.make_actor_generate_chunk(CFG, c)(*flat, tokens, prompt_len, live, *kv, raw)

    table = full_table()
    pool = fresh_pool_kv()
    pool = list(
        M.make_actor_prefill_paged(CFG)(*flat, tokens, prompt_len, reset, *pool, table)
    )
    pres = M.make_actor_generate_chunk_paged(CFG, c)(
        *flat, tokens, prompt_len, live, *pool, raw, table
    )

    l2 = 2 * CFG.n_layers
    np.testing.assert_array_equal(np.asarray(pres[0]), np.asarray(dres[0]))  # tokens
    np.testing.assert_array_equal(np.asarray(pres[1]), np.asarray(dres[1]))  # pos
    np.testing.assert_array_equal(
        np.asarray(pres[2 + l2]), np.asarray(dres[2 + l2])  # sampled tokens
    )
    for k in (3 + l2, 4 + l2):  # logp, value
        np.testing.assert_allclose(
            np.asarray(pres[k]), np.asarray(dres[k]), rtol=5e-4, atol=5e-4
        )
    # the paged KV content must equal the dense caches through the table
    for pk, dk in zip(pres[2 : 2 + l2], dres[2 : 2 + l2]):
        back = M.paged_gather(CFG, pk, table)
        np.testing.assert_allclose(
            np.asarray(back), np.asarray(dk), rtol=1e-5, atol=1e-5
        )


def test_paged_reward_prefill_matches_dense_streaming(params):
    """Chunk-streamed paged reward prefill == dense streamed prefill, with
    the table grown incrementally at chunk boundaries like the host does."""
    key = jax.random.PRNGKey(3)
    g = CFG.lanes
    lens = jnp.array([13, 24, 32, 9], jnp.int32)
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    flat = M.flatten_params(CFG, params)
    c = 4
    bs = CFG.kv_block_size

    dfn = M.make_reward_prefill_chunk(CFG, c)
    pfn = M.make_reward_prefill_chunk_paged(CFG, c)
    kv = fresh_dense_kv(g)
    pool = fresh_pool_kv()
    # incremental table: slots start at scratch 0 and grow as chunks land
    table = np.zeros((g, NBLK), np.int32)
    next_free = 1
    max_len = int(lens.max())
    for start in range(0, max_len, c):
        # host-side grow: cover [0, start + c) for every lane still streaming
        for lane in range(g):
            need = min(-(-min(start + c, int(lens[lane])) // bs), NBLK)
            while int((table[lane] != 0).sum()) < need:
                j = int((table[lane] != 0).sum())
                table[lane, j] = next_free
                next_free += 1
        chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
        starts = jnp.full((g,), start, jnp.int32)
        n_valid = jnp.clip(lens - start, 0, c)
        dres = dfn(*flat, chunk, starts, n_valid, *kv)
        kv = list(dres[: 2 * CFG.n_layers])
        pres = pfn(*flat, chunk, starts, n_valid, *pool, jnp.asarray(table))
        pool = list(pres[: 2 * CFG.n_layers])
        d_scores = np.asarray(dres[2 * CFG.n_layers])
        p_scores = np.asarray(pres[2 * CFG.n_layers])
        for lane in range(g):
            nv = int(n_valid[lane])
            np.testing.assert_allclose(
                p_scores[lane, :nv], d_scores[lane, :nv], rtol=5e-4, atol=5e-4,
                err_msg=f"lane {lane} chunk@{start}",
            )


def test_paged_ref_prefill_matches_dense_logprobs(params):
    """Paged streamed ref log-probs reproduce dense ``token_logprobs``
    across the cross-chunk boundary seam."""
    key = jax.random.PRNGKey(21)
    g = CFG.lanes
    lens = jnp.array([14, 23, 32, 7], jnp.int32)
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    tokens = tokens.at[:, 0].set(M.BOS)
    flat = M.flatten_params(CFG, params)
    dense, _ = M.token_logprobs(CFG, params, tokens)

    c = 4
    fn = M.make_ref_prefill_chunk_paged(CFG, c)
    pool = fresh_pool_kv()
    table = full_table()
    boundary = jnp.zeros((g, CFG.vocab), jnp.float32)
    got = np.full((g, CFG.s_max), np.nan, np.float32)
    for start in range(0, int(lens.max()), c):
        chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
        starts = jnp.full((g,), start, jnp.int32)
        n_valid = jnp.clip(lens - start, 0, c)
        res = fn(*flat, chunk, starts, n_valid, boundary, *pool, table)
        pool = list(res[: 2 * CFG.n_layers])
        boundary = res[2 * CFG.n_layers]
        logp = np.asarray(res[2 * CFG.n_layers + 1])
        for lane in range(g):
            nv = int(n_valid[lane])
            got[lane, start : start + nv] = logp[lane, :nv]
    for lane in range(g):
        n = int(lens[lane])
        np.testing.assert_allclose(
            got[lane, :n], np.asarray(dense)[lane, :n], rtol=5e-4, atol=5e-4,
            err_msg=f"lane {lane}",
        )


def test_scratch_block_garbage_does_not_leak(params):
    """Poisoning physical block 0 (the scratch sink unallocated table slots
    point at) must not change any valid output — the masked-attention
    garbage-in-garbage-out contract the allocator relies on."""
    key = jax.random.PRNGKey(5)
    g = CFG.lanes
    lens = jnp.full((g,), CFG.kv_block_size, jnp.int32)  # one block each
    tokens = jax.random.randint(key, (g, CFG.s_max), 3, CFG.vocab).astype(jnp.int32)
    flat = M.flatten_params(CFG, params)
    c = 8
    fn = M.make_reward_prefill_chunk_paged(CFG, c)

    # only block 0 of each lane allocated; the rest point at scratch 0
    table = np.zeros((g, NBLK), np.int32)
    table[:, 0] = 1 + np.arange(g)
    table = jnp.asarray(table)

    def run(poison):
        pool = fresh_pool_kv()
        if poison:
            pool = [p.at[0].set(1e6) for p in pool]
        out = None
        for start in range(0, int(lens.max()), c):
            chunk = jax.lax.dynamic_slice(tokens, (0, start), (g, c))
            starts = jnp.full((g,), start, jnp.int32)
            n_valid = jnp.clip(lens - start, 0, c)
            res = fn(*flat, chunk, starts, n_valid, *pool, table)
            pool = list(res[: 2 * CFG.n_layers])
            out = np.asarray(res[2 * CFG.n_layers])
        return out

    clean, poisoned = run(False), run(True)
    np.testing.assert_allclose(clean, poisoned, rtol=1e-6, atol=1e-6)


def test_paged_pallas_flavour_agrees(params):
    """The Pallas kernels run unchanged on the gathered dense view."""
    pcfg = dataclasses.replace(CFG, kernel_impl="pallas")
    key = jax.random.PRNGKey(14)
    g = CFG.lanes
    tokens = jax.random.randint(key, (g, 8), 3, CFG.vocab).astype(jnp.int32)
    start = jnp.zeros((g,), jnp.int32)
    nv = jnp.full((g,), 8, jnp.int32)
    flat = M.flatten_params(CFG, params)
    table = full_table()
    r_jnp = M.make_reward_prefill_chunk_paged(CFG, 8)(
        *flat, tokens, start, nv, *fresh_pool_kv(), table
    )
    r_pal = M.make_reward_prefill_chunk_paged(pcfg, 8)(
        *flat, tokens, start, nv, *fresh_pool_kv(), table
    )
    for a, b in zip(r_jnp, r_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
