//! Vendored mini-`once_cell` for the offline build: just `sync::Lazy`,
//! implemented over `std::sync::OnceLock`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access.  `F` defaults to a function
    /// pointer so `static L: Lazy<T> = Lazy::new(|| ...)` works with
    /// capture-free closures, as with the real crate.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        static N: Lazy<usize> = Lazy::new(|| 41 + 1);

        #[test]
        fn initializes_once() {
            assert_eq!(*N, 42);
            assert_eq!(*N, 42);
            let local: Lazy<String> = Lazy::new(|| "hi".to_string());
            assert_eq!(local.len(), 2);
        }
    }
}
