//! GPU device specs and utilization accounting (Fig. 2a / Fig. 5 inputs).

/// Peak capabilities of one accelerator (dense fp16/bf16).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// dense fp16 tensor throughput, TFLOP/s
    pub fp16_tflops: f64,
    /// HBM bandwidth, GB/s
    pub hbm_gbps: f64,
    /// device memory, GB
    pub mem_gb: f64,
}

impl GpuSpec {
    pub const A40: GpuSpec =
        GpuSpec { name: "A40", fp16_tflops: 149.7, hbm_gbps: 696.0, mem_gb: 48.0 };
    pub const A100_80: GpuSpec =
        GpuSpec { name: "A100-80GB", fp16_tflops: 312.0, hbm_gbps: 2039.0, mem_gb: 80.0 };
    pub const A100_40: GpuSpec =
        GpuSpec { name: "A100-40GB", fp16_tflops: 312.0, hbm_gbps: 1555.0, mem_gb: 40.0 };
    pub const H200: GpuSpec =
        GpuSpec { name: "H200", fp16_tflops: 989.0, hbm_gbps: 4800.0, mem_gb: 141.0 };
    pub const GH200_96: GpuSpec =
        GpuSpec { name: "GH200-96GB", fp16_tflops: 989.0, hbm_gbps: 4000.0, mem_gb: 96.0 };

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name {
            "A40" => Some(Self::A40),
            "A100-80GB" | "A100" => Some(Self::A100_80),
            "A100-40GB" => Some(Self::A100_40),
            "H200" => Some(Self::H200),
            "GH200-96GB" | "GH200" => Some(Self::GH200_96),
            _ => None,
        }
    }

    /// Seconds to stream `bytes` once through HBM.
    pub fn mem_time(&self, bytes: f64) -> f64 {
        bytes / (self.hbm_gbps * 1e9)
    }

    /// Seconds to execute `flops` at peak (caller applies efficiency).
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / (self.fp16_tflops * 1e12)
    }
}

/// Accumulates (busy-flop, wall-time) per named phase — utilization is
/// achieved-FLOPs over peak-FLOPs for the wall time, the metric behind
/// Figures 2a and 5.
#[derive(Clone, Debug, Default)]
pub struct UtilAccounting {
    entries: Vec<(String, f64, f64)>, // (phase, seconds, flops)
    peak_tflops: f64,
    gpus: f64,
}

impl UtilAccounting {
    pub fn new(peak_tflops: f64, gpus: f64) -> Self {
        Self { entries: Vec::new(), peak_tflops, gpus }
    }

    /// Record `seconds` of wall time in `phase` during which `flops` of
    /// useful work executed across the whole pool.
    pub fn record(&mut self, phase: &str, seconds: f64, flops: f64) {
        if seconds > 0.0 {
            self.entries.push((phase.to_string(), seconds, flops));
        }
    }

    /// Pool-wide utilization over all recorded time.
    pub fn overall(&self) -> f64 {
        let wall: f64 = self.entries.iter().map(|e| e.1).sum();
        let flops: f64 = self.entries.iter().map(|e| e.2).sum();
        if wall <= 0.0 {
            return 0.0;
        }
        (flops / (wall * self.peak_tflops * 1e12 * self.gpus)).min(1.0)
    }

    /// Utilization restricted to one phase.
    pub fn phase(&self, phase: &str) -> f64 {
        let wall: f64 = self.entries.iter().filter(|e| e.0 == phase).map(|e| e.1).sum();
        let flops: f64 = self.entries.iter().filter(|e| e.0 == phase).map(|e| e.2).sum();
        if wall <= 0.0 {
            return 0.0;
        }
        (flops / (wall * self.peak_tflops * 1e12 * self.gpus)).min(1.0)
    }

    pub fn total_wall(&self) -> f64 {
        self.entries.iter().map(|e| e.1).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        assert!(GpuSpec::H200.hbm_gbps > GpuSpec::A100_80.hbm_gbps);
        assert!(GpuSpec::A100_80.hbm_gbps > GpuSpec::A40.hbm_gbps);
        assert!(GpuSpec::by_name("H200").unwrap().mem_gb == 141.0);
        assert!(GpuSpec::by_name("nope").is_none());
    }

    #[test]
    fn roofline_times() {
        let g = GpuSpec::A100_80;
        // 2 GB stream at ~2 TB/s ≈ 1 ms
        let t = g.mem_time(2e9);
        assert!((t - 2e9 / 2.039e12).abs() < 1e-9);
        // 312 TFLOP at peak = 1 s
        assert!((g.compute_time(312e12) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_accounting() {
        let mut u = UtilAccounting::new(100.0, 1.0); // 100 TFLOP/s peak
        u.record("decode", 1.0, 20e12); // 20% busy
        u.record("train", 1.0, 80e12); // 80% busy
        assert!((u.phase("decode") - 0.2).abs() < 1e-9);
        assert!((u.phase("train") - 0.8).abs() < 1e-9);
        assert!((u.overall() - 0.5).abs() < 1e-9);
        assert_eq!(u.phase("missing"), 0.0);
    }

    #[test]
    fn utilization_caps_at_one() {
        let mut u = UtilAccounting::new(1.0, 1.0);
        u.record("x", 1.0, 9e12);
        assert_eq!(u.overall(), 1.0);
    }
}
