"""Pallas single-token decode attention — the actor's generation hot loop.

The actor stage of PPO-based RLHF is autoregressive decoding: one query
token per sequence per step, attending to its whole KV history.  The paper's
Figure 2a shows exactly why this stage underutilizes compute (memory-bound:
each step streams the entire cache once for O(1) queries) — the observation
OPPO exploits by scavenging the leftover compute for reward prefill.

Kernel schedule (TPU framing, DESIGN.md §7): the single query row is VMEM
resident; K/V stream HBM→VMEM in ``BLOCK_K`` blocks; running-softmax carries
keep the working set at ``1 × BLOCK_K``; blocks beyond ``pos`` are skipped
with a dynamic trip count, so a decode step at position ``p`` reads
``ceil((p+1)/BLOCK_K)`` blocks rather than the whole ``S_max`` cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_K = 32


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    d = q_ref.shape[1]
    # whole-block reads + squeeze: int ref indices fail interpret-mode
    # discharge on this jax version.
    pos = pos_ref[...][0]
    q = q_ref[...][0].astype(jnp.float32) * scale  # [D]

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    n_blocks = (pos // block_k) + 1  # skip blocks strictly beyond pos

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (slice(None), pl.dslice(kb * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (slice(None), pl.dslice(kb * block_k, block_k), slice(None)))[0]
        scores = k.astype(jnp.float32) @ q  # [BLOCK_K]
        jpos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        scores = jnp.where(jpos <= pos, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max())
        alpha = jnp.exp(m - m_new)
        p = jnp.where(jpos <= pos, jnp.exp(scores - m_new), 0.0)
        l_new = alpha * l + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(
    q: jax.Array,  # [B, H, D]
    k_cache: jax.Array,  # [B, H, S, D]
    v_cache: jax.Array,  # [B, H, S, D]
    pos: jax.Array,  # [B] int32 — absolute position of the query token
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:  # [B, H, D]
    """Pallas decode attention; semantics match ``ref.decode_attention``."""
    b, h, d = q.shape
    s = k_cache.shape[2]
    if s % block_k != 0:
        raise ValueError(f"cache length {s} must be a multiple of block_k={block_k}")
    scale = 1.0 / (d**0.5)

    qf = q.reshape(b * h, d)
    kf = k_cache.reshape(b * h, s, d)
    vf = v_cache.reshape(b * h, s, d)
    posf = jnp.repeat(pos.astype(jnp.int32), h)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, scale=scale),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        interpret=True,
    )(posf, qf, kf, vf)
    return out.reshape(b, h, d)
