//! Deprecated location shim (kept for one release): the dynamic Δ
//! controller moved to [`crate::ctl::delta`] when the controllers were
//! unified behind the [`crate::ctl::Controller`] trait.

/// Moved to [`crate::ctl::DeltaController`].
#[deprecated(note = "the controllers moved: use crate::ctl::DeltaController")]
pub type DeltaController = crate::ctl::DeltaController;

/// Moved to [`crate::ctl::Policy`].
#[deprecated(note = "the controllers moved: use crate::ctl::Policy")]
pub type Policy = crate::ctl::Policy;
