//! Fig. 7 — (a) fixed vs dynamic Δ; (b) chunk-size sweep (U-shaped step
//! latency with the optimum at moderate chunks).
use oppo::eval::{figures, print_table, save_rows};

fn main() {
    let a = figures::fig7a();
    print_table("Fig 7a — fixed Δ ∈ {4, 8} vs dynamic Δ", &a);
    save_rows("fig7a", &a).expect("save");
    let dynamic = a.iter().find(|r| r.label == "dynamic Δ").unwrap().cells[0].1;
    let best_fixed = a[..2].iter().map(|r| r.cells[0].1).fold(f64::INFINITY, f64::min);
    assert!(dynamic <= best_fixed * 1.10, "dynamic {dynamic} vs best fixed {best_fixed}");

    let b = figures::fig7b();
    print_table("Fig 7b — chunk size vs step latency", &b);
    save_rows("fig7b", &b).expect("save");
    for setup_rows in b.chunks(4) {
        let lat: Vec<f64> = setup_rows.iter().map(|r| r.cells[0].1).collect();
        // U-shape: the optimum is at a moderate chunk (500), not the edges
        let best = lat.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((lat[1] - best).abs() < 1e-9 || (lat[2] - best).abs() < 1e-9,
            "optimum not at a moderate chunk: {lat:?}");
    }
    println!("shape check passed: dynamic Δ wins; chunk sweep is U-shaped");
}
