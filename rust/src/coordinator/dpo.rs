//! DPO generalization (§4.3): the same B+Δ scheduling idea applied to an
//! RL-free preference method — "generate B+Δ items, update on the first B
//! completions, and carry unfinished long generations forward".
//!
//! Pair machinery: every prompt is sampled into *two* lanes; the rule
//! reward ranks the two completions into (chosen, rejected).  Completed
//! pairs enter a pool ordered by completion time; each step updates on the
//! first `B` pooled pairs (the OPPO selection rule at pair granularity) and
//! leaves the overflow pooled — the inter-step carry.  The reward model is
//! not used at all (DPO is reward-model-free), which also demonstrates the
//! claim that inter-step overlap alone generalizes.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::engine_ops::Ops;
use crate::data::tasks::{rule_reward, Task};
use crate::data::tokenizer::{Tokenizer, EOS};
use crate::data::PromptSampler;
use crate::metrics::{RunLog, StepRecord};
use crate::runtime::Engine;

/// One ranked preference pair (token rows are `[S]`-dense).
struct Pair {
    chosen: Vec<i32>,
    rejected: Vec<i32>,
    mask_c: Vec<f32>,
    mask_r: Vec<f32>,
    /// rule-reward margin (chosen − rejected), for logging
    margin: f32,
}

/// DPO trainer over the AOT `dpo_update` entry.
pub struct DpoTrainer {
    cfg: TrainConfig,
    engine: Arc<Engine>,
    ops: Ops,
    sampler: PromptSampler,
    tokenizer: Tokenizer,
    pool: VecDeque<Pair>,
    update_count: i32,
    log: RunLog,
}

impl DpoTrainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let engine = Arc::new(Engine::load(&cfg.artifacts_dir)?);
        Self::with_engine(cfg, engine)
    }

    pub fn with_engine(cfg: TrainConfig, engine: Arc<Engine>) -> Result<Self> {
        let m = engine.manifest().shape.clone();
        let tokenizer = Tokenizer::from_manifest(&engine.manifest().tokenizer)?;
        let task = Task::by_name(&cfg.task).context("unknown task")?;
        let sampler = PromptSampler::new(task, tokenizer.clone(), m.prompt_max, cfg.seed);
        let ops = Ops::new(engine.clone(), cfg.seed)?;
        let log = RunLog::new("dpo", &cfg.task, cfg.seed);
        Ok(Self {
            cfg,
            engine,
            ops,
            sampler,
            tokenizer,
            pool: VecDeque::new(),
            update_count: 0,
            log,
        })
    }

    pub fn run(mut self) -> Result<RunLog> {
        let started = Instant::now();
        for step in 0..self.cfg.steps as u64 {
            let t0 = Instant::now();
            let b = self.engine.manifest().shape.ppo_batch;
            // generate pairs until the pool can serve B (B+Δ-style
            // overcommit: we usually overshoot and carry the rest)
            while self.pool.len() < b {
                self.generate_pairs(step)?;
            }
            let pairs: Vec<Pair> = self.pool.drain(..b).collect();
            let deferred = self.pool.len();
            let mean_margin =
                pairs.iter().map(|p| p.margin as f64).sum::<f64>() / pairs.len() as f64;
            let stats = self.dpo_update(&pairs)?;
            self.log.push(StepRecord {
                step,
                wall_s: t0.elapsed().as_secs_f64(),
                elapsed_s: started.elapsed().as_secs_f64(),
                mean_score: mean_margin,
                delta: deferred,
                chunk: self.cfg.chunk_size,
                finished: pairs.len(),
                deferred,
                gen_tokens: 0,
                train_stats: [stats[0], stats[1], stats[2], stats[3], 0.0, 0.0],
                util: 0.0,
                stages: Vec::new(),
                ..Default::default()
            });
            if self.cfg.log_every > 0 && step % self.cfg.log_every as u64 == 0 {
                log::info!(
                    "dpo step {step}: loss={:.4} acc={:.3} margin={:.3}",
                    stats[0], stats[1], stats[2]
                );
            }
        }
        Ok(self.log)
    }

    /// Sample G/2 prompts, generate two completions each, rank by rule
    /// reward, pool the pairs (ties are dropped — no learning signal).
    fn generate_pairs(&mut self, _step: u64) -> Result<()> {
        let m = self.engine.manifest().shape.clone();
        let n_pairs = m.lanes / 2;
        let prompts: Vec<_> = (0..n_pairs).map(|_| self.sampler.next()).collect();

        let mut tokens = vec![0i32; m.lanes * m.s_max];
        let mut prompt_len = vec![1i32; m.lanes];
        for (i, p) in prompts.iter().enumerate() {
            for lane in [2 * i, 2 * i + 1] {
                tokens[lane * m.s_max..lane * m.s_max + p.tokens.len()]
                    .copy_from_slice(&p.tokens);
                prompt_len[lane] = p.tokens.len() as i32;
            }
        }
        let mut state = self.ops.fresh_actor_state(&tokens)?;
        self.ops.actor_prefill(&mut state, &tokens, &prompt_len, &vec![1; m.lanes])?;

        let chunk = self.cfg.chunk_size;
        let mut resp: Vec<Vec<i32>> = vec![Vec::new(); m.lanes];
        let mut done = vec![false; m.lanes];
        let mut pos = prompt_len.clone();
        while !done.iter().all(|&d| d) {
            let live: Vec<i32> = done.iter().map(|&d| if d { 0 } else { 1 }).collect();
            let out = self.ops.generate_chunk(&mut state, chunk, &pos, &live)?;
            for lane in 0..m.lanes {
                if done[lane] {
                    continue;
                }
                for j in 0..chunk {
                    let tok = out.tokens[lane * chunk + j];
                    resp[lane].push(tok);
                    pos[lane] += 1;
                    if tok == EOS
                        || resp[lane].len() >= self.cfg.max_new_tokens
                        || pos[lane] as usize >= m.s_max
                    {
                        done[lane] = true;
                        break;
                    }
                }
            }
        }

        for (i, p) in prompts.iter().enumerate() {
            let (a, b) = (&resp[2 * i], &resp[2 * i + 1]);
            let ra = rule_reward(&p.answer, &self.tokenizer.decode_until_eos(a, 0)) as f32;
            let rb = rule_reward(&p.answer, &self.tokenizer.decode_until_eos(b, 0)) as f32;
            if (ra - rb).abs() < 1e-6 {
                continue; // tie: no preference signal
            }
            let (ch, rj, margin) = if ra > rb { (a, b, ra - rb) } else { (b, a, rb - ra) };
            let dense = |r: &Vec<i32>| -> (Vec<i32>, Vec<f32>) {
                let mut toks = vec![0i32; m.s_max];
                let mut mask = vec![0f32; m.s_max];
                let plen = p.tokens.len();
                toks[..plen].copy_from_slice(&p.tokens);
                for (j, &t) in r.iter().enumerate() {
                    toks[plen + j] = t;
                    mask[plen + j] = 1.0;
                }
                (toks, mask)
            };
            let (chosen, mask_c) = dense(ch);
            let (rejected, mask_r) = dense(rj);
            self.pool.push_back(Pair { chosen, rejected, mask_c, mask_r, margin });
        }
        Ok(())
    }

    fn dpo_update(&mut self, pairs: &[Pair]) -> Result<[f32; 4]> {
        let m = self.engine.manifest().shape.clone();
        let (b, s) = (m.ppo_batch, m.s_max);
        debug_assert_eq!(pairs.len(), b);
        let flat = |f: fn(&Pair) -> &Vec<i32>| -> Vec<i32> {
            pairs.iter().flat_map(|p| f(p).iter().copied()).collect()
        };
        let flatf = |f: fn(&Pair) -> &Vec<f32>| -> Vec<f32> {
            pairs.iter().flat_map(|p| f(p).iter().copied()).collect()
        };
        let chosen = flat(|p| &p.chosen);
        let rejected = flat(|p| &p.rejected);
        let mask_c = flatf(|p| &p.mask_c);
        let mask_r = flatf(|p| &p.mask_r);

        // frozen-reference per-sequence log-prob sums
        let ref_lp_c = self.ops.ref_logprobs(&chosen)?;
        let ref_lp_r = self.ops.ref_logprobs(&rejected)?;
        let sum = |lp: &[f32], mask: &[f32]| -> Vec<f32> {
            (0..b)
                .map(|i| {
                    (0..s).map(|t| lp[i * s + t] * mask[i * s + t]).sum::<f32>()
                })
                .collect()
        };
        let ref_c = sum(&ref_lp_c, &mask_c);
        let ref_r = sum(&ref_lp_r, &mask_r);

        self.update_count += 1;
        self.ops.dpo_update(
            &chosen, &rejected, &mask_c, &mask_r, &ref_c, &ref_r, self.update_count,
        )
    }
}
