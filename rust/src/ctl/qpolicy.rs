//! Tabular Q-policy over binned pipeline telemetry — the learned
//! controller arm.
//!
//! The policy follows the train-in-simulator pattern (ROADMAP "Learned
//! pipeline controllers"): a small dependency-free Q-table is trained
//! offline inside `sim::env::PipelineEnv` with pinned-seed ε-greedy
//! exploration plus Dyna-Q planning, frozen into a versioned artifact by
//! `oppo train-controller`, and replayed greedily at deployment by
//! [`crate::ctl::LearnedController`] — in the simulator *and* in the real
//! scheduler, behind the `controller = "learned"` config flag.
//!
//! The Δ knob is controlled through [`DELTA_LEVELS`] quantized levels
//! rather than raw Δ values: a ±1 level nudge always moves the deployed Δ
//! far enough to change the encoded state, so the table's Markov property
//! survives the binning (a raw-Δ nudge inside one wide bin would be
//! indistinguishable from a no-op to the learner).
//!
//! Everything here is deterministic by construction: the state space is a
//! fixed binning of [`StepTelemetry`], ties in the argmax break toward the
//! no-op nudge (action index 0) and then the lowest action index,
//! exploration draws from the repo's SplitMix64 [`Rng`], and the artifact
//! writer emits a canonical byte sequence — two trainings with the same
//! seed produce byte-identical files (pinned by a tier-1 test).

use anyhow::{ensure, Context, Result};

use crate::ctl::StepTelemetry;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Artifact format version; bump on any change to the state binning,
/// action set, or serialization layout (a loaded artifact must have been
/// trained against the same encoder it is replayed with).
pub const FORMAT_VERSION: u64 = 1;

/// Quantization of the Δ knob: the controller walks one of this many
/// evenly spaced levels across `[delta_min, delta_max]` instead of raw Δ
/// values, so every level nudge is visible in the encoded state.
pub const DELTA_LEVELS: usize = 5;

/// Per-knob bins: chunk candidate index (capped), Δ level, relative
/// replica count, downstream utilization, actor idleness, and queue
/// pressure.
const CHUNK_BINS: usize = 5;
const REPLICA_BINS: usize = 4;
const UTIL_BINS: usize = 3;
const IDLE_BINS: usize = 3;
const QUEUE_BINS: usize = 3;

/// Total discrete states the table covers.
pub const N_STATES: usize =
    CHUNK_BINS * DELTA_LEVELS * REPLICA_BINS * UTIL_BINS * IDLE_BINS * QUEUE_BINS;

/// Number of discrete actions: the no-op plus one ±1 nudge per knob.  The
/// single-knob action set (vs. the 27 diagonal combinations) concentrates
/// the sample budget — each (state, action) cell is visited often enough
/// for the table to converge within the pinned CI training budget.
pub const N_ACTIONS: usize = 7;

/// The action set, no-op first (index 0 — the argmax tie-break target).
const ACTIONS: [(i8, i8, i8); N_ACTIONS] =
    [(0, 0, 0), (-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)];

/// One discrete control action: a nudge to exactly one knob (chunk
/// candidate index, Δ level, reward replicas), or the no-op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QAction {
    pub d_chunk: i8,
    pub d_delta_level: i8,
    pub d_replicas: i8,
}

impl QAction {
    /// The keep-everything-still action (index 0).
    pub const NOOP: QAction = QAction { d_chunk: 0, d_delta_level: 0, d_replicas: 0 };

    /// Dense action index in `0..N_ACTIONS`.
    pub fn index(&self) -> usize {
        ACTIONS
            .iter()
            .position(|&(c, d, r)| {
                c == self.d_chunk && d == self.d_delta_level && r == self.d_replicas
            })
            .expect("QAction not in the action set")
    }

    /// Inverse of [`QAction::index`].
    pub fn from_index(i: usize) -> QAction {
        assert!(i < N_ACTIONS);
        let (d_chunk, d_delta_level, d_replicas) = ACTIONS[i];
        QAction { d_chunk, d_delta_level, d_replicas }
    }
}

/// Legal ranges the knob state must stay inside.  Bounds are supplied by
/// the deployment site (the sim's sweep grid, or the manifest + config at
/// runtime), so one trained policy transfers across candidate sets — the
/// state encoding only ever sees *relative* knob positions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KnobBounds {
    /// Size of the chunk-candidate set `chunk_idx` indexes into.
    pub n_chunks: usize,
    pub delta_min: usize,
    pub delta_max: usize,
    pub min_replicas: usize,
    pub max_replicas: usize,
}

/// Δ value of a quantized level under `b`: `DELTA_LEVELS` evenly spaced
/// points from `delta_min` (level 0) to `delta_max` (the top level).
pub fn delta_of(level: usize, b: &KnobBounds) -> usize {
    let span = b.delta_max.saturating_sub(b.delta_min);
    b.delta_min + level.min(DELTA_LEVELS - 1) * span / (DELTA_LEVELS - 1)
}

/// Nearest level whose [`delta_of`] is closest to `delta` (lowest level
/// wins ties) — how deployment sites map a configured raw Δ onto the grid.
pub fn level_of(delta: usize, b: &KnobBounds) -> usize {
    let span = b.delta_max.saturating_sub(b.delta_min);
    if span == 0 {
        return 0;
    }
    let mut best = 0;
    let mut best_dist = usize::MAX;
    for level in 0..DELTA_LEVELS {
        let dist = delta_of(level, b).abs_diff(delta);
        if dist < best_dist {
            best = level;
            best_dist = dist;
        }
    }
    best
}

/// The controller-owned knob state an action nudges.  Shared between the
/// training environment and [`crate::ctl::LearnedController`] so the
/// action semantics at train time and deploy time are the same code path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KnobState {
    /// Index into the chunk-candidate set.
    pub chunk_idx: usize,
    /// Quantized Δ position in `0..DELTA_LEVELS` (see [`delta_of`]).
    pub delta_level: usize,
    pub replicas: usize,
}

impl KnobState {
    /// Apply one action's nudges, saturating at the bounds.
    pub fn apply(&mut self, a: QAction, b: &KnobBounds) {
        self.chunk_idx = nudge(self.chunk_idx, a.d_chunk, 0, b.n_chunks.saturating_sub(1));
        self.delta_level = nudge(self.delta_level, a.d_delta_level, 0, DELTA_LEVELS - 1);
        self.replicas = nudge(self.replicas, a.d_replicas, b.min_replicas, b.max_replicas);
    }

    /// Project the state into the bounds (used once at construction).
    pub fn clamp(&mut self, b: &KnobBounds) {
        self.chunk_idx = self.chunk_idx.min(b.n_chunks.saturating_sub(1));
        self.delta_level = self.delta_level.min(DELTA_LEVELS - 1);
        self.replicas = self.replicas.clamp(b.min_replicas.max(1), b.max_replicas.max(1));
    }

    /// The raw Δ this state deploys under `b`.
    pub fn delta(&self, b: &KnobBounds) -> usize {
        delta_of(self.delta_level, b)
    }
}

fn nudge(v: usize, d: i8, lo: usize, hi: usize) -> usize {
    let moved = v as isize + d as isize;
    moved.clamp(lo as isize, hi as isize) as usize
}

/// Bin one telemetry snapshot + knob state into a dense table index.
pub fn encode_state(t: &StepTelemetry, k: &KnobState, b: &KnobBounds) -> usize {
    let chunk_bin = k.chunk_idx.min(CHUNK_BINS - 1);
    let delta_bin = k.delta_level.min(DELTA_LEVELS - 1);
    let replica_bin =
        k.replicas.saturating_sub(b.min_replicas.max(1)).min(REPLICA_BINS - 1);
    let util_bin = frac_bin(t.util, UTIL_BINS);
    let idle_bin = if t.lane_idle_frac < 0.1 {
        0
    } else if t.lane_idle_frac < 0.3 {
        1
    } else {
        2
    };
    let queue_bin = if t.queue_dropped > 0 {
        2
    } else if t.queue_depth > 0 {
        1
    } else {
        0
    };
    ((((chunk_bin * DELTA_LEVELS + delta_bin) * REPLICA_BINS + replica_bin) * UTIL_BINS
        + util_bin)
        * IDLE_BINS
        + idle_bin)
        * QUEUE_BINS
        + queue_bin
}

fn frac_bin(x: f64, bins: usize) -> usize {
    ((x.clamp(0.0, 1.0) * bins as f64) as usize).min(bins - 1)
}

/// The tabular policy: a dense `N_STATES × N_ACTIONS` value table plus the
/// training provenance the artifact records.
#[derive(Clone, Debug, PartialEq)]
pub struct QPolicy {
    /// Seed the table was trained with (provenance).
    pub seed: u64,
    /// Episodes the table was trained for (provenance).
    pub episodes: u64,
    /// Chunk-candidate count at training time (provenance only; the state
    /// encoding is relative, so deployment sets may differ in size).
    pub n_chunk_candidates: usize,
    q: Vec<f64>,
}

impl QPolicy {
    /// A zero-initialized table (pessimism-free: unseen state-actions are
    /// worth 0, so early exploration is driven by ε, not the init).
    pub fn new(seed: u64, n_chunk_candidates: usize) -> Self {
        Self { seed, episodes: 0, n_chunk_candidates, q: vec![0.0; N_STATES * N_ACTIONS] }
    }

    pub fn value(&self, state: usize, action: QAction) -> f64 {
        self.q[state * N_ACTIONS + action.index()]
    }

    /// Greedy action for `state`.  Deterministic tie-break: the no-op
    /// nudge (index 0) wins if it is tied for the max (so a state the
    /// training never visited keeps the knobs where they are instead of
    /// walking them to a bound), otherwise the lowest tied index wins.
    pub fn best_action(&self, state: usize) -> QAction {
        let row = &self.q[state * N_ACTIONS..(state + 1) * N_ACTIONS];
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        QAction::from_index(best)
    }

    /// ε-greedy draw for training (deterministic given the caller's rng).
    pub fn epsilon_greedy(&self, state: usize, epsilon: f64, rng: &mut Rng) -> QAction {
        if rng.range_f64(0.0, 1.0) < epsilon {
            QAction::from_index(rng.range_usize(0, N_ACTIONS))
        } else {
            self.best_action(state)
        }
    }

    /// One Q-learning backup:
    /// `Q(s,a) += α · (r + γ·max_a' Q(s',a') − Q(s,a))`.
    pub fn update(
        &mut self,
        state: usize,
        action: QAction,
        reward: f64,
        next_state: usize,
        alpha: f64,
        gamma: f64,
    ) {
        let next_best = self.value(next_state, self.best_action(next_state));
        let idx = state * N_ACTIONS + action.index();
        self.q[idx] += alpha * (reward + gamma * next_best - self.q[idx]);
    }

    /// Number of table cells a backup has touched (training diagnostics).
    pub fn visited_cells(&self) -> usize {
        self.q.iter().filter(|v| **v != 0.0).count()
    }

    // ---- versioned artifact (canonical byte layout) ----

    /// Serialize to the canonical artifact text: fixed key order, sparse
    /// `[index, value]` cells sorted by index, floats in Rust's shortest
    /// round-trip form.  Byte-identical for identical tables.
    pub fn to_artifact_string(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\"format\":\"oppo-controller-q\",");
        out.push_str(&format!("\"version\":{FORMAT_VERSION},"));
        out.push_str(&format!("\"seed\":{},", self.seed));
        out.push_str(&format!("\"episodes\":{},", self.episodes));
        out.push_str(&format!("\"n_chunk_candidates\":{},", self.n_chunk_candidates));
        out.push_str(&format!("\"n_states\":{N_STATES},"));
        out.push_str(&format!("\"n_actions\":{N_ACTIONS},"));
        out.push_str("\"q\":[");
        let mut first = true;
        for (i, &v) in self.q.iter().enumerate() {
            if v != 0.0 {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("[{i},{v:?}]"));
            }
        }
        out.push_str("]}\n");
        out
    }

    /// Parse an artifact produced by [`QPolicy::to_artifact_string`],
    /// rejecting other formats/versions and out-of-range cells.
    pub fn from_artifact_str(text: &str) -> Result<Self> {
        let v = json::parse(text).context("controller policy artifact is not valid JSON")?;
        let format = v.get("format")?.as_str()?;
        ensure!(
            format == "oppo-controller-q",
            "not a controller policy artifact (format {format:?})"
        );
        let version = v.get("version")?.as_usize()?;
        ensure!(
            version as u64 == FORMAT_VERSION,
            "controller policy artifact is format v{version}, this build reads \
             v{FORMAT_VERSION} — retrain with `oppo train-controller`"
        );
        let n_states = v.get("n_states")?.as_usize()?;
        let n_actions = v.get("n_actions")?.as_usize()?;
        ensure!(
            n_states == N_STATES && n_actions == N_ACTIONS,
            "artifact table is {n_states}×{n_actions}, encoder is {N_STATES}×{N_ACTIONS} — \
             retrain with `oppo train-controller`"
        );
        let mut policy = QPolicy::new(
            v.get("seed")?.as_usize()? as u64,
            v.get("n_chunk_candidates")?.as_usize()?,
        );
        policy.episodes = v.get("episodes")?.as_usize()? as u64;
        for cell in v.get("q")?.as_arr()? {
            let pair = cell.as_arr()?;
            ensure!(pair.len() == 2, "q cell must be [index, value]");
            let idx = pair[0].as_usize()?;
            ensure!(idx < N_STATES * N_ACTIONS, "q cell index {idx} out of range");
            policy.q[idx] = pair[1].as_f64()?;
        }
        Ok(policy)
    }

    /// Write the artifact to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_artifact_string())
            .with_context(|| format!("writing controller policy to {path}"))
    }

    /// Load an artifact from `path`.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!(
                "reading controller policy from {path} — train one with \
                 `oppo train-controller --out {path}`"
            )
        })?;
        Self::from_artifact_str(&text)
    }
}

/// `Value` view of the artifact metadata for bench/CI JSON emission.
pub fn artifact_meta(p: &QPolicy) -> Value {
    json::obj(vec![
        ("version", json::num(FORMAT_VERSION as f64)),
        ("seed", json::num(p.seed as f64)),
        ("episodes", json::num(p.episodes as f64)),
        ("n_states", json::num(N_STATES as f64)),
        ("n_actions", json::num(N_ACTIONS as f64)),
        ("visited_cells", json::num(p.visited_cells() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_index_roundtrips() {
        for i in 0..N_ACTIONS {
            assert_eq!(QAction::from_index(i).index(), i);
        }
        assert_eq!(QAction::NOOP.index(), 0, "no-op must be the tie-break target");
    }

    #[test]
    fn delta_levels_span_the_bounds() {
        let b = KnobBounds {
            n_chunks: 5,
            delta_min: 0,
            delta_max: 12,
            min_replicas: 1,
            max_replicas: 4,
        };
        assert_eq!(delta_of(0, &b), 0);
        assert_eq!(delta_of(DELTA_LEVELS - 1, &b), 12);
        for level in 1..DELTA_LEVELS {
            assert!(delta_of(level, &b) > delta_of(level - 1, &b));
            // the grid must round-trip: each level is its own nearest level
            assert_eq!(level_of(delta_of(level, &b), &b), level);
        }
        // degenerate span collapses to level 0
        let flat = KnobBounds { delta_min: 3, delta_max: 3, ..b };
        assert_eq!(delta_of(2, &flat), 3);
        assert_eq!(level_of(7, &flat), 0);
    }

    #[test]
    fn knob_apply_saturates_at_bounds() {
        let b = KnobBounds {
            n_chunks: 3,
            delta_min: 1,
            delta_max: 4,
            min_replicas: 1,
            max_replicas: 2,
        };
        let mut k = KnobState { chunk_idx: 0, delta_level: 0, replicas: 1 };
        k.apply(QAction { d_chunk: -1, d_delta_level: -1, d_replicas: -1 }, &b);
        assert_eq!(k, KnobState { chunk_idx: 0, delta_level: 0, replicas: 1 });
        for _ in 0..10 {
            k.apply(QAction { d_chunk: 1, d_delta_level: 0, d_replicas: 0 }, &b);
            k.apply(QAction { d_chunk: 0, d_delta_level: 1, d_replicas: 0 }, &b);
            k.apply(QAction { d_chunk: 0, d_delta_level: 0, d_replicas: 1 }, &b);
        }
        assert_eq!(
            k,
            KnobState { chunk_idx: 2, delta_level: DELTA_LEVELS - 1, replicas: 2 }
        );
        assert_eq!(k.delta(&b), 4, "top level deploys delta_max");
    }

    #[test]
    fn encode_state_is_in_range_for_arbitrary_telemetry() {
        let b = KnobBounds {
            n_chunks: 5,
            delta_min: 0,
            delta_max: 12,
            min_replicas: 1,
            max_replicas: 4,
        };
        let mut rng = Rng::new(9);
        for _ in 0..2000 {
            let t = StepTelemetry {
                util: rng.range_f64(-0.5, 1.5),
                lane_idle_frac: rng.range_f64(0.0, 1.0),
                queue_depth: rng.range_usize(0, 100),
                queue_dropped: rng.range_usize(0, 3),
                ..Default::default()
            };
            let k = KnobState {
                chunk_idx: rng.range_usize(0, 5),
                delta_level: rng.range_usize(0, DELTA_LEVELS),
                replicas: rng.range_usize(1, 5),
            };
            let s = encode_state(&t, &k, &b);
            assert!(s < N_STATES, "state {s} out of range");
        }
    }

    #[test]
    fn best_action_tie_breaks_to_noop_then_lowest() {
        // untouched row: every value ties at 0.0 → keep the knobs still
        let mut p = QPolicy::new(0, 5);
        assert_eq!(p.best_action(0), QAction::NOOP);
        // two non-noop actions tied above the rest → lowest index wins
        p.update(1, QAction::from_index(2), 1.0, 0, 1.0, 0.0);
        p.update(1, QAction::from_index(5), 1.0, 0, 1.0, 0.0);
        assert_eq!(p.best_action(1).index(), 2);
    }

    #[test]
    fn update_moves_toward_target() {
        let mut p = QPolicy::new(0, 5);
        let a = QAction::from_index(3);
        p.update(7, a, 1.0, 8, 0.5, 0.9);
        assert!((p.value(7, a) - 0.5).abs() < 1e-12);
        // next-state value feeds back through the bootstrap term
        p.update(8, QAction::from_index(0), 2.0, 9, 1.0, 0.0);
        p.update(7, a, 1.0, 8, 0.5, 0.5);
        assert!(p.value(7, a) > 0.5);
    }

    #[test]
    fn artifact_roundtrips_and_is_canonical() {
        let mut p = QPolicy::new(42, 5);
        p.episodes = 7;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = rng.range_usize(0, N_STATES);
            let a = QAction::from_index(rng.range_usize(0, N_ACTIONS));
            p.update(s, a, rng.normal(), rng.range_usize(0, N_STATES), 0.3, 0.9);
        }
        let text = p.to_artifact_string();
        let back = QPolicy::from_artifact_str(&text).unwrap();
        assert_eq!(back, p);
        // canonical: re-serializing the parsed policy is byte-identical
        assert_eq!(back.to_artifact_string(), text);
    }

    #[test]
    fn artifact_rejects_wrong_version() {
        let p = QPolicy::new(0, 5);
        let text = p.to_artifact_string().replace("\"version\":1", "\"version\":999");
        let err = QPolicy::from_artifact_str(&text).unwrap_err().to_string();
        assert!(err.contains("format v999"), "{err}");
    }

    #[test]
    fn epsilon_zero_is_greedy() {
        let mut p = QPolicy::new(0, 5);
        p.update(0, QAction::from_index(5), 1.0, 0, 1.0, 0.0);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            assert_eq!(p.epsilon_greedy(0, 0.0, &mut rng).index(), 5);
        }
    }
}
